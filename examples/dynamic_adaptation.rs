//! Dynamic adaptation example (Fig. 3a's scenario, served live).
//!
//! The field-deployed ADC degrades from 8-bit to 6-bit; the analog
//! weights cannot be reprogrammed, but retraining ONLY the LoRA weights
//! off-chip and hot-swapping them onto the DPUs recovers most of the
//! lost accuracy. This example plays that out through the serving API:
//! traffic keeps flowing while the refreshed adapter is redeployed —
//! in-flight batches finish on their old `Arc` snapshot, later batches
//! pick up the new version, and the base model is never touched.
//!
//! ```bash
//! cargo run --release --example dynamic_adaptation -- --requests 32
//! cargo run --release --example dynamic_adaptation -- --full   # full Fig. 3a experiment
//! ```

use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::experiments;
use ahwa_lora::experiments::common::{infer_hw, pretrained_encoder, Ctx};
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{submit_wave, SchedConfig, Server};
use ahwa_lora::util::cli::Args;
use ahwa_lora::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.bool("full") {
        // the original drift/degradation study behind this scenario
        return experiments::run("fig3a", &args);
    }

    let n_requests = args.usize("requests", 32).max(1);
    let variant = args.str("variant", "mobilebert_proxy");
    let task = GlueTask::Sst2;

    let ctx = Ctx::new()?;
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;

    let registry = SharedRegistry::new();
    let v1 = registry.deploy(task.adapter_key(), ctx.init_train(&format!("{variant}/step_cls_lora"))?);
    println!("deployed adapter '{}' v{v1}", task.adapter_key());

    // 6-bit ADC: the degraded quantizer the deployed part is stuck with.
    // Batching stays pipeline-aware — the cost model is a property of
    // the tiles/PMCA, not of the quantizer that degraded.
    let server = Server::builder(&variant)
        .manifest(ctx.engine.manifest.clone())
        .hw(infer_hw(8, 6, 0.0, 0.0))
        .scheduler(SchedConfig::for_layer(v.d_model, v.d_model, v.rank))
        .build(meta, registry.clone())?;
    let client = server.client();

    let gen = GlueGen::new(task, v.vocab, v.seq);
    let mut rng = Pcg64::new(7);
    let mut jobs = Vec::new();
    for _ in 0..n_requests {
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }

    let before = submit_wave(&client, &jobs)?;
    println!(
        "pre-adaptation wave: {} responses on adapter v{}",
        before.len(),
        before[0].adapter_version
    );

    // Off-chip LoRA refresh (here: a re-initialised adapter standing in
    // for the retrained one) hot-swapped WHILE traffic flows.
    let refreshed = ctx.init_train(&format!("{variant}/step_cls_lora"))?;
    let v2 = registry.deploy(task.adapter_key(), refreshed);
    let after = submit_wave(&client, &jobs)?;
    println!(
        "post-adaptation wave: {} responses on adapter v{} (deployed v{v2}, base untouched)",
        after.len(),
        after[0].adapter_version
    );
    println!("{}", server.metrics_report());

    server.shutdown()?;
    Ok(())
}
