//! Dynamic adaptation example (Fig. 3a's scenario).
//!
//! The field-deployed ADC degrades from 8-bit to 6-bit; the analog
//! weights cannot be reprogrammed, but retraining ONLY the LoRA weights
//! off-chip and reloading them onto the DPUs recovers most of the lost
//! accuracy.
//!
//! ```bash
//! cargo run --release --example dynamic_adaptation -- --steps 200
//! ```

use ahwa_lora::experiments;
use ahwa_lora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    experiments::run("fig3a", &args)
}
