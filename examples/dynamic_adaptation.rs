//! Dynamic adaptation example (Fig. 3a's scenario, served live).
//!
//! A field-deployed part degrades in two ways: the ADC drops from 8-bit
//! to 6-bit, and the PCM conductances drift as
//! `g(t) = g_prog·((t+t₀)/t₀)^(−ν)`. The analog weights cannot be
//! reprogrammed — but retraining ONLY the LoRA weights off-chip and
//! hot-swapping them onto the DPUs recovers the lost accuracy. This
//! example plays that out through `serve::refresh` with the *sampled*
//! decay model: the served meta-weights are programmed onto the
//! simulated PCM substrate, predicted decay is measured by Monte-Carlo
//! reads through the full device model (drift → read noise → GDC), and
//! the refresh worker re-fits + hot-swaps when the tolerance is
//! crossed. Traffic keeps flowing while it happens — in-flight batches
//! finish on their old `Arc` snapshot, later batches pick up the new
//! version, and the base model is never touched.
//!
//! ```bash
//! cargo run --release --example dynamic_adaptation -- --requests 32
//! cargo run --release --example dynamic_adaptation -- --full   # full Fig. 3a experiment
//! ```

use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::eval::drift_eval::AnalogDeployment;
use ahwa_lora::experiments;
use ahwa_lora::experiments::common::{infer_hw, pretrained_encoder, Ctx};
use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    submit_wave, DecayModel, FnRefitter, Refit, RefreshConfig, SchedConfig, Server,
};
use ahwa_lora::util::cli::Args;
use ahwa_lora::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if args.bool("full") {
        // the original drift/degradation study behind this scenario
        return experiments::run("fig3a", &args);
    }

    let n_requests = args.usize("requests", 32).max(1);
    let variant = args.str("variant", "mobilebert_proxy");
    let task = GlueTask::Sst2;

    let ctx = Ctx::new()?;
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;

    let registry = SharedRegistry::new();
    let adapter0 = ctx.init_train(&format!("{variant}/step_cls_lora"))?;
    let v1 = registry.deploy(task.adapter_key(), adapter0);
    println!("deployed adapter '{}' v{v1}", task.adapter_key());

    // Program the served meta-weights onto the simulated PCM substrate:
    // the decay the refresh policy watches is now MEASURED through the
    // device model, not a closed form.
    let mut prog_rng = Pcg64::new(11);
    let deployment = Arc::new(AnalogDeployment::program(
        meta.clone(),
        PcmModel::default(),
        3.0,
        &mut prog_rng,
    ));
    let decay = DecayModel::sampled(deployment.clone(), 1, 17);
    let floor = decay.predicted_decay(0.0);
    // the sampled model has a programming-noise floor at age 0 — the
    // tolerance must sit above it or the policy would re-trigger forever
    let tol = (1.25 * floor).max(floor + 0.01);
    println!(
        "substrate: {} PCM devices; decay floor {floor:.4} -> tolerance {tol:.4}",
        deployment.n_devices()
    );
    for (label, secs) in [("1h", 3600.0), ("1d", 86_400.0), ("1m", 2_592_000.0)] {
        println!("  predicted decay at {label}: {:.4}", decay.predicted_decay(secs));
    }
    let age_star = decay.trigger_age(tol);
    println!("policy schedules a refresh after ~{:.1} days of drift", age_star / 86_400.0);

    // The refitter re-initialises the LoRA weights (standing in for an
    // off-chip retrain against `deployment.meta_at(age)` — the runner
    // hands exactly that drifted store to the refitter).
    let refreshed = ctx.init_train(&format!("{variant}/step_cls_lora"))?;
    let refitter = FnRefitter(
        move |_task: &str,
              _current: &ParamStore,
              _drifted: &ParamStore,
              budget: usize|
              -> anyhow::Result<Refit> {
            Ok(Refit { params: refreshed.clone(), steps: budget })
        },
    );
    let refresh = RefreshConfig::new(decay, Arc::new(refitter))
        .tolerance(tol)
        // accelerated drift: each wall second ages the substrate ~1 year
        .time_scale(args.f64("time-scale", 3e7))
        .step_budget(4)
        .check_every(Duration::from_secs(3600)); // driven via refresh_tick_now

    // 6-bit ADC: the degraded quantizer the deployed part is stuck with.
    // Batching stays pipeline-aware — the cost model is a property of
    // the tiles/PMCA, not of the quantizer that degraded.
    let server = Server::builder(&variant)
        .manifest(ctx.engine.manifest.clone())
        .hw(infer_hw(8, 6, 0.0, 0.0))
        .scheduler(SchedConfig::for_layer(v.d_model, v.d_model, v.rank))
        .refresh(refresh)
        .build(meta, registry.clone())?;
    let client = server.client();

    let gen = GlueGen::new(task, v.vocab, v.seq);
    let mut rng = Pcg64::new(7);
    let mut jobs = Vec::new();
    for _ in 0..n_requests {
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }

    let before = submit_wave(&client, &jobs)?;
    println!(
        "pre-adaptation wave: {} responses on adapter v{}",
        before.len(),
        before[0].adapter_version
    );

    // By now the accelerated clock has drifted the substrate past the
    // measured tolerance; one policy evaluation runs the whole cycle
    // (trigger -> refit against the drifted meta -> hot-swap) while the
    // client keeps submitting.
    for e in server.refresh_tick_now() {
        println!(
            "refreshed '{}' at drift age {:.1} days: decay {:.4} -> {:.4} (swapped to v{})",
            e.task,
            e.drift_age_secs / 86_400.0,
            e.pre_decay,
            e.post_decay,
            e.version
        );
    }
    let after = submit_wave(&client, &jobs)?;
    println!(
        "post-adaptation wave: {} responses on adapter v{} (base model untouched)",
        after.len(),
        after[0].adapter_version
    );
    println!("{}", server.metrics_report());

    server.shutdown()?;
    Ok(())
}
