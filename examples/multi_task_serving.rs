//! Multi-task serving example (Table III's deployment scenario).
//!
//! One analog base model; per-task LoRA adapter sets hot-swapped on the
//! DPUs; a concurrent client wave routed through the sharded engine
//! pool and dynamically batched per task.
//!
//! ```bash
//! cargo run --release --example multi_task_serving -- --requests 96 --workers 2
//! ```

use std::sync::Arc;
use std::time::{Duration, Instant};

use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::experiments::common::{pretrained_encoder, Ctx};
use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{
    submit_wave, DecayModel, FnRefitter, Refit, RefreshConfig, RefreshCoupling, SchedConfig,
    Server,
};
use ahwa_lora::util::cli::Args;
use ahwa_lora::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 96).max(1);
    let workers = args.usize("workers", 2);
    let variant = args.str("variant", "mobilebert_proxy");

    let ctx = Ctx::new()?;
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;

    // Deploy three task adapters (trained ones if the Table III run has
    // cached them, otherwise fresh inits — the serving path is identical).
    let registry = SharedRegistry::new();
    let tasks = [GlueTask::Sst2, GlueTask::Qnli, GlueTask::Cola];
    for t in tasks {
        let cache = ctx
            .runs_dir
            .join(format!("{variant}.glue.{}.train.bin", t.adapter_key()));
        let params = if cache.exists() {
            ahwa_lora::model::checkpoint::load(&cache)?
        } else {
            ctx.init_train(&format!("{variant}/step_cls_lora"))?
        };
        let version = registry.deploy(t.adapter_key(), params);
        println!("deployed adapter '{}' v{version}", t.adapter_key());
    }

    // Pipeline-aware batching: workers size batches from the Fig. 4
    // AIMC/PMCA balancing model of this variant's projection layer.
    let t_int = args.usize("t-int", 256) as f64;

    // Drift-aware refresh: the policy watches each task's deployment age
    // on the pool clock (accelerated: every wall second models
    // `--time-scale` seconds of conductance drift) and, past the decay
    // tolerance, re-fits + hot-swaps the adapter. The example's refitter
    // re-initialises the adapter — a stand-in for the bounded Trainer
    // refit the `serve-demo` CLI wires up (`--refresh-scale`).
    let fresh = ctx.init_train(&format!("{variant}/step_cls_lora"))?;
    let refitter = FnRefitter(
        move |_task: &str,
              _current: &ParamStore,
              _meta: &ParamStore,
              budget: usize|
              -> anyhow::Result<Refit> {
            Ok(Refit { params: fresh.clone(), steps: budget })
        },
    );
    let refresh = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), Arc::new(refitter))
        .tolerance(0.05)
        .time_scale(args.f64("time-scale", 2e6))
        .step_budget(4)
        // effectively manual: the example forces evaluations with
        // `refresh_tick_now` so the output is deterministic
        .check_every(Duration::from_secs(3600));

    // Refresh coupling: the workers' schedulers read the refresh
    // lifecycle (modeled trigger times, refits in flight) and shrink
    // fills / tighten deadlines ahead of a hot-swap, so the swap lands
    // between batches and the first post-swap batch serves the
    // refreshed adapter — `stale_reqs` / `swap_gap` in the metrics
    // report how well that works. With scheduler + refresh both set the
    // builder also wires the pool-level coordinator (serve::coord):
    // tasks sharing a drift tolerance get staggered triggers so their
    // shards never all stall at once, and the coupling window/hold
    // adapt to observed swap gaps and measured refit budgets
    // (`holds_peak` / `stagger_shift` report that). `--no-coord`
    // reverts to independent per-worker coupling.
    let mut builder = Server::builder(&variant)
        .manifest(ctx.engine.manifest.clone())
        .workers(workers)
        .queue_depth(args.usize("queue-depth", 128))
        .scheduler(
            SchedConfig::for_layer(v.d_model, v.d_model, v.rank)
                .t_int(t_int)
                .coupling(RefreshCoupling::default()),
        )
        .refresh(refresh);
    if args.bool("no-coord") {
        println!("pool refresh coordination: OFF (--no-coord)");
        builder = builder.no_coordination();
    }
    let server = builder.build(meta, registry.clone())?;
    let client = server.client();
    for t in tasks {
        println!(
            "task '{}' pinned to worker {}",
            t.adapter_key(),
            client.shard_for(t.adapter_key())
        );
    }

    // Mixed request wave across tasks — each worker's batcher groups per
    // task and hot-swaps adapters between batches.
    let mut rng = Pcg64::new(42);
    let mut jobs = Vec::new();
    for i in 0..n_requests {
        let task = tasks[i % tasks.len()];
        let gen = GlueGen::new(task, v.vocab, v.seq);
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }
    let t0 = Instant::now();
    let responses = submit_wave(&client, &jobs)?;
    let wall = t0.elapsed();

    println!(
        "\nserved {} requests in {:.1} ms  ({:.0} req/s)",
        responses.len(),
        wall.as_secs_f64() * 1e3,
        responses.len() as f64 / wall.as_secs_f64()
    );
    let agg = server.metrics();
    println!(
        "scheduler model: batch latency p50 {:.3} ms modeled vs {:.3} ms measured",
        agg.modeled_p50_ms, agg.lat_p50_ms
    );
    println!("{}", server.metrics_report());

    // By now the accelerated pool clock has aged every deployment past
    // the modeled decay threshold (at x2e6, one wall second is ~23 drift
    // days). Force a policy evaluation and watch the refresh cycle:
    // trigger → bounded refit → versioned hot-swap, base model untouched
    // and traffic never paused.
    let events = server.refresh_tick_now();
    println!();
    for e in &events {
        println!(
            "refreshed '{}' at drift age {:.0}s: decay {:.4} -> {:.4} ({} steps, swapped to v{})",
            e.task, e.drift_age_secs, e.pre_decay, e.post_decay, e.steps, e.version
        );
    }
    let again = submit_wave(&client, &jobs[..tasks.len().min(jobs.len())])?;
    println!("post-refresh responses report adapter v{}", again[0].adapter_version);
    let agg = server.metrics();
    println!(
        "refresh-aware scheduling: {} stale request(s); worst swap->serve gap {:.1} µs",
        agg.stale_batch_requests,
        agg.swap_gap_ns as f64 / 1e3
    );
    println!("{agg}");

    server.shutdown()?;
    Ok(())
}
