//! End-to-end driver (EXPERIMENTS.md §E2E):
//!
//! digital pretraining of the MobileBERT-proxy base → AHWA-LoRA
//! adaptation under the paper's hardware constraints (6.7 % weight
//! noise, 3σ clipping, 8-bit DAC/ADC) with a logged loss curve → PCM
//! programming → drift evaluation 0 s … 10 y with global drift
//! compensation.
//!
//! ```bash
//! cargo run --release --example train_e2e -- --steps 300 --trials 3
//! ```

use ahwa_lora::experiments;
use ahwa_lora::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    experiments::run("e2e", &args)
}
