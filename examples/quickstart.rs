//! Quickstart: the 60-second tour of the public API.
//!
//! ```bash
//! make artifacts            # once: AOT-compile the jax/pallas graphs
//! cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT artifacts, AHWA-LoRA-trains the tiny encoder for a few
//! steps on synthetic QA, programs it onto the simulated PCM arrays, and
//! evaluates at two drift times.

use ahwa_lora::config::run::TrainConfig;
use ahwa_lora::data::squad::SquadTask;
use ahwa_lora::eval::drift_eval::{pcm_eval_hw, AnalogDeployment, QaEvalSet};
use ahwa_lora::model::checkpoint;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::runtime::Engine;
use ahwa_lora::train::{OwnedArg, OwnedBatch, Trainer};
use ahwa_lora::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    // 1. Engine: PJRT CPU client + manifest of AOT-compiled graphs.
    let engine = Engine::from_artifacts()?;
    println!("loaded manifest with {} graphs", engine.manifest.graphs.len());

    // 2. Initial parameters, exported by the python compile path.
    let variant = engine.manifest.variant("tiny")?.clone();
    let meta = checkpoint::load(engine.manifest.init_path("tiny.meta"))?;
    let train0 = checkpoint::load(engine.manifest.init_path("tiny.step_qa_lora.train"))?;
    println!(
        "tiny encoder: {} meta params, {} trainable (LoRA+head)",
        meta.numel(),
        train0.numel()
    );

    // 3. AHWA-LoRA training: noisy analog forward, gradients into LoRA.
    let task = SquadTask::new(variant.vocab, variant.seq);
    let cfg = TrainConfig {
        steps: 40,
        lr: 5e-3,
        log_every: 10,
        ..Default::default()
    };
    let mut trainer = Trainer::new(&engine, "tiny/step_qa_lora", meta.clone(), train0, cfg)?;
    let b = variant.train_batch;
    trainer.run(move |_, rng| {
        let batch = task.batch(b, rng);
        OwnedBatch(vec![
            OwnedArg::I32(batch.tokens),
            OwnedArg::I32(batch.starts),
            OwnedArg::I32(batch.ends),
        ])
    })?;
    println!("final loss: {:.4}", trainer.tail_loss(5));

    // 4. Deploy to the simulated analog substrate and evaluate drift.
    let fwd = engine.load("tiny/fwd_qa")?;
    let eval = QaEvalSet::generate(&SquadTask::new(variant.vocab, variant.seq), 32, 7);
    let mut rng = Pcg64::new(1);
    let dep = AnalogDeployment::program(meta, PcmModel::default(), 3.0, &mut rng);
    for (label, secs) in [("0s", 0.0), ("1y", 31_536_000.0)] {
        let meta_t = dep.meta_at(secs, true, &mut rng);
        let (f1, em) = eval.score(&fwd, &meta_t, &trainer.train, pcm_eval_hw(127.0, 127.0, 0.04), 3)?;
        println!("drift {label}: F1 {f1:.2}  EM {em:.2}");
    }
    Ok(())
}
