"""L2: loss functions and full training-step graphs (AOT-lowered).

Each `make_*_step` returns a pure function suitable for jax.jit(...).lower:
the ENTIRE optimizer step — noisy forward, backward through the simulated
hardware constraints, global-norm gradient clipping, AdamW update on the
*trainable* tree only — is one HLO executable, so the rust training loop
(rust/src/train) is a thin driver that shuttles literals.

Trainable-tree selection implements the paper's two regimes:

* AHWA-LoRA: trainable = {LoRA adapters + digital task head}; the meta
  weights appear only as non-differentiated inputs ("the model senses the
  hardware, LoRA learns to compensate").
* full AHWA (baseline, Table I/II): trainable = {meta + head}; no LoRA.

The GRPO step implements Group Relative Policy Optimization exactly as
used in the paper (Methods — RL): advantages are computed by the rust
coordinator from grouped rewards; the graph computes the policy-gradient
loss -E[adv * mean-token-logp] over realized completions and applies
AdamW to the LoRA tree.
"""

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from . import model as M
from .configs import ModelConfig

# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def qa_loss(cfg, meta, lora, head, batch, key, hw):
    tokens, starts, ends = batch
    sl, el = M.fwd_qa(cfg, meta, lora, head, tokens, key, hw)
    ls = -jnp.mean(jax.nn.log_softmax(sl, -1)[jnp.arange(sl.shape[0]), starts])
    le = -jnp.mean(jax.nn.log_softmax(el, -1)[jnp.arange(el.shape[0]), ends])
    return 0.5 * (ls + le)


def cls_loss(cfg, meta, lora, head, batch, key, hw):
    tokens, labels = batch
    logits = M.fwd_cls(cfg, meta, lora, head, tokens, key, hw)
    lp = jax.nn.log_softmax(logits, -1)
    return -jnp.mean(lp[jnp.arange(logits.shape[0]), labels])


def reg_loss(cfg, meta, lora, head, batch, key, hw):
    tokens, targets = batch
    logits = M.fwd_cls(cfg, meta, lora, head, tokens, key, hw)
    return jnp.mean((logits[:, 0] - targets) ** 2)


def lm_loss(cfg, meta, lora, head, batch, key, hw):
    """Masked next-token cross-entropy (mask=1 on supervised positions)."""
    tokens, mask = batch
    logits = M.fwd_lm(cfg, meta, lora, tokens, key, hw)
    lp = jax.nn.log_softmax(logits[:, :-1], -1)
    tgt = tokens[:, 1:]
    tok_lp = jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    return -jnp.sum(tok_lp * m) / jnp.maximum(jnp.sum(m), 1.0)


def grpo_loss(cfg, meta, lora, head, batch, key, hw):
    """Policy-gradient objective with group-relative advantages.

    batch = (tokens [G,T], mask [G,T] response positions, adv [G]).
    """
    tokens, mask, adv = batch
    logits = M.fwd_lm(cfg, meta, lora, tokens, key, hw)
    lp = jax.nn.log_softmax(logits[:, :-1], -1)
    tok_lp = jnp.take_along_axis(lp, tokens[:, 1:][..., None], axis=-1)[..., 0]
    m = mask[:, 1:]
    seq_lp = jnp.sum(tok_lp * m, axis=-1) / jnp.maximum(jnp.sum(m, axis=-1), 1.0)
    return -jnp.mean(adv * seq_lp)


LOSSES: Dict[str, Callable] = {
    "qa": qa_loss,
    "cls": cls_loss,
    "reg": reg_loss,
    "lm": lm_loss,
    "grpo": grpo_loss,
}


# ---------------------------------------------------------------------------
# AdamW on a flat list of trainables
# ---------------------------------------------------------------------------


def adamw_update(params, grads, m, v, step, lr, wd, b1=0.9, b2=0.999, eps=1e-8):
    """One AdamW step over flat lists; returns (params', m', v')."""
    # global-norm gradient clipping at 1.0
    gn = jnp.sqrt(sum(jnp.sum(g * g) for g in grads) + 1e-12)
    scale = jnp.minimum(1.0, 1.0 / gn)
    grads = [g * scale for g in grads]

    bc1 = 1.0 - b1**step
    bc2 = 1.0 - b2**step
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
        new_p.append(p - lr * (upd + wd * p))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------


def _hw_from_vec(hw_vec):
    return {
        "noise": hw_vec[0],
        "clip_sigma": hw_vec[1],
        "dac_levels": hw_vec[2],
        "adc_levels": hw_vec[3],
        "adc_noise": hw_vec[4],
    }


def make_step(cfg: ModelConfig, loss_name: str, regime: str):
    """Build step(flat_meta, flat_train, flat_m, flat_v, *batch, key,
    hw_vec[5], opt_vec[3]) -> (flat_train', flat_m', flat_v', loss).

    regime: "lora" (trainable = lora+head) | "full" (trainable = meta+head).
    opt_vec = [lr, weight_decay, step_index].
    Templates for unflattening are captured at lowering time from the
    variant's init shapes; the manifest records the canonical order.
    """
    loss_fn = LOSSES[loss_name]
    key0 = jax.random.PRNGKey(0)
    meta_t = M.init_meta(cfg, key0)
    lora_t = M.init_lora(cfg, key0)
    head_name = {"qa": "qa", "cls": "cls", "reg": "cls", "lm": "lm", "grpo": "lm"}[loss_name]
    head_t = M.init_head(cfg, head_name, key0)

    def step(flat_meta, flat_train, flat_m, flat_v, batch, key, hw_vec, opt_vec):
        hw = _hw_from_vec(hw_vec)
        meta = M.unflatten_params(meta_t, flat_meta)

        if regime == "lora":
            train_template = {"head": head_t, "lora": lora_t}
        else:
            train_template = {"head": head_t, "meta": meta_t}

        def compute_loss(flat_train_):
            tr = M.unflatten_params(train_template, flat_train_)
            lora = tr.get("lora", {"layers": [{} for _ in range(cfg.n_layers)]})
            mt = tr.get("meta", meta)
            return loss_fn(cfg, mt, lora, tr["head"], batch, key, hw)

        loss, grads = jax.value_and_grad(compute_loss)(flat_train)
        lr, wd, st = opt_vec[0], opt_vec[1], opt_vec[2]
        new_t, new_m, new_v = adamw_update(flat_train, grads, flat_m, flat_v, st, lr, wd)
        return new_t, new_m, new_v, loss

    return step, meta_t, (
        {"head": head_t, "lora": lora_t} if regime == "lora" else {"head": head_t, "meta": meta_t}
    )


def make_fwd(cfg: ModelConfig, head_name: str):
    """Inference graph: (flat_meta, flat_train, tokens, key, hw_vec) -> logits.

    flat_train = {head, lora} so a single artifact serves pre/post
    adaptation, any adapter set (multi-task serving), and any noise level.
    """
    key0 = jax.random.PRNGKey(0)
    meta_t = M.init_meta(cfg, key0)
    lora_t = M.init_lora(cfg, key0)
    head_t = M.init_head(cfg, head_name, key0)
    train_t = {"head": head_t, "lora": lora_t}

    def fwd(flat_meta, flat_train, tokens, key, hw_vec):
        hw = _hw_from_vec(hw_vec)
        meta = M.unflatten_params(meta_t, flat_meta)
        tr = M.unflatten_params(train_t, flat_train)
        if head_name == "qa":
            sl, el = M.fwd_qa(cfg, meta, tr["lora"], tr["head"], tokens, key, hw)
            return sl, el
        if head_name == "cls":
            return (M.fwd_cls(cfg, meta, tr["lora"], tr["head"], tokens, key, hw),)
        return (M.fwd_lm(cfg, meta, tr["lora"], tokens, key, hw),)

    return fwd, meta_t, train_t
