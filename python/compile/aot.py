"""AOT compile path: lower every (variant x graph) to HLO TEXT + manifest.

Python runs ONCE here (`make artifacts`); the rust coordinator loads the
emitted artifacts via PJRT and never touches python again.

Interchange is HLO *text* — jax >= 0.5 serializes HloModuleProto with
64-bit instruction ids that xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md). Lowering goes
stablehlo -> XlaComputation (return_tuple=True) -> as_hlo_text().

Outputs
-------
artifacts/<graph_key>.hlo.txt      one per graph (weights are runtime
                                   inputs, so files stay small)
artifacts/init/<name>.bin          initial parameter values (ALTB format,
                                   read by rust/src/model/checkpoint.rs)
artifacts/manifest.json            variants, graph I/O orders, roles

Graph inventory (DESIGN.md experiment index):
  encoders: fwd_qa, fwd_cls, step_qa_lora, step_qa_full, step_cls_lora,
            step_reg_lora (+ rank/placement variants for Fig. 2)
  decoders: fwd_lm, step_lm_lora, step_lm_full, step_grpo_lora
"""

import argparse
import json
import os
import struct
import sys
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import train_graph as TG
from .configs import HW, VARIANTS, variant_dict

GRPO_GROUP = 16


def to_hlo_text(lowered, expected_params: int) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # jax silently dead-code-eliminates unused graph inputs; the rust
    # coordinator packs literals from the manifest, so any mismatch must
    # fail the build, not the first execution.
    got = len(comp.program_shape().parameter_shapes())
    if got != expected_params:
        raise RuntimeError(
            f"lowered graph kept {got} parameters but manifest lists "
            f"{expected_params}: some model input is unused (DCE'd)"
        )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# ALTB tensor container (mirrored by rust/src/model/checkpoint.rs)
# ---------------------------------------------------------------------------


def write_altb(path: str, tensors: List[Tuple[str, np.ndarray]]):
    with open(path, "wb") as f:
        f.write(b"ALTB")
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr, np.float32)
            nb = name.encode()
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


# ---------------------------------------------------------------------------
# Spec helpers
# ---------------------------------------------------------------------------


def _sds(tree):
    """ShapeDtypeStructs for a flat (name, arr) list."""
    return [jax.ShapeDtypeStruct(a.shape, a.dtype) for _, a in tree]


def _io_entry(name, role, arr_or_sds):
    return {
        "name": name,
        "role": role,
        "shape": list(arr_or_sds.shape),
        "dtype": str(arr_or_sds.dtype),
    }


def _batch_spec(loss: str, cfg, batch_size: int):
    """(names, ShapeDtypeStructs) of the data inputs for a loss kind."""
    i32, f32 = jnp.int32, jnp.float32
    B, S = batch_size, cfg.seq
    sd = jax.ShapeDtypeStruct
    if loss == "qa":
        return ["tokens", "starts", "ends"], [sd((B, S), i32), sd((B,), i32), sd((B,), i32)]
    if loss == "cls":
        return ["tokens", "labels"], [sd((B, S), i32), sd((B,), i32)]
    if loss == "reg":
        return ["tokens", "targets"], [sd((B, S), i32), sd((B,), f32)]
    if loss == "lm":
        return ["tokens", "mask"], [sd((B, S), i32), sd((B, S), f32)]
    if loss == "grpo":
        G = GRPO_GROUP
        return ["tokens", "mask", "adv"], [sd((G, S), i32), sd((G, S), f32), sd((G,), f32)]
    raise ValueError(loss)


KEY_SDS = jax.ShapeDtypeStruct((2,), jnp.uint32)
HW_SDS = jax.ShapeDtypeStruct((5,), jnp.float32)
OPT_SDS = jax.ShapeDtypeStruct((3,), jnp.float32)


# ---------------------------------------------------------------------------
# Lowering
# ---------------------------------------------------------------------------


def lower_step(cfg, loss: str, regime: str, rank=None, placement=None):
    """Lower one optimizer-step graph; returns (hlo_text, manifest_entry,
    init tensors)."""
    key0 = jax.random.PRNGKey(0)
    # Rebuild templates with the requested rank/placement
    meta_t = M.init_meta(cfg, key0)
    lora_t = M.init_lora(cfg, jax.random.PRNGKey(1), rank=rank, placement=placement)
    head_name = {"qa": "qa", "cls": "cls", "reg": "cls", "lm": "lm", "grpo": "lm"}[loss]
    head_t = M.init_head(cfg, head_name, jax.random.PRNGKey(2))
    train_t = {"head": head_t, "lora": lora_t} if regime == "lora" else {"head": head_t, "meta": meta_t}

    flat_meta = M.flatten_params(meta_t)
    flat_train = M.flatten_params(train_t)
    loss_fn = TG.LOSSES[loss]
    n_layers = cfg.n_layers

    # In the "full" regime the meta weights live INSIDE the trainable
    # tree; a separate meta input would be dead (jax DCEs it and the
    # compiled parameter list would disagree with the manifest), so the
    # graph signature drops it.
    has_meta_input = regime == "lora"

    def step(fm, ft, m, v, batch, key, hw_vec, opt_vec):
        hw = TG._hw_from_vec(hw_vec)
        meta = M.unflatten_params(meta_t, fm) if has_meta_input else None

        def compute_loss(ft_):
            tr = M.unflatten_params(train_t, ft_)
            lora = tr.get("lora", {"layers": [{} for _ in range(n_layers)]})
            mt = tr.get("meta", meta)
            return loss_fn(cfg, mt, lora, tr["head"], batch, key, hw)

        lossv, grads = jax.value_and_grad(compute_loss)(ft)
        new_t, new_m, new_v = TG.adamw_update(ft, grads, m, v, opt_vec[2], opt_vec[0], opt_vec[1])
        return new_t, new_m, new_v, lossv

    bnames, bsds = _batch_spec(loss, cfg, cfg.train_batch)
    meta_sds, train_sds = _sds(flat_meta), _sds(flat_train)
    # None is an empty pytree: the "full" graphs simply have no meta
    # inputs (jit flattens None to zero parameters).
    lowered = jax.jit(step).lower(
        meta_sds if has_meta_input else None,
        train_sds, train_sds, train_sds, tuple(bsds), KEY_SDS, HW_SDS, OPT_SDS
    )

    inputs = (
        ([_io_entry("meta." + n, "meta", a) for n, a in flat_meta] if has_meta_input else [])
        + [_io_entry(n, "train", a) for n, a in flat_train]
        + [_io_entry(n, "m", a) for n, a in flat_train]
        + [_io_entry(n, "v", a) for n, a in flat_train]
        + [_io_entry(n, "data", s) for n, s in zip(bnames, bsds)]
        + [_io_entry("key", "key", KEY_SDS), _io_entry("hw", "hw", HW_SDS), _io_entry("opt", "opt", OPT_SDS)]
    )
    outputs = (
        [_io_entry(n, "train", a) for n, a in flat_train]
        + [_io_entry(n, "m", a) for n, a in flat_train]
        + [_io_entry(n, "v", a) for n, a in flat_train]
        + [{"name": "loss", "role": "loss", "shape": [], "dtype": "float32"}]
    )
    entry = {"variant": cfg.name, "kind": f"step_{loss}_{regime}", "inputs": inputs, "outputs": outputs}
    inits = {"meta": flat_meta, "train": flat_train}
    return to_hlo_text(lowered, len(inputs)), entry, inits


def lower_fwd(cfg, head_name: str, rank=None, placement=None, batch=None):
    key0 = jax.random.PRNGKey(0)
    meta_t = M.init_meta(cfg, key0)
    lora_t = M.init_lora(cfg, jax.random.PRNGKey(1), rank=rank, placement=placement)
    head_t = M.init_head(cfg, head_name, jax.random.PRNGKey(2))
    train_t = {"head": head_t, "lora": lora_t}
    flat_meta = M.flatten_params(meta_t)
    flat_train = M.flatten_params(train_t)

    def fwd(fm, ft, tokens, key, hw_vec):
        hw = TG._hw_from_vec(hw_vec)
        meta = M.unflatten_params(meta_t, fm)
        tr = M.unflatten_params(train_t, ft)
        if head_name == "qa":
            return M.fwd_qa(cfg, meta, tr["lora"], tr["head"], tokens, key, hw)
        if head_name == "cls":
            return (M.fwd_cls(cfg, meta, tr["lora"], tr["head"], tokens, key, hw),)
        return (M.fwd_lm(cfg, meta, tr["lora"], tokens, key, hw),)

    B = batch or cfg.eval_batch
    tok_sds = jax.ShapeDtypeStruct((B, cfg.seq), jnp.int32)
    lowered = jax.jit(fwd).lower(_sds(flat_meta), _sds(flat_train), tok_sds, KEY_SDS, HW_SDS)

    inputs = (
        [_io_entry("meta." + n, "meta", a) for n, a in flat_meta]
        + [_io_entry(n, "train", a) for n, a in flat_train]
        + [_io_entry("tokens", "data", tok_sds)]
        + [_io_entry("key", "key", KEY_SDS), _io_entry("hw", "hw", HW_SDS)]
    )
    S, V, C = cfg.seq, cfg.vocab, cfg.n_cls
    if head_name == "qa":
        outputs = [
            {"name": "start_logits", "role": "logits", "shape": [B, S], "dtype": "float32"},
            {"name": "end_logits", "role": "logits", "shape": [B, S], "dtype": "float32"},
        ]
    elif head_name == "cls":
        outputs = [{"name": "logits", "role": "logits", "shape": [B, C], "dtype": "float32"}]
    else:
        outputs = [{"name": "logits", "role": "logits", "shape": [B, S, V], "dtype": "float32"}]
    entry = {"variant": cfg.name, "kind": f"fwd_{head_name}", "inputs": inputs, "outputs": outputs}
    return to_hlo_text(lowered, len(inputs)), entry, {"meta": flat_meta, "train": flat_train}


# ---------------------------------------------------------------------------
# Build plan
# ---------------------------------------------------------------------------


def build_plan() -> List[dict]:
    """(graph_key, lower_kwargs) for every artifact. See DESIGN.md."""
    plan = []

    def add(key, **kw):
        plan.append({"key": key, **kw})

    for vn in ["tiny", "mobilebert_proxy"]:
        add(f"{vn}/fwd_qa", variant=vn, fn="fwd", head="qa")
        add(f"{vn}/fwd_cls", variant=vn, fn="fwd", head="cls")
        add(f"{vn}/step_qa_lora", variant=vn, fn="step", loss="qa", regime="lora")
        add(f"{vn}/step_qa_full", variant=vn, fn="step", loss="qa", regime="full")
        add(f"{vn}/step_cls_lora", variant=vn, fn="step", loss="cls", regime="lora")
        add(f"{vn}/step_reg_lora", variant=vn, fn="step", loss="reg", regime="lora")

    # rank sweep (Fig. 2a / Table II) and placement ablation (Fig. 2b)
    for r in [1, 2, 4, 16]:
        add(f"mobilebert_proxy/step_qa_lora@r{r}", variant="mobilebert_proxy", fn="step", loss="qa", regime="lora", rank=r)
        add(f"mobilebert_proxy/fwd_qa@r{r}", variant="mobilebert_proxy", fn="fwd", head="qa", rank=r)
    for pl in ["qkv", "ffn"]:
        add(f"mobilebert_proxy/step_qa_lora@{pl}", variant="mobilebert_proxy", fn="step", loss="qa", regime="lora", placement=pl)
        add(f"mobilebert_proxy/fwd_qa@{pl}", variant="mobilebert_proxy", fn="fwd", head="qa", placement=pl)

    for vn in ["bert_base_proxy", "bert_large_proxy"]:
        add(f"{vn}/fwd_qa", variant=vn, fn="fwd", head="qa")
        add(f"{vn}/step_qa_lora", variant=vn, fn="step", loss="qa", regime="lora")
        add(f"{vn}/step_qa_full", variant=vn, fn="step", loss="qa", regime="full")

    for vn in ["tiny_dec", "llama_proxy"]:
        add(f"{vn}/fwd_lm", variant=vn, fn="fwd", head="lm")
        add(f"{vn}/step_lm_lora", variant=vn, fn="step", loss="lm", regime="lora")
        add(f"{vn}/step_lm_full", variant=vn, fn="step", loss="lm", regime="full")
        add(f"{vn}/step_grpo_lora", variant=vn, fn="step", loss="grpo", regime="lora")
    return plan


def key_to_file(key: str) -> str:
    return key.replace("/", ".") + ".hlo.txt"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on graph keys")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    os.makedirs(os.path.join(args.out_dir, "init"), exist_ok=True)

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"hw": HW.__dict__, "grpo_group": GRPO_GROUP, "variants": {}, "graphs": {}}
    if os.path.exists(manifest_path) and args.only:
        with open(manifest_path) as f:
            manifest = json.load(f)

    for name, cfg in VARIANTS.items():
        manifest["variants"][name] = variant_dict(cfg)

    written_inits = set()
    plan = build_plan()
    if args.only:
        plan = [p for p in plan if args.only in p["key"]]
    for i, spec in enumerate(plan):
        key = spec["key"]
        cfg = VARIANTS[spec["variant"]]
        print(f"[{i + 1}/{len(plan)}] lowering {key}", flush=True)
        if spec["fn"] == "fwd":
            hlo, entry, inits = lower_fwd(cfg, spec["head"], rank=spec.get("rank"), placement=spec.get("placement"))
        else:
            hlo, entry, inits = lower_step(cfg, spec["loss"], spec["regime"], rank=spec.get("rank"), placement=spec.get("placement"))
        fname = key_to_file(key)
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(hlo)
        entry["file"] = fname
        manifest["graphs"][key] = entry

        # initial values: meta once per variant; train tree once per
        # (variant, regime/rank/placement) signature
        vtag = spec["variant"]
        if vtag not in written_inits:
            write_altb(os.path.join(args.out_dir, "init", f"{vtag}.meta.bin"), [(n, np.asarray(a)) for n, a in inits["meta"]])
            written_inits.add(vtag)
        ttag = key.replace("/", ".")
        write_altb(os.path.join(args.out_dir, "init", f"{ttag}.train.bin"), [(n, np.asarray(a)) for n, a in inits["train"]])

        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)
    print(f"wrote {len(plan)} graphs + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
