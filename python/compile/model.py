"""L2: the transformer family (encoder + decoder) with AnalogLinear+LoRA.

Parameter trees
---------------
`init_meta(cfg)`   -> the frozen, AIMC-mapped "meta-weights" (paper's
                      pre-trained base). Every matrix listed in
                      configs.ALL_LINEARS plus the embedding transform and
                      the LM output matrix lives on tiles; LayerNorms,
                      biases and embedding *lookup* are digital.
`init_lora(cfg,..)`-> LoRA adapter tree (A zero-centred Gaussian, B zero,
                      so the adapted model starts exactly at the base).
`init_head(cfg,h)` -> digital task head ("qa" | "cls" | none for LM).

Trees flatten to a canonical `sorted-by-name` order via `flatten_params`;
artifacts/manifest.json records that order and the rust coordinator packs
PJRT literals to match (rust/src/runtime/pack.rs mirrors this function).

Forward passes take a `hw` dict of runtime scalars (noise level, clip
sigma, DAC/ADC levels, ADC noise) and a PRNG key, so one compiled
artifact covers the whole noise/bit-width experimental grid.
"""

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import configs
from .configs import ModelConfig, lora_targets
from .layers import (
    analog_linear,
    attention_scores,
    layer_norm,
    merge_heads,
    split_heads,
)

Params = Dict


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------


def init_meta(cfg: ModelConfig, key) -> Params:
    """The base-model ("meta") weights, later programmed onto AIMC tiles."""
    ks = jax.random.split(key, 4 + cfg.n_layers)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.d_emb

    def dense(k, i, o):
        return jax.random.normal(k, (i, o), jnp.float32) * (0.8 / jnp.sqrt(i))

    p: Params = {
        "tok_emb": jax.random.normal(ks[0], (cfg.vocab, e)) * 0.1,
        "pos_emb": jax.random.normal(ks[1], (cfg.seq, e)) * 0.02,
        "layers": [],
    }
    if cfg.kind == "encoder":
        p["emb_proj"] = dense(ks[2], e, d)  # MobileBERT-style embedding transform (analog)
    else:
        # decoder-only: analog LM output layer + final norm. (Encoders
        # must not carry these — jax DCEs unused graph inputs and the
        # manifest would disagree with the compiled parameter list.)
        p["w_lm"] = dense(ks[3], d, cfg.vocab)
        p["lm_ln_g"] = jnp.ones((d,))
        p["lm_ln_b"] = jnp.zeros((d,))

    for li in range(cfg.n_layers):
        kq, kk, kv, ko, k1, k2 = jax.random.split(ks[4 + li], 6)
        p["layers"].append(
            {
                "wq": dense(kq, d, d),
                "wk": dense(kk, d, d),
                "wv": dense(kv, d, d),
                "wo": dense(ko, d, d),
                "w1": dense(k1, d, f),
                "w2": dense(k2, f, d),
                "bq": jnp.zeros((d,)),
                "bk": jnp.zeros((d,)),
                "bv": jnp.zeros((d,)),
                "bo": jnp.zeros((d,)),
                "b1": jnp.zeros((f,)),
                "b2": jnp.zeros((d,)),
                "ln1_g": jnp.ones((d,)),
                "ln1_b": jnp.zeros((d,)),
                "ln2_g": jnp.ones((d,)),
                "ln2_b": jnp.zeros((d,)),
            }
        )
    return p


_LINEAR_DIMS = {
    "wq": ("d", "d"),
    "wk": ("d", "d"),
    "wv": ("d", "d"),
    "wo": ("d", "d"),
    "w1": ("d", "f"),
    "w2": ("f", "d"),
}


def init_lora(cfg: ModelConfig, key, rank: Optional[int] = None, placement: Optional[str] = None) -> Params:
    """LoRA adapters for the selected per-block linears (Fig. 2b study)."""
    rank = rank or cfg.rank
    placement = placement or cfg.lora_placement
    targets = lora_targets(placement)
    dims = {"d": cfg.d_model, "f": cfg.d_ff}
    p: Params = {"layers": []}
    for li in range(cfg.n_layers):
        blk = {}
        for t in targets:
            di, do = (_LINEAR_DIMS[t][0], _LINEAR_DIMS[t][1])
            key, ka = jax.random.split(key)
            blk[t + "_a"] = jax.random.normal(ka, (dims[di], rank)) * (1.0 / jnp.sqrt(dims[di]))
            blk[t + "_b"] = jnp.zeros((rank, dims[do]))
        p["layers"].append(blk)
    return p


def init_head(cfg: ModelConfig, head: str, key) -> Params:
    """Digital, DPU-resident task head (the paper's 'unmappable' params)."""
    d = cfg.d_model
    if head == "qa":
        return {
            "w_span": jax.random.normal(key, (d, 2)) * 0.02,
            "b_span": jnp.zeros((2,)),
        }
    if head == "cls":
        return {
            "w_cls": jax.random.normal(key, (d, cfg.n_cls)) * 0.02,
            "b_cls": jnp.zeros((cfg.n_cls,)),
        }
    if head == "lm":
        return {}
    raise ValueError(head)


def default_hw(noise=0.0, clip_sigma=0.0, dac_levels=0.0, adc_levels=0.0, adc_noise=0.0):
    f = jnp.float32
    return {
        "noise": f(noise),
        "clip_sigma": f(clip_sigma),
        "dac_levels": f(dac_levels),
        "adc_levels": f(adc_levels),
        "adc_noise": f(adc_noise),
    }


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def _lora_of(blk: Params, name: str) -> Optional[Tuple]:
    a = blk.get(name + "_a")
    return None if a is None else (a, blk[name + "_b"])


def _block(cfg, x, mp, lp, key, hw, lora_scale, causal):
    """One transformer block; analog linears + digital attention/LN."""
    keys = jax.random.split(key, 6)

    def lin(name, inp, k):
        return analog_linear(
            inp, mp[name], mp["b" + name[1:]], k, hw, _lora_of(lp, name), lora_scale
        )

    if cfg.kind == "encoder":  # post-LN (BERT family)
        q = lin("wq", x, keys[0])
        kk = lin("wk", x, keys[1])
        v = lin("wv", x, keys[2])
        att = attention_scores(
            split_heads(q, cfg.n_heads), split_heads(kk, cfg.n_heads), split_heads(v, cfg.n_heads), causal
        )
        x = layer_norm(x + lin("wo", merge_heads(att), keys[3]), mp["ln1_g"], mp["ln1_b"])
        h = jax.nn.gelu(lin("w1", x, keys[4]))
        x = layer_norm(x + lin("w2", h, keys[5]), mp["ln2_g"], mp["ln2_b"])
    else:  # pre-LN (LLaMA family)
        xin = layer_norm(x, mp["ln1_g"], mp["ln1_b"])
        q = lin("wq", xin, keys[0])
        kk = lin("wk", xin, keys[1])
        v = lin("wv", xin, keys[2])
        att = attention_scores(
            split_heads(q, cfg.n_heads), split_heads(kk, cfg.n_heads), split_heads(v, cfg.n_heads), causal
        )
        x = x + lin("wo", merge_heads(att), keys[3])
        xin = layer_norm(x, mp["ln2_g"], mp["ln2_b"])
        h = jax.nn.gelu(lin("w1", xin, keys[4]))
        x = x + lin("w2", h, keys[5])
    return x


def encode(cfg: ModelConfig, meta: Params, lora: Params, tokens, key, hw):
    """Shared trunk: tokens [B,S] int32 -> hidden states [B,S,D]."""
    b, s = tokens.shape
    x = meta["tok_emb"][tokens] + meta["pos_emb"][None, :s]
    key, ke = jax.random.split(key)
    if cfg.kind == "encoder":
        x = analog_linear(x, meta["emb_proj"], None, ke, hw)
    lora_scale = jnp.float32(cfg.lora_alpha) / _lora_rank(lora)
    causal = cfg.kind == "decoder"
    for li in range(cfg.n_layers):
        key, kb = jax.random.split(key)
        lp = lora["layers"][li] if lora["layers"] else {}
        x = _block(cfg, x, meta["layers"][li], lp, kb, hw, lora_scale, causal)
    if cfg.kind == "decoder":
        x = layer_norm(x, meta["lm_ln_g"], meta["lm_ln_b"])
    return x


def _lora_rank(lora: Params):
    for blk in lora["layers"]:
        for v in blk.values():
            return jnp.float32(v.shape[-1] if v.ndim == 2 and v.shape[-1] < v.shape[0] else v.shape[0])
    return jnp.float32(1.0)


def fwd_qa(cfg, meta, lora, head, tokens, key, hw):
    """Span-extraction head: -> (start_logits, end_logits) [B,S]."""
    x = encode(cfg, meta, lora, tokens, key, hw)
    logits = jnp.einsum("bsd,dk->bsk", x, head["w_span"]) + head["b_span"]
    return logits[..., 0], logits[..., 1]


def fwd_cls(cfg, meta, lora, head, tokens, key, hw):
    """Sequence classification/regression: -> logits [B, n_cls].

    Pooled on token 0 ([CLS]); regression tasks read channel 0.
    """
    x = encode(cfg, meta, lora, tokens, key, hw)
    pooled = x[:, 0]
    return pooled @ head["w_cls"] + head["b_cls"]


def fwd_lm(cfg, meta, lora, tokens, key, hw):
    """Decoder LM logits [B,T,V] through the analog output layer."""
    x = encode(cfg, meta, lora, tokens, key, hw)
    key, ko = jax.random.split(key)
    return analog_linear(x, meta["w_lm"], None, ko, hw)


# ---------------------------------------------------------------------------
# Canonical flattening (mirrored by rust/src/runtime/pack.rs)
# ---------------------------------------------------------------------------


def flatten_params(tree, prefix="") -> List[Tuple[str, jnp.ndarray]]:
    """Deterministic name-sorted flattening of a params tree."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree.keys()):
            out += flatten_params(tree[k], f"{prefix}{k}." if prefix or True else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out += flatten_params(v, f"{prefix}{i}.")
    else:
        out.append((prefix[:-1], tree))
    return out


def unflatten_params(template, flat: List[jnp.ndarray]):
    """Rebuild a tree shaped like `template` from the canonical flat list."""
    it = iter(flat)

    def go(t):
        if isinstance(t, dict):
            return {k: go(t[k]) for k in sorted(t.keys())}
        if isinstance(t, (list, tuple)):
            return [go(v) for v in t]
        return next(it)

    out = go(template)
    # exhaustiveness check
    try:
        next(it)
        raise ValueError("flat list longer than template")
    except StopIteration:
        return out


def param_count(tree) -> int:
    return sum(int(v.size) for _, v in flatten_params(tree))
