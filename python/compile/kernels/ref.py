"""Pure-jnp oracles for the L1 Pallas kernels.

These implement the *same* semantics as aimc_linear.py / lora.py with no
pallas machinery; pytest asserts allclose between kernel and oracle over
hypothesis-generated shapes/values (python/tests/test_kernels.py).

The only subtlety is quantizer *ranging granularity*: the kernel ranges
the DAC per (token-block x k-tile) block and the ADC per
(token-block x n-tile) column block, because that is what each physical
tile's converters see. The oracle reproduces exactly that blocking.
"""

import jax.numpy as jnp

from .aimc_linear import TILE_K, TILE_M, TILE_N, _EPS


def quant_sym(v, scale, levels):
    s = jnp.maximum(scale, _EPS)
    q = jnp.clip(jnp.round(v / s * levels), -levels, levels) / jnp.maximum(levels, 1.0) * s
    return jnp.where(levels > 0, q, v)


def aimc_matmul_ref(x, w, dac_levels, adc_levels):
    """Reference AIMC pipeline with identical tile blocking."""
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = min(m, TILE_M), min(k, TILE_K), min(n, TILE_N)
    dac_levels = jnp.float32(dac_levels)
    adc_levels = jnp.float32(adc_levels)

    out = jnp.zeros((m, n), jnp.float32)
    for i0 in range(0, m, bm):
        for j0 in range(0, n, bn):
            acc = jnp.zeros((min(bm, m - i0), min(bn, n - j0)), jnp.float32)
            for k0 in range(0, k, bk):
                xb = x[i0 : i0 + bm, k0 : k0 + bk]
                wb = w[k0 : k0 + bk, j0 : j0 + bn]
                xq = quant_sym(xb, jnp.max(jnp.abs(xb)), dac_levels)
                acc = acc + jnp.dot(xq, wb)
            ch = jnp.max(jnp.abs(acc), axis=0, keepdims=True)
            out = out.at[i0 : i0 + bm, j0 : j0 + bn].set(quant_sym(acc, ch, adc_levels))
    return out


def lora_matmul_ref(x, a, b, scale):
    return jnp.dot(jnp.dot(x, a), b) * jnp.float32(scale)
