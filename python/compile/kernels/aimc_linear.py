"""L1 Pallas kernel: one AIMC tile matrix-vector-multiply pipeline.

Models the paper's analog datapath for a dense layer mapped onto
512x512 PCM crossbar tiles (Methods - Model Mapping):

    DAC-quantize activations  ->  analog MVM against the (already noisy)
    meta-weights              ->  ADC-quantize per output channel
                              ->  digital affine rescale

Grid layout mirrors the physical tiling: one grid step = one crossbar
tile's worth of (tokens x 512-in x 512-out) work, with the k-dimension
accumulated digitally across tiles exactly as the chip's digital
periphery sums per-tile partial results. BlockSpec expresses the
HBM->VMEM schedule the crossbar mapping implies (DESIGN.md - Hardware
adaptation).

Quantizer *levels* are runtime scalars (float), so one compiled artifact
serves the 8-bit and 6-bit ADC studies (Fig. 3a); levels <= 0 disables a
quantizer (used by the LLaMA-proxy experiments, which omit explicit
DAC/ADC modeling per the paper).

interpret=True everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; real-TPU efficiency is estimated structurally (DESIGN.md
section Perf).

Gradients: the quantizers are straight-through (the paper trains through
the simulated hardware constraints); `analog_matmul` carries a
custom_vjp whose backward is the plain dense rule evaluated at the noisy
weights, which is exactly STE through round().
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Physical tile geometry (HardwareConfig.tile_rows/cols). Token-block of
# 128 matches the paper's largest parallel-token count t=128.
TILE_K = 512
TILE_N = 512
TILE_M = 128

_EPS = 1e-9


def _quant_sym(v, scale, levels):
    """Symmetric mid-tread quantizer with dynamic range `scale`.

    levels = 2^(bits-1) - 1 as a float; levels <= 0 bypasses (identity).
    """
    s = jnp.maximum(scale, _EPS)
    q = jnp.clip(jnp.round(v / s * levels), -levels, levels) / jnp.maximum(levels, 1.0) * s
    return jnp.where(levels > 0, q, v)


def _aimc_kernel(x_ref, w_ref, dac_ref, adc_ref, o_ref, *, nk: int):
    """One (token-block x tile) step; k accumulated across grid dim 2."""
    ik = pl.program_id(2)

    # --- DAC: per-tile dynamic input ranging (bound management) ---
    x = x_ref[...]
    dac_levels = dac_ref[0, 0]
    x_scale = jnp.max(jnp.abs(x))
    xq = _quant_sym(x, x_scale, dac_levels)

    # --- analog MVM on this tile (MXU-shaped 512-wide MAC) ---
    part = jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)

    # --- digital accumulation of per-tile partial sums ---
    @pl.when(ik == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += part

    # --- ADC on the completed column sum: per-channel dynamic ranging ---
    @pl.when(ik == nk - 1)
    def _adc():
        acc = o_ref[...]
        adc_levels = adc_ref[0, 0]
        ch_scale = jnp.max(jnp.abs(acc), axis=0, keepdims=True)
        o_ref[...] = _quant_sym(acc, ch_scale, adc_levels)


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def aimc_matmul_raw(x, w, dac_levels, adc_levels):
    """Tiled AIMC forward: x [m,k] @ w [k,n] through the tile pipeline.

    Inputs are zero-padded up to whole blocks (zero rows/cols change
    neither the dynamic quantizer ranges — abs-max is unaffected by
    zeros — nor the matmul), mirroring how unused crossbar rows are left
    at zero conductance on the physical tile.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bk, bn = min(m, TILE_M), min(k, TILE_K), min(n, TILE_N)
    nm, nk, nn = _ceil_div(m, bm), _ceil_div(k, bk), _ceil_div(n, bn)

    mp, kp, np_ = nm * bm, nk * bk, nn * bn
    if (mp, kp) != (m, k):
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if (kp, np_) != (k, n):
        w = jnp.pad(w, ((0, kp - k), (0, np_ - n)))

    dac = jnp.asarray(dac_levels, jnp.float32).reshape(1, 1)
    adc = jnp.asarray(adc_levels, jnp.float32).reshape(1, 1)

    out = pl.pallas_call(
        functools.partial(_aimc_kernel, nk=nk),
        grid=(nm, nn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, i_n, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, i_n, ik: (ik, i_n)),
            pl.BlockSpec((1, 1), lambda im, i_n, ik: (0, 0)),
            pl.BlockSpec((1, 1), lambda im, i_n, ik: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda im, i_n, ik: (im, i_n)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(x, w, dac, adc)
    return out[:m, :n] if (mp, np_) != (m, n) else out


@jax.custom_vjp
def analog_matmul(x, w, dac_levels, adc_levels):
    """Differentiable AIMC tile matmul (straight-through quantizers).

    `w` is the *already perturbed* weight (noise is sampled in L2 so the
    kernel stays deterministic, mirroring the real chip where stochastic
    behaviour lives in the devices, not the datapath).
    """
    return aimc_matmul_raw(x, w, dac_levels, adc_levels)


def _fwd(x, w, dac_levels, adc_levels):
    return aimc_matmul_raw(x, w, dac_levels, adc_levels), (x, w)


def _bwd(res, g):
    x, w = res
    # STE: d/dx round(x) ~= 1. Plain dense backward at the noisy weights.
    return (
        jnp.dot(g, w.T, preferred_element_type=jnp.float32),
        jnp.dot(x.T, g, preferred_element_type=jnp.float32),
        None,
        None,
    )


analog_matmul.defvjp(_fwd, _bwd)
