"""L1 Pallas kernel: fused low-rank adapter path  X @ A @ B.

On the paper's hardware this is the PMCA's job (Fig. 1b): while the AIMC
tile integrates X.W, the digital cluster computes the rank-r update
X.A.B and adds it to the tile output. The kernel keeps A [k,r] and
B [r,n] resident (r <= 16, so both fit comfortably in VMEM) and streams
token blocks, matching the PMCA's TCDM-resident adapter weights
(Fig. 4b).

interpret=True; see aimc_linear.py for why.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128


def _lora_kernel(x_ref, a_ref, b_ref, scale_ref, o_ref):
    x = x_ref[...]
    # rank-r bottleneck: two thin matmuls entirely in VMEM
    xa = jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = jnp.dot(xa, b_ref[...], preferred_element_type=jnp.float32) * scale_ref[0, 0]


def lora_matmul_raw(x, a, b, scale):
    """x [m,k] @ a [k,r] @ b [r,n], scaled by alpha/r."""
    m, k = x.shape
    k2, r = a.shape
    r2, n = b.shape
    assert k == k2 and r == r2, (x.shape, a.shape, b.shape)
    bm = min(m, BLOCK_M)
    nm = -(-m // bm)
    mp = nm * bm
    if mp != m:  # zero-pad the token dimension up to whole blocks
        x = jnp.pad(x, ((0, mp - m), (0, 0)))
    sc = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _lora_kernel,
        grid=(nm,),
        in_specs=[
            pl.BlockSpec((bm, k), lambda im: (im, 0)),
            pl.BlockSpec((k, r), lambda im: (0, 0)),
            pl.BlockSpec((r, n), lambda im: (0, 0)),
            pl.BlockSpec((1, 1), lambda im: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda im: (im, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=True,
    )(x, a, b, sc)
    return out[:m] if mp != m else out


@jax.custom_vjp
def lora_matmul(x, a, b, scale):
    """Differentiable fused LoRA path (the only trained weights)."""
    return lora_matmul_raw(x, a, b, scale)


def _fwd(x, a, b, scale):
    return lora_matmul_raw(x, a, b, scale), (x, a, b, scale)


def _bwd(res, g):
    x, a, b, scale = res
    gs = g * scale
    gb_in = jnp.dot(x, a)  # [m, r]
    gx = jnp.dot(jnp.dot(gs, b.T), a.T)
    ga = jnp.dot(x.T, jnp.dot(gs, b.T))
    gb = jnp.dot(gb_in.T, gs)
    return gx, ga, gb, None


lora_matmul.defvjp(_fwd, _bwd)
