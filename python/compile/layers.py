"""L2 building blocks: analog-mapped linears, LoRA adapters, attention.

Responsibility split mirrors the paper's Fig. 1:

* `analog_linear`   — dense layers whose weights live on AIMC tiles:
    per-channel clipping -> fresh Gaussian weight perturbation (the
    AHWA noise model, sampled *here* so the L1 kernel stays
    deterministic) -> L1 `analog_matmul` (DAC/MVM/ADC) -> ADC read
    noise -> digital bias -> optional LoRA path on the PMCA.
* attention scores  — dynamic matmuls; computed digitally (the paper
    assigns them to the PMCAs since weight-stationary AIMC cannot hold
    activations), so plain jnp here.
* LayerNorm, heads  — digital periphery / DPU-resident parameters.

All stochastic draws key off an explicit PRNG key threaded from the
graph inputs so the rust coordinator fully controls randomness.
"""

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels.aimc_linear import analog_matmul
from .kernels.lora import lora_matmul

_EPS = 1e-9


def clip_channelwise(w, clip_sigma):
    """Per-output-channel c-sigma clipping (Methods: 3-sigma on the fitted
    weight distribution, differential channel-wise mapping). clip_sigma<=0
    disables (the LLaMA experiments omit clipping)."""
    std = jnp.std(w, axis=0, keepdims=True) + _EPS
    lim = clip_sigma * std
    return jnp.where(clip_sigma > 0, jnp.clip(w, -lim, lim), w)


def perturb_weight(w, key, noise_level):
    """AHWA effective-noise model: zero-mean Gaussian with std equal to
    noise_level * max|w| (relative amplitude, AIHWKIT convention). The
    master weight stays clean; the draw is i.i.d. per minibatch."""
    amp = noise_level * jnp.max(jnp.abs(w))
    return w + amp * jax.random.normal(key, w.shape, w.dtype)


def analog_linear(
    x,
    w,
    b,
    key,
    hw,
    lora: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
    lora_scale: float = 1.0,
):
    """One AIMC-mapped dense layer with optional PMCA LoRA path.

    x: [..., k]; w: [k, n]; b: [n] or None.
    hw: dict of runtime scalars {noise, clip_sigma, dac_levels,
        adc_levels, adc_noise}.
    """
    shp = x.shape
    x2 = x.reshape(-1, shp[-1])

    kw, ko = jax.random.split(key)
    w_eff = clip_channelwise(w, hw["clip_sigma"])
    w_eff = perturb_weight(w_eff, kw, hw["noise"])

    y = analog_matmul(x2, w_eff, hw["dac_levels"], hw["adc_levels"])

    # ADC read noise: relative to the per-channel conversion range.
    ch = jax.lax.stop_gradient(jnp.max(jnp.abs(y), axis=0, keepdims=True))
    y = y + hw["adc_noise"] * ch * jax.random.normal(ko, y.shape, y.dtype)

    if lora is not None:
        a, bb = lora
        y = y + lora_matmul(x2, a, bb, lora_scale)
    if b is not None:
        y = y + b
    return y.reshape(shp[:-1] + (w.shape[1],))


def layer_norm(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * g + b


def attention_scores(q, k, v, causal: bool):
    """Digital (PMCA-assigned) scaled dot-product attention.

    q,k,v: [B, H, S, Dh] -> [B, H, S, Dh].
    """
    dh = q.shape[-1]
    att = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        s = q.shape[2]
        mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
        att = jnp.where(mask[None, None], att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", att, v)


def split_heads(x, n_heads):
    b, s, d = x.shape
    return x.reshape(b, s, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
