"""Model-variant and hardware configurations shared between the python
compile path (L1/L2) and the rust coordinator (L3).

Every variant is an architecturally faithful, CPU-trainable proxy of a
paper model (see DESIGN.md — Environment constraints & substitutions).
The variant dict is serialized into artifacts/manifest.json so the rust
side never hard-codes shapes.
"""

from dataclasses import dataclass, field, asdict
from typing import Dict, List, Tuple


@dataclass(frozen=True)
class ModelConfig:
    """Transformer family configuration (encoder or decoder).

    Mirrors the paper's model inventory: MobileBERT / BERT-Base /
    BERT-Large (encoder) and LLaMA-3.1 (decoder), at proxy scale.
    """

    name: str
    kind: str  # "encoder" | "decoder"
    vocab: int
    seq: int  # maximum sequence length baked into artifacts
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    d_emb: int  # embedding width before the (analog) embedding transform
    n_cls: int  # padded classifier width (GLUE heads slice from this)
    rank: int  # default LoRA rank (paper: 8 for encoders, 16 for LLaMA)
    lora_alpha: float = 16.0
    # Which linear layers carry LoRA adapters: "all" | "qkv" | "ffn" | "none"
    lora_placement: str = "all"
    train_batch: int = 8
    eval_batch: int = 32

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


@dataclass(frozen=True)
class HardwareConfig:
    """AIMC tile + PCM device constants (Methods — Model Mapping).

    The quantizer levels are *runtime scalars* in the exported graphs so a
    single artifact serves the 8-bit and 6-bit ADC studies (Fig. 3a).
    These defaults document the paper's configuration.
    """

    tile_rows: int = 512
    tile_cols: int = 512
    g_max_us: float = 25.0  # maximum device conductance, microsiemens
    dac_bits: int = 8
    adc_bits: int = 8
    weight_noise: float = 0.067  # effective Gaussian amplitude (training)
    adc_noise: float = 0.04  # relative output (ADC) noise amplitude
    clip_sigma: float = 3.0  # channel-wise clipping threshold, in sigmas
    t0_seconds: float = 20.0  # drift reference time (programming read)


# ---------------------------------------------------------------------------
# Variant registry.
# Proxy scaling keeps the paper's depth/width *ratios* and the full linear-
# layer inventory (QKV + output proj + FFN + embedding transform + task
# heads) while remaining trainable on a single CPU core. See DESIGN.md.
# ---------------------------------------------------------------------------

VARIANTS: Dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        # unit-test scale
        ModelConfig(
            name="tiny",
            kind="encoder",
            vocab=64,
            seq=16,
            d_model=32,
            n_layers=2,
            n_heads=2,
            d_ff=96,
            d_emb=16,
            n_cls=4,
            rank=4,
            train_batch=4,
            eval_batch=8,
        ),
        ModelConfig(
            name="tiny_dec",
            kind="decoder",
            vocab=64,
            seq=16,
            d_model=32,
            n_layers=2,
            n_heads=2,
            d_ff=96,
            d_emb=32,  # decoders: tied-width embeddings (no analog transform)
            n_cls=4,
            rank=4,
            train_batch=4,
            eval_batch=8,
        ),
        # MobileBERT proxy (paper: 25.3M) — main experimental workhorse
        ModelConfig(
            name="mobilebert_proxy",
            kind="encoder",
            vocab=512,
            seq=48,
            d_model=128,
            n_layers=4,
            n_heads=4,
            d_ff=384,
            d_emb=64,
            n_cls=4,
            rank=8,
        ),
        # BERT-Base proxy (paper: 108M)
        ModelConfig(
            name="bert_base_proxy",
            kind="encoder",
            vocab=512,
            seq=48,
            d_model=192,
            n_layers=6,
            n_heads=6,
            d_ff=576,
            d_emb=96,
            n_cls=4,
            rank=8,
        ),
        # BERT-Large proxy (paper: 334M)
        ModelConfig(
            name="bert_large_proxy",
            kind="encoder",
            vocab=512,
            seq=48,
            d_model=256,
            n_layers=8,
            n_heads=8,
            d_ff=768,
            d_emb=128,
            n_cls=4,
            rank=8,
        ),
        # LLaMA-3.1-8B proxy (decoder-only; paper rank 16)
        ModelConfig(
            name="llama_proxy",
            kind="decoder",
            vocab=512,
            seq=64,
            d_model=128,
            n_layers=4,
            n_heads=4,
            d_ff=384,
            d_emb=128,  # decoders use tied-width embeddings (no transform)
            n_cls=4,
            rank=16,
            train_batch=8,
            eval_batch=16,
        ),
    ]
}

HW = HardwareConfig()

# Linear-layer inventory per transformer block, used by LoRA placement and
# by the rust-side tile allocator. Matches the paper's mapping: QKV + attn
# output + both FFN matrices live on AIMC tiles.
QKV_LINEARS = ("wq", "wk", "wv")
ATTN_LINEARS = QKV_LINEARS + ("wo",)
FFN_LINEARS = ("w1", "w2")
ALL_LINEARS = ATTN_LINEARS + FFN_LINEARS


def lora_targets(placement: str) -> Tuple[str, ...]:
    """Which per-block linears receive LoRA adapters (Fig. 2b study)."""
    if placement == "all":
        return ALL_LINEARS
    if placement == "qkv":
        return QKV_LINEARS
    if placement == "ffn":
        return FFN_LINEARS
    if placement == "none":
        return ()
    raise ValueError(f"unknown lora placement: {placement}")


def variant_dict(cfg: ModelConfig) -> dict:
    d = asdict(cfg)
    d["d_head"] = cfg.d_head
    return d
