"""L1 kernel correctness: Pallas vs pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/values; every property asserts allclose against
the reference implementation — this is the CORE correctness signal for
the compute hot-spot.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except ImportError:  # pragma: no cover - hypothesis is expected in-image
    HAVE_HYP = False

from compile.kernels.aimc_linear import analog_matmul, aimc_matmul_raw, _quant_sym
from compile.kernels.lora import lora_matmul, lora_matmul_raw
from compile.kernels.ref import aimc_matmul_ref, lora_matmul_ref, quant_sym


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------------------
# Quantizer unit behaviour
# ---------------------------------------------------------------------------


class TestQuantizer:
    def test_bypass_when_levels_zero(self):
        v = rnd(0, (8, 8))
        out = _quant_sym(v, jnp.max(jnp.abs(v)), jnp.float32(0.0))
        np.testing.assert_allclose(out, v)

    def test_levels_bound_error(self):
        v = rnd(1, (64, 64))
        s = jnp.max(jnp.abs(v))
        for bits in (4, 6, 8):
            levels = float(2 ** (bits - 1) - 1)
            q = _quant_sym(v, s, jnp.float32(levels))
            step = float(s) / levels
            assert float(jnp.max(jnp.abs(q - v))) <= step / 2 + 1e-6

    def test_idempotent(self):
        v = rnd(2, (32, 32))
        s = jnp.max(jnp.abs(v))
        q1 = _quant_sym(v, s, jnp.float32(127.0))
        q2 = _quant_sym(q1, s, jnp.float32(127.0))
        np.testing.assert_allclose(q1, q2, atol=1e-6)

    def test_preserves_sign_and_clip(self):
        v = jnp.array([[-10.0, -0.1, 0.0, 0.1, 10.0]])
        q = _quant_sym(v, jnp.float32(1.0), jnp.float32(127.0))
        assert float(q[0, 0]) == -1.0 and float(q[0, 4]) == 1.0
        assert float(q[0, 2]) == 0.0

    def test_matches_ref_quant(self):
        v = rnd(3, (16, 16), 2.0)
        s = jnp.max(jnp.abs(v))
        np.testing.assert_allclose(
            _quant_sym(v, s, jnp.float32(31.0)), quant_sym(v, s, jnp.float32(31.0)), atol=1e-7
        )


# ---------------------------------------------------------------------------
# AIMC matmul kernel vs oracle
# ---------------------------------------------------------------------------

AIMC_SHAPES = [
    (1, 8, 8),
    (4, 16, 8),
    (20, 130, 70),  # multiple token blocks? no — m<128; k<512
    (130, 64, 64),  # multiple m blocks
    (16, 600, 40),  # k crosses the 512 tile boundary -> 2-tile accumulate
    (8, 1030, 520),  # 3 k-tiles, 2 n-tiles
    (256, 520, 12),
]


class TestAimcKernel:
    @pytest.mark.parametrize("m,k,n", AIMC_SHAPES)
    def test_matches_ref(self, m, k, n):
        x = rnd(m * 7 + n, (m, k))
        w = rnd(k * 3 + 1, (k, n), 0.1)
        y = aimc_matmul_raw(x, w, 127.0, 127.0)
        yr = aimc_matmul_ref(x, w, 127.0, 127.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("levels", [0.0, 7.0, 31.0, 127.0])
    def test_levels_sweep(self, levels):
        x, w = rnd(5, (24, 96)), rnd(6, (96, 48), 0.1)
        y = aimc_matmul_raw(x, w, levels, levels)
        yr = aimc_matmul_ref(x, w, levels, levels)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-5, atol=1e-5)

    def test_no_quant_equals_dense(self):
        x, w = rnd(7, (16, 32)), rnd(8, (32, 24), 0.1)
        y = aimc_matmul_raw(x, w, 0.0, 0.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w), rtol=1e-4, atol=1e-5)

    def test_quant_error_shrinks_with_bits(self):
        x, w = rnd(9, (32, 64)), rnd(10, (64, 32), 0.1)
        exact = np.asarray(x @ w)
        errs = []
        for bits in (4, 6, 8):
            lv = float(2 ** (bits - 1) - 1)
            y = np.asarray(aimc_matmul_raw(x, w, lv, lv))
            errs.append(np.abs(y - exact).mean())
        assert errs[0] > errs[1] > errs[2]

    def test_gradients_are_dense_ste(self):
        x, w = rnd(11, (8, 16)), rnd(12, (16, 8), 0.1)

        def f(x_, w_):
            return jnp.sum(analog_matmul(x_, w_, 127.0, 127.0) ** 2)

        gx, gw = jax.grad(f, argnums=(0, 1))(x, w)
        y = analog_matmul(x, w, 127.0, 127.0)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(2 * y @ w.T), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(x.T @ (2 * y)), rtol=1e-4, atol=1e-4)

    if HAVE_HYP:

        @settings(max_examples=25, deadline=None)
        @given(
            m=st.integers(1, 140),
            k=st.integers(1, 560),
            n=st.integers(1, 70),
            levels=st.sampled_from([0.0, 31.0, 127.0]),
            seed=st.integers(0, 2**16),
        )
        def test_hypothesis_shapes(self, m, k, n, levels, seed):
            x = rnd(seed, (m, k))
            w = rnd(seed + 1, (k, n), 0.1)
            y = aimc_matmul_raw(x, w, levels, levels)
            yr = aimc_matmul_ref(x, w, levels, levels)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# LoRA kernel vs oracle
# ---------------------------------------------------------------------------


class TestLoraKernel:
    @pytest.mark.parametrize("m,k,r,n", [(1, 8, 1, 8), (16, 32, 4, 32), (200, 128, 8, 128), (300, 64, 16, 48)])
    def test_matches_ref(self, m, k, r, n):
        x, a, b = rnd(1, (m, k)), rnd(2, (k, r), 0.3), rnd(3, (r, n), 0.3)
        y = lora_matmul_raw(x, a, b, 2.0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(lora_matmul_ref(x, a, b, 2.0)), rtol=1e-4, atol=1e-5)

    def test_zero_b_gives_zero(self):
        x, a = rnd(4, (8, 16)), rnd(5, (16, 4))
        y = lora_matmul_raw(x, a, jnp.zeros((4, 8)), 2.0)
        assert float(jnp.max(jnp.abs(y))) == 0.0

    def test_gradients_match_dense(self):
        x, a, b = rnd(6, (8, 16)), rnd(7, (16, 4), 0.3), rnd(8, (4, 8), 0.3)

        def f_kernel(a_, b_):
            return jnp.sum(lora_matmul(x, a_, b_, 2.0) ** 2)

        def f_ref(a_, b_):
            return jnp.sum(lora_matmul_ref(x, a_, b_, 2.0) ** 2)

        gk = jax.grad(f_kernel, argnums=(0, 1))(a, b)
        gr = jax.grad(f_ref, argnums=(0, 1))(a, b)
        for k_, r_ in zip(gk, gr):
            np.testing.assert_allclose(np.asarray(k_), np.asarray(r_), rtol=1e-4, atol=1e-5)

    if HAVE_HYP:

        @settings(max_examples=20, deadline=None)
        @given(
            m=st.integers(1, 260),
            k=st.sampled_from([16, 64, 128]),
            r=st.sampled_from([1, 2, 4, 8, 16]),
            n=st.sampled_from([16, 48, 128]),
            seed=st.integers(0, 2**16),
        )
        def test_hypothesis_shapes(self, m, k, r, n, seed):
            x, a, b = rnd(seed, (m, k)), rnd(seed + 1, (k, r), 0.3), rnd(seed + 2, (r, n), 0.3)
            y = lora_matmul_raw(x, a, b, 0.5)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(lora_matmul_ref(x, a, b, 0.5)), rtol=1e-4, atol=1e-4
            )
