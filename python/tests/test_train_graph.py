"""L2 training-step graphs: losses decrease, the right tree is updated."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train_graph as TG
from compile.configs import VARIANTS

CFG = VARIANTS["tiny"]
DEC = VARIANTS["tiny_dec"]
KEY = jax.random.PRNGKey(0)


def flat(tree):
    return [a for _, a in M.flatten_params(tree)]


def make_state(cfg, loss, regime):
    step, meta_t, train_t = TG.make_step(cfg, loss, regime)
    fm = flat(M.init_meta(cfg, KEY))
    ft = flat(
        {"head": M.init_head(cfg, {"qa": "qa", "cls": "cls", "reg": "cls", "lm": "lm", "grpo": "lm"}[loss], KEY)}
        | ({"lora": M.init_lora(cfg, KEY)} if regime == "lora" else {"meta": M.init_meta(cfg, KEY)})
    )
    m = [jnp.zeros_like(a) for a in ft]
    v = [jnp.zeros_like(a) for a in ft]
    return jax.jit(step), fm, ft, m, v


def qa_batch(cfg, seed=0):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (cfg.train_batch, cfg.seq), 0, cfg.vocab)
    return (toks, jnp.zeros((cfg.train_batch,), jnp.int32), jnp.ones((cfg.train_batch,), jnp.int32))


HW = jnp.array([0.05, 3.0, 127.0, 127.0, 0.02], jnp.float32)
OPT = jnp.array([1e-2, 0.0, 1.0], jnp.float32)


class TestLoraStep:
    def test_loss_decreases(self):
        step, fm, ft, m, v = make_state(CFG, "qa", "lora")
        batch = qa_batch(CFG)
        losses = []
        opt = np.array(OPT)
        for i in range(12):
            opt[2] = i + 1
            ft, m, v, loss = step(fm, ft, m, v, batch, jax.random.PRNGKey(i), HW, jnp.asarray(opt))
            losses.append(float(loss))
        assert losses[-1] < losses[0]

    def test_meta_not_an_output(self):
        """AHWA-LoRA trains ONLY the lora+head tree."""
        step, fm, ft, m, v = make_state(CFG, "qa", "lora")
        out_t, out_m, out_v, _ = step(fm, ft, m, v, qa_batch(CFG), KEY, HW, OPT)
        assert len(out_t) == len(ft) and len(ft) < len(fm)

    def test_full_regime_updates_meta(self):
        step, fm, ft, m, v = make_state(CFG, "qa", "full")
        out_t, _, _, _ = step(fm, ft, m, v, qa_batch(CFG), KEY, HW, OPT)
        assert len(out_t) == len(ft) and len(ft) > len(fm)  # meta + head

    def test_trainable_params_actually_change(self):
        step, fm, ft, m, v = make_state(CFG, "qa", "lora")
        out_t, _, _, _ = step(fm, ft, m, v, qa_batch(CFG), KEY, HW, OPT)
        deltas = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(out_t, ft)]
        assert max(deltas) > 0


class TestLosses:
    def test_cls_and_reg(self):
        for loss in ("cls", "reg"):
            step, fm, ft, m, v = make_state(CFG, loss, "lora")
            toks = jax.random.randint(KEY, (CFG.train_batch, CFG.seq), 0, CFG.vocab)
            lab = (
                jnp.zeros((CFG.train_batch,), jnp.int32)
                if loss == "cls"
                else jnp.zeros((CFG.train_batch,), jnp.float32)
            )
            _, _, _, lv = step(fm, ft, m, v, (toks, lab), KEY, HW, OPT)
            assert np.isfinite(float(lv))

    def test_lm_mask_zero_positions_ignored(self):
        step, meta_t, train_t = TG.make_step(DEC, "lm", "lora")
        fm = flat(M.init_meta(DEC, KEY))
        ft = flat({"head": {}, "lora": M.init_lora(DEC, KEY)})
        m = [jnp.zeros_like(a) for a in ft]
        v = [jnp.zeros_like(a) for a in ft]
        toks = jax.random.randint(KEY, (DEC.train_batch, DEC.seq), 0, DEC.vocab)
        mask = jnp.zeros((DEC.train_batch, DEC.seq), jnp.float32)
        js = jax.jit(step)
        _, _, _, lv = js(fm, ft, m, v, (toks, mask), KEY, HW, OPT)
        assert float(lv) == 0.0  # no supervised positions -> zero loss

    def test_grpo_zero_advantage_is_noop_loss(self):
        step, _, _ = TG.make_step(DEC, "grpo", "lora")
        fm = flat(M.init_meta(DEC, KEY))
        ft = flat({"head": {}, "lora": M.init_lora(DEC, KEY)})
        m = [jnp.zeros_like(a) for a in ft]
        v = [jnp.zeros_like(a) for a in ft]
        G = 4
        toks = jax.random.randint(KEY, (G, DEC.seq), 0, DEC.vocab)
        mask = jnp.ones((G, DEC.seq), jnp.float32)
        adv = jnp.zeros((G,), jnp.float32)
        _, _, _, lv = jax.jit(step)(fm, ft, m, v, (toks, mask, adv), KEY, HW, OPT)
        assert float(lv) == 0.0

    def test_grpo_prefers_high_advantage(self):
        """After steps with +adv on sequence s, logp(s) increases."""
        step, _, _ = TG.make_step(DEC, "grpo", "lora")
        meta = M.init_meta(DEC, KEY)
        fm = flat(meta)
        lora0 = M.init_lora(DEC, KEY)
        ft = flat({"head": {}, "lora": lora0})
        m = [jnp.zeros_like(a) for a in ft]
        v = [jnp.zeros_like(a) for a in ft]
        G = 4
        toks = jax.random.randint(KEY, (G, DEC.seq), 0, DEC.vocab)
        mask = jnp.ones((G, DEC.seq), jnp.float32)
        adv = jnp.array([2.0, -1.0, -0.5, -0.5], jnp.float32)
        hw0 = jnp.array([0.0, 0.0, 0.0, 0.0, 0.0], jnp.float32)

        def seq_lp(lora_tree):
            logits = M.fwd_lm(DEC, meta, lora_tree, toks, KEY, M.default_hw())
            lp = jax.nn.log_softmax(logits[:, :-1], -1)
            tlp = jnp.take_along_axis(lp, toks[:, 1:][..., None], -1)[..., 0]
            return float(jnp.mean(tlp[0]))

        before = seq_lp(lora0)
        js = jax.jit(step)
        opt = np.array([5e-2, 0.0, 1.0])
        for i in range(8):
            opt[2] = i + 1
            ft, m, v, _ = js(fm, ft, m, v, (toks, mask, adv), jax.random.PRNGKey(i), hw0, jnp.asarray(opt))
        lora_after = M.unflatten_params({"head": {}, "lora": lora0}, list(ft))["lora"]
        assert seq_lp(lora_after) > before


class TestAdamW:
    def test_moves_toward_minimum(self):
        p = [jnp.array([4.0]), jnp.array([-3.0])]
        m = [jnp.zeros(1)] * 2
        v = [jnp.zeros(1)] * 2
        for t in range(1, 200):
            g = [2 * x for x in p]  # grad of x^2
            p, m, v = TG.adamw_update(p, g, m, v, jnp.float32(t), 0.1, 0.0)
        assert abs(float(p[0][0])) < 0.1 and abs(float(p[1][0])) < 0.1

    def test_grad_clipping_bounds_update(self):
        p = [jnp.array([0.0])]
        m = [jnp.zeros(1)]
        v = [jnp.zeros(1)]
        p2, _, _ = TG.adamw_update(p, [jnp.array([1e6])], m, v, jnp.float32(1), 0.1, 0.0)
        assert abs(float(p2[0][0])) < 0.2  # clipped to unit norm then adam-scaled

    def test_weight_decay_shrinks(self):
        p = [jnp.array([10.0])]
        m = [jnp.zeros(1)]
        v = [jnp.zeros(1)]
        p2, _, _ = TG.adamw_update(p, [jnp.zeros(1)], m, v, jnp.float32(1), 0.1, 0.5)
        assert float(p2[0][0]) < 10.0
