"""L2 model tests: shapes, noise semantics, LoRA identities, flattening."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import VARIANTS, lora_targets
from compile.layers import clip_channelwise, perturb_weight

CFG = VARIANTS["tiny"]
DEC = VARIANTS["tiny_dec"]
KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def meta():
    return M.init_meta(CFG, KEY)


@pytest.fixture(scope="module")
def lora():
    return M.init_lora(CFG, KEY)


def tokens(cfg, b=2, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, cfg.seq), 0, cfg.vocab)


class TestInit:
    def test_meta_inventory(self, meta):
        assert "emb_proj" in meta and "w_lm" not in meta  # LM head is decoder-only
        assert len(meta["layers"]) == CFG.n_layers
        for blk in meta["layers"]:
            for n in ("wq", "wk", "wv", "wo", "w1", "w2"):
                assert n in blk

    def test_decoder_has_no_emb_proj(self):
        m = M.init_meta(DEC, KEY)
        assert "emb_proj" not in m
        assert "w_lm" in m and "lm_ln_g" in m

    def test_lora_b_zero_init(self, lora):
        for blk in lora["layers"]:
            for n, v in blk.items():
                if n.endswith("_b"):
                    assert float(jnp.max(jnp.abs(v))) == 0.0

    @pytest.mark.parametrize("placement,n_per_block", [("all", 12), ("qkv", 6), ("ffn", 4), ("none", 0)])
    def test_placement(self, placement, n_per_block):
        lp = M.init_lora(CFG, KEY, placement=placement)
        assert all(len(blk) == n_per_block for blk in lp["layers"])

    @pytest.mark.parametrize("rank", [1, 2, 4, 8, 16])
    def test_rank_scales_params_linearly(self, rank):
        lp = M.init_lora(CFG, KEY, rank=rank)
        n = M.param_count(lp)
        lp1 = M.init_lora(CFG, KEY, rank=1)
        assert n == rank * M.param_count(lp1)


class TestNoiseModel:
    def test_perturb_amplitude(self):
        w = jax.random.normal(KEY, (64, 64))
        dw = perturb_weight(w, KEY, jnp.float32(0.1)) - w
        expected = 0.1 * float(jnp.max(jnp.abs(w)))
        assert 0.7 * expected < float(jnp.std(dw)) < 1.3 * expected

    def test_perturb_zero_level_is_identity(self):
        w = jax.random.normal(KEY, (16, 16))
        np.testing.assert_allclose(perturb_weight(w, KEY, jnp.float32(0.0)), w)

    def test_perturb_unbiased(self):
        w = jax.random.normal(KEY, (64, 64))
        draws = [perturb_weight(w, jax.random.PRNGKey(i), jnp.float32(0.1)) for i in range(64)]
        mean = jnp.mean(jnp.stack(draws), 0)
        assert float(jnp.max(jnp.abs(mean - w))) < 0.05 * float(jnp.max(jnp.abs(w)))

    def test_clip_channelwise(self):
        w = jax.random.normal(KEY, (128, 8)) * jnp.linspace(0.1, 2.0, 8)
        c = clip_channelwise(w, jnp.float32(1.0))
        std = np.asarray(jnp.std(w, axis=0))
        assert np.all(np.asarray(jnp.max(jnp.abs(c), axis=0)) <= std * 1.0 + 1e-5)

    def test_clip_disabled(self):
        w = jax.random.normal(KEY, (32, 4)) * 10
        np.testing.assert_allclose(clip_channelwise(w, jnp.float32(0.0)), w)


class TestForward:
    def test_qa_shapes(self, meta, lora):
        head = M.init_head(CFG, "qa", KEY)
        hw = M.default_hw()
        sl, el = M.fwd_qa(CFG, meta, lora, head, tokens(CFG), KEY, hw)
        assert sl.shape == (2, CFG.seq) and el.shape == (2, CFG.seq)

    def test_cls_shapes(self, meta, lora):
        head = M.init_head(CFG, "cls", KEY)
        logits = M.fwd_cls(CFG, meta, lora, head, tokens(CFG), KEY, M.default_hw())
        assert logits.shape == (2, CFG.n_cls)

    def test_lm_shapes(self):
        m = M.init_meta(DEC, KEY)
        lp = M.init_lora(DEC, KEY)
        logits = M.fwd_lm(DEC, m, lp, tokens(DEC), KEY, M.default_hw())
        assert logits.shape == (2, DEC.seq, DEC.vocab)

    def test_fresh_lora_is_identity(self, meta, lora):
        """B=0 init => adapted model == base model exactly."""
        head = M.init_head(CFG, "qa", KEY)
        none_lora = M.init_lora(CFG, KEY, placement="none")
        hw = M.default_hw()
        sl1, _ = M.fwd_qa(CFG, meta, lora, head, tokens(CFG), KEY, hw)
        sl2, _ = M.fwd_qa(CFG, meta, none_lora, head, tokens(CFG), KEY, hw)
        np.testing.assert_allclose(np.asarray(sl1), np.asarray(sl2), atol=1e-5)

    def test_noise_changes_output_and_key_reproduces(self, meta, lora):
        head = M.init_head(CFG, "qa", KEY)
        hw = M.default_hw(noise=0.067)
        a1, _ = M.fwd_qa(CFG, meta, lora, head, tokens(CFG), KEY, hw)
        a2, _ = M.fwd_qa(CFG, meta, lora, head, tokens(CFG), KEY, hw)
        b1, _ = M.fwd_qa(CFG, meta, lora, head, tokens(CFG), jax.random.PRNGKey(9), hw)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))
        assert float(jnp.max(jnp.abs(a1 - b1))) > 1e-4

    def test_causal_masking(self):
        """Changing a future token must not affect past decoder logits."""
        m = M.init_meta(DEC, KEY)
        lp = M.init_lora(DEC, KEY)
        hw = M.default_hw()
        t1 = tokens(DEC, 1)
        t2 = t1.at[0, -1].set((t1[0, -1] + 1) % DEC.vocab)
        l1 = M.fwd_lm(DEC, m, lp, t1, KEY, hw)
        l2 = M.fwd_lm(DEC, m, lp, t2, KEY, hw)
        np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5)


class TestFlattening:
    def test_roundtrip(self, meta):
        flat = M.flatten_params(meta)
        rebuilt = M.unflatten_params(meta, [a for _, a in flat])
        for (n1, a1), (n2, a2) in zip(flat, M.flatten_params(rebuilt)):
            assert n1 == n2
            np.testing.assert_allclose(np.asarray(a1), np.asarray(a2))

    def test_sorted_names(self, meta):
        names = [n for n, _ in M.flatten_params(meta)]
        assert names == sorted(names)

    def test_names_are_dotted_paths(self, meta):
        names = [n for n, _ in M.flatten_params(meta)]
        assert "layers.0.wq" in names and "tok_emb" in names

    def test_length_mismatch_raises(self, meta):
        flat = [a for _, a in M.flatten_params(meta)]
        with pytest.raises(ValueError):
            M.unflatten_params(meta, flat + [flat[0]])

    def test_param_count_tiny(self, meta):
        n = M.param_count(meta)
        assert n > 10_000  # sanity: all layers present
        lora = M.init_lora(CFG, KEY)
        assert M.param_count(lora) < 0.25 * n  # adapters are "lightweight"
