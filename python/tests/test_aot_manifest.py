"""AOT/manifest invariants: I/O ordering, ALTB container, HLO text form.

These tests lower only the tiny variants so they stay fast; the full
artifact build is exercised by `make artifacts` + the rust integration
tests.
"""

import json
import os
import struct
import tempfile

import jax
import numpy as np
import pytest

from compile import aot
from compile import model as M
from compile.configs import VARIANTS


class TestAltb:
    def test_roundtrip_layout(self):
        ts = [("b_name", np.arange(6, dtype=np.float32).reshape(2, 3)), ("a", np.zeros((1,), np.float32))]
        with tempfile.TemporaryDirectory() as d:
            p = os.path.join(d, "t.bin")
            aot.write_altb(p, ts)
            with open(p, "rb") as f:
                assert f.read(4) == b"ALTB"
                (n,) = struct.unpack("<I", f.read(4))
                assert n == 2
                (ln,) = struct.unpack("<H", f.read(2))
                assert f.read(ln) == b"b_name"
                (nd,) = struct.unpack("<B", f.read(1))
                dims = struct.unpack(f"<{nd}I", f.read(4 * nd))
                assert dims == (2, 3)
                data = np.frombuffer(f.read(24), np.float32)
                np.testing.assert_allclose(data, np.arange(6, dtype=np.float32))


class TestLowering:
    @pytest.fixture(scope="class")
    def tiny_fwd(self):
        cfg = VARIANTS["tiny"]
        return aot.lower_fwd(cfg, "qa")

    def test_hlo_is_text(self, tiny_fwd):
        hlo, entry, _ = tiny_fwd
        assert hlo.startswith("HloModule")
        assert "ENTRY" in hlo

    def test_input_order_meta_train_data_scalars(self, tiny_fwd):
        _, entry, _ = tiny_fwd
        roles = [i["role"] for i in entry["inputs"]]
        # canonical segment order
        segs = []
        for r in roles:
            if not segs or segs[-1] != r:
                segs.append(r)
        assert segs == ["meta", "train", "data", "key", "hw"]

    def test_meta_names_sorted(self, tiny_fwd):
        _, entry, _ = tiny_fwd
        metas = [i["name"] for i in entry["inputs"] if i["role"] == "meta"]
        assert metas == sorted(metas)

    def test_hlo_param_count_matches_manifest(self, tiny_fwd):
        hlo, entry, _ = tiny_fwd
        n_params = hlo.count("parameter(")
        assert n_params >= len(entry["inputs"])  # fusion params repeat; entry count lower-bounds

    def test_step_outputs_shape(self):
        cfg = VARIANTS["tiny"]
        hlo, entry, inits = aot.lower_step(cfg, "qa", "lora")
        n_train = sum(1 for i in entry["inputs"] if i["role"] == "train")
        n_out = len(entry["outputs"])
        assert n_out == 3 * n_train + 1  # train', m', v', loss
        assert entry["outputs"][-1]["role"] == "loss"

    def test_rank_changes_train_shapes(self):
        cfg = VARIANTS["tiny"]
        _, e1, _ = aot.lower_step(cfg, "qa", "lora", rank=1)
        _, e8, _ = aot.lower_step(cfg, "qa", "lora", rank=8)

        def lora_sizes(e):
            return sum(
                int(np.prod(i["shape"]))
                for i in e["inputs"]
                if i["role"] == "train" and i["name"].startswith("lora.")
            )

        assert lora_sizes(e8) == 8 * lora_sizes(e1)


class TestBuildPlan:
    def test_covers_every_experiment_surface(self):
        keys = {p["key"] for p in aot.build_plan()}
        # Table I / II / VI-VIII need lora+full steps on the workhorse
        assert "mobilebert_proxy/step_qa_lora" in keys
        assert "mobilebert_proxy/step_qa_full" in keys
        # Fig 2a rank sweep
        for r in (1, 2, 4, 16):
            assert f"mobilebert_proxy/step_qa_lora@r{r}" in keys
        # Fig 2b placement
        for pl in ("qkv", "ffn"):
            assert f"mobilebert_proxy/step_qa_lora@{pl}" in keys
        # Fig 3b scalability
        assert "bert_base_proxy/step_qa_lora" in keys and "bert_large_proxy/step_qa_lora" in keys
        # Tables IV/V/IX/X
        assert "llama_proxy/step_grpo_lora" in keys and "llama_proxy/step_lm_lora" in keys
        # GLUE (Table III)
        assert "mobilebert_proxy/step_cls_lora" in keys and "mobilebert_proxy/step_reg_lora" in keys

    def test_key_to_file_bijective_enough(self):
        keys = [p["key"] for p in aot.build_plan()]
        files = {aot.key_to_file(k) for k in keys}
        assert len(files) == len(keys)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")),
    reason="artifacts not built",
)
class TestBuiltManifest:
    def test_manifest_graphs_exist_on_disk(self):
        root = os.path.join(os.path.dirname(__file__), "../../artifacts")
        with open(os.path.join(root, "manifest.json")) as f:
            man = json.load(f)
        for k, g in man["graphs"].items():
            assert os.path.exists(os.path.join(root, g["file"])), k

    def test_variants_recorded(self):
        root = os.path.join(os.path.dirname(__file__), "../../artifacts")
        with open(os.path.join(root, "manifest.json")) as f:
            man = json.load(f)
        assert "mobilebert_proxy" in man["variants"]
        assert man["variants"]["mobilebert_proxy"]["d_model"] == 128
