#!/usr/bin/env bash
# Lint + test gate for the public API: run before every PR.
#
#   ./ci.sh                   # every stage, in order
#   ./ci.sh --stage <name>    # one stage: fmt | clippy | test | test-release |
#                             # features | bench-smoke | doc (CI fans these
#                             # out as jobs)
#   ./ci.sh --fix             # apply rustfmt instead of checking
#
# PJRT-backed integration tests self-skip when `artifacts/` has not
# been built; everything else (unit tests, channel-level serving tests,
# the virtual-clock drift-refresh tests) runs hermetically.
set -euo pipefail

cd "$(dirname "$0")"

# the cargo workspace may sit at the repo root or under rust/
if [[ -f Cargo.toml ]]; then
    :
elif [[ -f rust/Cargo.toml ]]; then
    cd rust
else
    echo "ci.sh: no Cargo.toml found at repo root or rust/" >&2
    exit 1
fi

# named group output: foldable groups on GitHub Actions, plain headers
# everywhere else, so failures are attributable at a glance
group() {
    if [[ -n "${GITHUB_ACTIONS:-}" ]]; then
        echo "::group::$1"
    else
        echo "== $1 =="
    fi
}
endgroup() {
    if [[ -n "${GITHUB_ACTIONS:-}" ]]; then
        echo "::endgroup::"
    fi
}

stage_fmt() {
    group fmt
    cargo fmt --all -- --check
    endgroup
}

stage_clippy() {
    group clippy
    cargo clippy --all-targets -- -D warnings
    endgroup
}

stage_test() {
    group test
    if ! cargo test -q; then
        # seed-failure triage, printed INTO the stage output so a red
        # matrix job explains itself without archaeology
        cat >&2 <<'EOF'
== test-stage triage ==
PJRT-backed integration tests self-skip when artifacts/ is missing, so
a failure here is in a HERMETIC suite (no engine, no wall clock):
  - unit tests                    cargo test -q --lib
  - scheduler/refresh e2e         cargo test -q --test refresh_sched_e2e
  - pool-coordination conformance cargo test -q --test coord_conformance
  - decode conformance            cargo test -q --test decode_conformance
  - adapter-cache conformance     cargo test -q --test cache_conformance
  - backend-HAL conformance       cargo test -q --test hal_conformance
    (includes the adaptive-rebalance/hysteresis property tests and the
    live span-migration suite on the routed SimPool virtual clock; the
    crossover gaps are MEASURED from the cost model at runtime, so a
    failure usually means a latency-model change moved a crossover, not
    a broken scheduler)
  - scheduler property tests      cargo test -q --test sched_properties
  - PCM property tests            cargo test -q --test pcm_properties
  - pipeline golden values        cargo test -q --test pipeline_golden
Property-test failures print a replay seed; re-run the one suite above
that failed rather than the whole stage. Concurrency stress tests (and
the multi-worker coord stress variant in coord_conformance.rs, the
8-worker long-sequence decode storm in decode_conformance.rs, and the
adapter-cache eviction storm in cache_conformance.rs) only run in the
test-release stage and cannot be the cause here.
EOF
        exit 1
    fi
    endgroup
}

# the pipeline-latency / scheduler model tests also run in release:
# debug_assert guards are compiled out and the hot numeric paths take
# their optimised shapes there, which is what production serves. The
# refresh/scheduler concurrency stress tests (tests/refresh_stress.rs),
# the multi-worker coordination stress variant
# (coord_conformance::coord_stress_many_tasks_many_workers — 8 workers
# x 16 tasks on the virtual clock), the long-sequence decode storm
# (decode_conformance::eight_worker_long_sequence_decode_stress — 8
# continuous-batching lanes crossing a shared hot-swap), and the
# adapter-cache eviction storm
# (cache_conformance::eviction_storm_holds_every_invariant — 128 tasks
# over 8 resident slots, 64k zipf requests, residency and accounting
# invariants asserted after every event) gate themselves on
# `cfg!(debug_assertions)` and therefore run ONLY in this stage,
# keeping the debug lane fast.
stage_test_release() {
    group test-release
    cargo test --release -q
    endgroup
}

# feature matrix for the serve-API surface: the lean build
# (--no-default-features) drops the digital-reference backend and must
# keep compiling AND keep its hermetic tests green — downstream users
# who disable default features get the PCM+PJRT-only HAL; all-features
# is the forward guard for any future additive feature. The default
# feature set is already covered by every other stage.
stage_features() {
    group "features: lean (--no-default-features)"
    cargo build --no-default-features
    cargo test -q --no-default-features
    endgroup
    group "features: all (--all-features)"
    cargo build --all-features
    endgroup
}

# compile (but do not run) every bench target: the benches are plain
# `fn main` programs on the in-tree harness and sit outside the normal
# test graph, so without this stage a benches/-only breakage (e.g. an
# API change under benches/hot_paths.rs) lands silently and is found by
# the next person profiling a regression.
stage_bench_smoke() {
    group bench-smoke
    cargo bench --no-run
    endgroup
}

stage_doc() {
    group doc
    RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
    endgroup
}

run_stage() {
    case "$1" in
        fmt)          stage_fmt ;;
        clippy)       stage_clippy ;;
        test)         stage_test ;;
        test-release) stage_test_release ;;
        features)     stage_features ;;
        bench-smoke)  stage_bench_smoke ;;
        doc)          stage_doc ;;
        *)
            echo "ci.sh: unknown stage '$1' (fmt|clippy|test|test-release|features|bench-smoke|doc)" >&2
            exit 2
            ;;
    esac
}

case "${1:-}" in
    --fix)
        # apply rustfmt, then still run the rest of the gate (the
        # pre-stage script behaved this way too)
        cargo fmt --all
        for s in clippy test test-release features bench-smoke doc; do
            run_stage "$s"
        done
        ;;
    --stage)
        run_stage "${2:?usage: ci.sh --stage <fmt|clippy|test|test-release|features|bench-smoke|doc>}"
        ;;
    "")
        for s in fmt clippy test test-release features bench-smoke doc; do
            run_stage "$s"
        done
        ;;
    *)
        echo "ci.sh: unknown flag '$1' (try --stage <name> or --fix)" >&2
        exit 2
        ;;
esac
