#!/usr/bin/env bash
# Lint + test gate for the public API: run before every PR.
#
#   ./ci.sh            # fmt --check, clippy -D warnings, tests
#   ./ci.sh --fix      # apply rustfmt instead of checking
#
# PJRT-backed integration tests self-skip when `artifacts/` has not
# been built; everything else (unit tests, channel-level serving tests)
# runs hermetically.
set -euo pipefail

cd "$(dirname "$0")"

# the cargo workspace may sit at the repo root or under rust/
if [[ -f Cargo.toml ]]; then
    :
elif [[ -f rust/Cargo.toml ]]; then
    cd rust
else
    echo "ci.sh: no Cargo.toml found at repo root or rust/" >&2
    exit 1
fi

if [[ "${1:-}" == "--fix" ]]; then
    cargo fmt --all
else
    cargo fmt --all -- --check
fi

cargo clippy --all-targets -- -D warnings
cargo test -q
# the pipeline-latency / scheduler model tests also run in release:
# debug_assert guards are compiled out and the hot numeric paths take
# their optimised shapes there, which is what production serves
cargo test --release -q
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet
