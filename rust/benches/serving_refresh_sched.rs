//! Stale-version request count with and without refresh-coupled
//! scheduling — hermetic (no artifacts), zero real sleeps: the whole
//! deploy → serve → drift → refresh → hot-swap cycle runs on the
//! virtual clock, through the SAME harness the conformance suite uses
//! (`tests/common/refresh_sim.rs`), just with a longer stream.
//!
//! The scenario is the regression the coupling exists to fix: a
//! sustained request stream crosses a modeled drift trigger mid-run.
//! Uncoupled, the scheduler batches blindly through the hot-swap and a
//! tail of requests is served at the stale, drift-degraded adapter
//! version; coupled, fills shrink and deadlines tighten ahead of the
//! trigger so the swap lands between batches. Reported per mode: stale
//! requests (the headline delta), batches spanning the swap, the
//! registry-swap → first-serve gap, coupling activity (Drain/Hold
//! decisions), and modeled per-request latency p50/p95 (what the
//! coupling costs).

#[path = "../tests/common/refresh_sim.rs"]
mod refresh_sim;

use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::stats;
use refresh_sim::{simulate, SimRun};

const N_REQUESTS: usize = 4000;

fn report(label: &str, run: &SimRun) {
    let p = |q: f64| stats::percentile(&run.lat_ns, q) / 1e3;
    println!(
        "{label}: {} stale request(s), {} batch(es) spanned the swap, \
         swap->serve gap {:.1} µs, {} drain / {} hold decision(s), \
         modeled latency p50 {:.2} µs p95 {:.2} µs",
        run.stale_after_trigger(),
        run.spanning_batches(),
        run.swap_gap().as_nanos() as f64 / 1e3,
        run.drains,
        run.holds,
        p(50.0),
        p(95.0),
    );
}

fn main() {
    let mut b = Bencher::with_budget(0.5);
    let coupled = b.once("sched/refresh wave, coupling ON", || simulate(true, N_REQUESTS));
    let uncoupled = b.once("sched/refresh wave, coupling OFF", || {
        simulate(false, N_REQUESTS)
    });
    assert_eq!(coupled.swap_version, 2, "exactly one hot-swap per run");
    assert_eq!(uncoupled.swap_version, 2);

    report("coupling OFF", &uncoupled);
    report("coupling ON ", &coupled);
    println!(
        "stale-request delta: {} -> {} ({} request(s) rescued from the \
         drift-degraded adapter)",
        uncoupled.stale_after_trigger(),
        coupled.stale_after_trigger(),
        uncoupled
            .stale_after_trigger()
            .saturating_sub(coupled.stale_after_trigger()),
    );
    assert_eq!(
        coupled.stale_after_trigger(),
        0,
        "coupling must eliminate stale service"
    );
    assert!(
        uncoupled.stale_after_trigger() > 0,
        "the baseline regression must be visible"
    );
}
