//! Stale-version request count with and without refresh-coupled
//! scheduling, plus coordinated-vs-uncoordinated multi-worker refresh
//! — hermetic (no artifacts), zero real sleeps: the whole
//! deploy → serve → drift → refresh → hot-swap cycle runs on the
//! virtual clock, through the SAME `SimPool` harness the conformance
//! suites use (`tests/common/refresh_sim.rs`), just with longer
//! streams.
//!
//! Scenario 1 (single worker) is the regression the coupling exists to
//! fix: a sustained request stream crosses a modeled drift trigger
//! mid-run. Uncoupled, the scheduler batches blindly through the
//! hot-swap and a tail of requests is served at the stale,
//! drift-degraded adapter version; coupled, fills shrink and deadlines
//! tighten ahead of the trigger so the swap lands between batches.
//!
//! Scenario 2 (4 workers × 4 tasks sharing one tolerance) is the
//! correlated-stall failure the pool coordinator exists to fix: with
//! every worker coupling to the one refresh runner independently, all
//! shards enter their hold windows at once (`concurrent_holds_peak` ==
//! worker count) and the serialized refits stretch tail latency; the
//! coordinator staggers the triggers (peak == `max_concurrent_holds`)
//! and adapts window/hold from observed swap gaps and measured refit
//! budgets. Reported per mode: hold-concurrency peak, worst stagger
//! shift, and the modeled per-request p50/p99 latency delta.

#[path = "../tests/common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::atomic::Ordering;

use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::stats;
use refresh_sim::{simulate, CoordGeom, SimPool, SimRun};

const N_REQUESTS: usize = 4000;

/// 4-worker scenario (same scale-free geometry as
/// tests/coord_conformance.rs, longer stream).
const POOL_TASKS: [&str; 4] = ["t0", "t1", "t2", "t3"];
const POOL_ROUNDS: usize = 3000;

fn report(label: &str, run: &SimRun) {
    let p = |q: f64| stats::percentile(&run.lat_ns, q) / 1e3;
    println!(
        "{label}: {} stale request(s), {} batch(es) spanned the swap, \
         swap->serve gap {:.1} µs, {} drain / {} hold decision(s), \
         modeled latency p50 {:.2} µs p95 {:.2} µs",
        run.stale_after_trigger(),
        run.spanning_batches(),
        run.swap_gap().as_nanos() as f64 / 1e3,
        run.drains,
        run.holds,
        p(50.0),
        p(95.0),
    );
}

fn report_pool(label: &str, pool: &SimPool) {
    let p = |q: f64| stats::percentile(&pool.lat_ns, q) / 1e3;
    println!(
        "{label}: holds_peak={} (observed {}), stagger_shift {:.1} µs, \
         {} swap(s), {} hold decision(s), modeled latency p50 {:.2} µs p99 {:.2} µs",
        pool.metrics.concurrent_holds_peak.load(Ordering::Relaxed),
        pool.max_holding,
        pool.metrics.stagger_shift_ns.load(Ordering::Relaxed) as f64 / 1e3,
        pool.swaps.len(),
        pool.holds,
        p(50.0),
        p(99.0),
    );
}

fn main() {
    let mut b = Bencher::with_budget(0.5);

    // -- scenario 1: single worker, coupling ON vs OFF -----------------
    let coupled = b.once("sched/refresh wave, coupling ON", || simulate(true, N_REQUESTS));
    let uncoupled = b.once("sched/refresh wave, coupling OFF", || {
        simulate(false, N_REQUESTS)
    });
    assert_eq!(coupled.swap_version, 2, "exactly one hot-swap per run");
    assert_eq!(uncoupled.swap_version, 2);

    report("coupling OFF", &uncoupled);
    report("coupling ON ", &coupled);
    println!(
        "stale-request delta: {} -> {} ({} request(s) rescued from the \
         drift-degraded adapter)",
        uncoupled.stale_after_trigger(),
        coupled.stale_after_trigger(),
        uncoupled
            .stale_after_trigger()
            .saturating_sub(coupled.stale_after_trigger()),
    );
    assert_eq!(
        coupled.stale_after_trigger(),
        0,
        "coupling must eliminate stale service"
    );
    assert!(
        uncoupled.stale_after_trigger() > 0,
        "the baseline regression must be visible"
    );

    // -- scenario 2: 4 workers × 4 tasks, coordinator ON vs OFF --------
    let geom = CoordGeom::derive();
    let coordinated = b.once("pool refresh, coordinator ON", || {
        let mut p = geom.pool(4, &POOL_TASKS, true, 1);
        p.run_rounds(POOL_ROUNDS, geom.ia);
        p.flush(geom.ia);
        p
    });
    let correlated = b.once("pool refresh, coordinator OFF", || {
        let mut p = geom.pool(4, &POOL_TASKS, false, 1);
        p.run_rounds(POOL_ROUNDS, geom.ia);
        p.flush(geom.ia);
        p
    });

    report_pool("coordinator OFF", &correlated);
    report_pool("coordinator ON ", &coordinated);
    let p99 = |p: &SimPool| stats::percentile(&p.lat_ns, 99.0) / 1e3;
    println!(
        "concurrent-holds peak: {} -> {}; modeled p99 latency: {:.2} µs -> {:.2} µs \
         ({:+.2} µs delta from de-correlating the stalls)",
        correlated.max_holding,
        coordinated.max_holding,
        p99(&correlated),
        p99(&coordinated),
        p99(&coordinated) - p99(&correlated),
    );
    assert_eq!(
        correlated.max_holding,
        POOL_TASKS.len(),
        "the uncoordinated pool must exhibit the correlated stall"
    );
    assert!(
        coordinated.max_holding <= 1,
        "the coordinator must bound hold concurrency at max_concurrent_holds"
    );
}
