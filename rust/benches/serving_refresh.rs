//! Request-latency impact of a background drift refresh + hot-swap
//! under sustained load — hermetic (no artifacts), zero real sleeps:
//! the serving scenario runs entirely on the virtual clock.
//!
//! Two measurements:
//!
//! 1. **Hot-path contention.** The only cost a refresh can inflict on a
//!    request thread is the registry read racing the swap's write lock:
//!    `snapshot()` is timed quiescent vs under a redeploy storm.
//! 2. **Virtual-clock serving scenario.** A fixed-cadence request
//!    stream drives the pipeline-aware scheduler; a drift refresh
//!    triggers mid-run and hot-swaps the adapter. Per-request modeled
//!    latency (queue wait + modeled batch service) is reported with the
//!    refresh on vs off — background refresh must not move the
//!    distribution — plus the wall cost of the `tick()` that performs
//!    the refit + swap.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use ahwa_lora::model::params::{ParamStore, Tensor};
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::serve::batcher::Batcher;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::sched::Decision;
use ahwa_lora::serve::{
    BatchScheduler, Clock, DecayModel, FnRefitter, Metrics, Refit, RefreshConfig, RefreshRunner,
    SchedConfig, VirtualClock,
};
use ahwa_lora::util::bench::{black_box, Bencher};
use ahwa_lora::util::stats;

const N_REQUESTS: usize = 4000;
const MAX_BATCH: usize = 8;

fn adapter(tag: f32) -> ParamStore {
    ParamStore::from_tensors(vec![Tensor {
        name: "lora.a".to_string(),
        shape: vec![64],
        data: vec![tag; 64],
    }])
}

/// Run the sustained-load scenario; returns per-request modeled latency
/// samples (ns) and the number of refreshes performed.
fn simulate(with_refresh: bool) -> (Vec<f64>, u64) {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy("task", adapter(1.0));

    let metrics = Arc::new(Metrics::default());
    let cfg = RefreshConfig::new(
        DecayModel::analytic(PcmModel::default()),
        Arc::new(FnRefitter(
            |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
                Ok(Refit { params: adapter(2.0), steps: budget })
            },
        )),
    )
    .tolerance(0.05)
    .step_budget(32);
    let mut runner = RefreshRunner::new(
        cfg,
        registry.clone(),
        Arc::new(ParamStore::default()),
        metrics.clone(),
    );
    runner.track_deployed(clock.now());
    let trigger_secs = runner.policy().trigger_age_secs("task").unwrap();

    let max_wait = Duration::from_millis(5);
    let mut sched = BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(320),
        MAX_BATCH,
        max_wait,
    );
    let mut batcher: Batcher<Instant> =
        Batcher::with_clock(MAX_BATCH, max_wait, clock.clone() as Arc<dyn Clock>);

    // cadence that makes the modeled-optimal fill 4 (between per-request
    // cost at fills 3 and 4)
    let per = |b: usize| sched.modeled_batch_ns(b) / b as f64;
    let ia = Duration::from_nanos(((per(3) + per(4)) / 2.0).round() as u64);

    // position the run so the drift threshold is crossed halfway through
    let half_span = ia * (N_REQUESTS as u32 / 2);
    clock.advance(Duration::from_secs_f64(trigger_secs) - half_span);

    let mut lat_ns: Vec<f64> = Vec::with_capacity(N_REQUESTS);
    let drain = |batcher: &mut Batcher<Instant>, sched: &BatchScheduler, lat: &mut Vec<f64>| {
        loop {
            let now = clock.now();
            let Decision::Close { task, fill } = sched.pick(batcher, now) else {
                break;
            };
            let reqs = batcher.pop_task(&task, fill).expect("ready batch");
            // the request path's only registry touch
            black_box(registry.snapshot(&task).expect("deployed"));
            let service = sched.modeled_batch(reqs.len());
            for enqueued in reqs {
                let done = now + service;
                lat.push(done.saturating_duration_since(enqueued).as_nanos() as f64);
            }
        }
    };

    for i in 0..N_REQUESTS {
        clock.advance(ia);
        let now = clock.now();
        sched.observe_arrival("task", now);
        batcher.push("task", now);
        drain(&mut batcher, &sched, &mut lat_ns);
        // the production worker evaluates the policy on its check cadence
        if with_refresh && i % 64 == 0 {
            runner.tick(clock.now());
        }
    }
    // flush the tail past its deadline
    clock.advance(max_wait + Duration::from_millis(1));
    drain(&mut batcher, &sched, &mut lat_ns);

    assert_eq!(lat_ns.len(), N_REQUESTS, "every request served");
    (lat_ns, metrics.refreshes.load(Ordering::Relaxed))
}

fn main() {
    let mut b = Bencher::with_budget(0.5);

    // 1. hot-path contention: snapshot() quiescent vs under a deploy storm
    let quiet = SharedRegistry::new();
    quiet.deploy("t", adapter(0.0));
    b.bench("refresh/snapshot quiescent", || {
        black_box(quiet.snapshot("t"));
    });

    let reg = SharedRegistry::new();
    reg.deploy("t", adapter(0.0));
    let stop = Arc::new(AtomicBool::new(false));
    let writer = {
        let (reg, stop) = (reg.clone(), stop.clone());
        std::thread::spawn(move || {
            let mut i = 0f32;
            while !stop.load(Ordering::Acquire) {
                i += 1.0;
                reg.deploy("t", adapter(i));
            }
        })
    };
    b.bench("refresh/snapshot under redeploy storm", || {
        black_box(reg.snapshot("t"));
    });
    stop.store(true, Ordering::Release);
    writer.join().unwrap();

    // 2. virtual-clock scenario: sustained load across a refresh
    let (without, r0) = b.once("serve/virtual wave, refresh OFF", || simulate(false));
    assert_eq!(r0, 0);
    let (with, r1) = b.once("serve/virtual wave, refresh ON", || simulate(true));
    assert!(r1 >= 1, "the drift refresh must have triggered mid-run");

    let p = |xs: &[f64], q: f64| stats::percentile(xs, q) / 1e3;
    println!(
        "modeled request latency, refresh OFF: p50 {:.2} µs  p95 {:.2} µs",
        p(&without, 50.0),
        p(&without, 95.0),
    );
    println!(
        "modeled request latency, refresh ON ({r1} refresh): p50 {:.2} µs  p95 {:.2} µs",
        p(&with, 50.0),
        p(&with, 95.0),
    );
    println!(
        "p95 delta from background refresh: {:+.2} µs (swap is O(pointer) off the hot path)",
        p(&with, 95.0) - p(&without, 95.0),
    );
}
