//! Modeled-vs-measured serving latency under the multi-task wave.
//!
//! Drives the pipeline-aware scheduler (`serve::sched`) end to end: a
//! mixed GLUE request wave through the sharded engine pool, with the
//! AIMC/PMCA cost model's predicted batch latency reported next to the
//! measured wall time (the model predicts on-target hardware time, so
//! on the simulation host the ratio is the point of the report, not a
//! match). Requires `make artifacts`; skips gracefully if missing.

use std::time::Duration;

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest, Role};
use ahwa_lora::data::glue::{GlueGen, GlueTask};
use ahwa_lora::model::checkpoint;
use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{submit_wave, BatchScheduler, SchedConfig, Server};
use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::rng::Pcg64;

const WAVE: usize = 96;
const WORKERS: usize = 2;
const MAX_BATCH: usize = 8;

fn main() -> anyhow::Result<()> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first; skipping");
        return Ok(());
    }
    let manifest = Manifest::load(default_artifacts_dir())?;
    let v = manifest.variant("tiny")?.clone();
    let meta = checkpoint::load(manifest.init_path("tiny.meta"))?;
    let adapter = checkpoint::load(manifest.init_path("tiny.step_cls_lora.train"))?;

    let registry = SharedRegistry::new();
    let tasks = [GlueTask::Sst2, GlueTask::Qnli, GlueTask::Cola];
    for t in tasks {
        registry.deploy(t.adapter_key(), adapter.clone());
    }

    // seq resolved from the serving graph, exactly as the builder does
    let graph_seq = manifest
        .graph("tiny/fwd_cls")?
        .inputs_with_role(Role::Data)
        .next()
        .filter(|io| io.shape.len() == 2)
        .map(|io| io.shape[1])
        .unwrap_or(v.seq);
    let sched = SchedConfig::for_layer(v.d_model, v.d_model, v.rank);
    let server = Server::builder("tiny")
        .manifest(manifest)
        .workers(WORKERS)
        .max_batch(MAX_BATCH)
        .scheduler(sched)
        .build(meta, registry)?;
    let client = server.client();

    let mut rng = Pcg64::new(42);
    let jobs: Vec<(String, Vec<i32>)> = (0..WAVE)
        .map(|i| {
            let task = tasks[i % tasks.len()];
            let gen = GlueGen::new(task, v.vocab, v.seq);
            let (tokens, _, _) = gen.example(&mut rng);
            (task.adapter_key().to_string(), tokens)
        })
        .collect();

    // the model's prediction for the whole wave: full batches at the
    // committed token parallelism, split across the worker shards
    let model = BatchScheduler::new(sched.seq(graph_seq), MAX_BATCH, Duration::from_millis(5));
    let batches_per_worker = WAVE.div_ceil(MAX_BATCH * WORKERS) as f64;
    let wave_model_ns = model.modeled_batch_ns(MAX_BATCH) * batches_per_worker;

    let mut b = Bencher::with_budget(1.0);
    println!(
        "== serving wave, pipeline-aware sched (t_opt={} for {}x{} rank {}) ==",
        model.t_opt(),
        v.d_model,
        v.d_model,
        v.rank
    );
    let responses = b.once_modeled(
        &format!("serve/multi-task wave {WAVE} reqs"),
        wave_model_ns,
        || submit_wave(&client, &jobs),
    )?;
    assert_eq!(responses.len(), WAVE, "every request must resolve");

    let agg = server.metrics();
    println!(
        "batch latency: modeled p50 {:.3} ms vs measured p50 {:.3} ms (batch_mean {:.1})",
        agg.modeled_p50_ms, agg.lat_p50_ms, agg.batch_mean
    );
    println!("{}", server.metrics_report());
    server.shutdown()?;
    if let Err(e) = b.write_json("serving_sched") {
        eprintln!("could not write BENCH_serving_sched.json: {e}");
    }
    Ok(())
}
