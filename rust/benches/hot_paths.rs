//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf, L3 targets).
//!
//! The drift-evaluation inner loop regenerates every analog weight per
//! trial (PCG normals → drift exp → read noise → compensation), so the
//! PCM pipeline throughput bounds the whole evaluation harness; the
//! batcher/JSON/quant paths bound the serving coordinator.

use std::time::Duration;

use ahwa_lora::aimc::mapping::program_tensor;
use ahwa_lora::aimc::quant;
use ahwa_lora::pcm::{read_tensor, PcmModel};
use ahwa_lora::runtime::pack::PaddedChunks;
use ahwa_lora::runtime::PrepackedBuf;
use ahwa_lora::serve::batcher::Batcher;
use ahwa_lora::serve::sched::{BatchScheduler, SchedConfig};
use ahwa_lora::util::bench::{black_box, Bencher};
use ahwa_lora::util::json::Value;
use ahwa_lora::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::with_budget(1.5);
    println!("== hot paths ==");

    // RNG: the substrate of every stochastic device model
    let mut rng = Pcg64::new(1);
    let mut buf = vec![0f32; 1 << 16];
    b.bench_items("rng/fill_normal 64k", Some(buf.len() as u64), || {
        rng.fill_normal(&mut buf, 0.0, 1.0);
        black_box(buf[0]);
    });

    // PCM: program once / read per (drift time x trial) — the eval hot path
    let model = PcmModel::default();
    let mut w = vec![0f32; 128 * 128];
    rng.fill_normal(&mut w, 0.0, 0.05);
    b.bench_items("pcm/program_tensor 128x128", Some((128 * 128) as u64), || {
        black_box(program_tensor(&model, &w, 128, 128, 3.0, &mut rng));
    });
    let pt = program_tensor(&model, &w, 128, 128, 3.0, &mut rng);
    b.bench_items("pcm/read_tensor 128x128 @1y", Some((128 * 128) as u64), || {
        black_box(read_tensor(&model, &pt, 31_536_000.0, true, &mut rng));
    });

    // quantizer sweep (ADC model)
    let mut q = vec![0f32; 4096];
    rng.fill_normal(&mut q, 0.0, 1.0);
    b.bench_items("aimc/quant_block 4k @8bit", Some(4096), || {
        let mut v = q.clone();
        quant::quant_block(&mut v, 127.0);
        black_box(v[0]);
    });

    // serving batcher ops
    b.bench("serve/batcher push+pop (8 tasks)", || {
        let mut batcher: Batcher<u32> = Batcher::new(8, std::time::Duration::from_millis(0));
        for i in 0..64u32 {
            batcher.push(["a", "b", "c", "d", "e", "f", "g", "h"][(i % 8) as usize], i);
        }
        while batcher.pop_ready(std::time::Instant::now()).is_some() {}
        black_box(batcher.pending());
    });

    // manifest-scale JSON parse
    let manifest_path = ahwa_lora::config::manifest::default_artifacts_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        b.bench_items("json/parse manifest", Some(text.len() as u64), || {
            black_box(Value::parse(&text).unwrap());
        });
    }

    // Host-side batch packing on the scheduler's committed fills: the
    // padded reference path re-allocates a chunk buffer and zeroes the
    // tail every batch, the compile pipeline's prepacked buffer zeroes
    // the tail once at build and head-copies per batch, and fill ==
    // graph batch is a pure pass-through (no host work at all).
    let sched = BatchScheduler::new(
        SchedConfig::for_layer(128, 128, 8).seq(320),
        8,
        Duration::from_millis(5),
    );
    let fills = sched.committed_fills();
    println!("committed fills (per-request frontier of the cost table): {fills:?}");
    let (batch, seq) = (8usize, 320usize);
    let tokens = vec![7i32; batch * seq];
    for &f in &fills {
        let want = &tokens[..f * seq];
        if f == batch {
            b.bench_items(&format!("pack/pass-through fill={f}/{batch}"), Some(f as u64), || {
                black_box(want);
            });
            continue;
        }
        b.bench_items(&format!("pack/padded fill={f}/{batch}"), Some(f as u64), || {
            let mut chunks = PaddedChunks::new(want, batch, seq);
            let (chunk, take, _) = chunks.next_chunk().unwrap();
            black_box((chunk[0], take));
        });
        let mut pre = PrepackedBuf::new(f, batch, seq);
        b.bench_items(&format!("pack/prepacked fill={f}/{batch}"), Some(f as u64), || {
            black_box(pre.pack(want).unwrap()[0]);
        });
    }

    // End-to-end forward through real PJRT executables, padded vs
    // shape-specialized, per committed fill (needs built artifacts).
    if let Err(e) = bench_pjrt_forward(&mut b, &fills) {
        eprintln!("skipping PJRT forward benches: {e:#}");
    }

    if let Err(e) = b.write_json("hot_paths") {
        eprintln!("could not write BENCH_hot_paths.json: {e}");
    }
    println!("\nall hot-path benches done");
}

/// Per-request forward latency on the committed fills: the padded
/// reference path (an unspecialized pipeline, which falls back to the
/// max-shape chunk walk) against the AOT-specialized pipeline, both
/// through the same `cls_logits` entry point so the comparison is the
/// lowering alone. The item count is the fill, so the reported
/// throughput is requests/second and `mean_ns / fill` is the
/// per-request latency ISSUE acceptance asks for.
fn bench_pjrt_forward(b: &mut Bencher, fills: &[usize]) -> anyhow::Result<()> {
    use ahwa_lora::config::manifest::{Manifest, Role};
    use ahwa_lora::model::params::ParamStore;
    use ahwa_lora::runtime::FwdPipeline;

    let dir = ahwa_lora::config::manifest::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not built at {}", dir.display());
    }
    let manifest = Manifest::load(&dir)?;
    let key = manifest
        .graphs
        .values()
        .find(|g| g.kind == "fwd_cls")
        .map(|g| g.key.clone())
        .ok_or_else(|| anyhow::anyhow!("no fwd_cls graph in the manifest"))?;

    let padded = FwdPipeline::compile(manifest.clone(), &key)?;
    let mut specialized = FwdPipeline::compile(manifest, &key)?;
    specialized.specialize(fills)?;

    let spec = &padded.base().spec;
    let meta = ParamStore::zeros_like_role(spec, Role::Meta);
    let train = ParamStore::zeros_like_role(spec, Role::Train);
    let (batch, seq) = (padded.ir().batch, padded.ir().seq);
    let hw = [0.0f32, 3.0, 127.0, 127.0, 0.04];

    for &f in fills.iter().filter(|&&f| f > 0 && f <= batch) {
        let tokens = vec![11i32; f * seq];
        b.bench_items(&format!("fwd/padded fill={f}/{batch}"), Some(f as u64), || {
            black_box(padded.cls_logits(&meta, &train, &tokens, hw, 42).unwrap());
        });
        b.bench_items(&format!("fwd/specialized fill={f}/{batch}"), Some(f as u64), || {
            black_box(specialized.cls_logits(&meta, &train, &tokens, hw, 42).unwrap());
        });
    }
    Ok(())
}
