//! Hot-path micro-benchmarks (EXPERIMENTS.md §Perf, L3 targets).
//!
//! The drift-evaluation inner loop regenerates every analog weight per
//! trial (PCG normals → drift exp → read noise → compensation), so the
//! PCM pipeline throughput bounds the whole evaluation harness; the
//! batcher/JSON/quant paths bound the serving coordinator.

use ahwa_lora::aimc::mapping::program_tensor;
use ahwa_lora::aimc::quant;
use ahwa_lora::pcm::{read_tensor, PcmModel};
use ahwa_lora::serve::batcher::Batcher;
use ahwa_lora::util::bench::{black_box, Bencher};
use ahwa_lora::util::json::Value;
use ahwa_lora::util::rng::Pcg64;

fn main() {
    let mut b = Bencher::with_budget(1.5);
    println!("== hot paths ==");

    // RNG: the substrate of every stochastic device model
    let mut rng = Pcg64::new(1);
    let mut buf = vec![0f32; 1 << 16];
    b.bench_items("rng/fill_normal 64k", Some(buf.len() as u64), || {
        rng.fill_normal(&mut buf, 0.0, 1.0);
        black_box(buf[0]);
    });

    // PCM: program once / read per (drift time x trial) — the eval hot path
    let model = PcmModel::default();
    let mut w = vec![0f32; 128 * 128];
    rng.fill_normal(&mut w, 0.0, 0.05);
    b.bench_items("pcm/program_tensor 128x128", Some((128 * 128) as u64), || {
        black_box(program_tensor(&model, &w, 128, 128, 3.0, &mut rng));
    });
    let pt = program_tensor(&model, &w, 128, 128, 3.0, &mut rng);
    b.bench_items("pcm/read_tensor 128x128 @1y", Some((128 * 128) as u64), || {
        black_box(read_tensor(&model, &pt, 31_536_000.0, true, &mut rng));
    });

    // quantizer sweep (ADC model)
    let mut q = vec![0f32; 4096];
    rng.fill_normal(&mut q, 0.0, 1.0);
    b.bench_items("aimc/quant_block 4k @8bit", Some(4096), || {
        let mut v = q.clone();
        quant::quant_block(&mut v, 127.0);
        black_box(v[0]);
    });

    // serving batcher ops
    b.bench("serve/batcher push+pop (8 tasks)", || {
        let mut batcher: Batcher<u32> = Batcher::new(8, std::time::Duration::from_millis(0));
        for i in 0..64u32 {
            batcher.push(["a", "b", "c", "d", "e", "f", "g", "h"][(i % 8) as usize], i);
        }
        while batcher.pop_ready(std::time::Instant::now()).is_some() {}
        black_box(batcher.pending());
    });

    // manifest-scale JSON parse
    let manifest_path = ahwa_lora::config::manifest::default_artifacts_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        b.bench_items("json/parse manifest", Some(text.len() as u64), || {
            black_box(Value::parse(&text).unwrap());
        });
    }

    println!("\nall hot-path benches done");
}
