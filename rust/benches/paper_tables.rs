//! Benches regenerating the paper's TABLES' end-to-end hot paths:
//!
//! * Table I/VI–VIII — optimizer-step latency, AHWA-LoRA vs full AHWA
//!   (the >15× trainable-parameter gap shows up as step-time and
//!   state-transfer cost),
//! * Table II — parameter/memory accounting (exact counts, printed),
//! * Table III — serving throughput with adapter hot-swaps and drift
//!   evaluation trial latency.
//!
//! Requires `make artifacts`. Skips gracefully if missing.

use ahwa_lora::config::manifest::{default_artifacts_dir, Role};
use ahwa_lora::config::run::TrainConfig;
use ahwa_lora::data::squad::SquadTask;
use ahwa_lora::eval::drift_eval::{pcm_eval_hw, AnalogDeployment, QaEvalSet};
use ahwa_lora::model::checkpoint;
use ahwa_lora::model::params::ParamStore;
use ahwa_lora::pcm::PcmModel;
use ahwa_lora::runtime::Engine;
use ahwa_lora::train::memory::{graph_param_counts, training_memory, MemoryModel};
use ahwa_lora::train::{OwnedArg, OwnedBatch, Trainer};
use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first; skipping");
        return Ok(());
    }
    let engine = Engine::from_artifacts()?;
    let variant = "mobilebert_proxy";
    let v = engine.manifest.variant(variant)?.clone();
    let task = SquadTask::new(v.vocab, v.seq);
    let mut b = Bencher::with_budget(5.0);

    // ---- Table I hot path: one optimizer step, LoRA vs full ----------
    println!("== Table I/II counterpart — optimizer-step latency ==");
    let meta = checkpoint::load(engine.manifest.init_path(&format!("{variant}.meta")))?;
    for (label, graph_key, use_meta) in [
        ("step/ahwa-lora", format!("{variant}/step_qa_lora"), true),
        ("step/full-ahwa", format!("{variant}/step_qa_full"), false),
    ] {
        let train0 = checkpoint::load(
            engine
                .manifest
                .init_path(&format!("{}.train", graph_key.replace('/', "."))),
        )?;
        let m = if use_meta { meta.clone() } else { ParamStore::default() };
        let cfg = TrainConfig {
            log_every: 0,
            ..Default::default()
        };
        let mut trainer = Trainer::new(&engine, &graph_key, m, train0, cfg)?;
        let mut rng = Pcg64::new(7);
        let batch = task.batch(v.train_batch, &mut rng);
        let owned = OwnedBatch(vec![
            OwnedArg::I32(batch.tokens),
            OwnedArg::I32(batch.starts),
            OwnedArg::I32(batch.ends),
        ]);
        // warm compile happens on first call inside bench warmup
        b.bench(label, || {
            trainer.step(&owned.args()).unwrap();
        });
    }

    // ---- Table II: exact counts + analytic memory ---------------------
    println!("\n== Table II — exact parameter accounting ==");
    let mm = MemoryModel {
        batch: 32,
        seq: v.seq,
        d_model: v.d_model,
        d_ff: v.d_ff,
        n_layers: v.n_layers,
        act_tensors_per_layer: 6.0,
    };
    for key in [
        format!("{variant}/step_qa_full"),
        format!("{variant}/step_qa_lora"),
        format!("{variant}/step_qa_lora@r1"),
        format!("{variant}/step_qa_lora@r16"),
    ] {
        let spec = engine.manifest.graph(&key)?;
        let (n_total, n_map, n_train) = graph_param_counts(spec);
        let mem = training_memory(&mm, n_total, n_map, n_train);
        println!(
            "  {key:<40} trainable {:>9}  mem {:.3} GB",
            n_train,
            mem.total_gb()
        );
    }

    // ---- Table I/III drift-eval trial latency --------------------------
    println!("\n== drift-evaluation trial hot path ==");
    let fwd = engine.load(&format!("{variant}/fwd_qa"))?;
    let train0 = checkpoint::load(engine.manifest.init_path(&format!("{variant}.step_qa_lora.train")))?;
    let eval = QaEvalSet::generate(&task, 64, 3);
    let mut rng = Pcg64::new(5);
    let dep = AnalogDeployment::program(meta.clone(), PcmModel::default(), 3.0, &mut rng);
    b.bench("pcm/meta_at 1y (all layers)", || {
        let _ = dep.meta_at(31_536_000.0, true, &mut rng);
    });
    let meta_1y = dep.meta_at(31_536_000.0, true, &mut rng);
    b.bench_items("eval/qa 64 examples", Some(64), || {
        eval.score(&fwd, &meta_1y, &train0, pcm_eval_hw(127.0, 127.0, 0.04), 3)
            .unwrap();
    });

    // ---- Table III serving hot path ------------------------------------
    println!("\n== Table III counterpart — adapter swap cost ==");
    let spec = engine.manifest.graph(&format!("{variant}/step_cls_lora"))?;
    println!(
        "  adapter set: {:.3} M params -> swap = clone of that store only",
        spec.param_count(Role::Train) as f64 / 1e6
    );
    let adapter = checkpoint::load(engine.manifest.init_path(&format!("{variant}.step_cls_lora.train")))?;
    b.bench("serve/adapter clone (hot-swap cost)", || {
        let _ = std::hint::black_box(adapter.clone());
    });

    println!("\npaper_tables benches done");
    Ok(())
}
