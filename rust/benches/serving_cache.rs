//! Adapter capacity tier: cold-start latency and hit rate with the
//! predictive prefetcher ON vs OFF — hermetic (no artifacts), zero
//! real sleeps: the demand traces run on the virtual clock through the
//! SAME `CacheSim` harness the conformance suite uses
//! (`tests/common/refresh_sim.rs`), just with longer traces.
//!
//! Scenario 1 (periodic) is the regression the prefetcher exists to
//! fix: 16 tasks on a strict period over 8 resident slots. Plain LRU
//! evicts every adapter ~half a period before its next use, so steady
//! state is a 100% demand-miss thrash; the arrival-EWMA predictor sees
//! every arrival coming a full horizon out and pages the adapter in
//! before the request lands.
//!
//! Scenario 2 (zipf) is the realistic many-tenant mix: a hot head the
//! LRU keeps resident regardless, plus a long cold tail. Prefetch wins
//! less here — the interesting number is that it does not LOSE (no
//! thrash from stale predictions, shed stays bounded).
//!
//! Reported per mode: hit rate, cold-start p99 / mean, evictions,
//! prefetch hits, shed count.

#[path = "../tests/common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::atomic::Ordering;
use std::time::Duration;

use ahwa_lora::serve::CacheConfig;
use ahwa_lora::util::bench::Bencher;
use refresh_sim::{cache_sim, periodic_trace, zipf_trace, CacheSim};

fn report(label: &str, sim: &CacheSim) {
    println!(
        "{label}: hit_rate {:.1}%, cold p99 {:.3} ms, cold mean {:.3} ms, \
         {} eviction(s), {} prefetch hit(s), {} shed",
        sim.hit_rate() * 100.0,
        sim.cold_p99_ms(),
        sim.mean_cold_ms(),
        sim.metrics.cache_evictions.load(Ordering::Relaxed),
        sim.metrics.cache_prefetch_hits.load(Ordering::Relaxed),
        sim.shed,
    );
}

fn run(n_tasks: usize, cfg: CacheConfig, trace: &[usize], ia: Duration) -> CacheSim {
    let mut sim = cache_sim(n_tasks, cfg);
    sim.drive(trace, ia);
    sim
}

fn main() {
    let mut b = Bencher::with_budget(0.5);

    // -- scenario 1: periodic 16 tasks over 8 slots --------------------
    let periodic = periodic_trace(16_384, 16);
    let ia = Duration::from_millis(1);
    let base = || {
        CacheConfig::new(8)
            .load_latency(Duration::from_micros(200))
            .prefetch_horizon(Duration::from_millis(2))
    };
    let on = b.once("cache/periodic, prefetch ON", || {
        run(16, base().prefetch(true), &periodic, ia)
    });
    let off = b.once("cache/periodic, prefetch OFF", || {
        run(16, base().prefetch(false), &periodic, ia)
    });
    report("periodic prefetch ON ", &on);
    report("periodic prefetch OFF", &off);
    println!(
        "periodic: prefetch cuts cold p99 {:.3} ms -> {:.3} ms and lifts \
         hit rate {:.1}% -> {:.1}%",
        off.cold_p99_ms(),
        on.cold_p99_ms(),
        off.hit_rate() * 100.0,
        on.hit_rate() * 100.0,
    );

    // -- scenario 2: zipf 64 tasks over 8 slots ------------------------
    let zipf = zipf_trace(16_384, 64, 7);
    let ia = Duration::from_micros(250);
    let zbase = || CacheConfig::new(8).load_latency(Duration::from_micros(200));
    let zon = b.once("cache/zipf, prefetch ON", || {
        run(64, zbase().prefetch(true), &zipf, ia)
    });
    let zoff = b.once("cache/zipf, prefetch OFF", || {
        run(64, zbase().prefetch(false), &zipf, ia)
    });
    report("zipf prefetch ON ", &zon);
    report("zipf prefetch OFF", &zoff);
    assert!(
        zon.hit_rate() + 0.05 >= zoff.hit_rate(),
        "prefetch must never materially hurt the zipf mix"
    );
    if let Err(e) = b.write_json("serving_cache") {
        eprintln!("could not write BENCH_serving_cache.json: {e}");
    }
}
