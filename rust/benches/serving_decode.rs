//! Continuous-batching vs static run-to-completion decode over the SAME
//! arrival trace — hermetic, zero real sleeps: both modes run on the
//! `VirtualClock` through the shared `SimDecode` harness
//! (`tests/common/refresh_sim.rs`), the same lane model the
//! `decode_conformance` suite pins, just with a longer burst.
//!
//! Reported per mode: modeled step-batch occupancy, step count,
//! time-to-first-token p50, inter-token p50/p99, and makespan — plus
//! the continuous-vs-static occupancy and inter-token p99 deltas. The
//! occupancy and makespan wins are asserted (they are the tentpole
//! claim); the inter-token delta is reported only, since fuller
//! step-batches trade per-step latency for throughput.

#[path = "../tests/common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::serve::registry::SharedRegistry;
use ahwa_lora::serve::{Metrics, VirtualClock};
use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::stats;
use refresh_sim::{adapter, decode_trace, drive_decode, SimDecode};

const N_REQUESTS: usize = 160;
/// Mixed generation lengths: the spread is what makes rows retire at
/// different steps, which is exactly where continuous join wins.
const GEN_LENS: [usize; 8] = [4, 19, 7, 15, 5, 17, 9, 12];
const B: usize = 8;
const S: usize = 64;

fn run(continuous: bool) -> (SimDecode, Duration) {
    let clock = Arc::new(VirtualClock::new());
    let registry = SharedRegistry::new();
    registry.deploy("task", adapter(1.0));
    let start = clock.now();
    let mut sim = SimDecode::new(clock, Arc::new(Metrics::default()), B, S, continuous);
    let trace = decode_trace(N_REQUESTS, Duration::ZERO, &GEN_LENS);
    drive_decode(&mut sim, &registry, None, None, "task", &trace);
    let makespan = sim.makespan(start);
    (sim, makespan)
}

fn report(label: &str, sim: &SimDecode, makespan: Duration) {
    println!(
        "{label}: occupancy {:.1}%, {} step(s), ttft p50 {:.2} µs, \
         inter-token p50 {:.2} µs p99 {:.2} µs, makespan {:.2} µs",
        sim.occupancy() * 100.0,
        sim.steps.len(),
        stats::percentile(&sim.ttft_ns, 50.0) / 1e3,
        stats::percentile(&sim.itl_ns, 50.0) / 1e3,
        stats::percentile(&sim.itl_ns, 99.0) / 1e3,
        makespan.as_nanos() as f64 / 1e3,
    );
}

fn main() {
    let mut b = Bencher::with_budget(0.5);

    let (cont, cont_span) = b.once("decode/continuous join", || run(true));
    let (stat, stat_span) = b.once("decode/static batching", || run(false));

    // both modes complete the identical workload, token for token
    assert_eq!(cont.finished.len(), N_REQUESTS);
    assert_eq!(stat.finished.len(), N_REQUESTS);
    for g in &cont.finished {
        let twin = stat
            .finished
            .iter()
            .find(|h| h.id == g.id)
            .expect("same request set");
        assert_eq!(g.tokens, twin.tokens, "request {} diverged", g.id);
    }

    report("static batching ", &stat, stat_span);
    report("continuous join ", &cont, cont_span);
    let itl_p99 = |s: &SimDecode| stats::percentile(&s.itl_ns, 99.0) / 1e3;
    println!(
        "continuous-vs-static: occupancy {:+.1} pp, inter-token p99 {:+.2} µs, \
         makespan {:+.2} µs ({} fewer step(s) for the same {} tokens)",
        (cont.occupancy() - stat.occupancy()) * 100.0,
        itl_p99(&cont) - itl_p99(&stat),
        (cont_span.as_nanos() as f64 - stat_span.as_nanos() as f64) / 1e3,
        stat.steps.len() as i64 - cont.steps.len() as i64,
        cont.finished.iter().map(|g| g.tokens.len()).sum::<usize>(),
    );

    assert!(
        cont.occupancy() > stat.occupancy(),
        "continuous join must beat static occupancy ({:.3} vs {:.3})",
        cont.occupancy(),
        stat.occupancy()
    );
    assert!(
        cont_span < stat_span,
        "same tokens in fuller steps must shorten the makespan"
    );
    if let Err(e) = b.write_json("serving_decode") {
        eprintln!("could not write BENCH_serving_decode.json: {e}");
    }
}
