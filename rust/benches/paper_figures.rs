//! Benches regenerating the paper's FIGURES (Figs. 2–4).
//!
//! Fig. 4 is fully simulator-driven and prints the actual series; the
//! Fig. 2/3 training-dependent figures are exercised via their
//! per-step/per-eval hot paths (full runs live in `ahwa-lora exp`).

use ahwa_lora::pipeline::balance::{best, sweep};
use ahwa_lora::pipeline::schedule::{pipeline_latency, INTEGRATION_TIMES_NS, TOKEN_PARALLELISM};
use ahwa_lora::pmca::cluster::SnitchCluster;
use ahwa_lora::pmca::kernels::LoraWorkload;
use ahwa_lora::pmca::redmule::RedMulE;
use ahwa_lora::pmca::tcdm;
use ahwa_lora::util::bench::{black_box, Bencher};

fn main() {
    let mut b = Bencher::with_budget(1.0);
    let (c, e) = (SnitchCluster::default(), RedMulE::default());

    println!("== Fig. 4a — PMCA/AIMC latency ratios (model output) ==");
    for (name, m, n) in [("128x128", 128usize, 128usize), ("512x128", 512, 128)] {
        for t_int in INTEGRATION_TIMES_NS {
            let series: Vec<String> = TOKEN_PARALLELISM
                .iter()
                .map(|&t| {
                    let w = LoraWorkload { m, n, r: 8, t };
                    let p = pipeline_latency(&w, t_int, 320, &c, &e);
                    format!("t={t}:{:.2}", p.ratio())
                })
                .collect();
            println!("  {name} @{t_int}ns  {}", series.join("  "));
        }
    }

    println!("\n== Fig. 4b — TCDM KiB vs t (model output) ==");
    for (name, m, n) in [("128x128", 128usize, 128usize), ("512x128", 512, 128)] {
        let series: Vec<String> = TOKEN_PARALLELISM
            .iter()
            .map(|&t| {
                let w = LoraWorkload { m, n, r: 8, t };
                format!("t={t}:{:.1}", tcdm::footprint(&w).kib())
            })
            .collect();
        println!("  {name}  {}", series.join("  "));
    }

    println!("\n== Fig. 4c — steady-state overhead at best balance ==");
    for (name, m, n) in [("128x128", 128usize, 128usize), ("512x128", 512, 128)] {
        for t_int in INTEGRATION_TIMES_NS {
            let p = best(&sweep(m, n, 8, t_int, 320, &c, &e));
            println!(
                "  {name} @{t_int}ns  best t={} overhead {:+.2}%",
                p.t,
                100.0 * p.latency.overhead()
            );
        }
    }

    println!("\n== simulator throughput ==");
    b.bench_items("fig4/full sweep (2 layers x 3 T_int x 5 t)", Some(30), || {
        for (m, n) in [(128usize, 128usize), (512, 128)] {
            for t_int in INTEGRATION_TIMES_NS {
                black_box(best(&sweep(m, n, 8, t_int, 320, &c, &e)));
            }
        }
    });

    // Fig. 2a counterpart: per-rank LoRA pipeline latency scaling
    println!("\n== Fig. 2a counterpart — PMCA latency vs rank ==");
    for r in [1usize, 2, 4, 8, 16] {
        let w = LoraWorkload { m: 128, n: 128, r, t: 64 };
        println!("  r={r}: {:.2} µs / batch", w.latency_ns(&c, &e) / 1e3);
    }
}
