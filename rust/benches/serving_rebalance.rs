//! Adaptive heterogeneous routing: the cadenced rebalancer vs sticky
//! cold placement on shifted traffic — hermetic (no artifacts), zero
//! real sleeps: both drives run on the SAME routed `SimPool`
//! virtual-clock harness the conformance suite uses
//! (`tests/common/refresh_sim.rs`).
//!
//! Scenario: two PCM substrates whose service/maintenance trade flips
//! with arrival rate — a fast tier with an expensive refit against a
//! 4× slower lean tier that refits for free. Tasks cold-place on the
//! fast tier (cheapest at saturation, the only evidence at build
//! time); the measured traffic then arrives at an inter-arrival
//! provably past the cost crossover, so the sticky pool keeps paying
//! the maintenance bill while the adaptive pool migrates away from it
//! after the arrival EWMAs seed.
//!
//! Reported: wall time of each 60-round drive, the modeled
//! per-request placement cost p99 of both modes, the p99 win, and the
//! number of migrations the rebalancer applied.

#[path = "../tests/common/refresh_sim.rs"]
mod refresh_sim;

use std::sync::Arc;
use std::time::Duration;

use ahwa_lora::serve::hal::route_one;
use ahwa_lora::serve::{Backend, BackendProfile, PcmPjrt, RebalanceConfig, SchedConfig};
use ahwa_lora::util::bench::Bencher;
use ahwa_lora::util::stats;
use refresh_sim::{gap_shifting_from, SimPool};

/// The crossover geometry of the conformance suite's migration tests:
/// `(backends, ia)` with the hysteresis gate provably open toward the
/// lean tier at inter-arrival `ia` — the saving over 600 cooldown
/// arrivals clears a 0.5 hysteresis bar with 2× margin.
fn shift_geometry() -> (Vec<Arc<dyn Backend>>, Duration) {
    let fast: Arc<dyn Backend> = Arc::new(PcmPjrt::default().refit_ns(5.0e9));
    let lean: Arc<dyn Backend> = Arc::new(
        PcmPjrt::default()
            .named("pcm-lean")
            .t_int_scale(4.0)
            .refit_ns(0.0)
            .deploy_latency(Duration::from_micros(100)),
    );
    let backends = vec![fast, lean];
    let layer = SchedConfig::for_layer(128, 128, 8).seq(320);
    let profiles: Vec<BackendProfile> = backends
        .iter()
        .map(|b| BackendProfile::of(b.as_ref(), &layer, refresh_sim::MAX_BATCH))
        .collect();
    let cold = route_one(&profiles, f64::INFINITY, 0.05);
    let dest = 1 - cold;
    let need = 0.5 * profiles[dest].deploy_latency.as_nanos() as f64 * 2.0 / 600.0;
    let gap = gap_shifting_from(&profiles, cold, 0.05, need).expect("crossover gap exists");
    let ia_ns = gap.ceil();
    assert_eq!(
        route_one(&profiles, ia_ns, 0.05),
        dest,
        "still shifted at the integer gap"
    );
    (backends, Duration::from_nanos(ia_ns as u64))
}

/// One 60-round drive (3 tasks, 180 requests): 3 warmup rounds seed
/// the arrival EWMAs (and let the adaptive pool converge), then a
/// clean 57-round window is measured.
fn drive(adaptive: bool) -> SimPool {
    let (backends, ia) = shift_geometry();
    let mut b = SimPool::builder()
        .workers(2)
        .tasks(&["s0", "s1", "s2"])
        .backends(&backends)
        .trigger_in(Duration::from_secs(1_000_000_000));
    if adaptive {
        b = b.rebalance(
            RebalanceConfig::new()
                .hysteresis(0.5)
                .cooldown(ia * 600)
                .idle_retire(None),
        );
    }
    let mut pool = b.build();
    pool.run_rounds(3, ia);
    pool.modeled_cost_ns.clear();
    pool.run_rounds(57, ia);
    pool.flush(ia);
    assert_eq!(pool.lat_ns.len(), 180, "every request served");
    pool
}

fn main() {
    let mut b = Bencher::with_budget(0.5);

    let adaptive = b.once("rebalance/adaptive drive (60 rounds x 3 tasks)", || drive(true));
    let sticky = b.once("rebalance/sticky drive (60 rounds x 3 tasks)", || drive(false));

    let pa = stats::percentile(&adaptive.modeled_cost_ns, 99.0);
    let ps = stats::percentile(&sticky.modeled_cost_ns, 99.0);
    b.once_modeled("rebalance/adaptive modeled p99", pa, || ());
    b.once_modeled("rebalance/sticky modeled p99", ps, || ());
    b.once_modeled("rebalance/p99 win (sticky - adaptive)", ps - pa, || ());
    b.once_modeled("rebalance/migrations applied", adaptive.moves.len() as f64, || ());

    assert!(sticky.moves.is_empty(), "the sticky pool never moves");
    assert!(
        !adaptive.moves.is_empty(),
        "the adaptive pool must migrate off the cold placement"
    );
    assert!(
        pa < ps,
        "adaptive modeled p99 ({pa:.0} ns) must beat sticky ({ps:.0} ns) on shifted traffic"
    );
    println!(
        "rebalance: {} migrations cut the modeled placement p99 {:.0} ns -> {:.0} ns",
        adaptive.moves.len(),
        ps,
        pa,
    );

    if let Err(e) = b.write_json("serving_rebalance") {
        eprintln!("could not write BENCH_serving_rebalance.json: {e}");
    }
}
