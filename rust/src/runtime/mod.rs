//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Compiled executables are cached per
//! graph key ([`client`]); [`pack`] converts between [`ParamStore`]s /
//! host arrays and XLA literals in the manifest's canonical order.

pub mod client;
pub mod pack;

pub use client::{Engine, LoadedGraph};
