//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Wraps the `xla` crate exactly as /opt/xla-example/load_hlo does:
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. Compiled executables are cached per
//! graph key ([`client`]); [`pack`] converts between [`ParamStore`]s /
//! host arrays and XLA literals in the manifest's canonical order.
//!
//! PJRT handles are not `Send`, so anything concurrent (the serving
//! pool) creates one [`Engine`] per worker thread via [`Engine::new`]
//! with a shared, already-parsed manifest — parse once, compile per
//! worker.
//!
//! [`compile`] is the staged front half of that story: manifest →
//! graph IR → passes (shape inference, input-segment layout
//! validation, dead-output elision) → lowering → per-`(key, batch)`
//! compilation, with ahead-of-time shape specialization for the batch
//! fills the serving scheduler commits to.
//!
//! [`ParamStore`]: crate::model::params::ParamStore

pub mod client;
pub mod compile;
pub mod pack;

pub use client::{Engine, LoadedGraph};
pub use compile::{FwdPipeline, GraphIr, Lowering, PrepackedBuf};
