//! Staged forward-graph compile pipeline.
//!
//! Forward-graph bring-up used to be one implicit step — `Engine::load`
//! parsed the HLO text and compiled it, and every shape decision
//! (padding partial batches up to the one AOT batch dimension) was
//! made per batch on the request path. This module restructures that
//! into explicit stages, SionFlowRT-style:
//!
//! ```text
//! manifest load ──► graph IR ──► passes ──► lowering ──► per-shape
//!  (GraphSpec)    (GraphIr:    shape inference         PJRT compile
//!                  role        input-segment layout    ((key, batch)-
//!                  segments)   dead-output elision      keyed cache)
//! ```
//!
//! The payoff is at the end: [`FwdPipeline::specialize`] lowers each
//! batch fill the scheduler commits to
//! ([`crate::serve::sched::BatchScheduler::committed_fills`]) into the
//! cheapest execution that is **bit-identical** to the padded
//! reference path:
//!
//! * [`Lowering::Exact`] — the manifest carries a sibling graph of the
//!   same kind/variant whose data batch is exactly the fill: compile
//!   it ([`crate::runtime::Engine::load_specialized`]) and execute with
//!   zero padding and zero re-pack.
//! * [`Lowering::PassThrough`] — the fill equals the graph batch: the
//!   token buffer is already the exact shape, no copy at all.
//! * [`Lowering::Padded`] — a persistent [`PrepackedBuf`] whose tail
//!   was zeroed ONCE at specialization time; each batch overwrites the
//!   head rows only. Same executable, same input bytes as the per-call
//!   padded path — minus its per-batch allocation and tail zero-fill.
//!
//! Fills that were never specialized (or exceed the graph batch) fall
//! back to the unchanged padded reference loop in
//! [`crate::eval::drift_eval`]. Bit-identity across all four paths is
//! pinned by `tests/compile_golden.rs`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::config::manifest::{GraphSpec, Manifest, Role};
use crate::model::params::ParamStore;
use crate::runtime::client::{Engine, LoadedGraph};
use crate::runtime::pack::{assemble_inputs, literal_to_f32, DataArg};

// ---------------------------------------------------------------------------
// Graph IR + passes
// ---------------------------------------------------------------------------

/// Canonical input-segment rank (aot.py exports every graph's inputs
/// in this order: `meta | train | m | v | data... | key | hw | [opt]`).
fn segment_rank(role: Role) -> Option<usize> {
    match role {
        Role::Meta => Some(0),
        Role::Train => Some(1),
        Role::M => Some(2),
        Role::V => Some(3),
        Role::Data => Some(4),
        Role::Key => Some(5),
        Role::Hw => Some(6),
        Role::Opt => Some(7),
        _ => None,
    }
}

/// The ingestion product of the compile pipeline: one graph's spec
/// plus everything the passes derived from it — the `[batch, seq]`
/// shape, the input-segment layout, and the live-output mask.
///
/// Built by [`GraphIr::build`], which runs the pass sequence (shape
/// inference → input-segment layout validation → dead-output elision)
/// and fails with the graph key on any manifest inconsistency, so a
/// malformed export is rejected at bring-up instead of panicking (or
/// silently mis-packing) on the first batch.
#[derive(Clone, Debug)]
pub struct GraphIr {
    pub spec: GraphSpec,
    /// Native batch dimension of the data inputs.
    pub batch: usize,
    /// Sequence length of the data inputs.
    pub seq: usize,
    /// `(role, input count)` runs, in canonical segment order.
    pub segments: Vec<(Role, usize)>,
    /// `live[i]` ⇔ `spec.outputs[i]` is read by the forward consumers;
    /// lowering skips the host conversion of dead outputs.
    pub live_outputs: Vec<bool>,
}

impl GraphIr {
    /// Run the pass sequence over one graph spec.
    pub fn build(spec: &GraphSpec) -> Result<GraphIr> {
        let mut ir = GraphIr {
            spec: spec.clone(),
            batch: 0,
            seq: 0,
            segments: Vec::new(),
            live_outputs: Vec::new(),
        };
        ir.infer_shapes()?;
        ir.validate_layout()?;
        ir.elide_dead_outputs();
        Ok(ir)
    }

    /// Pass 1 — shape inference: derive `[batch, seq]` from the data
    /// inputs and check every data input and batched output agrees.
    fn infer_shapes(&mut self) -> Result<()> {
        let mut data = self.spec.inputs_with_role(Role::Data);
        let Some(first) = data.next() else {
            bail!(
                "graph '{}': no data input to infer a batch shape from",
                self.spec.key
            );
        };
        if first.shape.len() < 2 || first.shape[0] == 0 || first.shape[1] == 0 {
            bail!(
                "graph '{}': data input '{}' is not [batch, seq] (shape {:?})",
                self.spec.key,
                first.name,
                first.shape
            );
        }
        self.batch = first.shape[0];
        self.seq = first.shape[1];
        for io in data {
            if io.shape.first() != Some(&self.batch) {
                bail!(
                    "graph '{}': data input '{}' batch {:?} disagrees with inferred batch {}",
                    self.spec.key,
                    io.name,
                    io.shape.first(),
                    self.batch
                );
            }
        }
        for out in self.spec.outputs.iter().filter(|o| o.role == Role::Logits) {
            if out.shape.first() != Some(&self.batch) {
                bail!(
                    "graph '{}': logits output '{}' batch {:?} disagrees with inferred batch {}",
                    self.spec.key,
                    out.name,
                    out.shape.first(),
                    self.batch
                );
            }
        }
        Ok(())
    }

    /// Pass 2 — input-segment layout validation: the inputs must form
    /// contiguous role runs in canonical order, because
    /// [`assemble_inputs`] packs positionally and a re-ordered export
    /// would bind literals to the wrong parameters.
    fn validate_layout(&mut self) -> Result<()> {
        self.segments.clear();
        let mut last_rank = 0usize;
        for io in &self.spec.inputs {
            let Some(rank) = segment_rank(io.role) else {
                bail!(
                    "graph '{}': input '{}' has role {:?}, which is not a valid input segment",
                    self.spec.key,
                    io.name,
                    io.role
                );
            };
            match self.segments.last_mut() {
                Some((role, n)) if *role == io.role => *n += 1,
                _ => {
                    if rank < last_rank {
                        bail!(
                            "graph '{}': input '{}' (role {:?}) is out of canonical \
                             segment order (meta|train|m|v|data|key|hw|opt)",
                            self.spec.key,
                            io.name,
                            io.role
                        );
                    }
                    if self.segments.iter().any(|(r, _)| *r == io.role) {
                        bail!(
                            "graph '{}': role {:?} appears in two non-contiguous input segments",
                            self.spec.key,
                            io.role
                        );
                    }
                    self.segments.push((io.role, 1));
                    last_rank = rank;
                }
            }
        }
        Ok(())
    }

    /// Pass 3 — dead-output elision: mark which outputs the forward
    /// consumers actually read. Forward graphs are read for their
    /// logits only; every other output's host conversion is skipped at
    /// lowering. Non-forward kinds keep everything live (the training
    /// step reads all of `train'|m'|v'|loss`).
    fn elide_dead_outputs(&mut self) {
        let fwd = self.spec.kind.starts_with("fwd");
        self.live_outputs = self
            .spec
            .outputs
            .iter()
            .map(|o| !fwd || o.role == Role::Logits)
            .collect();
    }

    /// Index of the first live logits output (what the cls path reads).
    fn logits_index(&self) -> Result<usize> {
        self.spec
            .outputs
            .iter()
            .position(|o| o.role == Role::Logits)
            .ok_or_else(|| {
                anyhow::anyhow!("graph '{}': no logits output", self.spec.key)
            })
    }
}

// ---------------------------------------------------------------------------
// Lowering
// ---------------------------------------------------------------------------

/// Public tag for how one committed fill was lowered (introspection
/// for tests and benches; the executable choice lives in the private
/// enum).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lowering {
    /// Exact-shape sibling executable — zero padding, zero re-pack.
    Exact,
    /// Fill equals the graph batch — the token buffer is used as-is.
    PassThrough,
    /// Max-shape executable fed from a persistent [`PrepackedBuf`].
    Padded,
}

enum Lowered {
    Exact(Rc<LoadedGraph>),
    PassThrough,
    Padded(RefCell<PrepackedBuf>),
}

impl Lowered {
    fn tag(&self) -> Lowering {
        match self {
            Lowered::Exact(_) => Lowering::Exact,
            Lowered::PassThrough => Lowering::PassThrough,
            Lowered::Padded(_) => Lowering::Padded,
        }
    }
}

/// Persistent pre-zeroed pack buffer for one committed fill: the tail
/// rows are zeroed exactly once (at construction) and never rewritten,
/// so each batch pays a head-row copy instead of the per-call
/// allocate + copy + tail-zero of
/// [`crate::runtime::pack::PaddedChunks`]. The produced bytes are
/// identical to a `PaddedChunks` chunk for the same tokens, which is
/// what keeps the specialized path bit-identical (pinned in
/// `tests/compile_golden.rs`).
pub struct PrepackedBuf {
    buf: Vec<i32>,
    fill: usize,
    seq: usize,
}

impl PrepackedBuf {
    /// Buffer for batches of exactly `fill` rows, padded to
    /// `[batch, seq]`.
    pub fn new(fill: usize, batch: usize, seq: usize) -> PrepackedBuf {
        debug_assert!(fill > 0 && fill <= batch && seq > 0);
        PrepackedBuf {
            buf: vec![0i32; batch * seq],
            fill,
            seq,
        }
    }

    /// Overwrite the head rows with `tokens` (which must be exactly
    /// `fill` rows) and return the full padded buffer.
    pub fn pack(&mut self, tokens: &[i32]) -> Result<&[i32]> {
        if tokens.len() != self.fill * self.seq {
            bail!(
                "prepacked buffer holds {} rows of {} tokens, got {} tokens",
                self.fill,
                self.seq,
                tokens.len()
            );
        }
        self.buf[..tokens.len()].copy_from_slice(tokens);
        Ok(&self.buf)
    }

    pub fn fill(&self) -> usize {
        self.fill
    }
}

// ---------------------------------------------------------------------------
// The compiled pipeline
// ---------------------------------------------------------------------------

/// One forward graph, taken through the full pipeline and ready to
/// execute at any fill: the max-shape base executable plus the
/// per-fill specializations [`FwdPipeline::specialize`] lowered.
///
/// Not `Send` (it owns PJRT handles) — the pool builds one per worker
/// thread, exactly like the engine it wraps.
pub struct FwdPipeline {
    engine: Engine,
    key: String,
    ir: GraphIr,
    base: Rc<LoadedGraph>,
    shapes: BTreeMap<usize, Lowered>,
}

impl FwdPipeline {
    /// Run the staged pipeline for `key`: manifest load → IR → passes
    /// → lowering of the native shape (the max-shape base executable).
    pub fn compile(manifest: Manifest, key: &str) -> Result<FwdPipeline> {
        let engine = Engine::new(manifest)?;
        let base = engine.load(key)?;
        let ir = GraphIr::build(&base.spec)?;
        Ok(FwdPipeline {
            engine,
            key: key.to_string(),
            ir,
            base,
            shapes: BTreeMap::new(),
        })
    }

    pub fn ir(&self) -> &GraphIr {
        &self.ir
    }

    pub fn base(&self) -> &Rc<LoadedGraph> {
        &self.base
    }

    /// Total PJRT compile wall-time (base + specializations) — grows
    /// when [`Self::specialize`] compiles exact-shape siblings.
    pub fn compile_ms(&self) -> u128 {
        self.engine.total_compile_ms()
    }

    /// Lower each committed fill to its cheapest bit-identical
    /// execution (see the module docs for the three lowerings). Fills
    /// larger than the graph batch stay on the multi-chunk padded
    /// path and are skipped, not errors; a zero fill is a caller bug.
    pub fn specialize(&mut self, fills: &[usize]) -> Result<()> {
        for &fill in fills {
            if fill == 0 {
                bail!("graph '{}': cannot specialize a zero batch fill", self.key);
            }
            if fill > self.ir.batch || self.shapes.contains_key(&fill) {
                continue;
            }
            let lowered = if fill == self.ir.batch {
                Lowered::PassThrough
            } else {
                match self.engine.load_specialized(&self.key, fill)? {
                    Some(g) => {
                        let sib = GraphIr::build(&g.spec)?;
                        if sib.seq != self.ir.seq {
                            bail!(
                                "graph '{}': exact-shape sibling '{}' has seq {}, base has {}",
                                self.key,
                                g.spec.key,
                                sib.seq,
                                self.ir.seq
                            );
                        }
                        Lowered::Exact(g)
                    }
                    None => Lowered::Padded(RefCell::new(PrepackedBuf::new(
                        fill,
                        self.ir.batch,
                        self.ir.seq,
                    ))),
                }
            };
            self.shapes.insert(fill, lowered);
        }
        Ok(())
    }

    /// The fills specialized so far, ascending.
    pub fn specialized_fills(&self) -> Vec<usize> {
        self.shapes.keys().copied().collect()
    }

    /// How `fill` was lowered (`None` = not specialized: the per-call
    /// padded reference path serves it).
    pub fn lowering(&self, fill: usize) -> Option<Lowering> {
        self.shapes.get(&fill).map(Lowered::tag)
    }

    /// The executable serving a `token_len`-token batch: the exact
    /// sibling when one was lowered, the base graph otherwise.
    fn graph_for(&self, token_len: usize) -> &Rc<LoadedGraph> {
        if self.ir.seq > 0 && token_len % self.ir.seq == 0 {
            if let Some(Lowered::Exact(g)) = self.shapes.get(&(token_len / self.ir.seq)) {
                return g;
            }
        }
        &self.base
    }

    /// Classification logit rows, through the specialized lowering for
    /// this batch's fill when one exists.
    ///
    /// Single-chunk seeds: the padded reference XORs each chunk's seed
    /// with its row offset, and every specialized execution is one
    /// chunk at offset 0 — the raw seed passes through on both sides,
    /// which is what makes the paths bit-comparable at all.
    pub fn cls_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let (b, s) = (self.ir.batch, self.ir.seq);
        if tokens.is_empty() {
            return Ok(Vec::new());
        }
        if tokens.len() % s != 0 {
            // the padded reference path owns the whole-rows contract
            return crate::eval::drift_eval::cls_logits(
                &self.base, meta, adapter, tokens, hw, seed,
            );
        }
        let rows = tokens.len() / s;
        if rows == b {
            // trivially exact: the buffer already is [batch, seq]
            return self.run_cls(&self.base, tokens, rows, meta, adapter, hw, seed);
        }
        match self.shapes.get(&rows) {
            Some(Lowered::Exact(g)) => self.run_cls(g, tokens, rows, meta, adapter, hw, seed),
            Some(Lowered::Padded(buf)) => {
                let mut buf = buf.borrow_mut();
                let chunk = buf.pack(tokens)?;
                self.run_cls(&self.base, chunk, rows, meta, adapter, hw, seed)
            }
            // rows == b was handled above; anything else un-specialized
            // (including multi-chunk fills) takes the reference loop
            _ => crate::eval::drift_eval::cls_logits(&self.base, meta, adapter, tokens, hw, seed),
        }
    }

    /// One single-chunk execution of `g` (whose data input `data`
    /// already matches exactly), returning the first `rows` logit
    /// rows. Only the live logits output is converted to host floats —
    /// this is where the dead-output elision pays.
    #[allow(clippy::too_many_arguments)]
    fn run_cls(
        &self,
        g: &LoadedGraph,
        data: &[i32],
        rows: usize,
        meta: &ParamStore,
        adapter: &ParamStore,
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let inputs = assemble_inputs(
            &g.spec,
            meta,
            adapter,
            None,
            &[DataArg::I32(data)],
            seed,
            hw,
            None,
        )?;
        let outs = g.run(&inputs)?;
        let idx = self.ir.logits_index()?;
        let n_cls = g.spec.outputs[idx].shape[1];
        let logits = literal_to_f32(&outs[idx])?;
        Ok((0..rows)
            .map(|i| logits[i * n_cls..(i + 1) * n_cls].to_vec())
            .collect())
    }

    /// QA span predictions. The eval-path decode rule lives in
    /// [`crate::eval::drift_eval::qa_predict`]; specialization only
    /// swaps in the exact-shape executable when one was lowered, so
    /// the span window/offset logic cannot diverge between paths.
    pub fn qa_predict(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<(usize, usize)>> {
        let g = self.graph_for(tokens.len());
        crate::eval::drift_eval::qa_predict(g, meta, adapter, tokens, hw, seed)
    }

    /// Full-sequence LM logits (exact `[batch, seq]` contract —
    /// already shape-exact, nothing to specialize).
    pub fn lm_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<f32>> {
        crate::eval::drift_eval::lm_logits(&self.base, meta, adapter, tokens, hw, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::manifest::IoSpec;
    use crate::runtime::pack::PaddedChunks;

    fn io(name: &str, role: Role, shape: &[usize], dtype: &str) -> IoSpec {
        IoSpec {
            name: name.into(),
            role,
            shape: shape.to_vec(),
            dtype: dtype.into(),
        }
    }

    fn fwd_spec() -> GraphSpec {
        GraphSpec {
            key: "base/fwd_cls".into(),
            kind: "fwd_cls".into(),
            variant: "base".into(),
            file: String::new(),
            inputs: vec![
                io("meta/emb", Role::Meta, &[8, 4], "float32"),
                io("train/a", Role::Train, &[4, 2], "float32"),
                io("data/tokens", Role::Data, &[4, 16], "int32"),
                io("key", Role::Key, &[2], "uint32"),
                io("hw", Role::Hw, &[5], "float32"),
            ],
            outputs: vec![io("logits", Role::Logits, &[4, 3], "float32")],
        }
    }

    #[test]
    fn shape_inference_and_segments() {
        let ir = GraphIr::build(&fwd_spec()).unwrap();
        assert_eq!((ir.batch, ir.seq), (4, 16));
        assert_eq!(
            ir.segments,
            vec![
                (Role::Meta, 1),
                (Role::Train, 1),
                (Role::Data, 1),
                (Role::Key, 1),
                (Role::Hw, 1),
            ]
        );
        assert_eq!(ir.live_outputs, vec![true]);
        assert_eq!(ir.logits_index().unwrap(), 0);
    }

    #[test]
    fn shape_inference_rejects_batch_disagreement() {
        let mut spec = fwd_spec();
        spec.inputs
            .insert(3, io("data/mask", Role::Data, &[2, 16], "int32"));
        let err = GraphIr::build(&spec).unwrap_err().to_string();
        assert!(err.contains("base/fwd_cls"), "{err}");
        assert!(err.contains("disagrees"), "{err}");
    }

    #[test]
    fn shape_inference_rejects_missing_data_input() {
        let mut spec = fwd_spec();
        spec.inputs.retain(|i| i.role != Role::Data);
        let err = GraphIr::build(&spec).unwrap_err().to_string();
        assert!(err.contains("no data input"), "{err}");
    }

    #[test]
    fn layout_validation_rejects_out_of_order_segments() {
        let mut spec = fwd_spec();
        spec.inputs.swap(0, 2); // data before meta
        let err = GraphIr::build(&spec).unwrap_err().to_string();
        assert!(err.contains("canonical"), "{err}");
    }

    #[test]
    fn layout_validation_rejects_split_segments() {
        let mut spec = fwd_spec();
        // meta | train | meta — rank goes backwards
        spec.inputs
            .insert(2, io("meta/late", Role::Meta, &[2, 2], "float32"));
        let err = GraphIr::build(&spec).unwrap_err().to_string();
        assert!(err.contains("canonical") || err.contains("non-contiguous"), "{err}");
    }

    #[test]
    fn layout_validation_rejects_output_roles_as_inputs() {
        let mut spec = fwd_spec();
        spec.inputs
            .push(io("loss", Role::Loss, &[], "float32"));
        let err = GraphIr::build(&spec).unwrap_err().to_string();
        assert!(err.contains("not a valid input segment"), "{err}");
    }

    #[test]
    fn dead_output_elision_keeps_step_outputs_live() {
        let mut spec = fwd_spec();
        spec.kind = "step_cls_lora".into();
        spec.outputs = vec![
            io("train/a", Role::Train, &[4, 2], "float32"),
            io("m/a", Role::M, &[4, 2], "float32"),
            io("v/a", Role::V, &[4, 2], "float32"),
            io("loss", Role::Loss, &[], "float32"),
        ];
        let ir = GraphIr::build(&spec).unwrap();
        assert_eq!(ir.live_outputs, vec![true; 4]);
    }

    // ── PrepackedBuf: the packing half of the golden bit-identity ──

    #[test]
    fn prepacked_buf_matches_padded_chunks_bit_for_bit() {
        let (b, s) = (8usize, 5usize);
        for fill in 1..b {
            let tokens: Vec<i32> = (0..(fill * s) as i32).map(|t| t * 7 - 3).collect();
            let mut reference = PaddedChunks::new(&tokens, b, s);
            let (chunk, take, offset) = reference.next_chunk().unwrap();
            assert_eq!((take, offset), (fill, 0));
            let mut buf = PrepackedBuf::new(fill, b, s);
            assert_eq!(buf.pack(&tokens).unwrap(), chunk, "fill {fill}");
        }
    }

    #[test]
    fn prepacked_buf_tail_stays_zero_across_packs() {
        let mut buf = PrepackedBuf::new(2, 4, 3);
        for round in 0..3 {
            let tokens = vec![round + 1; 6];
            let packed = buf.pack(&tokens).unwrap();
            assert_eq!(&packed[..6], &tokens[..]);
            assert!(packed[6..].iter().all(|&v| v == 0), "round {round}");
        }
        assert_eq!(buf.fill(), 2);
    }

    #[test]
    fn prepacked_buf_rejects_wrong_fill() {
        let mut buf = PrepackedBuf::new(2, 4, 3);
        assert!(buf.pack(&[1, 2, 3]).is_err());
    }
}
