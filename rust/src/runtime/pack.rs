//! Literal packing: host data ⇄ XLA literals in manifest order.
//!
//! Every exported graph takes inputs in the canonical segment order
//! `meta | train | m | v | data... | key | hw | [opt]` (see aot.py).
//! [`StepIo`]/[`FwdIo`] assemble those segments from [`ParamStore`]s and
//! host arrays, validating names/shapes against the [`GraphSpec`].

use anyhow::{bail, Context, Result};

use crate::config::manifest::{GraphSpec, Role};
use crate::model::params::{ParamStore, Tensor};

// ---------------------------------------------------------------------------
// Literal constructors / extractors
// ---------------------------------------------------------------------------

pub fn f32_literal(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        shape,
        bytes,
    )?)
}

pub fn i32_literal(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        shape,
        bytes,
    )?)
}

pub fn u32_literal(shape: &[usize], data: &[u32]) -> Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::U32,
        shape,
        bytes,
    )?)
}

/// PRNG key literal: jax legacy uint32[2] key from a u64 seed.
pub fn key_literal(seed: u64) -> Result<xla::Literal> {
    u32_literal(&[2], &[(seed >> 32) as u32, seed as u32])
}

pub fn literal_to_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

pub fn scalar_f32(lit: &xla::Literal) -> Result<f32> {
    Ok(lit.get_first_element::<f32>()?)
}

/// Convert a ParamStore to literals in its canonical order.
pub fn store_literals(store: &ParamStore) -> Result<Vec<xla::Literal>> {
    store
        .tensors
        .iter()
        .map(|t| f32_literal(&t.shape, &t.data))
        .collect()
}

/// Overwrite a ParamStore's tensors from a slice of output literals
/// (same canonical order).
pub fn update_store(store: &mut ParamStore, lits: &[xla::Literal]) -> Result<()> {
    if lits.len() != store.len() {
        bail!("literal count {} != store tensors {}", lits.len(), store.len());
    }
    for (t, l) in store.tensors.iter_mut().zip(lits) {
        let v = l.to_vec::<f32>()?;
        if v.len() != t.data.len() {
            bail!("numel mismatch for '{}': {} vs {}", t.name, v.len(), t.data.len());
        }
        t.data = v;
    }
    Ok(())
}

/// Build a ParamStore from output literals using the graph's role spec
/// for names/shapes.
pub fn store_from_outputs(spec: &GraphSpec, role: Role, lits: &[xla::Literal], offset: usize) -> Result<ParamStore> {
    let ios: Vec<_> = spec.outputs.iter().filter(|o| o.role == role).collect();
    // a runtime that returns fewer outputs than the manifest claims
    // (truncated tuple, stale artifact) must surface as a typed error,
    // not an index panic in the worker thread
    if offset + ios.len() > lits.len() {
        bail!(
            "graph '{}': {} output(s) with role {:?} expected at literals [{}, {}), \
             but only {} literal(s) were returned (truncated output list)",
            spec.key,
            ios.len(),
            role,
            offset,
            offset + ios.len(),
            lits.len()
        );
    }
    let mut tensors = Vec::with_capacity(ios.len());
    for (i, io) in ios.iter().enumerate() {
        let v = lits[offset + i].to_vec::<f32>()?;
        tensors.push(Tensor {
            name: io.name.clone(),
            shape: io.shape.clone(),
            data: v,
        });
    }
    Ok(ParamStore::from_tensors(tensors))
}

// ---------------------------------------------------------------------------
// Graph I/O assembly
// ---------------------------------------------------------------------------

/// Data segment: the per-batch host arrays, in graph order.
pub enum DataArg<'a> {
    I32(&'a [i32]),
    F32(&'a [f32]),
}

/// Walk a flat `[n, s]` token buffer in graph-batch-sized chunks,
/// zero-padding the final partial chunk to exactly `[b, s]`.
///
/// AOT-compiled forward graphs have a fixed batch dimension, but both
/// the eval harness and the serving pool's cost-based scheduler produce
/// batches of any fill ≤ `b`; this is the one place that padding rule
/// lives. The chunk buffer is reused across iterations, so this is a
/// lending iterator: call [`PaddedChunks::next_chunk`] until it returns
/// `None`.
pub struct PaddedChunks<'a> {
    tokens: &'a [i32],
    b: usize,
    s: usize,
    n: usize,
    done: usize,
    chunk: Vec<i32>,
}

impl<'a> PaddedChunks<'a> {
    /// `tokens.len()` must be a multiple of the sequence length `s`.
    pub fn new(tokens: &'a [i32], b: usize, s: usize) -> PaddedChunks<'a> {
        debug_assert!(b > 0 && s > 0);
        debug_assert_eq!(tokens.len() % s, 0, "tokens must be whole rows");
        PaddedChunks {
            tokens,
            b,
            s,
            n: tokens.len() / s,
            done: 0,
            chunk: vec![0i32; b * s],
        }
    }

    /// Next `(padded chunk of b·s tokens, valid rows, starting row)`;
    /// `None` once every row has been yielded.
    pub fn next_chunk(&mut self) -> Option<(&[i32], usize, usize)> {
        if self.done >= self.n {
            return None;
        }
        let take = (self.n - self.done).min(self.b);
        let start = self.done * self.s;
        self.chunk[..take * self.s].copy_from_slice(&self.tokens[start..start + take * self.s]);
        for v in self.chunk[take * self.s..].iter_mut() {
            *v = 0;
        }
        let offset = self.done;
        self.done += take;
        Some((&self.chunk, take, offset))
    }
}

/// Assemble the full input vector for any exported graph.
///
/// `opt` is `Some([lr, wd, step])` for training graphs, `None` for
/// forward graphs.
pub fn assemble_inputs(
    spec: &GraphSpec,
    meta: &ParamStore,
    train: &ParamStore,
    moments: Option<(&ParamStore, &ParamStore)>,
    data: &[DataArg],
    seed: u64,
    hw: [f32; 5],
    opt: Option<[f32; 3]>,
) -> Result<Vec<xla::Literal>> {
    meta.validate_against(spec, Role::Meta)
        .context("meta params")?;
    train
        .validate_against(spec, Role::Train)
        .context("train params")?;

    let mut out = Vec::with_capacity(spec.inputs.len());
    out.extend(store_literals(meta)?);
    out.extend(store_literals(train)?);
    if let Some((m, v)) = moments {
        out.extend(store_literals(m)?);
        out.extend(store_literals(v)?);
    }

    let data_specs: Vec<_> = spec.inputs_with_role(Role::Data).collect();
    if data_specs.len() != data.len() {
        bail!(
            "graph '{}' wants {} data inputs, got {}",
            spec.key,
            data_specs.len(),
            data.len()
        );
    }
    for (io, arg) in data_specs.iter().zip(data) {
        let lit = match (io.dtype.as_str(), arg) {
            ("int32", DataArg::I32(v)) => {
                if v.len() != io.numel() {
                    bail!("data '{}' numel {} != expected {}", io.name, v.len(), io.numel());
                }
                i32_literal(&io.shape, v)?
            }
            ("float32", DataArg::F32(v)) => {
                if v.len() != io.numel() {
                    bail!("data '{}' numel {} != expected {}", io.name, v.len(), io.numel());
                }
                f32_literal(&io.shape, v)?
            }
            (dt, _) => bail!("data '{}' dtype mismatch: graph wants {dt}", io.name),
        };
        out.push(lit);
    }

    out.push(key_literal(seed)?);
    out.push(f32_literal(&[5], &hw)?);
    if let Some(o) = opt {
        out.push(f32_literal(&[3], &o)?);
    }

    if out.len() != spec.inputs.len() {
        bail!(
            "assembled {} inputs for '{}', manifest says {}",
            out.len(),
            spec.key,
            spec.inputs.len()
        );
    }
    Ok(out)
}

/// Parse a training-step graph's outputs: (train', m', v', loss).
///
/// The step layout is `train' | m' | v' | loss`, one moment tensor per
/// trainable tensor — a manifest where the per-role counts disagree
/// (or a runtime that returns a truncated list) gets a typed error
/// naming the graph and role instead of a misaligned read or a panic.
pub fn parse_step_outputs(
    spec: &GraphSpec,
    lits: &[xla::Literal],
) -> Result<(ParamStore, ParamStore, ParamStore, f32)> {
    let n = spec.outputs.iter().filter(|o| o.role == Role::Train).count();
    let n_m = spec.outputs.iter().filter(|o| o.role == Role::M).count();
    let n_v = spec.outputs.iter().filter(|o| o.role == Role::V).count();
    if n_m != n || n_v != n {
        bail!(
            "graph '{}': step outputs must carry one {:?} and one {:?} per {:?} tensor \
             (got {n} train, {n_m} m, {n_v} v)",
            spec.key,
            Role::M,
            Role::V,
            Role::Train,
        );
    }
    let train = store_from_outputs(spec, Role::Train, lits, 0)?;
    let m = store_from_outputs(spec, Role::M, lits, n)?;
    let v = store_from_outputs(spec, Role::V, lits, 2 * n)?;
    let loss = lits.get(3 * n).ok_or_else(|| {
        anyhow::anyhow!(
            "graph '{}': loss output expected at literal index {}, \
             but only {} literal(s) were returned",
            spec.key,
            3 * n,
            lits.len()
        )
    })?;
    let loss = scalar_f32(loss)?;
    Ok((train, m, v, loss))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let lit = f32_literal(&[2, 3], &data).unwrap();
        assert_eq!(literal_to_f32(&lit).unwrap(), data);
        assert_eq!(lit.element_count(), 6);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let data = vec![-1i32, 0, 7];
        let lit = i32_literal(&[3], &data).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), data);
    }

    #[test]
    fn key_literal_splits_seed() {
        let lit = key_literal(0x1234_5678_9abc_def0).unwrap();
        assert_eq!(lit.to_vec::<u32>().unwrap(), vec![0x1234_5678, 0x9abc_def0]);
    }

    #[test]
    fn padded_chunks_cover_rows_and_zero_fill() {
        let tokens: Vec<i32> = (1..=10).collect(); // 5 rows of s=2
        let mut chunks = PaddedChunks::new(&tokens, 2, 2);
        let mut seen_rows = 0;
        let mut offsets = Vec::new();
        while let Some((chunk, take, offset)) = chunks.next_chunk() {
            assert_eq!(chunk.len(), 4, "always the full graph shape");
            assert_eq!(&chunk[..take * 2], &tokens[offset * 2..(offset + take) * 2]);
            assert!(chunk[take * 2..].iter().all(|&v| v == 0), "tail is zero-padded");
            offsets.push(offset);
            seen_rows += take;
        }
        assert_eq!(seen_rows, 5);
        assert_eq!(offsets, vec![0, 2, 4]);
    }

    #[test]
    fn padded_chunks_empty_input_yields_nothing() {
        let mut chunks = PaddedChunks::new(&[], 4, 8);
        assert!(chunks.next_chunk().is_none());
    }

    fn step_spec() -> GraphSpec {
        let out = |name: &str, role: Role| crate::config::manifest::IoSpec {
            name: name.into(),
            role,
            shape: vec![2],
            dtype: "float32".into(),
        };
        GraphSpec {
            key: "tiny/step_qa_lora".into(),
            kind: "step_qa_lora".into(),
            variant: "tiny".into(),
            file: String::new(),
            inputs: Vec::new(),
            outputs: vec![
                out("train/a", Role::Train),
                out("train/b", Role::Train),
                out("m/a", Role::M),
                out("m/b", Role::M),
                out("v/a", Role::V),
                out("v/b", Role::V),
                crate::config::manifest::IoSpec {
                    name: "loss".into(),
                    role: Role::Loss,
                    shape: vec![],
                    dtype: "float32".into(),
                },
            ],
        }
    }

    fn lits(n: usize) -> Vec<xla::Literal> {
        (0..n)
            .map(|i| f32_literal(&[2], &[i as f32, i as f32]).unwrap())
            .collect()
    }

    #[test]
    fn truncated_output_list_is_a_typed_error_not_a_panic() {
        let spec = step_spec();
        // 4 of the 7 promised literals: the V segment is truncated
        let err = parse_step_outputs(&spec, &lits(4)).unwrap_err().to_string();
        assert!(err.contains("tiny/step_qa_lora"), "{err}");
        assert!(err.contains("V"), "{err}");
        assert!(err.contains("truncated"), "{err}");
        // all tensors present but the trailing loss scalar missing
        let err = parse_step_outputs(&spec, &lits(6)).unwrap_err().to_string();
        assert!(err.contains("tiny/step_qa_lora"), "{err}");
        assert!(err.contains("loss"), "{err}");
        // store_from_outputs itself reports the role it ran out at
        let err = store_from_outputs(&spec, Role::M, &lits(3), 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains("tiny/step_qa_lora"), "{err}");
        assert!(err.contains("M"), "{err}");
    }

    #[test]
    fn mismatched_moment_counts_are_rejected() {
        let mut spec = step_spec();
        spec.outputs.remove(4); // drop one V tensor: |V| != |Train|
        let err = parse_step_outputs(&spec, &lits(7)).unwrap_err().to_string();
        assert!(err.contains("tiny/step_qa_lora"), "{err}");
        assert!(err.contains("2 train"), "{err}");
        assert!(err.contains("1 v"), "{err}");
    }

    #[test]
    fn full_output_list_still_parses() {
        let spec = step_spec();
        let mut all = lits(6);
        all.push(f32_literal(&[], &[0.25]).unwrap());
        let (train, m, v, loss) = parse_step_outputs(&spec, &all).unwrap();
        assert_eq!(train.len(), 2);
        assert_eq!(m.len(), 2);
        assert_eq!(v.len(), 2);
        assert_eq!(loss, 0.25);
    }

    #[test]
    fn update_store_roundtrip() {
        let mut store = ParamStore::from_tensors(vec![Tensor::zeros("x", &[2, 2])]);
        let lit = f32_literal(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        update_store(&mut store, &[lit]).unwrap();
        assert_eq!(store.get("x").unwrap().data, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
