//! PJRT client + compiled-executable cache.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::config::manifest::{GraphSpec, Manifest, Role};

/// One compiled HLO graph ready to execute.
pub struct LoadedGraph {
    pub spec: GraphSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Compile wall-time (surfaced in logs; PJRT CPU compiles can take
    /// seconds for the larger training graphs).
    pub compile_ms: u128,
}

impl LoadedGraph {
    /// Execute with host literals; returns the flat list of outputs
    /// (the graphs are lowered with return_tuple=True, so the single
    /// result tuple is decomposed here).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "graph '{}' expects {} inputs, got {}",
                self.spec.key,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "graph '{}' returned {} outputs, manifest says {}",
                self.spec.key,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// The HLO text loader takes a `&str` path: surface a non-UTF-8
/// artifacts directory as a contextual error instead of panicking in
/// the worker thread that compiles the graph.
pub fn hlo_path_str(path: &Path) -> Result<&str> {
    path.to_str().ok_or_else(|| {
        anyhow!(
            "HLO artifact path {} is not valid UTF-8 (the PJRT text loader needs a UTF-8 path)",
            path.display()
        )
    })
}

/// Native batch dimension of a graph's first data input (0 when the
/// graph has none — such graphs never shape-specialize).
fn native_batch(spec: &GraphSpec) -> usize {
    spec.inputs_with_role(Role::Data)
        .next()
        .and_then(|io| io.shape.first().copied())
        .unwrap_or(0)
}

/// PJRT engine: owns the CPU client, the manifest, and the compile
/// cache — keyed by `(graph key, batch shape)` so one logical graph
/// can hold both its native-shape executable and exact-shape
/// specializations ([`Engine::load_specialized`]) side by side.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<(String, usize), Rc<LoadedGraph>>>,
    pub verbose: bool,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            verbose: std::env::var("AHWA_VERBOSE").is_ok(),
        })
    }

    /// Load from the default artifacts location.
    pub fn from_artifacts() -> Result<Engine> {
        let dir = crate::config::manifest::default_artifacts_dir();
        Engine::new(Manifest::load(dir)?)
    }

    /// Fetch (compiling + caching on first use) the graph for `key` at
    /// its native batch shape.
    pub fn load(&self, key: &str) -> Result<Rc<LoadedGraph>> {
        let spec = self.manifest.graph(key)?.clone();
        let cache_key = (key.to_string(), native_batch(&spec));
        if let Some(g) = self.cache.borrow().get(&cache_key) {
            return Ok(g.clone());
        }
        let g = self.compile_spec(&spec)?;
        self.cache.borrow_mut().insert(cache_key, g.clone());
        Ok(g)
    }

    /// Fetch an exact-shape specialization of `key`: a manifest graph
    /// of the same kind and variant whose data batch is exactly
    /// `batch`. Returns `Ok(None)` when the manifest carries no such
    /// artifact — callers fall back to the padded max-shape graph, so
    /// a sparse export degrades instead of failing. Cached under
    /// `(key, batch)`.
    pub fn load_specialized(&self, key: &str, batch: usize) -> Result<Option<Rc<LoadedGraph>>> {
        let cache_key = (key.to_string(), batch);
        if let Some(g) = self.cache.borrow().get(&cache_key) {
            return Ok(Some(g.clone()));
        }
        let want = self.manifest.graph(key)?.clone();
        let sibling = self.manifest.graphs.values().find(|g| {
            g.key != want.key
                && g.kind == want.kind
                && g.variant == want.variant
                && native_batch(g) == batch
        });
        let Some(spec) = sibling.cloned() else {
            return Ok(None);
        };
        let g = self.compile_spec(&spec)?;
        self.cache.borrow_mut().insert(cache_key, g.clone());
        Ok(Some(g))
    }

    fn compile_spec(&self, spec: &GraphSpec) -> Result<Rc<LoadedGraph>> {
        let path = self.manifest.hlo_path(spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(hlo_path_str(&path)?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of '{}'", spec.key))?;
        let compile_ms = t0.elapsed().as_millis();
        if self.verbose {
            eprintln!("[runtime] compiled '{}' in {compile_ms} ms", spec.key);
        }
        Ok(Rc::new(LoadedGraph {
            spec: spec.clone(),
            exe,
            compile_ms,
        }))
    }

    pub fn cached_graphs(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Total PJRT compile wall-time across cached graphs — the startup
    /// cost each serving worker pays for its private engine (base
    /// graphs plus any shape specializations), surfaced in the pool's
    /// per-worker metrics.
    pub fn total_compile_ms(&self) -> u128 {
        self.cache.borrow().values().map(|g| g.compile_ms).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hlo_path_str_passes_utf8_through() {
        let p = Path::new("/artifacts/tiny/fwd_cls.hlo.txt");
        assert_eq!(hlo_path_str(p).unwrap(), "/artifacts/tiny/fwd_cls.hlo.txt");
    }

    #[cfg(unix)]
    #[test]
    fn hlo_path_str_reports_non_utf8_instead_of_panicking() {
        use std::ffi::OsStr;
        use std::os::unix::ffi::OsStrExt;
        let p = Path::new(OsStr::from_bytes(b"/artifacts/\xff\xfe/fwd.hlo.txt"));
        let err = hlo_path_str(p).unwrap_err().to_string();
        assert!(err.contains("not valid UTF-8"), "{err}");
    }
}
