//! PJRT client + compiled-executable cache.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::manifest::{GraphSpec, Manifest};

/// One compiled HLO graph ready to execute.
pub struct LoadedGraph {
    pub spec: GraphSpec,
    exe: xla::PjRtLoadedExecutable,
    /// Compile wall-time (surfaced in logs; PJRT CPU compiles can take
    /// seconds for the larger training graphs).
    pub compile_ms: u128,
}

impl LoadedGraph {
    /// Execute with host literals; returns the flat list of outputs
    /// (the graphs are lowered with return_tuple=True, so the single
    /// result tuple is decomposed here).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!(
                "graph '{}' expects {} inputs, got {}",
                self.spec.key,
                self.spec.inputs.len(),
                inputs.len()
            );
        }
        let result = self.exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            bail!(
                "graph '{}' returned {} outputs, manifest says {}",
                self.spec.key,
                outs.len(),
                self.spec.outputs.len()
            );
        }
        Ok(outs)
    }
}

/// PJRT engine: owns the CPU client, the manifest, and the compile cache.
pub struct Engine {
    pub client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<LoadedGraph>>>,
    pub verbose: bool,
}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            verbose: std::env::var("AHWA_VERBOSE").is_ok(),
        })
    }

    /// Load from the default artifacts location.
    pub fn from_artifacts() -> Result<Engine> {
        let dir = crate::config::manifest::default_artifacts_dir();
        Engine::new(Manifest::load(dir)?)
    }

    /// Fetch (compiling + caching on first use) the graph for `key`.
    pub fn load(&self, key: &str) -> Result<Rc<LoadedGraph>> {
        if let Some(g) = self.cache.borrow().get(key) {
            return Ok(g.clone());
        }
        let spec = self.manifest.graph(key)?.clone();
        let path = self.manifest.hlo_path(&spec);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("PJRT compile of '{key}'"))?;
        let compile_ms = t0.elapsed().as_millis();
        if self.verbose {
            eprintln!("[runtime] compiled '{key}' in {compile_ms} ms");
        }
        let g = Rc::new(LoadedGraph {
            spec,
            exe,
            compile_ms,
        });
        self.cache.borrow_mut().insert(key.to_string(), g.clone());
        Ok(g)
    }

    pub fn cached_graphs(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Total PJRT compile wall-time across cached graphs — the startup
    /// cost each serving worker pays for its private engine, surfaced
    /// in the pool's per-worker metrics.
    pub fn total_compile_ms(&self) -> u128 {
        self.cache.borrow().values().map(|g| g.compile_ms).sum()
    }
}
