//! ALTB tensor-container I/O.
//!
//! Binary format written by `python/compile/aot.py::write_altb` (and by
//! this module for training checkpoints):
//!
//! ```text
//! magic "ALTB" | u32 count | count x {
//!     u16 name_len | name utf-8 | u8 ndim | ndim x u32 dims | f32 data
//! }
//! ```
//! All integers little-endian; data row-major f32.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::params::{ParamStore, Tensor};

pub fn save(path: impl AsRef<Path>, store: &ParamStore) -> Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path.as_ref())?);
    f.write_all(b"ALTB")?;
    f.write_all(&(store.tensors.len() as u32).to_le_bytes())?;
    for t in &store.tensors {
        let nb = t.name.as_bytes();
        f.write_all(&(nb.len() as u16).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        // bulk-write the payload
        let bytes: &[u8] = unsafe {
            std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
        };
        f.write_all(bytes)?;
    }
    Ok(())
}

pub fn load(path: impl AsRef<Path>) -> Result<ParamStore> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path.as_ref())
            .with_context(|| format!("opening checkpoint {}", path.as_ref().display()))?,
    );
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != b"ALTB" {
        bail!("bad magic {:?} in {}", magic, path.as_ref().display());
    }
    let count = read_u32(&mut f)? as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u16(&mut f)? as usize;
        let mut nb = vec![0u8; name_len];
        f.read_exact(&mut nb)?;
        let name = String::from_utf8(nb)?;
        let mut ndim = [0u8; 1];
        f.read_exact(&mut ndim)?;
        let mut shape = Vec::with_capacity(ndim[0] as usize);
        for _ in 0..ndim[0] {
            shape.push(read_u32(&mut f)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut data = vec![0f32; numel];
        let bytes: &mut [u8] = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, numel * 4)
        };
        f.read_exact(bytes)?;
        tensors.push(Tensor { name, shape, data });
    }
    Ok(ParamStore::from_tensors(tensors))
}

fn read_u32(f: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u16(f: &mut impl Read) -> Result<u16> {
    let mut b = [0u8; 2];
    f.read_exact(&mut b)?;
    Ok(u16::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg64::new(1);
        let mut a = Tensor::zeros("layers.0.wq", &[8, 4]);
        rng.fill_normal(&mut a.data, 0.0, 1.0);
        let b = Tensor::zeros("tok_emb", &[16, 2]);
        let store = ParamStore::from_tensors(vec![a.clone(), b]);
        let dir = std::env::temp_dir().join("ahwa_ckpt_test");
        let path = dir.join("t.bin");
        save(&path, &store).unwrap();
        let re = load(&path).unwrap();
        assert_eq!(re.len(), 2);
        let ra = re.get("layers.0.wq").unwrap();
        assert_eq!(ra.shape, vec![8, 4]);
        assert_eq!(ra.data, a.data);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn reads_python_written_init() {
        let dir = crate::config::manifest::default_artifacts_dir();
        let p = dir.join("init/tiny.meta.bin");
        if !p.exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let store = load(&p).unwrap();
        assert!(store.get("tok_emb").is_ok());
        assert!(store.get("layers.0.wq").is_ok());
        // name-sorted canonical order
        let names: Vec<&str> = store.names().collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("ahwa_ckpt_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
