//! Flat, canonically-ordered parameter stores.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::config::manifest::{GraphSpec, IoSpec, Role};
use crate::util::rng::Pcg64;

/// One named f32 tensor.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(name: &str, shape: &[usize]) -> Tensor {
        Tensor {
            name: name.to_string(),
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Row-major matrix view accessors (most analog weights are 2-D).
    pub fn rows(&self) -> usize {
        if self.shape.len() >= 2 {
            self.shape[..self.shape.len() - 1].iter().product()
        } else {
            1
        }
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }
}

/// An ordered collection of named tensors. Order is ALWAYS the canonical
/// (name-sorted) order used by the manifest; `index` allows O(log n)
/// name lookup.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl ParamStore {
    pub fn from_tensors(mut tensors: Vec<Tensor>) -> ParamStore {
        tensors.sort_by(|a, b| a.name.cmp(&b.name));
        let index = tensors
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), i))
            .collect();
        ParamStore { tensors, index }
    }

    /// Zero-initialised store matching a graph's tensors of one role
    /// (used for Adam moment state).
    pub fn zeros_like_role(spec: &GraphSpec, role: Role) -> ParamStore {
        ParamStore::from_tensors(
            spec.inputs_with_role(role)
                .map(|io| Tensor::zeros(&io.name, &io.shape))
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn numel(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.index
            .get(name)
            .map(|&i| &self.tensors[i])
            .ok_or_else(|| anyhow!("tensor '{name}' not in store"))
    }

    pub fn get_mut(&mut self, name: &str) -> Result<&mut Tensor> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("tensor '{name}' not in store"))?;
        Ok(&mut self.tensors[i])
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.tensors.iter().map(|t| t.name.as_str())
    }

    /// Validate that this store exactly matches the graph's expectation
    /// for `role` (names, order, shapes).
    pub fn validate_against(&self, spec: &GraphSpec, role: Role) -> Result<()> {
        let expected: Vec<&IoSpec> = spec.inputs_with_role(role).collect();
        if expected.len() != self.tensors.len() {
            bail!(
                "store has {} tensors, graph '{}' expects {} for {:?}",
                self.tensors.len(),
                spec.key,
                expected.len(),
                role
            );
        }
        for (t, io) in self.tensors.iter().zip(&expected) {
            let want = strip_role_prefix(&io.name, role);
            if t.name != want {
                bail!("tensor order mismatch: '{}' vs manifest '{}'", t.name, want);
            }
            if t.shape != io.shape {
                bail!("shape mismatch for '{}': {:?} vs {:?}", t.name, t.shape, io.shape);
            }
        }
        Ok(())
    }

    /// Gaussian re-initialisation (used by ablations that restart LoRA).
    pub fn reinit_normal(&mut self, sigma: f32, rng: &mut Pcg64) {
        for t in &mut self.tensors {
            rng.fill_normal(&mut t.data, 0.0, sigma);
        }
    }

    /// L2 norm over all tensors (training diagnostics).
    pub fn global_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data.iter())
            .map(|v| (*v as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }
}

/// Manifest meta names carry a "meta." prefix; stores keep bare names.
pub fn strip_role_prefix(name: &str, role: Role) -> String {
    match role {
        Role::Meta => name.strip_prefix("meta.").unwrap_or(name).to_string(),
        _ => name.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> ParamStore {
        ParamStore::from_tensors(vec![
            Tensor::zeros("b", &[2, 3]),
            Tensor::zeros("a", &[4]),
            Tensor::zeros("c.0.x", &[1, 2, 2]),
        ])
    }

    #[test]
    fn canonical_order_is_sorted() {
        let s = store();
        let names: Vec<&str> = s.names().collect();
        assert_eq!(names, vec!["a", "b", "c.0.x"]);
    }

    #[test]
    fn numel_and_lookup() {
        let s = store();
        assert_eq!(s.numel(), 4 + 6 + 4);
        assert_eq!(s.get("b").unwrap().rows(), 2);
        assert_eq!(s.get("c.0.x").unwrap().rows(), 2);
        assert_eq!(s.get("c.0.x").unwrap().cols(), 2);
        assert!(s.get("zz").is_err());
    }

    #[test]
    fn strip_prefix_only_for_meta() {
        assert_eq!(strip_role_prefix("meta.layers.0.wq", Role::Meta), "layers.0.wq");
        assert_eq!(strip_role_prefix("lora.layers.0.wq_a", Role::Train), "lora.layers.0.wq_a");
    }

    #[test]
    fn global_norm() {
        let mut s = store();
        s.get_mut("a").unwrap().data = vec![3.0, 4.0, 0.0, 0.0];
        assert!((s.global_norm() - 5.0).abs() < 1e-9);
    }
}
