//! Named LoRA adapter sets — the unit of multi-task serving.
//!
//! The paper's Table III scenario: ONE analog base model, N adapter
//! sets (1.6 M params each at proxy scale), hot-swapped on the DPUs to
//! switch tasks without touching the AIMC arrays. An [`AdapterRegistry`]
//! owns the sets; `serve::registry` wraps it behind a lock for the
//! concurrent server.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::params::ParamStore;

/// Metadata for one adapter set.
#[derive(Clone, Debug)]
pub struct AdapterInfo {
    pub task: String,
    /// LoRA + head parameter count (the paper's "1.6M per task").
    pub n_params: usize,
    /// Monotone version, bumped on every re-deployment (dynamic
    /// adaptation / refresh after hardware degradation).
    pub version: u64,
}

/// Adapter sets are held behind `Arc` so readers (the serving workers)
/// take O(pointer) snapshots instead of cloning megabytes of LoRA
/// weights per batch; a redeploy installs a fresh `Arc` while in-flight
/// batches keep the snapshot they started with.
#[derive(Default)]
pub struct AdapterRegistry {
    sets: BTreeMap<String, (AdapterInfo, Arc<ParamStore>)>,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deploy (or re-deploy) an adapter set for `task`. Returns the new
    /// version number. This is the paper's "updating the 1.6M LoRA
    /// weights" operation — O(adapter), never O(base model).
    pub fn deploy(&mut self, task: &str, params: ParamStore) -> u64 {
        let n_params = params.numel();
        let version = self.sets.get(task).map(|(i, _)| i.version + 1).unwrap_or(1);
        self.sets.insert(
            task.to_string(),
            (
                AdapterInfo {
                    task: task.to_string(),
                    n_params,
                    version,
                },
                Arc::new(params),
            ),
        );
        version
    }

    /// Compare-and-swap deploy: install `params` only if the live
    /// version is still `expected` (`expected == 0` means "task not
    /// deployed yet"). Returns the new version, or `None` when a
    /// concurrent deploy won the race — the caller's refit was computed
    /// against a stale adapter and must not clobber the newer one.
    pub fn deploy_if_version(
        &mut self,
        task: &str,
        params: ParamStore,
        expected: u64,
    ) -> Option<u64> {
        let live = self.sets.get(task).map(|(i, _)| i.version).unwrap_or(0);
        if live != expected {
            return None;
        }
        Some(self.deploy(task, params))
    }

    pub fn get(&self, task: &str) -> Result<&Arc<ParamStore>> {
        self.sets
            .get(task)
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow!("no adapter deployed for task '{task}'"))
    }

    /// Adapter + version read together (no torn view across a redeploy).
    pub fn snapshot(&self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        self.sets.get(task).map(|(i, p)| (p.clone(), i.version))
    }

    pub fn contains(&self, task: &str) -> bool {
        self.sets.contains_key(task)
    }

    pub fn info(&self, task: &str) -> Option<&AdapterInfo> {
        self.sets.get(task).map(|(i, _)| i)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.sets.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Total adapter parameters across tasks (Table III accounting:
    /// N×1.6M on DPUs vs N full models on N chips).
    pub fn total_params(&self) -> usize {
        self.sets.values().map(|(i, _)| i.n_params).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;

    fn adapter(n: usize) -> ParamStore {
        ParamStore::from_tensors(vec![Tensor::zeros("lora.layers.0.wq_a", &[n, 8])])
    }

    #[test]
    fn deploy_and_get() {
        let mut r = AdapterRegistry::new();
        assert_eq!(r.deploy("sst2", adapter(16)), 1);
        assert_eq!(r.deploy("mnli", adapter(16)), 1);
        assert!(r.get("sst2").is_ok());
        assert!(r.get("qqp").is_err());
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn redeploy_bumps_version() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        assert_eq!(r.deploy("sst2", adapter(16)), 2);
        assert_eq!(r.info("sst2").unwrap().version, 2);
    }

    #[test]
    fn deploy_if_version_is_a_cas() {
        let mut r = AdapterRegistry::new();
        // expected 0 = "not deployed yet"
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 0), Some(1));
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 0), None);
        // matching expectation wins, stale expectation loses
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 1), Some(2));
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 1), None);
        assert_eq!(r.info("sst2").unwrap().version, 2);
    }

    #[test]
    fn snapshot_is_shared_not_cloned() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        let (a, v1) = r.snapshot("sst2").unwrap();
        let (b, _) = r.snapshot("sst2").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "snapshots must share one allocation");
        assert_eq!(v1, 1);
        // redeploy installs a NEW Arc; old snapshots stay valid
        r.deploy("sst2", adapter(16));
        let (c, v2) = r.snapshot("sst2").unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(v2, 2);
        assert_eq!(a.numel(), 16 * 8);
        assert!(r.snapshot("missing").is_none());
    }

    #[test]
    fn total_params_sums_tasks() {
        let mut r = AdapterRegistry::new();
        r.deploy("a", adapter(4));
        r.deploy("b", adapter(8));
        assert_eq!(r.total_params(), 4 * 8 + 8 * 8);
    }
}
