//! Named LoRA adapter sets — the unit of multi-task serving.
//!
//! The paper's Table III scenario: ONE analog base model, N adapter
//! sets (1.6 M params each at proxy scale), hot-swapped on the DPUs to
//! switch tasks without touching the AIMC arrays. An [`AdapterRegistry`]
//! owns the sets; `serve::registry` wraps it behind a lock for the
//! concurrent server.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::params::ParamStore;

/// Metadata for one adapter set.
#[derive(Clone, Debug)]
pub struct AdapterInfo {
    pub task: String,
    /// LoRA + head parameter count (the paper's "1.6M per task").
    pub n_params: usize,
    /// Monotone version, bumped on every re-deployment (dynamic
    /// adaptation / refresh after hardware degradation).
    pub version: u64,
}

/// Adapter sets are held behind `Arc` so readers (the serving workers)
/// take O(pointer) snapshots instead of cloning megabytes of LoRA
/// weights per batch; a redeploy installs a fresh `Arc` while in-flight
/// batches keep the snapshot they started with.
#[derive(Default)]
pub struct AdapterRegistry {
    sets: BTreeMap<String, (AdapterInfo, Arc<ParamStore>)>,
    /// Version counters of evicted tasks. An eviction (adapter paged off
    /// the DPUs by the capacity tier) is NOT a forget: the task keeps its
    /// place in the version sequence so a later deploy stays monotone and
    /// a restore of the same bytes comes back at the same version.
    retired: BTreeMap<String, u64>,
}

impl AdapterRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Deploy (or re-deploy) an adapter set for `task`. Returns the new
    /// version number. This is the paper's "updating the 1.6M LoRA
    /// weights" operation — O(adapter), never O(base model).
    pub fn deploy(&mut self, task: &str, params: ParamStore) -> u64 {
        let n_params = params.numel();
        // Continue the version sequence across evictions: a redeploy of
        // an evicted task must not reuse version numbers that in-flight
        // snapshots or the refresh tracker may still hold.
        let prior = self
            .sets
            .get(task)
            .map(|(i, _)| i.version)
            .or_else(|| self.retired.get(task).copied())
            .unwrap_or(0);
        let version = prior + 1;
        self.retired.remove(task);
        self.sets.insert(
            task.to_string(),
            (
                AdapterInfo {
                    task: task.to_string(),
                    n_params,
                    version,
                },
                Arc::new(params),
            ),
        );
        version
    }

    /// Compare-and-swap deploy: install `params` only if the live
    /// version is still `expected` (`expected == 0` means "task not
    /// deployed yet"). Returns the new version, or `None` when a
    /// concurrent deploy won the race — the caller's refit was computed
    /// against a stale adapter and must not clobber the newer one.
    /// An evicted task always loses the CAS: the refit was computed for
    /// an adapter that is no longer resident, and landing it would
    /// resurrect the task behind the capacity tier's back. Re-load goes
    /// through [`AdapterRegistry::restore`] instead.
    pub fn deploy_if_version(
        &mut self,
        task: &str,
        params: ParamStore,
        expected: u64,
    ) -> Option<u64> {
        if !self.sets.contains_key(task) && self.retired.contains_key(task) {
            return None;
        }
        let live = self.sets.get(task).map(|(i, _)| i.version).unwrap_or(0);
        if live != expected {
            return None;
        }
        Some(self.deploy(task, params))
    }

    /// Page an adapter out (capacity eviction). The entry is removed —
    /// readers miss from now on — but the version counter is retained so
    /// the task's version sequence survives the residency gap. Returns
    /// the evicted adapter + its version (the bytes the cache keeps in
    /// host memory for a later [`AdapterRegistry::restore`]).
    pub fn evict(&mut self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        let (info, params) = self.sets.remove(task)?;
        self.retired.insert(task.to_string(), info.version);
        Some((params, info.version))
    }

    /// Re-install a previously evicted adapter at its ORIGINAL version:
    /// same bytes, same version — a reload is not a new deployment, and
    /// keeping the version stable is what lets the drift-refresh tracker
    /// recognise the adapter and preserve its drift anchor. Refuses
    /// (`false`) when the task is live again (a concurrent deploy won)
    /// or when `version` is not the version that was evicted (the cached
    /// bytes are stale).
    pub fn restore(&mut self, task: &str, params: Arc<ParamStore>, version: u64) -> bool {
        if self.sets.contains_key(task) || self.retired.get(task) != Some(&version) {
            return false;
        }
        self.retired.remove(task);
        let n_params = params.numel();
        self.sets.insert(
            task.to_string(),
            (
                AdapterInfo {
                    task: task.to_string(),
                    n_params,
                    version,
                },
                params,
            ),
        );
        true
    }

    /// Task was deployed at some point and is currently paged out.
    pub fn is_evicted(&self, task: &str) -> bool {
        !self.sets.contains_key(task) && self.retired.contains_key(task)
    }

    pub fn get(&self, task: &str) -> Result<&Arc<ParamStore>> {
        self.sets
            .get(task)
            .map(|(_, p)| p)
            .ok_or_else(|| anyhow!("no adapter deployed for task '{task}'"))
    }

    /// Adapter + version read together (no torn view across a redeploy).
    pub fn snapshot(&self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        self.sets.get(task).map(|(i, p)| (p.clone(), i.version))
    }

    pub fn contains(&self, task: &str) -> bool {
        self.sets.contains_key(task)
    }

    pub fn info(&self, task: &str) -> Option<&AdapterInfo> {
        self.sets.get(task).map(|(i, _)| i)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.sets.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.sets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sets.is_empty()
    }

    /// Total adapter parameters across tasks (Table III accounting:
    /// N×1.6M on DPUs vs N full models on N chips).
    pub fn total_params(&self) -> usize {
        self.sets.values().map(|(i, _)| i.n_params).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;

    fn adapter(n: usize) -> ParamStore {
        ParamStore::from_tensors(vec![Tensor::zeros("lora.layers.0.wq_a", &[n, 8])])
    }

    #[test]
    fn deploy_and_get() {
        let mut r = AdapterRegistry::new();
        assert_eq!(r.deploy("sst2", adapter(16)), 1);
        assert_eq!(r.deploy("mnli", adapter(16)), 1);
        assert!(r.get("sst2").is_ok());
        assert!(r.get("qqp").is_err());
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn redeploy_bumps_version() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        assert_eq!(r.deploy("sst2", adapter(16)), 2);
        assert_eq!(r.info("sst2").unwrap().version, 2);
    }

    #[test]
    fn deploy_if_version_is_a_cas() {
        let mut r = AdapterRegistry::new();
        // expected 0 = "not deployed yet"
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 0), Some(1));
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 0), None);
        // matching expectation wins, stale expectation loses
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 1), Some(2));
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 1), None);
        assert_eq!(r.info("sst2").unwrap().version, 2);
    }

    #[test]
    fn snapshot_is_shared_not_cloned() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        let (a, v1) = r.snapshot("sst2").unwrap();
        let (b, _) = r.snapshot("sst2").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "snapshots must share one allocation");
        assert_eq!(v1, 1);
        // redeploy installs a NEW Arc; old snapshots stay valid
        r.deploy("sst2", adapter(16));
        let (c, v2) = r.snapshot("sst2").unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(v2, 2);
        assert_eq!(a.numel(), 16 * 8);
        assert!(r.snapshot("missing").is_none());
    }

    #[test]
    fn evict_retains_version_sequence() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        r.deploy("sst2", adapter(16)); // v2
        let (params, v) = r.evict("sst2").unwrap();
        assert_eq!(v, 2);
        assert_eq!(params.numel(), 16 * 8);
        assert!(!r.contains("sst2"));
        assert!(r.is_evicted("sst2"));
        assert!(r.snapshot("sst2").is_none());
        // a fresh deploy continues the sequence, never restarts at 1
        assert_eq!(r.deploy("sst2", adapter(16)), 3);
        assert!(!r.is_evicted("sst2"));
        assert!(r.evict("missing").is_none());
    }

    #[test]
    fn restore_reinstalls_at_original_version() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        let (params, v) = r.evict("sst2").unwrap();
        assert!(r.restore("sst2", params.clone(), v));
        assert_eq!(r.info("sst2").unwrap().version, 1, "reload is not a redeploy");
        // double-restore refuses (already live)
        assert!(!r.restore("sst2", params, v));
    }

    #[test]
    fn restore_loses_to_concurrent_deploy_and_stale_bytes() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        let (params, v) = r.evict("sst2").unwrap();
        // concurrent manual deploy wins the race; restore must refuse
        assert_eq!(r.deploy("sst2", adapter(16)), 2);
        assert!(!r.restore("sst2", params.clone(), v));
        assert_eq!(r.info("sst2").unwrap().version, 2);
        // stale-version bytes refuse even when the task is evicted
        let (p2, v2) = r.evict("sst2").unwrap();
        assert!(!r.restore("sst2", params, v));
        assert!(r.restore("sst2", p2, v2));
    }

    #[test]
    fn cas_never_resurrects_an_evicted_task() {
        let mut r = AdapterRegistry::new();
        r.deploy("sst2", adapter(16));
        r.evict("sst2").unwrap();
        // the refresh worker's CAS must lose for every expectation:
        // 0 ("not deployed") would bypass the capacity tier, and the
        // evicted version would land a refit nobody can serve.
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 0), None);
        assert_eq!(r.deploy_if_version("sst2", adapter(16), 1), None);
        assert!(r.is_evicted("sst2"));
    }

    #[test]
    fn total_params_sums_tasks() {
        let mut r = AdapterRegistry::new();
        r.deploy("a", adapter(4));
        r.deploy("b", adapter(8));
        assert_eq!(r.total_params(), 4 * 8 + 8 * 8);
    }
}
