//! Parameter trees, checkpoints, and LoRA adapter sets.
//!
//! Parameters cross the rust↔HLO boundary as flat, name-sorted tensor
//! lists (the canonical order defined by `model.py::flatten_params` and
//! recorded per graph in the manifest). [`params`] stores them;
//! [`checkpoint`] persists them in the ALTB container written by
//! `aot.py`; [`lora`] manages named adapter sets for multi-task serving.

pub mod checkpoint;
pub mod lora;
pub mod params;
