//! # AHWA-LoRA — analog-hardware-aware low-rank adaptation, reproduced
//!
//! Rust reproduction of *"Efficient transformer adaptation for analog
//! in-memory computing via low-rank adapters"* (Li, Ferro, Lammie,
//! Le Gallo, Boybat, Rajendran — CS.AR 2024).
//!
//! This crate is **Layer 3** of the three-layer stack described in
//! `DESIGN.md`: it owns every runtime path — the training-loop driver,
//! the PCM/AIMC device simulation, the drift-evaluation harness, the
//! multi-task LoRA serving coordinator, and the AIMC⇄PMCA latency
//! pipeline model. The JAX/Pallas layers (L2/L1) run once at build time
//! (`make artifacts`) and are loaded here as AOT-compiled HLO via PJRT
//! (the `xla` crate); python is never on a request path.
//!
//! Module map (see `DESIGN.md` §System inventory):
//!
//! * [`util`] — infrastructure the offline image lacks crates for:
//!   JSON, PCG RNG, stats, CLI, tables.
//! * [`config`] — manifest-driven model/hardware/training configuration.
//! * [`pcm`] — statistical PCM device model (programming noise, drift,
//!   read noise, global drift compensation).
//! * [`aimc`] — crossbar tile model: differential channel-wise mapping,
//!   clipping, tile allocation, quantization.
//! * [`pmca`] — RISC-V (Snitch + RedMulE) programmable multi-core
//!   accelerator performance model.
//! * [`pipeline`] — AIMC⇄PMCA pipeline scheduler and latency balancing.
//! * [`runtime`] — PJRT artifact store + manifest-driven literal packing.
//! * [`model`] — parameter trees, LoRA adapter sets, checkpoint I/O.
//! * [`data`] — synthetic task suite (SQuAD-like, GLUE-like, instruction,
//!   GSM-like) standing in for the paper's corpora (DESIGN.md
//!   §Substitutions).
//! * [`train`] — AHWA-LoRA / full-AHWA training drivers + memory model.
//! * [`rl`] — GRPO reinforcement-learning driver (rewards, sampling).
//! * [`eval`] — drift evaluation harness + metric zoo.
//! * [`serve`] — multi-task serving: typed builder/client API
//!   (`serve::api`), sharded engine pool with bounded admission and
//!   backpressure, per-task dynamic batcher, `Arc`-snapshot adapter
//!   registry.
//! * [`experiments`] — one driver per paper table/figure.

pub mod aimc;
pub mod config;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod model;
pub mod pcm;
pub mod pipeline;
pub mod pmca;
pub mod rl;
pub mod runtime;
pub mod serve;
pub mod train;
pub mod util;
