//! Synthetic span-extraction QA (SQuAD v1.1 stand-in).
//!
//! Each passage embeds an *entity span*: an entity marker from a small
//! reserved pool (token ids 25–31), followed by 1–3 content tokens,
//! closed by a delimiter. The question names the entity
//! (`[CLS] Q <entity> [SEP] …`) and the answer is the whole span,
//! marker through delimiter inclusive. Both span edges are therefore
//! token-identity detections, which a proxy-scale encoder learns from
//! scratch to ~zero loss; edges defined by *relative* position (or
//! distractor entities requiring query matching) measurably do NOT
//! train at this scale — see DESIGN.md §Substitutions for the
//! learnability study. `n_distractors` is kept configurable for larger
//! substrates. F1/EM are token-overlap / exact-span, exactly as SQuAD.

use super::tokenizer::{CLS, CONTENT_START, QTOK, SEP};
use crate::util::rng::Pcg64;

/// Entity-marker pool (reserved ids below CONTENT_START).
pub const ENTITY_POOL: [i32; 7] = [25, 26, 27, 28, 29, 30, 31];
/// Span delimiter token.
pub const DELIM: i32 = 24;

#[derive(Clone, Debug)]
pub struct SquadTask {
    pub vocab: usize,
    pub seq: usize,
    /// Maximum answer span length in content tokens (marker adds 1).
    pub max_span: usize,
    /// Distractor entity spans per passage.
    pub n_distractors: usize,
}

/// One batch of QA examples as graph-ready flat arrays.
#[derive(Clone, Debug)]
pub struct QaBatch {
    pub tokens: Vec<i32>, // [b, seq]
    pub starts: Vec<i32>, // [b]
    pub ends: Vec<i32>,   // [b]
    pub b: usize,
    pub seq: usize,
}

impl SquadTask {
    pub fn new(vocab: usize, seq: usize) -> SquadTask {
        // short test sequences get a reduced layout that still fits
        let max_span = if seq < 32 { 2 } else { 3 };
        SquadTask {
            vocab,
            seq,
            max_span,
            n_distractors: 0,
        }
    }

    const Q_LEN: usize = 4; // [CLS] QTOK entity [SEP]

    /// Generate one example; returns (tokens, start, end), span
    /// inclusive: tokens[start] is the entity marker, tokens[end] the
    /// closing delimiter.
    pub fn example(&self, rng: &mut Pcg64) -> (Vec<i32>, usize, usize) {
        let content = (self.vocab - CONTENT_START as usize) as i32;
        debug_assert!(content > 8, "vocab too small for QA task");

        let n_entities = 1 + self.n_distractors;
        let picks = rng.choose(ENTITY_POOL.len(), n_entities);

        let mut toks = vec![0i32; self.seq];
        toks[0] = CLS;
        toks[1] = QTOK;
        toks[2] = ENTITY_POOL[picks[0]];
        toks[3] = SEP;
        for t in toks.iter_mut().skip(Self::Q_LEN) {
            *t = CONTENT_START + rng.below(content as usize) as i32;
        }

        // place disjoint entity spans: marker + span + delim needs
        // max_span + 2 slots; keep a gap so spans never merge
        let mut slots: Vec<(usize, usize)> = Vec::with_capacity(n_entities);
        let lo = Self::Q_LEN;
        let hi = self.seq - (self.max_span + 2);
        let mut guard = 0;
        while slots.len() < n_entities {
            guard += 1;
            assert!(guard < 10_000, "seq too short for entity layout");
            let span_len = 1 + rng.below(self.max_span);
            let p = lo + rng.below(hi - lo + 1);
            if slots
                .iter()
                .all(|&(q, ql)| p + span_len + 1 < q || q + ql + 1 < p)
            {
                slots.push((p, span_len));
            }
        }
        for (slot, &pick) in slots.iter().zip(&picks) {
            let (p, span_len) = *slot;
            toks[p] = ENTITY_POOL[pick];
            toks[p + span_len + 1] = DELIM;
        }
        let (p, span_len) = slots[0];
        (toks, p, p + span_len + 1)
    }

    pub fn batch(&self, b: usize, rng: &mut Pcg64) -> QaBatch {
        let mut tokens = Vec::with_capacity(b * self.seq);
        let mut starts = Vec::with_capacity(b);
        let mut ends = Vec::with_capacity(b);
        for _ in 0..b {
            let (t, s, e) = self.example(rng);
            tokens.extend_from_slice(&t);
            starts.push(s as i32);
            ends.push(e as i32);
        }
        QaBatch {
            tokens,
            starts,
            ends,
            b,
            seq: self.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn example_structure() {
        proptest::check("squad-structure", 30, |g| {
            let task = SquadTask::new(*g.pick(&[64usize, 512]), 48);
            let mut rng = Pcg64::new(g.seed);
            let (toks, s, e) = task.example(&mut rng);
            assert_eq!(toks.len(), task.seq);
            assert_eq!(toks[0], CLS);
            assert_eq!(toks[3], SEP);
            let entity = toks[2];
            assert!(ENTITY_POOL.contains(&entity));
            // the gold span starts at the queried entity's marker
            assert_eq!(toks[s], entity);
            // and ends on the delimiter
            assert_eq!(toks[e], DELIM);
            assert!(e > s && e < task.seq);
            assert!(e - s <= task.max_span + 1);
            // gold entity appears exactly once in the passage
            let occ = (4..task.seq).filter(|&i| toks[i] == entity).count();
            assert_eq!(occ, 1);
            // distractor entities present
            let n_markers = (4..task.seq)
                .filter(|&i| ENTITY_POOL.contains(&toks[i]))
                .count();
            assert_eq!(n_markers, 1 + task.n_distractors);
            assert_eq!(task.n_distractors, 0); // default: see module docs
        });
    }

    #[test]
    fn tiny_seq_still_fits() {
        let task = SquadTask {
            vocab: 64,
            seq: 16,
            max_span: 2,
            n_distractors: 1,
        };
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let (toks, s, e) = task.example(&mut rng);
            assert_eq!(toks.len(), 16);
            assert!(e < 16 && s >= 4);
        }
    }

    #[test]
    fn batch_shapes() {
        let task = SquadTask::new(512, 48);
        let mut rng = Pcg64::new(9);
        let b = task.batch(8, &mut rng);
        assert_eq!(b.tokens.len(), 8 * 48);
        assert_eq!(b.starts.len(), 8);
        assert!(b.starts.iter().zip(&b.ends).all(|(s, e)| e >= s));
    }

    #[test]
    fn deterministic_in_seed() {
        let task = SquadTask::new(512, 48);
        let a = task.batch(4, &mut Pcg64::new(5));
        let b = task.batch(4, &mut Pcg64::new(5));
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.starts, b.starts);
    }
}
