//! Synthetic instruction-tuning corpus (Alpaca stand-in) and zero-shot
//! evaluation suites (the Table IV benchmark battery stand-in).
//!
//! A prompt is `[BOS] <type> src… [SEP]` and the target response is a
//! deterministic transform of `src` selected by the instruction type:
//! copy, reverse, or +1-map over content ids. SFT supervises response
//! positions only (mask). The three *eval suites* reuse the same
//! machinery with held-out source sequences; suite accuracy is
//! greedy-decode exact-match, playing the role of the paper's
//! HellaSwag/BoolQ/PIQA battery.

use super::tokenizer::{BOS, CONTENT_START, EOS, SEP};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Instruction {
    Copy,
    Reverse,
    MapPlusOne,
}

pub const ALL_INSTRUCTIONS: [Instruction; 3] = [Instruction::Copy, Instruction::Reverse, Instruction::MapPlusOne];

impl Instruction {
    /// Instruction-type token (drawn from the low content range so tiny
    /// vocabs still work).
    pub fn type_token(&self) -> i32 {
        match self {
            Instruction::Copy => CONTENT_START,
            Instruction::Reverse => CONTENT_START + 1,
            Instruction::MapPlusOne => CONTENT_START + 2,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Instruction::Copy => "copy-suite",
            Instruction::Reverse => "reverse-suite",
            Instruction::MapPlusOne => "map-suite",
        }
    }

    pub fn apply(&self, src: &[i32], vocab: usize) -> Vec<i32> {
        match self {
            Instruction::Copy => src.to_vec(),
            Instruction::Reverse => src.iter().rev().copied().collect(),
            Instruction::MapPlusOne => {
                let (lo, hi) = source_alphabet(vocab);
                src.iter()
                    .map(|&t| if t + 1 >= hi { lo } else { t + 1 })
                    .collect()
            }
        }
    }
}

/// Source tokens come from a small alphabet (32 symbols) so the +1-map
/// instruction is learnable at proxy scale — the model must learn the
/// full permutation table, which is feasible over 32 symbols but not
/// over the whole content vocabulary.
pub fn source_alphabet(vocab: usize) -> (i32, i32) {
    let lo = CONTENT_START + 3; // skip the 3 instruction-type tokens
    let hi = (lo + 32).min(vocab as i32);
    (lo, hi)
}

/// One supervised LM example: full token buffer + response mask.
#[derive(Clone, Debug)]
pub struct LmExample {
    pub tokens: Vec<i32>,
    pub mask: Vec<f32>,
    /// Index of the first response token.
    pub response_start: usize,
    pub response: Vec<i32>,
}

#[derive(Clone, Debug)]
pub struct InstructTask {
    pub vocab: usize,
    pub seq: usize,
    pub src_len: usize,
}

impl InstructTask {
    pub fn new(vocab: usize, seq: usize) -> InstructTask {
        // prompt = BOS + type + src + SEP; response = src_len + EOS
        let src_len = ((seq - 4) / 2).min(6);
        InstructTask { vocab, seq, src_len }
    }

    pub fn example(&self, kind: Instruction, rng: &mut Pcg64) -> LmExample {
        let (lo, hi) = source_alphabet(self.vocab);
        let src: Vec<i32> = (0..self.src_len)
            .map(|_| lo + rng.below((hi - lo) as usize) as i32)
            .collect();
        let resp = kind.apply(&src, self.vocab);

        let mut tokens = vec![0i32; self.seq];
        let mut mask = vec![0f32; self.seq];
        tokens[0] = BOS;
        tokens[1] = kind.type_token();
        for (i, &s) in src.iter().enumerate() {
            tokens[2 + i] = s;
        }
        let sep_at = 2 + src.len();
        tokens[sep_at] = SEP;
        let response_start = sep_at + 1;
        for (j, &t) in resp.iter().enumerate() {
            tokens[response_start + j] = t;
            mask[response_start + j] = 1.0;
        }
        tokens[response_start + resp.len()] = EOS;
        mask[response_start + resp.len()] = 1.0;
        LmExample {
            tokens,
            mask,
            response_start,
            response: resp,
        }
    }

    /// Mixed-instruction SFT batch (graph-ready flat arrays).
    pub fn batch(&self, b: usize, rng: &mut Pcg64) -> (Vec<i32>, Vec<f32>) {
        let mut tokens = Vec::with_capacity(b * self.seq);
        let mut mask = Vec::with_capacity(b * self.seq);
        for _ in 0..b {
            let kind = *ALL_INSTRUCTIONS.get(rng.below(3)).unwrap();
            let ex = self.example(kind, rng);
            tokens.extend_from_slice(&ex.tokens);
            mask.extend_from_slice(&ex.mask);
        }
        (tokens, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transforms_are_correct() {
        let src = vec![40, 41, 45];
        assert_eq!(Instruction::Copy.apply(&src, 64), vec![40, 41, 45]);
        assert_eq!(Instruction::Reverse.apply(&src, 64), vec![45, 41, 40]);
        assert_eq!(Instruction::MapPlusOne.apply(&src, 64), vec![41, 42, 46]);
        // wraparound at the source-alphabet edge
        let (lo, hi) = source_alphabet(64);
        assert_eq!(Instruction::MapPlusOne.apply(&[hi - 1], 64), vec![lo]);
    }

    #[test]
    fn example_layout() {
        let task = InstructTask::new(512, 64);
        let mut rng = Pcg64::new(1);
        let ex = task.example(Instruction::Reverse, &mut rng);
        assert_eq!(ex.tokens[0], BOS);
        assert_eq!(ex.tokens[1], Instruction::Reverse.type_token());
        assert_eq!(ex.tokens[ex.response_start - 1], SEP);
        // mask covers exactly response + EOS
        let n_masked = ex.mask.iter().filter(|&&m| m > 0.0).count();
        assert_eq!(n_masked, ex.response.len() + 1);
        // response tokens appear at the masked positions
        for (j, &t) in ex.response.iter().enumerate() {
            assert_eq!(ex.tokens[ex.response_start + j], t);
        }
    }

    #[test]
    fn fits_sequence() {
        for seq in [16usize, 32, 64] {
            let task = InstructTask::new(64, seq);
            let mut rng = Pcg64::new(2);
            for kind in ALL_INSTRUCTIONS {
                let ex = task.example(kind, &mut rng);
                assert_eq!(ex.tokens.len(), seq);
                assert!(ex.response_start + ex.response.len() + 1 <= seq);
            }
        }
    }

    /// The decode conformance suite replays generator output across
    /// processes: identical seeds must reproduce examples and batches
    /// bit-for-bit, and distinct streams must actually diverge.
    #[test]
    fn examples_and_batches_are_seed_deterministic() {
        let task = InstructTask::new(128, 32);
        for kind in ALL_INSTRUCTIONS {
            let a = task.example(kind, &mut Pcg64::with_stream(7, 3));
            let b = task.example(kind, &mut Pcg64::with_stream(7, 3));
            assert_eq!(a.tokens, b.tokens);
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.response, b.response);
        }
        let (t1, m1) = task.batch(6, &mut Pcg64::new(11));
        let (t2, m2) = task.batch(6, &mut Pcg64::new(11));
        assert_eq!(t1, t2);
        assert_eq!(m1, m2);
        let (t3, _) = task.batch(6, &mut Pcg64::new(12));
        assert_ne!(t1, t3, "different seeds must produce different batches");
    }

    /// EOS placement: exactly one EOS per example, directly after the
    /// response, supervised (masked), with only padding behind it.
    #[test]
    fn eos_terminates_every_response() {
        let task = InstructTask::new(128, 32);
        let mut rng = Pcg64::new(5);
        for kind in ALL_INSTRUCTIONS {
            for _ in 0..20 {
                let ex = task.example(kind, &mut rng);
                let eos_at = ex.response_start + ex.response.len();
                assert_eq!(ex.tokens[eos_at], EOS);
                assert_eq!(ex.mask[eos_at], 1.0, "EOS is a supervised position");
                assert_eq!(
                    ex.tokens.iter().filter(|&&t| t == EOS).count(),
                    1,
                    "exactly one EOS per example"
                );
                assert!(
                    ex.tokens[eos_at + 1..].iter().all(|&t| t == 0),
                    "nothing but padding after EOS"
                );
                assert!(
                    ex.mask[eos_at + 1..].iter().all(|&m| m == 0.0),
                    "padding is never supervised"
                );
            }
        }
    }

    /// Prompt/target shape invariants of batched output: row-major
    /// `[b, seq]`, every row `[BOS] <type> src… [SEP] resp… EOS`, source
    /// tokens drawn from the small source alphabet.
    #[test]
    fn batch_rows_keep_the_prompt_shape() {
        let (vocab, seq, b) = (128usize, 32usize, 8usize);
        let task = InstructTask::new(vocab, seq);
        let (tokens, mask) = task.batch(b, &mut Pcg64::new(9));
        assert_eq!(tokens.len(), b * seq);
        assert_eq!(mask.len(), b * seq);
        let (lo, hi) = source_alphabet(vocab);
        let type_tokens: Vec<i32> = ALL_INSTRUCTIONS.iter().map(|k| k.type_token()).collect();
        for row in 0..b {
            let t = &tokens[row * seq..(row + 1) * seq];
            let m = &mask[row * seq..(row + 1) * seq];
            assert_eq!(t[0], BOS);
            assert!(type_tokens.contains(&t[1]), "row {row}: bad type token {}", t[1]);
            assert_eq!(t[2 + task.src_len], SEP);
            for (i, &s) in t[2..2 + task.src_len].iter().enumerate() {
                assert!((lo..hi).contains(&s), "row {row} src[{i}] = {s} outside alphabet");
            }
            // prompt positions are never supervised
            assert!(m[..2 + task.src_len + 1].iter().all(|&x| x == 0.0));
            // response + EOS are: src_len transformed tokens, then EOS
            let resp_start = 2 + task.src_len + 1;
            assert_eq!(t[resp_start + task.src_len], EOS);
            assert!(m[resp_start..=resp_start + task.src_len].iter().all(|&x| x == 1.0));
        }
    }
}
