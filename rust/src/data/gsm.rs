//! Synthetic multi-step arithmetic with chain-of-thought (GSM8K
//! stand-in for the RL experiments, Table V / Supp. Note 3).
//!
//! Problem: `a + b = ?` with a, b < 50. The model is trained (via GRPO)
//! to emit the paper's exact output grammar:
//!
//! `<start_working_out> a-digits + b-digits <end_working_out>
//!  <SOLUTION> c-digits </SOLUTION>`
//!
//! Rewards (4 components, max 9.5 — Methods: "maximum achievable reward
//! of 9.5") live in `rl::reward` and parse this format.

use super::tokenizer::{encode_number, BOS, EQUALS, PLUS, SEP};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct GsmProblem {
    pub a: u32,
    pub b: u32,
    /// Prompt tokens: [BOS] a + b = [SEP]
    pub prompt: Vec<i32>,
}

impl GsmProblem {
    pub fn answer(&self) -> u32 {
        self.a + self.b
    }

    /// The ideal completion in the required format (reference policy /
    /// format oracle for tests).
    pub fn ideal_completion(&self) -> Vec<i32> {
        use super::tokenizer::{EOW, ESOL, SOL, SOW};
        let mut out = vec![SOW];
        encode_number(self.a, &mut out);
        out.push(PLUS);
        encode_number(self.b, &mut out);
        out.push(EOW);
        out.push(SOL);
        encode_number(self.answer(), &mut out);
        out.push(ESOL);
        out
    }
}

#[derive(Clone, Debug)]
pub struct GsmTask {
    pub seq: usize,
    pub max_operand: u32,
}

impl GsmTask {
    pub fn new(seq: usize) -> GsmTask {
        GsmTask {
            seq,
            max_operand: 50,
        }
    }

    pub fn problem(&self, rng: &mut Pcg64) -> GsmProblem {
        let a = rng.below(self.max_operand as usize) as u32;
        let b = rng.below(self.max_operand as usize) as u32;
        let mut prompt = vec![BOS];
        encode_number(a, &mut prompt);
        prompt.push(PLUS);
        encode_number(b, &mut prompt);
        prompt.push(EQUALS);
        prompt.push(SEP);
        GsmProblem { a, b, prompt }
    }

    /// SFT-style batch of ideal completions (used to warm-start the
    /// policy and for the "digital post-LoRA" baseline row of Table V).
    pub fn sft_batch(&self, b: usize, rng: &mut Pcg64) -> (Vec<i32>, Vec<f32>) {
        let mut tokens = Vec::with_capacity(b * self.seq);
        let mut mask = Vec::with_capacity(b * self.seq);
        for _ in 0..b {
            let p = self.problem(rng);
            let mut toks = p.prompt.clone();
            let start = toks.len();
            toks.extend(p.ideal_completion());
            toks.resize(self.seq, super::tokenizer::PAD);
            let mut m = vec![0f32; self.seq];
            let end = (start + p.ideal_completion().len()).min(self.seq);
            for v in m.iter_mut().take(end).skip(start) {
                *v = 1.0;
            }
            tokens.extend_from_slice(&toks);
            mask.extend_from_slice(&m);
        }
        (tokens, mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::{decode_number, ESOL, SOL};

    #[test]
    fn prompt_layout() {
        let task = GsmTask::new(64);
        let mut rng = Pcg64::new(1);
        let p = task.problem(&mut rng);
        assert_eq!(p.prompt[0], BOS);
        assert_eq!(*p.prompt.last().unwrap(), SEP);
        assert!(p.prompt.len() <= 8);
    }

    #[test]
    fn ideal_completion_contains_answer_in_solution_tags() {
        let p = GsmProblem {
            a: 17,
            b: 25,
            prompt: vec![],
        };
        let c = p.ideal_completion();
        let sol = c.iter().position(|&t| t == SOL).unwrap();
        let (val, _) = decode_number(&c, sol + 1).unwrap();
        assert_eq!(val, 42);
        assert_eq!(*c.last().unwrap(), ESOL);
    }

    #[test]
    fn sft_batch_masks_only_completions() {
        let task = GsmTask::new(32);
        let mut rng = Pcg64::new(2);
        let (tokens, mask) = task.sft_batch(4, &mut rng);
        assert_eq!(tokens.len(), 4 * 32);
        assert_eq!(mask.len(), 4 * 32);
        for ex in 0..4 {
            let m = &mask[ex * 32..(ex + 1) * 32];
            let t = &tokens[ex * 32..(ex + 1) * 32];
            // prompt positions unmasked
            assert_eq!(m[0], 0.0);
            // some completion positions masked
            assert!(m.iter().sum::<f32>() >= 6.0);
            // first masked position is the SOW tag
            let first = m.iter().position(|&x| x > 0.0).unwrap();
            assert_eq!(t[first], crate::data::tokenizer::SOW);
        }
    }

    #[test]
    fn operands_in_range() {
        let task = GsmTask::new(64);
        let mut rng = Pcg64::new(3);
        for _ in 0..100 {
            let p = task.problem(&mut rng);
            assert!(p.a < 50 && p.b < 50);
            assert!(p.answer() < 100);
        }
    }

    /// The decode conformance suite replays these prompts: identical
    /// seeds must reproduce the problem stream bit-for-bit.
    #[test]
    fn problems_and_batches_are_seed_deterministic() {
        let task = GsmTask::new(32);
        let ps1: Vec<_> = {
            let mut rng = Pcg64::new(21);
            (0..16).map(|_| task.problem(&mut rng)).collect()
        };
        let ps2: Vec<_> = {
            let mut rng = Pcg64::new(21);
            (0..16).map(|_| task.problem(&mut rng)).collect()
        };
        for (p, q) in ps1.iter().zip(&ps2) {
            assert_eq!((p.a, p.b), (q.a, q.b));
            assert_eq!(p.prompt, q.prompt);
        }
        let (t1, m1) = task.sft_batch(4, &mut Pcg64::new(22));
        let (t2, m2) = task.sft_batch(4, &mut Pcg64::new(22));
        assert_eq!(t1, t2);
        assert_eq!(m1, m2);
        let (t3, _) = task.sft_batch(4, &mut Pcg64::new(23));
        assert_ne!(t1, t3, "different seeds must produce different batches");
    }

    /// Prompt shape invariant: `[BOS] a-digits + b-digits = [SEP]`, with
    /// the operands recoverable by the tokenizer round-trip.
    #[test]
    fn prompt_encodes_both_operands() {
        let task = GsmTask::new(64);
        let mut rng = Pcg64::new(31);
        for _ in 0..50 {
            let p = task.problem(&mut rng);
            assert_eq!(p.prompt[0], BOS);
            let (a, a_len) = decode_number(&p.prompt, 1).unwrap();
            assert_eq!(a, p.a);
            let plus_at = 1 + a_len;
            assert_eq!(p.prompt[plus_at], PLUS);
            let (b, b_len) = decode_number(&p.prompt, plus_at + 1).unwrap();
            assert_eq!(b, p.b);
            let equals_at = plus_at + 1 + b_len;
            assert_eq!(p.prompt[equals_at], EQUALS);
            assert_eq!(p.prompt[equals_at + 1], SEP);
            assert_eq!(p.prompt.len(), equals_at + 2);
        }
    }

    /// End-of-solution placement: `ESOL` closes every ideal completion
    /// exactly once (it is the decode loop's stop token), and the whole
    /// prompt+completion fits the RL sequence budget.
    #[test]
    fn ideal_completion_ends_with_esol_and_fits_seq() {
        let task = GsmTask::new(32);
        let mut rng = Pcg64::new(41);
        for _ in 0..50 {
            let p = task.problem(&mut rng);
            let c = p.ideal_completion();
            assert_eq!(*c.last().unwrap(), ESOL);
            assert_eq!(c.iter().filter(|&&t| t == ESOL).count(), 1);
            assert!(
                p.prompt.len() + c.len() <= task.seq,
                "prompt+completion ({} + {}) must fit seq {}",
                p.prompt.len(),
                c.len(),
                task.seq
            );
        }
    }
}
