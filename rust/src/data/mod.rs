//! Synthetic task suite.
//!
//! The offline image has no SQuAD/GLUE/Alpaca/GSM8K; these generators
//! build structurally analogous tasks over a small token vocabulary so
//! that every code path the paper exercises — span extraction QA,
//! 8-task classification/regression with GLUE's metric zoo, instruction
//! following, and multi-step arithmetic with chain-of-thought format —
//! runs end-to-end (DESIGN.md §Substitutions).
//!
//! All generators are deterministic in (task, seed) and stream batches
//! without materialising datasets.

pub mod glue;
pub mod gsm;
pub mod instruct;
pub mod squad;
pub mod tokenizer;
