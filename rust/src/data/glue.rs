//! Synthetic 8-task GLUE stand-in (Table III).
//!
//! One generator per GLUE task, each with the *same metric* as the
//! original and a task structure exercising an analogous capability —
//! at a difficulty a proxy-scale encoder can learn from scratch
//! (DESIGN.md §Substitutions):
//!
//! | task    | structure                                       | metric  |
//! |---------|-------------------------------------------------|---------|
//! | SST-2   | majority token polarity (low/high content pool) | acc     |
//! | MNLI-m  | topic-token relation at fixed positions (3-way) | acc     |
//! | MNLI-mm | same, shifted token domain                      | acc     |
//! | MRPC    | paraphrase: s2 = noisy copy vs unrelated        | F1      |
//! | QNLI    | does the sentence mention any entity marker?    | acc     |
//! | QQP     | duplicate detection, heavier perturbation       | F1      |
//! | RTE     | entity-mention entailment + 25 % label noise    | acc     |
//! | STS-B   | token-overlap similarity regression             | Pearson |
//! | CoLA    | "grammar": position-parity token classes        | Matthews|
//!
//! RTE's label noise and CoLA's sensitivity to small logit shifts are
//! deliberate: the paper's Table III shows exactly those two tasks
//! degrading hardest under analog constraints.

use super::squad::ENTITY_POOL;
use super::tokenizer::{CLS, CONTENT_START, SEP};
use crate::util::rng::Pcg64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    F1,
    Matthews,
    PearsonSpearman,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum GlueTask {
    Sst2,
    MnliM,
    MnliMm,
    Mrpc,
    Qnli,
    Qqp,
    Rte,
    StsB,
    Cola,
}

pub const ALL_TASKS: [GlueTask; 9] = [
    GlueTask::Sst2,
    GlueTask::MnliM,
    GlueTask::MnliMm,
    GlueTask::Mrpc,
    GlueTask::Qnli,
    GlueTask::Qqp,
    GlueTask::Rte,
    GlueTask::StsB,
    GlueTask::Cola,
];

impl GlueTask {
    pub fn name(&self) -> &'static str {
        match self {
            GlueTask::Sst2 => "SST-2",
            GlueTask::MnliM => "MNLI-m",
            GlueTask::MnliMm => "MNLI-mm",
            GlueTask::Mrpc => "MRPC",
            GlueTask::Qnli => "QNLI",
            GlueTask::Qqp => "QQP",
            GlueTask::Rte => "RTE",
            GlueTask::StsB => "STS-B",
            GlueTask::Cola => "CoLA",
        }
    }

    pub fn metric(&self) -> Metric {
        match self {
            GlueTask::Mrpc | GlueTask::Qqp => Metric::F1,
            GlueTask::StsB => Metric::PearsonSpearman,
            GlueTask::Cola => Metric::Matthews,
            _ => Metric::Accuracy,
        }
    }

    pub fn n_classes(&self) -> usize {
        match self {
            GlueTask::MnliM | GlueTask::MnliMm => 3,
            GlueTask::StsB => 1, // regression
            _ => 2,
        }
    }

    pub fn is_regression(&self) -> bool {
        matches!(self, GlueTask::StsB)
    }

    /// MNLI-mm and -m share an adapter (one MNLI model reports m/mm in
    /// the paper's table); everything else trains its own.
    pub fn adapter_key(&self) -> &'static str {
        match self {
            GlueTask::MnliM | GlueTask::MnliMm => "MNLI",
            t => t.name(),
        }
    }
}

/// Classification/regression example batch.
#[derive(Clone, Debug)]
pub struct ClsBatch {
    pub tokens: Vec<i32>,  // [b, seq]
    pub labels: Vec<i32>,  // [b] (classification)
    pub targets: Vec<f32>, // [b] (regression)
    pub b: usize,
    pub seq: usize,
}

#[derive(Clone, Debug)]
pub struct GlueGen {
    pub task: GlueTask,
    pub vocab: usize,
    pub seq: usize,
}

impl GlueGen {
    pub fn new(task: GlueTask, vocab: usize, seq: usize) -> GlueGen {
        GlueGen { task, vocab, seq }
    }

    fn content(&self) -> usize {
        self.vocab - CONTENT_START as usize
    }

    /// Random content token from a fractional sub-range of the content
    /// alphabet (pools: polarity classes, topic domains, …).
    fn rand_content(&self, rng: &mut Pcg64, lo_frac: f64, hi_frac: f64) -> i32 {
        let n = self.content() as f64;
        let lo = (n * lo_frac) as usize;
        let hi = ((n * hi_frac) as usize).max(lo + 1);
        CONTENT_START + (lo + rng.below(hi - lo)) as i32
    }

    /// Generate one example: (tokens, class_label, regression_target).
    pub fn example(&self, rng: &mut Pcg64) -> (Vec<i32>, i32, f32) {
        let s = self.seq;
        let mut t = vec![0i32; s];
        t[0] = CLS;
        let body = s - 1;
        let half = body / 2;

        match self.task {
            GlueTask::Sst2 => {
                // majority polarity: pool A = low half of content ids,
                // pool B = high half; 70/30 mix keeps headroom.
                let label = rng.below(2) as i32;
                let maj = (body * 7) / 10;
                for i in 0..body {
                    let from_major = i < maj;
                    let positive = (label == 1) == from_major;
                    t[1 + i] = if positive {
                        self.rand_content(rng, 0.5, 1.0)
                    } else {
                        self.rand_content(rng, 0.0, 0.5)
                    };
                }
                rng.shuffle(&mut t[1..]);
                (t, label, 0.0)
            }
            GlueTask::MnliM | GlueTask::MnliMm => {
                // topic tokens at FIXED positions (1 and half+2): the
                // premise topic and hypothesis topic. entail = same
                // topic; contradict = "opposite" topic (same index in
                // the complementary pool); neutral = unrelated topic.
                // -mm shifts the filler domain (domain transfer).
                let (lo, hi) = if self.task == GlueTask::MnliMm {
                    (0.5, 1.0)
                } else {
                    (0.0, 0.5)
                };
                let label = rng.below(3) as i32;
                let n_topics = 8usize;
                let topic = rng.below(n_topics);
                let topic_tok = |k: usize, pool: usize| -> i32 {
                    // two disjoint topic alphabets at the bottom of the
                    // content range
                    CONTENT_START + (pool * n_topics + k) as i32
                };
                t[1] = topic_tok(topic, 0);
                for i in 2..=half {
                    t[i] = self.rand_content(rng, lo, hi);
                }
                t[half + 1] = SEP;
                t[half + 2] = match label {
                    0 => topic_tok(topic, 0),                                  // entailment
                    1 => topic_tok(topic, 1),                                  // contradiction
                    _ => topic_tok((topic + 1 + rng.below(n_topics - 1)) % n_topics, 0), // neutral
                };
                for i in half + 3..s {
                    t[i] = self.rand_content(rng, lo, hi);
                }
                (t, label, 0.0)
            }
            GlueTask::Mrpc | GlueTask::Qqp => {
                let noise = if self.task == GlueTask::Qqp { 0.3 } else { 0.15 };
                let label = rng.below(2) as i32;
                // sentence pools: a paraphrase shares its source pool
                let pool = rng.below(4);
                let (plo, phi) = (pool as f64 * 0.25, pool as f64 * 0.25 + 0.25);
                let s1: Vec<i32> = (0..half - 1).map(|_| self.rand_content(rng, plo, phi)).collect();
                let s2: Vec<i32> = if label == 1 {
                    s1.iter()
                        .map(|&v| {
                            if rng.uniform() < noise {
                                self.rand_content(rng, plo, phi)
                            } else {
                                v
                            }
                        })
                        .collect()
                } else {
                    // unrelated: different pool entirely
                    let other = (pool + 1 + rng.below(3)) % 4;
                    let (qlo, qhi) = (other as f64 * 0.25, other as f64 * 0.25 + 0.25);
                    (0..half - 1).map(|_| self.rand_content(rng, qlo, qhi)).collect()
                };
                for (i, &v) in s1.iter().enumerate() {
                    t[1 + i] = v;
                }
                t[1 + s1.len()] = SEP;
                for (j, &v) in s2.iter().enumerate().take(s - 2 - s1.len()) {
                    t[2 + s1.len() + j] = v;
                }
                (t, label, 0.0)
            }
            GlueTask::Qnli | GlueTask::Rte => {
                // entailment = the sentence mentions an entity marker
                // (reserved pool, detectable like the QA task's spans)
                let label = rng.below(2) as i32; // 0 = entailed/mentioned
                t[1] = super::tokenizer::QTOK;
                t[2] = SEP;
                for i in 3..s {
                    t[i] = self.rand_content(rng, 0.0, 1.0);
                }
                if label == 0 {
                    let p = 3 + rng.below(s - 3);
                    t[p] = ENTITY_POOL[rng.below(ENTITY_POOL.len())];
                }
                let mut final_label = label;
                if self.task == GlueTask::Rte && rng.uniform() < 0.25 {
                    final_label = 1 - label; // label noise: RTE's low ceiling
                }
                (t, final_label, 0.0)
            }
            GlueTask::StsB => {
                // similarity = 5 * pool-overlap fraction between halves
                let pool = rng.below(4);
                let (plo, phi) = (pool as f64 * 0.25, pool as f64 * 0.25 + 0.25);
                let other = (pool + 1 + rng.below(3)) % 4;
                let (qlo, qhi) = (other as f64 * 0.25, other as f64 * 0.25 + 0.25);
                let overlap = rng.uniform();
                let s1_len = half - 1;
                let s2_len = s - 2 - s1_len;
                let mut shared = 0usize;
                for i in 0..s1_len {
                    t[1 + i] = self.rand_content(rng, plo, phi);
                }
                t[1 + s1_len] = SEP;
                for j in 0..s2_len {
                    t[2 + s1_len + j] = if rng.uniform() < overlap {
                        shared += 1;
                        self.rand_content(rng, plo, phi)
                    } else {
                        self.rand_content(rng, qlo, qhi)
                    };
                }
                let target = 5.0 * shared as f32 / s2_len as f32;
                (t, 0, target)
            }
            GlueTask::Cola => {
                // "grammatical" = even body positions from the low pool,
                // odd from the high pool; corruptions flip the parity of
                // a few positions.
                let label = rng.below(2) as i32;
                for i in 0..body {
                    let (lo, hi) = if i % 2 == 0 { (0.0, 0.5) } else { (0.5, 1.0) };
                    t[1 + i] = self.rand_content(rng, lo, hi);
                }
                if label == 0 {
                    for _ in 0..3 {
                        let i = rng.below(body);
                        let (lo, hi) = if i % 2 == 0 { (0.5, 1.0) } else { (0.0, 0.5) };
                        t[1 + i] = self.rand_content(rng, lo, hi);
                    }
                }
                (t, label, 0.0)
            }
        }
    }

    pub fn batch(&self, b: usize, rng: &mut Pcg64) -> ClsBatch {
        let mut tokens = Vec::with_capacity(b * self.seq);
        let mut labels = Vec::with_capacity(b);
        let mut targets = Vec::with_capacity(b);
        for _ in 0..b {
            let (t, l, y) = self.example(rng);
            tokens.extend_from_slice(&t);
            labels.push(l);
            targets.push(y);
        }
        ClsBatch {
            tokens,
            labels,
            targets,
            b,
            seq: self.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_batches() {
        for task in ALL_TASKS {
            let g = GlueGen::new(task, 512, 48);
            let mut rng = Pcg64::new(1);
            let b = g.batch(16, &mut rng);
            assert_eq!(b.tokens.len(), 16 * 48, "{task:?}");
            assert!(b.tokens.iter().all(|&t| t >= 0 && (t as usize) < 512), "{task:?}");
            if !task.is_regression() {
                assert!(b.labels.iter().all(|&l| (l as usize) < task.n_classes()), "{task:?}");
            }
        }
    }

    #[test]
    fn labels_are_balanced_enough() {
        for task in [GlueTask::Sst2, GlueTask::Mrpc, GlueTask::Cola] {
            let g = GlueGen::new(task, 512, 48);
            let mut rng = Pcg64::new(2);
            let b = g.batch(400, &mut rng);
            let ones = b.labels.iter().filter(|&&l| l == 1).count();
            assert!((120..280).contains(&ones), "{task:?}: {ones}/400");
        }
    }

    #[test]
    fn stsb_targets_in_range() {
        let g = GlueGen::new(GlueTask::StsB, 512, 48);
        let mut rng = Pcg64::new(3);
        let b = g.batch(100, &mut rng);
        assert!(b.targets.iter().all(|&y| (0.0..=5.0).contains(&y)));
        let lo = b.targets.iter().cloned().fold(f32::MAX, f32::min);
        let hi = b.targets.iter().cloned().fold(f32::MIN, f32::max);
        assert!(hi - lo > 2.0);
    }

    #[test]
    fn mnli_topic_positions_encode_label() {
        let g = GlueGen::new(GlueTask::MnliM, 512, 48);
        let mut rng = Pcg64::new(4);
        let half = 47 / 2;
        for _ in 0..50 {
            let (t, label, _) = g.example(&mut rng);
            assert_eq!(t[half + 1], SEP);
            let prem = t[1];
            let hyp = t[half + 2];
            match label {
                0 => assert_eq!(prem, hyp),
                1 => assert_eq!(hyp - prem, 8), // complementary pool
                _ => {
                    assert_ne!(prem, hyp);
                    assert!(hyp < CONTENT_START + 8);
                }
            }
        }
    }

    #[test]
    fn mnli_domains_differ() {
        let m = GlueGen::new(GlueTask::MnliM, 512, 48);
        let mm = GlueGen::new(GlueTask::MnliMm, 512, 48);
        let mut rng = Pcg64::new(4);
        let bm = m.batch(50, &mut rng);
        let bmm = mm.batch(50, &mut rng);
        let avg = |b: &ClsBatch| {
            let filler: Vec<f64> = b
                .tokens
                .iter()
                .filter(|&&t| t >= CONTENT_START + 16)
                .map(|&t| t as f64)
                .collect();
            filler.iter().sum::<f64>() / filler.len() as f64
        };
        assert!(avg(&bmm) > avg(&bm) + 50.0, "domain shift missing");
    }

    #[test]
    fn qnli_mention_matches_label() {
        let g = GlueGen::new(GlueTask::Qnli, 512, 48);
        let mut rng = Pcg64::new(5);
        for _ in 0..50 {
            let (t, label, _) = g.example(&mut rng);
            let mentioned = t[3..].iter().any(|tok| ENTITY_POOL.contains(tok));
            assert_eq!(mentioned, label == 0);
        }
    }

    #[test]
    fn metric_assignment_matches_glue() {
        assert_eq!(GlueTask::Cola.metric(), Metric::Matthews);
        assert_eq!(GlueTask::StsB.metric(), Metric::PearsonSpearman);
        assert_eq!(GlueTask::Qqp.metric(), Metric::F1);
        assert_eq!(GlueTask::Sst2.metric(), Metric::Accuracy);
    }

    #[test]
    fn mnli_shares_adapter() {
        assert_eq!(GlueTask::MnliM.adapter_key(), GlueTask::MnliMm.adapter_key());
        assert_ne!(GlueTask::Sst2.adapter_key(), GlueTask::Qqp.adapter_key());
    }
}
