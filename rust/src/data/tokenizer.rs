//! Shared token-id conventions for the synthetic vocabulary.
//!
//! Layout (within any variant's vocab size V):
//!   0..=5   control: PAD CLS SEP MASK BOS EOS
//!   6..=15  digits 0-9 (GSM arithmetic)
//!   16..=23 operators / format tags: + = <sow> <eow> <sol> </sol> Q A
//!   32..V   content tokens

pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
pub const MASK: i32 = 3;
pub const BOS: i32 = 4;
pub const EOS: i32 = 5;

pub const DIGIT0: i32 = 6; // ..=15

pub const PLUS: i32 = 16;
pub const EQUALS: i32 = 17;
pub const SOW: i32 = 18; // <start_working_out>
pub const EOW: i32 = 19; // <end_working_out>
pub const SOL: i32 = 20; // <SOLUTION>
pub const ESOL: i32 = 21; // </SOLUTION>
pub const QTOK: i32 = 22;
pub const ATOK: i32 = 23;

pub const CONTENT_START: i32 = 32;

pub fn digit(d: u32) -> i32 {
    debug_assert!(d < 10);
    DIGIT0 + d as i32
}

pub fn digit_value(tok: i32) -> Option<u32> {
    if (DIGIT0..DIGIT0 + 10).contains(&tok) {
        Some((tok - DIGIT0) as u32)
    } else {
        None
    }
}

/// Encode a non-negative number as digit tokens (most-significant first).
pub fn encode_number(n: u32, out: &mut Vec<i32>) {
    if n >= 10 {
        encode_number(n / 10, out);
    }
    out.push(digit(n % 10));
}

/// Decode a digit-token run starting at `pos`; returns (value, len).
pub fn decode_number(toks: &[i32], pos: usize) -> Option<(u32, usize)> {
    let mut val: u64 = 0;
    let mut len = 0;
    while pos + len < toks.len() {
        match digit_value(toks[pos + len]) {
            Some(d) if len < 9 => {
                val = val * 10 + d as u64;
                len += 1;
            }
            _ => break,
        }
    }
    if len == 0 {
        None
    } else {
        Some((val as u32, len))
    }
}

/// Number of content tokens available in a vocab of size `v`.
pub fn content_range(v: usize) -> std::ops::Range<i32> {
    CONTENT_START..v as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn number_roundtrip() {
        for n in [0u32, 7, 10, 42, 99, 123, 999] {
            let mut toks = vec![];
            encode_number(n, &mut toks);
            let (val, len) = decode_number(&toks, 0).unwrap();
            assert_eq!(val, n);
            assert_eq!(len, toks.len());
        }
    }

    #[test]
    fn decode_stops_at_non_digit() {
        let toks = vec![digit(4), digit(2), PLUS, digit(1)];
        assert_eq!(decode_number(&toks, 0), Some((42, 2)));
        assert_eq!(decode_number(&toks, 2), None);
    }

    #[test]
    fn content_range_disjoint_from_specials() {
        let r = content_range(64);
        assert!(r.start > ESOL && r.start > ATOK);
        assert_eq!(r.end, 64);
    }
}
