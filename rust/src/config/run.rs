//! Runtime configuration with the paper's experimental defaults
//! (Methods — Training and Inference Details), overridable from the CLI.

use crate::util::cli::Args;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Peak learning rate (paper: 2e-4, Adam, linear decay).
    pub lr: f64,
    /// AdamW weight decay (paper: 0 for encoders, 0.01 for SFT).
    pub weight_decay: f64,
    /// Total optimizer steps (paper trains 15 epochs; proxy tasks
    /// converge in a few hundred steps — see EXPERIMENTS.md).
    pub steps: usize,
    /// Linear warmup steps (paper SFT: 5).
    pub warmup: usize,
    /// Relative Gaussian weight-noise amplitude during training
    /// (paper: 0.067; RL: 0.030).
    pub weight_noise: f64,
    /// ADC output-noise amplitude (paper: 0.04).
    pub adc_noise: f64,
    /// Channel clipping threshold in sigmas (paper: 3.0; 0 disables).
    pub clip_sigma: f64,
    /// DAC/ADC bit widths (0 disables explicit converter modeling).
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub seed: u64,
    /// Print a log line every `log_every` steps.
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 2e-4,
            weight_decay: 0.0,
            steps: 300,
            warmup: 10,
            weight_noise: 0.067,
            adc_noise: 0.04,
            clip_sigma: 3.0,
            dac_bits: 8,
            adc_bits: 8,
            seed: 7,
            log_every: 50,
        }
    }
}

impl TrainConfig {
    /// Digital (no hardware constraints) configuration for baselines and
    /// base-model "pretraining".
    pub fn digital() -> Self {
        TrainConfig {
            weight_noise: 0.0,
            adc_noise: 0.0,
            clip_sigma: 0.0,
            dac_bits: 0,
            adc_bits: 0,
            ..Default::default()
        }
    }

    pub fn from_args(args: &Args) -> Self {
        let mut c = TrainConfig::default();
        c.lr = args.f64("lr", c.lr);
        c.weight_decay = args.f64("wd", c.weight_decay);
        c.steps = args.usize("steps", c.steps);
        c.warmup = args.usize("warmup", c.warmup);
        c.weight_noise = args.f64("noise", c.weight_noise);
        c.adc_noise = args.f64("adc-noise", c.adc_noise);
        c.clip_sigma = args.f64("clip", c.clip_sigma);
        c.dac_bits = args.usize("dac-bits", c.dac_bits as usize) as u32;
        c.adc_bits = args.usize("adc-bits", c.adc_bits as usize) as u32;
        c.seed = args.u64("seed", c.seed);
        c
    }

    /// Learning rate at `step`: linear warmup then linear decay to zero
    /// (the paper's schedule).
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup {
            self.lr * (step + 1) as f64 / self.warmup.max(1) as f64
        } else {
            let remain = (self.steps - step) as f64 / (self.steps - self.warmup).max(1) as f64;
            self.lr * remain.max(0.0)
        }
    }

    /// The 5-scalar hw vector consumed by every exported graph.
    pub fn hw_vec(&self) -> [f32; 5] {
        [
            self.weight_noise as f32,
            self.clip_sigma as f32,
            levels(self.dac_bits),
            levels(self.adc_bits),
            self.adc_noise as f32,
        ]
    }
}

fn levels(bits: u32) -> f32 {
    if bits == 0 {
        0.0
    } else {
        ((1u32 << (bits - 1)) - 1) as f32
    }
}

#[derive(Clone, Debug)]
pub struct EvalConfig {
    /// Monte-Carlo trials per drift time (paper: 10).
    pub trials: usize,
    /// Evaluation examples per task.
    pub examples: usize,
    /// Apply global drift compensation (paper: yes).
    pub compensate: bool,
    /// Inference-time Gaussian noise level (Tables IX/X sweeps); when
    /// negative, the full PCM statistical model is used instead.
    pub gaussian_noise: f64,
    pub seed: u64,
}

impl Default for EvalConfig {
    fn default() -> Self {
        EvalConfig {
            trials: 3,
            examples: 256,
            compensate: true,
            gaussian_noise: -1.0,
            seed: 1234,
        }
    }
}

impl EvalConfig {
    pub fn from_args(args: &Args) -> Self {
        let mut c = EvalConfig::default();
        c.trials = args.usize("trials", c.trials);
        c.examples = args.usize("examples", c.examples);
        c.compensate = !args.bool("no-gdc");
        c.gaussian_noise = args.f64("eval-noise", c.gaussian_noise);
        c.seed = args.u64("eval-seed", c.seed);
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let c = TrainConfig {
            lr: 1.0,
            steps: 100,
            warmup: 10,
            ..Default::default()
        };
        assert!(c.lr_at(0) < c.lr_at(9));
        assert!((c.lr_at(9) - 1.0).abs() < 1e-9);
        assert!(c.lr_at(50) < 1.0);
        assert!(c.lr_at(99) < c.lr_at(50));
        assert!(c.lr_at(99) >= 0.0);
    }

    #[test]
    fn hw_vec_bits() {
        let c = TrainConfig::default();
        let v = c.hw_vec();
        assert_eq!(v[2], 127.0);
        assert_eq!(v[3], 127.0);
        let d = TrainConfig::digital();
        assert_eq!(d.hw_vec(), [0.0; 5]);
    }

    #[test]
    fn args_override() {
        let args = Args::parse(
            "x --lr 0.001 --steps 42 --noise 0.03 --adc-bits 6"
                .split_whitespace()
                .map(String::from),
        );
        let c = TrainConfig::from_args(&args);
        assert_eq!(c.lr, 0.001);
        assert_eq!(c.steps, 42);
        assert_eq!(c.weight_noise, 0.03);
        assert_eq!(c.hw_vec()[3], 31.0);
    }
}
