//! Manifest-driven configuration.
//!
//! `artifacts/manifest.json` (written by `python/compile/aot.py`) is the
//! single source of truth for model variants, graph I/O layouts, and
//! hardware defaults; nothing about tensor shapes is hard-coded on the
//! rust side. [`manifest`] parses it; [`run`] holds runtime knobs
//! (training schedule, eval trials, noise levels) with paper defaults.

pub mod manifest;
pub mod run;
