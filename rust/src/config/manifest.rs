//! Parse `artifacts/manifest.json` into typed configuration.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Value;

/// Tensor role inside a graph's flat I/O list (mirrors aot.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Meta,
    Train,
    M,
    V,
    Data,
    Key,
    Hw,
    Opt,
    Logits,
    Loss,
}

impl Role {
    pub fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "meta" => Role::Meta,
            "train" => Role::Train,
            "m" => Role::M,
            "v" => Role::V,
            "data" => Role::Data,
            "key" => Role::Key,
            "hw" => Role::Hw,
            "opt" => Role::Opt,
            "logits" => Role::Logits,
            "loss" => Role::Loss,
            _ => return Err(anyhow!("unknown role '{s}'")),
        })
    }
}

#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub role: Role,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub key: String,
    pub kind: String,
    pub variant: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl GraphSpec {
    pub fn inputs_with_role(&self, role: Role) -> impl Iterator<Item = &IoSpec> {
        self.inputs.iter().filter(move |i| i.role == role)
    }

    pub fn n_inputs_with_role(&self, role: Role) -> usize {
        self.inputs_with_role(role).count()
    }

    pub fn param_count(&self, role: Role) -> usize {
        self.inputs_with_role(role).map(|i| i.numel()).sum()
    }
}

/// Architecture of one model variant (proxy of a paper model).
#[derive(Clone, Debug)]
pub struct VariantCfg {
    pub name: String,
    pub kind: String, // "encoder" | "decoder"
    pub vocab: usize,
    pub seq: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub d_emb: usize,
    pub n_cls: usize,
    pub rank: usize,
    pub lora_alpha: f64,
    pub train_batch: usize,
    pub eval_batch: usize,
}

/// Hardware defaults recorded by the compile path.
#[derive(Clone, Debug)]
pub struct HwDefaults {
    pub weight_noise: f64,
    pub adc_noise: f64,
    pub clip_sigma: f64,
    pub dac_bits: u32,
    pub adc_bits: u32,
    pub g_max_us: f64,
    pub t0_seconds: f64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub hw: HwDefaults,
    pub grpo_group: usize,
    pub variants: BTreeMap<String, VariantCfg>,
    pub graphs: BTreeMap<String, GraphSpec>,
}

impl Manifest {
    /// Load from an artifacts directory (default: `artifacts/`).
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;

        let hw_v = v.get("hw")?;
        let hw = HwDefaults {
            weight_noise: hw_v.get("weight_noise")?.as_f64()?,
            adc_noise: hw_v.get("adc_noise")?.as_f64()?,
            clip_sigma: hw_v.get("clip_sigma")?.as_f64()?,
            dac_bits: hw_v.get("dac_bits")?.as_f64()? as u32,
            adc_bits: hw_v.get("adc_bits")?.as_f64()? as u32,
            g_max_us: hw_v.get("g_max_us")?.as_f64()?,
            t0_seconds: hw_v.get("t0_seconds")?.as_f64()?,
        };

        let mut variants = BTreeMap::new();
        for (name, cv) in v.get("variants")?.as_obj()? {
            variants.insert(
                name.clone(),
                VariantCfg {
                    name: name.clone(),
                    kind: cv.get("kind")?.as_str()?.to_string(),
                    vocab: cv.get("vocab")?.as_usize()?,
                    seq: cv.get("seq")?.as_usize()?,
                    d_model: cv.get("d_model")?.as_usize()?,
                    n_layers: cv.get("n_layers")?.as_usize()?,
                    n_heads: cv.get("n_heads")?.as_usize()?,
                    d_ff: cv.get("d_ff")?.as_usize()?,
                    d_emb: cv.get("d_emb")?.as_usize()?,
                    n_cls: cv.get("n_cls")?.as_usize()?,
                    rank: cv.get("rank")?.as_usize()?,
                    lora_alpha: cv.get("lora_alpha")?.as_f64()?,
                    train_batch: cv.get("train_batch")?.as_usize()?,
                    eval_batch: cv.get("eval_batch")?.as_usize()?,
                },
            );
        }

        let mut graphs = BTreeMap::new();
        for (key, gv) in v.get("graphs")?.as_obj()? {
            let parse_io = |arr: &Value| -> Result<Vec<IoSpec>> {
                arr.as_arr()?
                    .iter()
                    .map(|io| {
                        Ok(IoSpec {
                            name: io.get("name")?.as_str()?.to_string(),
                            role: Role::parse(io.get("role")?.as_str()?)?,
                            shape: io.get("shape")?.usize_arr()?,
                            dtype: io.get("dtype")?.as_str()?.to_string(),
                        })
                    })
                    .collect()
            };
            graphs.insert(
                key.clone(),
                GraphSpec {
                    key: key.clone(),
                    kind: gv.get("kind")?.as_str()?.to_string(),
                    variant: gv.get("variant")?.as_str()?.to_string(),
                    file: gv.get("file")?.as_str()?.to_string(),
                    inputs: parse_io(gv.get("inputs")?)?,
                    outputs: parse_io(gv.get("outputs")?)?,
                },
            );
        }

        Ok(Manifest {
            root,
            hw,
            grpo_group: v.opt("grpo_group").map(|g| g.as_usize()).transpose()?.unwrap_or(16),
            variants,
            graphs,
        })
    }

    pub fn graph(&self, key: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(key)
            .ok_or_else(|| anyhow!("graph '{key}' not in manifest (have: {:?})", self.graphs.keys().take(8).collect::<Vec<_>>()))
    }

    pub fn variant(&self, name: &str) -> Result<&VariantCfg> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("variant '{name}' not in manifest"))
    }

    pub fn hlo_path(&self, g: &GraphSpec) -> PathBuf {
        self.root.join(&g.file)
    }

    pub fn init_path(&self, tag: &str) -> PathBuf {
        self.root.join("init").join(format!("{tag}.bin"))
    }
}

/// Locate the artifacts directory relative to the current working dir
/// (supports running from repo root or from `rust/`).
pub fn default_artifacts_dir() -> PathBuf {
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        default_artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(default_artifacts_dir()).unwrap();
        assert!(m.variants.contains_key("mobilebert_proxy"));
        assert!(m.graphs.contains_key("tiny/step_qa_lora"));
        assert_eq!(m.hw.dac_bits, 8);
    }

    #[test]
    fn graph_roles_are_ordered() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(default_artifacts_dir()).unwrap();
        let g = m.graph("tiny/step_qa_lora").unwrap();
        // canonical segment order: meta, train, m, v, data, key, hw, opt
        let first_train = g.inputs.iter().position(|i| i.role == Role::Train).unwrap();
        let last_meta = g.inputs.iter().rposition(|i| i.role == Role::Meta).unwrap();
        assert!(last_meta < first_train);
        assert_eq!(g.inputs.last().unwrap().role, Role::Opt);
        // outputs end with the scalar loss
        assert_eq!(g.outputs.last().unwrap().role, Role::Loss);
        assert_eq!(
            g.n_inputs_with_role(Role::Train),
            g.n_inputs_with_role(Role::M)
        );
    }

    #[test]
    fn lora_param_budget_matches_paper_scale() {
        if !have_artifacts() {
            return;
        }
        // AHWA-LoRA trains only a few percent of what full AHWA trains
        // (paper: 1.63M vs 24.67M on MobileBERT, >15x reduction).
        let m = Manifest::load(default_artifacts_dir()).unwrap();
        let lora = m.graph("mobilebert_proxy/step_qa_lora").unwrap().param_count(Role::Train);
        let full = m.graph("mobilebert_proxy/step_qa_full").unwrap().param_count(Role::Train);
        assert!(full > 8 * lora, "full={full} lora={lora}");
    }
}
