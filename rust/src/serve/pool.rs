//! The sharded worker pool behind [`super::api`].
//!
//! Each worker thread owns its own forward executor (PJRT handles are
//! not `Send`), brought up through its backend's [`Backend::forward`]
//! seam from the ONE manifest the builder already parsed, and drains a
//! per-worker dynamic batcher. The pool's contract with the API layer:
//! **every admitted request receives exactly one terminal result**, on
//! every path — success, adapter miss, batch failure, injected fault,
//! backend-init failure, and shutdown drain.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::manifest::Manifest;
use crate::model::params::ParamStore;

use super::api::{Metrics, Response, ServeError, ServeResult};
use super::batcher::Batcher;
use super::cache::{AdapterCache, CacheLookup};
use super::decode::{step_gate, GenConfig, StepEngine, StepGate, TokenEvent};
use super::hal::{Backend, Forward};
use super::refresh::RefreshHandle;
use super::registry::SharedRegistry;
use super::sched::{BatchScheduler, Clock, Decision, SchedConfig};

/// One admitted request travelling to a worker.
pub(crate) struct WorkRequest {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
    pub resp: Sender<ServeResult<Response>>,
}

/// One admitted generation travelling to a worker; tokens stream back
/// on `resp` as the step-batch advances, ending with exactly one
/// terminal event (`done` token or error).
pub(crate) struct GenRequest {
    pub id: u64,
    pub task: String,
    pub prompt: Vec<i32>,
    pub cfg: GenConfig,
    pub resp: Sender<ServeResult<TokenEvent>>,
}

pub(crate) enum Job {
    Req(WorkRequest),
    Gen(GenRequest),
    Shutdown,
}

/// Client-side view of one worker: its queue, in-flight budget, and
/// counters.
pub(crate) struct WorkerHandle {
    pub tx: Sender<Job>,
    pub inflight: Arc<AtomicUsize>,
    pub queue_depth: usize,
    pub metrics: Arc<Metrics>,
}

#[derive(Clone)]
pub(crate) struct WorkerConfig {
    pub worker: usize,
    pub graph_key: String,
    /// Sequence length the builder derived from the graph spec — the
    /// same value admission validates against, so client and worker
    /// can never segment a batch differently.
    pub seq: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub hw: [f32; 5],
    /// Chaos knob: fail every n-th batch (0 = off).
    pub fail_every: u64,
    /// Batch fills to AOT shape-specialize the forward executor for
    /// (`runtime::compile`) — the committed-fill frontier of this
    /// worker's backend-adapted cost table, computed by the builder.
    /// Empty when the pool runs without cost-based scheduling (no fill
    /// commitment exists to specialize for).
    pub specialize: Vec<usize>,
    /// Pipeline-aware scheduling: when set, batch fills come from the
    /// AIMC/PMCA cost model instead of the fixed size/deadline policy.
    pub sched: Option<SchedConfig>,
    /// Shared refresh-lifecycle view (present when the pool runs a
    /// drift-refresh worker): powers the scheduler's refresh coupling
    /// and the worker's stale-batch / swap-gap accounting.
    pub refresh: Option<RefreshHandle>,
    /// Bounded adapter residency ([`super::cache`]): the worker lands
    /// due page-ins and prefetches each pass, classifies snapshot
    /// misses as cold (typed, retryable) instead of missing, and keeps
    /// its live decode lanes' adapters warm.
    pub cache: Option<Arc<AdapterCache>>,
    /// Time source for enqueue stamps, deadlines, and latency metrics
    /// (virtual in deterministic tests).
    pub clock: Arc<dyn Clock>,
    /// The substrate this worker executes on ([`super::hal`]): its
    /// forward executor is brought up on the worker thread, and its
    /// drift/cost parameters were already threaded into this worker's
    /// `sched`/refresh/cache configuration by the builder.
    pub backend: Arc<dyn Backend>,
}

/// After a shutdown signal, how long to wait for admitted-but-not-yet-
/// enqueued racers before giving up (they would resolve as `Lost`).
const DRAIN_GRACE: Duration = Duration::from_millis(500);

/// Fallback step-boundary hold budget when no coordinator-adapted hold
/// is published (mirrors `RefreshCoupling::hold`'s default).
const DECODE_HOLD: Duration = Duration::from_millis(20);

/// Client-side state for one in-flight generation occupying an engine
/// row.
struct GenSeq {
    resp: Sender<ServeResult<TokenEvent>>,
    /// Pool-clock instant the worker accepted the generation (TTFT
    /// anchor).
    enqueued_at: Instant,
    last_token_at: Option<Instant>,
}

/// Continuous-batching decode state for ONE task: the step engine, the
/// per-row client channels, and the joiners waiting for a free row.
/// Batches never mix tasks, and neither do step-batches — each task
/// decodes through its own lane on the shared worker.
struct DecodeLane {
    engine: StepEngine,
    seqs: Vec<Option<GenSeq>>,
    /// Joiners waiting for a free row, with their worker-accept stamp.
    queue: VecDeque<(GenRequest, Instant)>,
    /// Step-boundary hold anchor (managed by `decode::step_gate`).
    held_since: Option<Instant>,
    /// Adapter version the previous step ran at — a change while
    /// sequences are live is a drain-free mid-sequence hot-swap.
    last_version: Option<u64>,
}

impl DecodeLane {
    fn new(b: usize, s: usize, vocab: usize) -> DecodeLane {
        DecodeLane {
            engine: StepEngine::new(b, s, vocab),
            seqs: (0..b).map(|_| None).collect(),
            queue: VecDeque::new(),
            held_since: None,
            last_version: None,
        }
    }

    fn busy(&self) -> bool {
        self.engine.occupied() > 0 || !self.queue.is_empty()
    }
}

enum LaneOutcome {
    /// The lane executed a step (or shed work) — state advanced.
    Progressed,
    /// A due hot-swap has not landed: step deferred until `until`.
    Held { until: Instant },
    Idle,
}

pub(crate) fn spawn_worker(
    cfg: WorkerConfig,
    manifest: Manifest,
    meta: Arc<ParamStore>,
    registry: SharedRegistry,
    queue_depth: usize,
) -> std::io::Result<(WorkerHandle, std::thread::JoinHandle<ServeResult<()>>)> {
    let (tx, rx) = channel::<Job>();
    let inflight = Arc::new(AtomicUsize::new(0));
    let metrics = Arc::new(Metrics::default());
    let handle = WorkerHandle {
        tx,
        inflight: inflight.clone(),
        queue_depth,
        metrics: metrics.clone(),
    };
    let name = format!("ahwa-serve-{}", cfg.worker);
    let join = std::thread::Builder::new()
        .name(name)
        .spawn(move || worker_loop(cfg, manifest, meta, registry, rx, inflight, metrics))?;
    Ok((handle, join))
}

fn worker_loop(
    cfg: WorkerConfig,
    manifest: Manifest,
    meta: Arc<ParamStore>,
    registry: SharedRegistry,
    rx: Receiver<Job>,
    inflight: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
) -> ServeResult<()> {
    // forward handles (PJRT executables) are not Send: the executor is
    // brought up HERE, through the worker's backend, from the manifest
    // the builder parsed once for the whole pool.
    let mut fwd = match cfg.backend.forward(&manifest, &cfg.graph_key) {
        Ok(f) => f,
        Err(e) => {
            return fail_all(
                &cfg,
                rx,
                &inflight,
                &metrics,
                format!(
                    "backend '{}', graph '{}': {e:#}",
                    cfg.backend.name(),
                    cfg.graph_key
                ),
            )
        }
    };
    // AOT shape specialization for the scheduler's committed fills.
    // Failure is non-fatal: the padded max-shape path serves every
    // fill bit-identically, so a worker degrades to it rather than
    // refusing traffic.
    if !cfg.specialize.is_empty() {
        if let Err(e) = fwd.specialize(&cfg.specialize) {
            eprintln!(
                "[serve] worker {} (backend '{}'): shape specialization failed ({e:#}); \
                 serving on the padded path",
                cfg.worker,
                cfg.backend.name()
            );
        }
    }
    let fwd: &dyn Forward = fwd.as_ref();
    // read AFTER specialize: covers base compile + specializations
    metrics
        .compile_ms
        .store(fwd.compile_ms(), Ordering::Relaxed);
    debug_assert_eq!(fwd.batch_shape().1, cfg.seq);
    // generative serving needs [batch, seq, vocab] logits; classify
    // graphs keep `vocab` empty and bounce `Job::Gen` with a typed error
    let vocab = fwd.vocab();

    let mut batcher: Batcher<WorkRequest> =
        Batcher::with_clock(cfg.max_batch, cfg.max_wait, cfg.clock.clone());
    let mut sched = cfg.sched.map(|s| {
        let s = BatchScheduler::new(s, cfg.max_batch, cfg.max_wait);
        match cfg.refresh.clone() {
            // refresh coupling: the scheduler reads trigger times and
            // refit-in-flight flags from the same handle the refresh
            // runner writes, on the same pool clock
            Some(h) => s.with_refresh(h),
            None => s,
        }
    });
    // (task, version) of the adapter loaded on the DPUs: a drift-refresh
    // hot-swap of the SAME task is an adapter swap too
    let mut last_adapter: Option<(String, u64)> = None;
    // per-task version whose swap→serve gap was already recorded, so a
    // later RELOAD of the same refreshed adapter (after serving another
    // task) cannot re-record a bogus, ever-growing "gap"
    let mut gap_recorded: BTreeMap<String, u64> = BTreeMap::new();
    let mut batch_idx: u64 = 0;
    let mut open = true;
    let mut drain_deadline = cfg.clock.now(); // set when `open` flips
    // the scheduler's own wake instant (coupled deadlines tighten, and
    // held tasks wake at deadline+hold, so the batcher's plain earliest
    // deadline is no longer always the right sleep bound)
    let mut sched_wake: Option<Instant> = None;
    // the ONE task this shard is currently deferring for a pending
    // hot-swap (pick surfaces at most one Hold at a time). Keeping it
    // worker-local means the shared handle is only touched on actual
    // hold transitions — never on the ordinary per-batch path — and
    // the pool-wide holding count stays a count of stalled SHARDS.
    let mut holding_task: Option<String> = None;
    // continuous-batching decode state, one lane per task with live or
    // queued generations (lanes drop as soon as they empty)
    let mut lanes: BTreeMap<String, DecodeLane> = BTreeMap::new();

    loop {
        let mut incoming: Vec<Job> = Vec::new();
        if open {
            if lanes.values().any(|l| l.busy()) {
                // live generations: never block on the channel — drain
                // whatever raced in so it joins at THIS step boundary
                while let Ok(job) = rx.try_recv() {
                    incoming.push(job);
                }
            } else {
                // block until work/shutdown arrives or, if batches are
                // queued, exactly until the next actionable instant — no
                // fixed polling tick. For the fixed batcher that is its
                // earliest deadline; for the scheduler it is whatever
                // `pick` last said to wake at (tightened deadline or hold
                // bound).
                let wake = sched_wake.or_else(|| batcher.next_deadline());
                let msg = match wake {
                    Some(d) => match rx.recv_timeout(d.saturating_duration_since(cfg.clock.now())) {
                        Ok(job) => Some(job),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => Some(Job::Shutdown),
                    },
                    None => Some(rx.recv().unwrap_or(Job::Shutdown)),
                };
                if let Some(job) = msg {
                    incoming.push(job);
                }
            }
        } else {
            // drain mode: soak up racing submits without blocking
            while let Ok(job) = rx.try_recv() {
                incoming.push(job);
            }
        }
        for job in incoming {
            match job {
                Job::Req(r) => {
                    let task = r.task.clone();
                    if let Some(s) = sched.as_mut() {
                        s.observe_arrival(&task, cfg.clock.now());
                    }
                    batcher.push(&task, r);
                }
                Job::Gen(g) => accept_gen(&cfg, fwd, vocab, &metrics, &inflight, &mut lanes, g),
                Job::Shutdown => {
                    if open {
                        open = false;
                        drain_deadline = cfg.clock.now() + DRAIN_GRACE;
                        // drain mode bypasses the scheduler's Close arm, so
                        // release any hold now — a dead shard must not keep
                        // inflating the pool-wide holding count
                        if let Some(prev) = holding_task.take() {
                            if let Some(h) = cfg.refresh.as_ref() {
                                h.set_holding(&prev, false);
                            }
                        }
                    }
                }
            }
        }

        // capacity-tier upkeep, once per pass: land every due page-in
        // (so this pass's registry snapshots hit) and start prefetch
        // loads for tasks whose predicted next arrival — per-task EWMAs
        // from the scheduler — is within the horizon
        if let Some(cache) = cfg.cache.as_ref() {
            cache.poll(cfg.clock.now());
            if let Some(s) = sched.as_ref() {
                cache.prefetch(cfg.clock.now(), &s.arrival_rates());
            }
        }

        // serve EVERY ready batch before sleeping again — a full batch
        // must never wait on another task's deadline
        sched_wake = None;
        loop {
            let now = cfg.clock.now();
            let ready = if !open {
                // everything goes, deadlines notwithstanding
                batcher.pop_ready(now + cfg.max_wait + Duration::from_millis(1))
            } else if let Some(s) = sched.as_ref() {
                match s.pick(&batcher, now) {
                    Decision::Close { task, fill } | Decision::Drain { task, fill } => {
                        if holding_task.as_deref() == Some(task.as_str()) {
                            if let Some(h) = cfg.refresh.as_ref() {
                                h.set_holding(&task, false);
                            }
                            holding_task = None;
                        }
                        let popped = batcher.pop_task(&task, fill);
                        // span-migration handoff completes HERE: once a
                        // migrating task's queue is served out, this
                        // worker (the old span) clears the flag — new
                        // submissions already route to the destination
                        // span, so the queue cannot refill
                        if popped.is_some() && batcher.pending_for(&task) == 0 {
                            if let Some(h) = cfg.refresh.as_ref() {
                                if h.is_migrating(&task) {
                                    h.set_migrating(&task, false);
                                }
                            }
                        }
                        popped.map(|items| (task, items))
                    }
                    Decision::Hold { task, until } => {
                        // publish the deferral (on transitions only):
                        // the pool coordinator's stagger exists to
                        // bound how many shards sit here at once, and
                        // `concurrent_holds_peak` reports whether it
                        // succeeded
                        if holding_task.as_deref() != Some(task.as_str()) {
                            if let Some(h) = cfg.refresh.as_ref() {
                                if let Some(prev) = holding_task.take() {
                                    h.set_holding(&prev, false);
                                }
                                let holding = h.set_holding(&task, true) as u64;
                                metrics
                                    .concurrent_holds_peak
                                    .fetch_max(holding, Ordering::Relaxed);
                            }
                            holding_task = Some(task);
                        }
                        sched_wake = Some(until);
                        None
                    }
                    Decision::Wait { until } => {
                        sched_wake = Some(until);
                        None
                    }
                    Decision::Idle => None,
                }
            } else {
                batcher.pop_ready(now)
            };
            let Some((task, reqs)) = ready else { break };
            batch_idx += 1;
            let modeled = sched.as_ref().map(|s| s.modeled_batch(reqs.len()));
            serve_batch(
                &cfg, fwd, &meta, &registry, &metrics, &inflight, batch_idx,
                &mut last_adapter, &mut gap_recorded, task, reqs, modeled,
            );
            if !open {
                // progress resets the grace window: slow batches must
                // not eat the time reserved for in-flight racers
                drain_deadline = cfg.clock.now() + DRAIN_GRACE;
            }
        }

        // decode lanes: ONE step per pass, so channel arrivals drained
        // above join at every step boundary and a due hot-swap gets a
        // fresh registry snapshot between any two steps of a sequence
        let mut decode_hold_wake: Option<Instant> = None;
        let mut decode_progress = false;
        for (task, lane) in lanes.iter_mut() {
            let outcome = step_lane(
                &cfg,
                fwd,
                &meta,
                &registry,
                &metrics,
                &inflight,
                sched.as_ref(),
                &mut batch_idx,
                &mut last_adapter,
                &mut gap_recorded,
                task,
                lane,
            );
            match outcome {
                LaneOutcome::Progressed => decode_progress = true,
                LaneOutcome::Held { until } => {
                    decode_hold_wake = Some(decode_hold_wake.map_or(until, |w| w.min(until)));
                }
                LaneOutcome::Idle => {}
            }
        }
        lanes.retain(|_, l| l.busy());
        if decode_progress && !open {
            // progress resets the grace window, same as batch serving
            drain_deadline = cfg.clock.now() + DRAIN_GRACE;
        }
        if !decode_progress {
            if let Some(until) = decode_hold_wake {
                // every busy lane is deferring for a pending hot-swap:
                // nap briefly so the refresh worker can land it, never
                // past the earliest hold bound
                let nap = until
                    .saturating_duration_since(cfg.clock.now())
                    .min(Duration::from_micros(100));
                if nap > Duration::ZERO {
                    cfg.clock.sleep(nap);
                }
            }
        }

        if !open {
            if lanes.values().any(|l| l.busy()) && cfg.clock.now() >= drain_deadline {
                // the grace window is spent: shed every in-flight
                // generation mid-stream, explicitly and non-retryably
                for (task, lane) in lanes.iter_mut() {
                    let t = task.clone();
                    shed_lane(lane, &inflight, &metrics, |streamed| ServeError::Shed {
                        task: t.clone(),
                        streamed,
                    });
                }
                lanes.retain(|_, l| l.busy());
            }
            if batcher.pending() == 0 && !lanes.values().any(|l| l.busy()) {
                // an admission bumps `inflight` BEFORE its send reaches
                // the channel; wait those racers out so no ticket is
                // lost.
                if inflight.load(Ordering::Acquire) == 0 || cfg.clock.now() >= drain_deadline {
                    break;
                }
                cfg.clock.sleep(Duration::from_micros(100));
            }
        }
    }
    Ok(())
}

/// Accept one generation onto its task's decode lane (creating the lane
/// on first use), or bounce it with a typed error when this worker's
/// graph cannot generate.
fn accept_gen(
    cfg: &WorkerConfig,
    fwd: &dyn Forward,
    vocab: Option<usize>,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    lanes: &mut BTreeMap<String, DecodeLane>,
    mut g: GenRequest,
) {
    let (b, s) = fwd.batch_shape();
    let Some(vocab) = vocab else {
        metrics.errors.fetch_add(1, Ordering::Relaxed);
        let _ = g.resp.send(Err(ServeError::Batch {
            task: g.task.clone(),
            detail: format!(
                "graph '{}' is not generative (want [batch, seq, vocab] logits)",
                cfg.graph_key
            ),
        }));
        inflight.fetch_sub(1, Ordering::AcqRel);
        return;
    };
    // `Client::generate` validates prompts up front; guard the raw
    // channel path too, since a zero-token generation has no token to
    // carry its terminal event
    if g.prompt.is_empty() {
        metrics.errors.fetch_add(1, Ordering::Relaxed);
        let _ = g.resp.send(Err(ServeError::BadPrompt { got: 0, max: s - 1 }));
        inflight.fetch_sub(1, Ordering::AcqRel);
        return;
    }
    g.cfg.max_new = g.cfg.max_new.max(1);
    let at = cfg.clock.now();
    lanes
        .entry(g.task.clone())
        .or_insert_with(|| DecodeLane::new(b, s, vocab))
        .queue
        .push_back((g, at));
}

/// Advance one task's decode lane by at most ONE step: join queued
/// generations at the boundary, consult the refresh lifecycle, take a
/// FRESH adapter snapshot, run one fixed-shape forward, stream the
/// emitted tokens, and retire finished rows immediately.
#[allow(clippy::too_many_arguments)]
fn step_lane(
    cfg: &WorkerConfig,
    fwd: &dyn Forward,
    meta: &ParamStore,
    registry: &SharedRegistry,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    sched: Option<&BatchScheduler>,
    batch_idx: &mut u64,
    last_adapter: &mut Option<(String, u64)>,
    gap_recorded: &mut BTreeMap<String, u64>,
    task: &str,
    lane: &mut DecodeLane,
) -> LaneOutcome {
    // rows live BEFORE this boundary's joins: only those can observe a
    // version change mid-sequence
    let carried = lane.engine.live() > 0;
    // join at the step boundary: rows freed by retirement go straight
    // to waiting joiners
    while lane.engine.has_room() {
        let Some((g, at)) = lane.queue.pop_front() else {
            break;
        };
        let row = lane
            .engine
            .admit(g.id, &g.prompt, g.cfg.max_new, &g.cfg.stop_tokens)
            .expect("has_room guaranteed a free row");
        lane.seqs[row] = Some(GenSeq {
            resp: g.resp,
            enqueued_at: at,
            last_token_at: None,
        });
    }
    let fill = lane.engine.live();
    if fill == 0 {
        return LaneOutcome::Idle;
    }

    let now = cfg.clock.now();
    // a FRESH snapshot at every boundary is the whole mechanism: a swap
    // that landed since the previous step is picked up immediately, no
    // drain — in-flight sequences finish on the new version
    let Some((adapter, version)) = registry.snapshot(task) else {
        // an evicted decode task sheds its lane mid-stream with the
        // typed cold error (the page-in is queued); `Shed` semantics —
        // never auto-replayed — still apply because ticket errors are
        // terminal regardless of retryability
        let err = cold_or_missing(cfg, task, fill);
        shed_lane(lane, inflight, metrics, |_| err.clone());
        return LaneOutcome::Progressed;
    };
    if let Some(cache) = cfg.cache.as_ref() {
        // warmth-only touch (weight 0): a live decode lane keeps its
        // adapter paged in without counting a hit per step
        cache.lookup(task, now, 0);
    }
    if let Some(h) = cfg.refresh.as_ref() {
        match step_gate(h.view(task), version, now, DECODE_HOLD, &mut lane.held_since) {
            StepGate::Hold { until } => return LaneOutcome::Held { until },
            StepGate::Go => {}
        }
        // past the hold budget the step runs anyway (liveness over
        // freshness) — but it is counted as knowingly stale
        if h.is_stale(task, version, now) {
            metrics
                .stale_batch_requests
                .fetch_add(fill as u64, Ordering::Relaxed);
        }
    }
    if carried && lane.last_version.map_or(false, |v| v != version) {
        // the drain-free mid-sequence hot-swap: sequences that started
        // on the previous version finish on this one
        metrics.mid_seq_swaps.fetch_add(1, Ordering::Relaxed);
    }
    lane.last_version = Some(version);
    note_adapter_load(cfg, metrics, last_adapter, gap_recorded, task, version);

    // per-step re-balance: the modeled cost of THIS step-batch size is
    // a lookup into the scheduler's committed sweep, not a re-sweep
    let modeled = sched.map(|s| s.modeled_batch(fill));
    *batch_idx += 1;
    let seed = batch_idx
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(cfg.worker as u64);
    let logits = match fwd.lm_logits(meta, &adapter, lane.engine.inputs(), cfg.hw, seed) {
        Ok(l) => l,
        Err(e) => {
            let detail = format!("{e:#}");
            shed_lane(lane, inflight, metrics, |_| ServeError::Batch {
                task: task.to_string(),
                detail: detail.clone(),
            });
            return LaneOutcome::Progressed;
        }
    };
    let emits = lane.engine.apply_logits(&logits);
    let after = cfg.clock.now();
    metrics.record_decode_step(fill, lane.engine.capacity(), emits.len(), modeled);
    for e in emits {
        let seq = lane.seqs[e.row].as_mut().expect("live row has a client");
        if e.index == 0 {
            metrics.record_ttft(after.saturating_duration_since(seq.enqueued_at));
        } else if let Some(prev) = seq.last_token_at {
            metrics.record_intertoken(after.saturating_duration_since(prev));
        }
        seq.last_token_at = Some(after);
        // a dropped ticket just discards events; the row still decodes
        // to completion so the slot accounting stays exact
        let _ = seq.resp.send(Ok(TokenEvent {
            id: e.id,
            task: task.to_string(),
            worker: cfg.worker,
            token: e.token,
            index: e.index,
            done: e.finished,
            adapter_version: version,
            step_fill: fill,
        }));
        if e.finished {
            lane.seqs[e.row] = None;
            lane.engine.release(e.row);
            inflight.fetch_sub(1, Ordering::AcqRel);
            metrics.generations.fetch_add(1, Ordering::Relaxed);
        }
    }
    LaneOutcome::Progressed
}

/// Terminate every generation on a lane — live rows and queued joiners
/// alike — with the error `err` builds from the streamed-token count.
fn shed_lane(
    lane: &mut DecodeLane,
    inflight: &AtomicUsize,
    metrics: &Metrics,
    mut err: impl FnMut(usize) -> ServeError,
) {
    for row in 0..lane.engine.capacity() {
        if let Some(seq) = lane.seqs[row].take() {
            let streamed = lane.engine.emitted(row);
            let _ = seq.resp.send(Err(err(streamed)));
            lane.engine.release(row);
            inflight.fetch_sub(1, Ordering::AcqRel);
            metrics.errors.fetch_add(1, Ordering::Relaxed);
        }
    }
    for (g, _) in lane.queue.drain(..) {
        let _ = g.resp.send(Err(err(0)));
        inflight.fetch_sub(1, Ordering::AcqRel);
        metrics.errors.fetch_add(1, Ordering::Relaxed);
    }
}

/// Adapter-swap and swap-gap accounting shared by the batch and decode
/// paths: a task switch OR a new version of the same task (redeploy /
/// drift refresh) costs a DPU adapter swap, and the FIRST serve at a
/// refresh-installed version records the registry-swap → first-serve
/// gap exactly once per (task, version).
fn note_adapter_load(
    cfg: &WorkerConfig,
    metrics: &Metrics,
    last_adapter: &mut Option<(String, u64)>,
    gap_recorded: &mut BTreeMap<String, u64>,
    task: &str,
    version: u64,
) {
    let loaded = (task.to_string(), version);
    if last_adapter.as_ref() == Some(&loaded) {
        return;
    }
    metrics.adapter_swaps.fetch_add(1, Ordering::Relaxed);
    if let Some(h) = cfg.refresh.as_ref() {
        if let Some((at, v)) = h.last_swap(task) {
            if v == version && gap_recorded.get(task) != Some(&version) {
                let gap = cfg.clock.now().saturating_duration_since(at);
                metrics
                    .swap_gap_ns
                    .fetch_max(gap.as_nanos() as u64, Ordering::Relaxed);
                // feed the coordinator's adaptive window: the EWMA of
                // these gaps replaces the fixed coupling window
                h.observe_swap_gap(task, gap);
                gap_recorded.insert(task.to_string(), version);
            }
        }
    }
    *last_adapter = Some(loaded);
}

/// Classify a registry-snapshot miss mid-pipeline. With a capacity
/// tier the usual cause is an eviction racing admission: the lookup
/// queues the page-in (counting `weight` misses) and the answer is the
/// retryable [`ServeError::AdapterCold`]. Without a tier — or for a
/// task the tier never saw — the adapter genuinely vanished.
fn cold_or_missing(cfg: &WorkerConfig, task: &str, weight: usize) -> ServeError {
    if let Some(cache) = cfg.cache.as_ref() {
        match cache.lookup(task, cfg.clock.now(), weight) {
            // the page-in landed between the snapshot and this lookup:
            // still answer cold-retryable — the retry will hit
            CacheLookup::Hit | CacheLookup::Loading { .. } | CacheLookup::Queued { .. } => {
                return ServeError::AdapterCold {
                    task: task.to_string(),
                    loading: true,
                };
            }
            CacheLookup::Shed => {
                return ServeError::AdapterCold {
                    task: task.to_string(),
                    loading: false,
                };
            }
            CacheLookup::Unknown => {}
        }
    }
    ServeError::AdapterMissing {
        task: task.to_string(),
    }
}

/// Execute one task-pure batch and deliver a terminal result to every
/// request in it.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    cfg: &WorkerConfig,
    fwd: &dyn Forward,
    meta: &ParamStore,
    registry: &SharedRegistry,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    batch_idx: u64,
    last_adapter: &mut Option<(String, u64)>,
    gap_recorded: &mut BTreeMap<String, u64>,
    task: String,
    reqs: Vec<WorkRequest>,
    modeled: Option<Duration>,
) {
    let n = reqs.len();
    let Some((adapter, version)) = registry.snapshot(&task) else {
        metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
        let err = cold_or_missing(cfg, &task, n);
        respond_all(reqs, inflight, |_| Err(err.clone()));
        return;
    };
    if let Some(cache) = cfg.cache.as_ref() {
        // LRU warmth + hit accounting for the whole served batch
        cache.lookup(&task, cfg.clock.now(), n);
    }
    if let Some(h) = cfg.refresh.as_ref() {
        // requests knowingly served at a drift-degraded (or already
        // replaced) adapter version — the number refresh-aware
        // scheduling exists to drive to zero
        if h.is_stale(&task, version, cfg.clock.now()) {
            metrics
                .stale_batch_requests
                .fetch_add(n as u64, Ordering::Relaxed);
        }
    }
    note_adapter_load(cfg, metrics, last_adapter, gap_recorded, &task, version);
    if cfg.fail_every > 0 && batch_idx % cfg.fail_every == 0 {
        metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
        respond_all(reqs, inflight, |_| {
            Err(ServeError::Batch {
                task: task.clone(),
                detail: "injected batch failure".to_string(),
            })
        });
        return;
    }

    let t0 = cfg.clock.now();
    let mut tokens = Vec::with_capacity(n * cfg.seq);
    for r in &reqs {
        tokens.extend_from_slice(&r.tokens);
    }
    let seed = batch_idx
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(cfg.worker as u64);
    match fwd.cls_logits(meta, &adapter, &tokens, cfg.hw, seed) {
        Ok(rows) if rows.len() != n => {
            metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
            let detail = format!("graph returned {} rows for {n} requests", rows.len());
            respond_all(reqs, inflight, |_| {
                Err(ServeError::Batch {
                    task: task.clone(),
                    detail: detail.clone(),
                })
            });
        }
        Ok(rows) => {
            let latency = cfg.clock.now().saturating_duration_since(t0);
            metrics.record_modeled(n, latency, modeled);
            for (r, row) in reqs.into_iter().zip(rows) {
                let _ = r.resp.send(Ok(Response {
                    id: r.id,
                    task: task.clone(),
                    worker: cfg.worker,
                    logits: row,
                    latency,
                    batch_size: n,
                    adapter_version: version,
                }));
                inflight.fetch_sub(1, Ordering::AcqRel);
            }
        }
        Err(e) => {
            metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
            let detail = format!("{e:#}");
            respond_all(reqs, inflight, |_| {
                Err(ServeError::Batch {
                    task: task.clone(),
                    detail: detail.clone(),
                })
            });
        }
    }
}

fn respond_all<F>(reqs: Vec<WorkRequest>, inflight: &AtomicUsize, mut result: F)
where
    F: FnMut(&WorkRequest) -> ServeResult<Response>,
{
    for r in reqs {
        let out = result(&r);
        let _ = r.resp.send(out);
        inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Engine bring-up failed: answer every request (present and future)
/// with a terminal `WorkerInit` error until shutdown, then surface the
/// error to `Server::shutdown`.
fn fail_all(
    cfg: &WorkerConfig,
    rx: Receiver<Job>,
    inflight: &AtomicUsize,
    metrics: &Metrics,
    detail: String,
) -> ServeResult<()> {
    let err = ServeError::WorkerInit {
        worker: cfg.worker,
        detail,
    };
    eprintln!("[serve] worker {} init failed: {err}", cfg.worker);
    let reject = |r: WorkRequest| {
        let _ = r.resp.send(Err(err.clone()));
        inflight.fetch_sub(1, Ordering::AcqRel);
        metrics.errors.fetch_add(1, Ordering::Relaxed);
    };
    let reject_gen = |g: GenRequest| {
        let _ = g.resp.send(Err(err.clone()));
        inflight.fetch_sub(1, Ordering::AcqRel);
        metrics.errors.fetch_add(1, Ordering::Relaxed);
    };
    loop {
        match rx.recv() {
            Ok(Job::Req(r)) => reject(r),
            Ok(Job::Gen(g)) => reject_gen(g),
            Ok(Job::Shutdown) | Err(_) => break,
        }
    }
    while let Ok(job) = rx.try_recv() {
        match job {
            Job::Req(r) => reject(r),
            Job::Gen(g) => reject_gen(g),
            Job::Shutdown => {}
        }
    }
    Err(err)
}
