//! The sharded worker pool behind [`super::api`].
//!
//! Each worker thread owns its own PJRT engine (the handles are not
//! `Send`), built from the ONE manifest the builder already parsed, and
//! drains a per-worker dynamic batcher. The pool's contract with the
//! API layer: **every admitted request receives exactly one terminal
//! result**, on every path — success, adapter miss, batch failure,
//! injected fault, engine-init failure, and shutdown drain.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::manifest::Manifest;
use crate::eval::drift_eval::{cls_logits, fwd_batch_shape};
use crate::model::params::ParamStore;

use super::api::{Metrics, Response, ServeError, ServeResult};
use super::batcher::Batcher;
use super::refresh::RefreshHandle;
use super::registry::SharedRegistry;
use super::sched::{BatchScheduler, Clock, Decision, SchedConfig};

/// One admitted request travelling to a worker.
pub(crate) struct WorkRequest {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
    pub resp: Sender<ServeResult<Response>>,
}

pub(crate) enum Job {
    Req(WorkRequest),
    Shutdown,
}

/// Client-side view of one worker: its queue, in-flight budget, and
/// counters.
pub(crate) struct WorkerHandle {
    pub tx: Sender<Job>,
    pub inflight: Arc<AtomicUsize>,
    pub queue_depth: usize,
    pub metrics: Arc<Metrics>,
}

#[derive(Clone)]
pub(crate) struct WorkerConfig {
    pub worker: usize,
    pub graph_key: String,
    /// Sequence length the builder derived from the graph spec — the
    /// same value admission validates against, so client and worker
    /// can never segment a batch differently.
    pub seq: usize,
    pub max_batch: usize,
    pub max_wait: Duration,
    pub hw: [f32; 5],
    /// Chaos knob: fail every n-th batch (0 = off).
    pub fail_every: u64,
    /// Pipeline-aware scheduling: when set, batch fills come from the
    /// AIMC/PMCA cost model instead of the fixed size/deadline policy.
    pub sched: Option<SchedConfig>,
    /// Shared refresh-lifecycle view (present when the pool runs a
    /// drift-refresh worker): powers the scheduler's refresh coupling
    /// and the worker's stale-batch / swap-gap accounting.
    pub refresh: Option<RefreshHandle>,
    /// Time source for enqueue stamps, deadlines, and latency metrics
    /// (virtual in deterministic tests).
    pub clock: Arc<dyn Clock>,
}

/// After a shutdown signal, how long to wait for admitted-but-not-yet-
/// enqueued racers before giving up (they would resolve as `Lost`).
const DRAIN_GRACE: Duration = Duration::from_millis(500);

pub(crate) fn spawn_worker(
    cfg: WorkerConfig,
    manifest: Manifest,
    meta: Arc<ParamStore>,
    registry: SharedRegistry,
    queue_depth: usize,
) -> std::io::Result<(WorkerHandle, std::thread::JoinHandle<ServeResult<()>>)> {
    let (tx, rx) = channel::<Job>();
    let inflight = Arc::new(AtomicUsize::new(0));
    let metrics = Arc::new(Metrics::default());
    let handle = WorkerHandle {
        tx,
        inflight: inflight.clone(),
        queue_depth,
        metrics: metrics.clone(),
    };
    let name = format!("ahwa-serve-{}", cfg.worker);
    let join = std::thread::Builder::new()
        .name(name)
        .spawn(move || worker_loop(cfg, manifest, meta, registry, rx, inflight, metrics))?;
    Ok((handle, join))
}

fn worker_loop(
    cfg: WorkerConfig,
    manifest: Manifest,
    meta: Arc<ParamStore>,
    registry: SharedRegistry,
    rx: Receiver<Job>,
    inflight: Arc<AtomicUsize>,
    metrics: Arc<Metrics>,
) -> ServeResult<()> {
    // PJRT handles are not Send: the engine is created HERE, from the
    // manifest the builder parsed once for the whole pool.
    let engine = match crate::runtime::Engine::new(manifest) {
        Ok(e) => e,
        Err(e) => return fail_all(&cfg, rx, &inflight, &metrics, format!("engine: {e:#}")),
    };
    let graph = match engine.load(&cfg.graph_key) {
        Ok(g) => g,
        Err(e) => {
            return fail_all(
                &cfg,
                rx,
                &inflight,
                &metrics,
                format!("graph '{}': {e:#}", cfg.graph_key),
            )
        }
    };
    metrics
        .compile_ms
        .store(engine.total_compile_ms() as u64, Ordering::Relaxed);
    debug_assert_eq!(fwd_batch_shape(&graph).1, cfg.seq);

    let mut batcher: Batcher<WorkRequest> =
        Batcher::with_clock(cfg.max_batch, cfg.max_wait, cfg.clock.clone());
    let mut sched = cfg.sched.map(|s| {
        let s = BatchScheduler::new(s, cfg.max_batch, cfg.max_wait);
        match cfg.refresh.clone() {
            // refresh coupling: the scheduler reads trigger times and
            // refit-in-flight flags from the same handle the refresh
            // runner writes, on the same pool clock
            Some(h) => s.with_refresh(h),
            None => s,
        }
    });
    // (task, version) of the adapter loaded on the DPUs: a drift-refresh
    // hot-swap of the SAME task is an adapter swap too
    let mut last_adapter: Option<(String, u64)> = None;
    // per-task version whose swap→serve gap was already recorded, so a
    // later RELOAD of the same refreshed adapter (after serving another
    // task) cannot re-record a bogus, ever-growing "gap"
    let mut gap_recorded: BTreeMap<String, u64> = BTreeMap::new();
    let mut batch_idx: u64 = 0;
    let mut open = true;
    let mut drain_deadline = cfg.clock.now(); // set when `open` flips
    // the scheduler's own wake instant (coupled deadlines tighten, and
    // held tasks wake at deadline+hold, so the batcher's plain earliest
    // deadline is no longer always the right sleep bound)
    let mut sched_wake: Option<Instant> = None;
    // the ONE task this shard is currently deferring for a pending
    // hot-swap (pick surfaces at most one Hold at a time). Keeping it
    // worker-local means the shared handle is only touched on actual
    // hold transitions — never on the ordinary per-batch path — and
    // the pool-wide holding count stays a count of stalled SHARDS.
    let mut holding_task: Option<String> = None;

    loop {
        if open {
            // block until work/shutdown arrives or, if batches are
            // queued, exactly until the next actionable instant — no
            // fixed polling tick. For the fixed batcher that is its
            // earliest deadline; for the scheduler it is whatever
            // `pick` last said to wake at (tightened deadline or hold
            // bound).
            let wake = sched_wake.or_else(|| batcher.next_deadline());
            let msg = match wake {
                Some(d) => match rx.recv_timeout(d.saturating_duration_since(cfg.clock.now())) {
                    Ok(job) => Some(job),
                    Err(RecvTimeoutError::Timeout) => None,
                    Err(RecvTimeoutError::Disconnected) => Some(Job::Shutdown),
                },
                None => Some(rx.recv().unwrap_or(Job::Shutdown)),
            };
            match msg {
                Some(Job::Req(r)) => {
                    let task = r.task.clone();
                    if let Some(s) = sched.as_mut() {
                        s.observe_arrival(&task, cfg.clock.now());
                    }
                    batcher.push(&task, r);
                }
                Some(Job::Shutdown) => {
                    open = false;
                    drain_deadline = cfg.clock.now() + DRAIN_GRACE;
                    // drain mode bypasses the scheduler's Close arm, so
                    // release any hold now — a dead shard must not keep
                    // inflating the pool-wide holding count
                    if let Some(prev) = holding_task.take() {
                        if let Some(h) = cfg.refresh.as_ref() {
                            h.set_holding(&prev, false);
                        }
                    }
                }
                None => {}
            }
        } else {
            // drain mode: soak up racing submits without blocking
            while let Ok(job) = rx.try_recv() {
                if let Job::Req(r) = job {
                    let task = r.task.clone();
                    batcher.push(&task, r);
                }
            }
        }

        // serve EVERY ready batch before sleeping again — a full batch
        // must never wait on another task's deadline
        sched_wake = None;
        loop {
            let now = cfg.clock.now();
            let ready = if !open {
                // everything goes, deadlines notwithstanding
                batcher.pop_ready(now + cfg.max_wait + Duration::from_millis(1))
            } else if let Some(s) = sched.as_ref() {
                match s.pick(&batcher, now) {
                    Decision::Close { task, fill } | Decision::Drain { task, fill } => {
                        if holding_task.as_deref() == Some(task.as_str()) {
                            if let Some(h) = cfg.refresh.as_ref() {
                                h.set_holding(&task, false);
                            }
                            holding_task = None;
                        }
                        batcher.pop_task(&task, fill).map(|items| (task, items))
                    }
                    Decision::Hold { task, until } => {
                        // publish the deferral (on transitions only):
                        // the pool coordinator's stagger exists to
                        // bound how many shards sit here at once, and
                        // `concurrent_holds_peak` reports whether it
                        // succeeded
                        if holding_task.as_deref() != Some(task.as_str()) {
                            if let Some(h) = cfg.refresh.as_ref() {
                                if let Some(prev) = holding_task.take() {
                                    h.set_holding(&prev, false);
                                }
                                let holding = h.set_holding(&task, true) as u64;
                                metrics
                                    .concurrent_holds_peak
                                    .fetch_max(holding, Ordering::Relaxed);
                            }
                            holding_task = Some(task);
                        }
                        sched_wake = Some(until);
                        None
                    }
                    Decision::Wait { until } => {
                        sched_wake = Some(until);
                        None
                    }
                    Decision::Idle => None,
                }
            } else {
                batcher.pop_ready(now)
            };
            let Some((task, reqs)) = ready else { break };
            batch_idx += 1;
            let modeled = sched.as_ref().map(|s| s.modeled_batch(reqs.len()));
            serve_batch(
                &cfg, &graph, &meta, &registry, &metrics, &inflight, batch_idx,
                &mut last_adapter, &mut gap_recorded, task, reqs, modeled,
            );
            if !open {
                // progress resets the grace window: slow batches must
                // not eat the time reserved for in-flight racers
                drain_deadline = cfg.clock.now() + DRAIN_GRACE;
            }
        }

        if !open && batcher.pending() == 0 {
            // an admission bumps `inflight` BEFORE its send reaches the
            // channel; wait those racers out so no ticket is lost.
            if inflight.load(Ordering::Acquire) == 0 || cfg.clock.now() >= drain_deadline {
                break;
            }
            cfg.clock.sleep(Duration::from_micros(100));
        }
    }
    Ok(())
}

/// Execute one task-pure batch and deliver a terminal result to every
/// request in it.
#[allow(clippy::too_many_arguments)]
fn serve_batch(
    cfg: &WorkerConfig,
    graph: &crate::runtime::LoadedGraph,
    meta: &ParamStore,
    registry: &SharedRegistry,
    metrics: &Metrics,
    inflight: &AtomicUsize,
    batch_idx: u64,
    last_adapter: &mut Option<(String, u64)>,
    gap_recorded: &mut BTreeMap<String, u64>,
    task: String,
    reqs: Vec<WorkRequest>,
    modeled: Option<Duration>,
) {
    let n = reqs.len();
    let Some((adapter, version)) = registry.snapshot(&task) else {
        metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
        respond_all(reqs, inflight, |_| {
            Err(ServeError::AdapterMissing { task: task.clone() })
        });
        return;
    };
    if let Some(h) = cfg.refresh.as_ref() {
        // requests knowingly served at a drift-degraded (or already
        // replaced) adapter version — the number refresh-aware
        // scheduling exists to drive to zero
        if h.is_stale(&task, version, cfg.clock.now()) {
            metrics
                .stale_batch_requests
                .fetch_add(n as u64, Ordering::Relaxed);
        }
    }
    // a task switch OR a new version of the same task (redeploy /
    // drift refresh) costs a DPU adapter swap
    let loaded = (task.clone(), version);
    if last_adapter.as_ref() != Some(&loaded) {
        metrics.adapter_swaps.fetch_add(1, Ordering::Relaxed);
        // FIRST batch at a refresh-installed version: record how long
        // the refreshed adapter sat in the registry before serving.
        // Once per (task, version) — a later reload of the same version
        // after serving other tasks is an adapter swap, not a swap gap.
        if let Some(h) = cfg.refresh.as_ref() {
            if let Some((at, v)) = h.last_swap(&task) {
                if v == version && gap_recorded.get(&task) != Some(&version) {
                    let gap = cfg.clock.now().saturating_duration_since(at);
                    metrics
                        .swap_gap_ns
                        .fetch_max(gap.as_nanos() as u64, Ordering::Relaxed);
                    // feed the coordinator's adaptive window: the EWMA
                    // of these gaps replaces the fixed coupling window
                    h.observe_swap_gap(&task, gap);
                    gap_recorded.insert(task.clone(), version);
                }
            }
        }
        *last_adapter = Some(loaded);
    }
    if cfg.fail_every > 0 && batch_idx % cfg.fail_every == 0 {
        metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
        respond_all(reqs, inflight, |_| {
            Err(ServeError::Batch {
                task: task.clone(),
                detail: "injected batch failure".to_string(),
            })
        });
        return;
    }

    let t0 = cfg.clock.now();
    let mut tokens = Vec::with_capacity(n * cfg.seq);
    for r in &reqs {
        tokens.extend_from_slice(&r.tokens);
    }
    let seed = batch_idx
        .wrapping_mul(0x9e37_79b9_7f4a_7c15)
        .wrapping_add(cfg.worker as u64);
    match cls_logits(graph, meta, &adapter, &tokens, cfg.hw, seed) {
        Ok(rows) if rows.len() != n => {
            metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
            let detail = format!("graph returned {} rows for {n} requests", rows.len());
            respond_all(reqs, inflight, |_| {
                Err(ServeError::Batch {
                    task: task.clone(),
                    detail: detail.clone(),
                })
            });
        }
        Ok(rows) => {
            let latency = cfg.clock.now().saturating_duration_since(t0);
            metrics.record_modeled(n, latency, modeled);
            for (r, row) in reqs.into_iter().zip(rows) {
                let _ = r.resp.send(Ok(Response {
                    id: r.id,
                    task: task.clone(),
                    worker: cfg.worker,
                    logits: row,
                    latency,
                    batch_size: n,
                    adapter_version: version,
                }));
                inflight.fetch_sub(1, Ordering::AcqRel);
            }
        }
        Err(e) => {
            metrics.errors.fetch_add(n as u64, Ordering::Relaxed);
            let detail = format!("{e:#}");
            respond_all(reqs, inflight, |_| {
                Err(ServeError::Batch {
                    task: task.clone(),
                    detail: detail.clone(),
                })
            });
        }
    }
}

fn respond_all<F>(reqs: Vec<WorkRequest>, inflight: &AtomicUsize, mut result: F)
where
    F: FnMut(&WorkRequest) -> ServeResult<Response>,
{
    for r in reqs {
        let out = result(&r);
        let _ = r.resp.send(out);
        inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Engine bring-up failed: answer every request (present and future)
/// with a terminal `WorkerInit` error until shutdown, then surface the
/// error to `Server::shutdown`.
fn fail_all(
    cfg: &WorkerConfig,
    rx: Receiver<Job>,
    inflight: &AtomicUsize,
    metrics: &Metrics,
    detail: String,
) -> ServeResult<()> {
    let err = ServeError::WorkerInit {
        worker: cfg.worker,
        detail,
    };
    eprintln!("[serve] worker {} init failed: {err}", cfg.worker);
    let mut reject = |r: WorkRequest| {
        let _ = r.resp.send(Err(err.clone()));
        inflight.fetch_sub(1, Ordering::AcqRel);
        metrics.errors.fetch_add(1, Ordering::Relaxed);
    };
    loop {
        match rx.recv() {
            Ok(Job::Req(r)) => reject(r),
            Ok(Job::Shutdown) | Err(_) => break,
        }
    }
    while let Ok(job) = rx.try_recv() {
        if let Job::Req(r) = job {
            reject(r);
        }
    }
    Err(err)
}
