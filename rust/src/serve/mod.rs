//! Multi-task serving (Table III's deployment story), production-shaped.
//!
//! ONE analog base model (weight-stationary on the AIMC tiles — here, a
//! fixed meta store evaluated through the compiled forward graph) serves
//! N tasks by hot-swapping N small LoRA adapter sets on the DPUs. The
//! public surface is [`api`]:
//!
//! * [`api::ServerBuilder`] — variant, graph, worker count, queue depth,
//!   batching knobs; `build` spawns the pool.
//! * [`api::Client`] — cloneable submit handle; `submit` returns a typed
//!   [`api::Pending`] ticket that ALWAYS resolves (success or a
//!   per-request [`api::ServeError`] — no hung receivers).
//! * an engine pool — N worker threads, each owning its own PJRT engine
//!   (the handles are not `Send`), tasks pinned to workers by stable
//!   hash, bounded admission with `Overloaded` try-again backpressure.
//! * [`api::Metrics`] — per-worker counters plus a pool aggregate.
//!
//! # Adapter lifecycle
//!
//! A long-lived pool runs every adapter through one loop — deployment
//! onto the (drifting) analog substrate, service, modeled decay, and a
//! digital-side refresh that never touches the arrays:
//!
//! ```text
//!              SharedRegistry::deploy (version v, Arc snapshot)
//!                   │
//!      ┌────────────▼────────────┐
//!      │          SERVE          │ workers read Arc<ParamStore>
//!      │  (batches pin task+v)   │ snapshots; in-flight batches
//!      └────────────┬────────────┘ always finish on their snapshot
//!                   │ time passes on the pool Clock
//!      ┌────────────▼────────────┐
//!      │          DRIFT          │ g(t) = g_prog·((t+t₀)/t₀)^(−ν)
//!      │ RefreshPolicy predicts  │ post-GDC residual decay vs the
//!      │ decay from drift age    │ per-task tolerance
//!      └────────────┬────────────┘
//!                   │ decay ≥ tolerance
//!      ┌────────────▼────────────┐
//!      │         REFRESH         │ Refitter re-fits LoRA against the
//!      │  (bounded step budget)  │ drifted meta-weights (Trainer)
//!      └────────────┬────────────┘
//!                   │ deploy_if_version(v) — CAS: a concurrent manual
//!                   ▼              deploy wins, the stale refit is dropped
//!              HOT-SWAP (version v+1, O(pointer)) ──► back to SERVE
//! ```
//!
//! Supporting pieces:
//!
//! * [`registry`] — thread-safe adapter registry handing out
//!   `Arc<ParamStore>` snapshots (hot-swap is O(pointer) on the request
//!   path),
//! * [`batcher`]  — per-task dynamic batching with a max-wait deadline
//!   (batches never mix tasks: a task switch costs an adapter swap),
//! * [`sched`]    — pipeline-aware batch scheduling: the Fig. 4
//!   AIMC ⇄ PMCA balancing model picks the token parallelism and the
//!   modeled-optimal batch fill per task, and every timestamp flows
//!   through a [`sched::Clock`] (real or virtual) so timing behaviour
//!   is testable without sleeps,
//! * [`refresh`]  — drift-aware adapter refresh: per-task drift-age
//!   tracking on the pool clock, decay prediction (closed-form or
//!   Monte-Carlo through the device model), bounded LoRA refits, and
//!   versioned hot-swaps, all testable on the virtual clock,
//! * [`router`] / [`server`] — deprecated shims over [`api`]. The old
//!   call shapes (`Server::start`, `server.router`, raw `Msg` channels,
//!   `Router::submit` returning a bare receiver) are gone; the shims
//!   only point migrating code at the replacements.

pub mod api;
pub mod batcher;
mod pool;
pub mod refresh;
pub mod registry;
pub mod router;
pub mod sched;
pub mod server;

pub use api::{
    aggregate, submit_wave, submit_wave_results, Client, Metrics, MetricsSnapshot, Pending,
    Response, ServeError, ServeResult, Server, ServerBuilder,
};
pub use refresh::{
    DecayModel, FnRefitter, Refit, Refitter, RefreshConfig, RefreshEvent, RefreshPolicy,
    RefreshRunner, TrainerRefitter,
};
pub use sched::{BatchScheduler, Clock, RealClock, SchedConfig, VirtualClock};
