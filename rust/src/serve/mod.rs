//! Multi-task serving coordinator (Table III's deployment story).
//!
//! ONE analog base model (weight-stationary on the AIMC tiles — here, a
//! fixed meta store evaluated through the compiled forward graph) serves
//! N tasks by hot-swapping N small LoRA adapter sets on the DPUs:
//!
//! * [`registry`] — thread-safe adapter registry (deploy / swap / version),
//! * [`batcher`]  — per-task dynamic batching with a max-wait deadline,
//! * [`router`]   — request admission + task routing,
//! * [`server`]   — the worker loop that owns the PJRT engine and drains
//!   batches through the forward graph, with latency/throughput metrics.
//!
//! The PJRT handles are not Send, so the engine lives on the worker
//! thread; clients talk over mpsc channels — the same ownership shape a
//! vLLM-style router/worker split uses.

pub mod batcher;
pub mod registry;
pub mod router;
pub mod server;
