//! Multi-task serving (Table III's deployment story), production-shaped.
//!
//! ONE analog base model (weight-stationary on the AIMC tiles — here, a
//! fixed meta store evaluated through the compiled forward graph) serves
//! N tasks by hot-swapping N small LoRA adapter sets on the DPUs. The
//! public surface is [`api`]:
//!
//! * [`api::ServerBuilder`] — variant, graph, worker count, queue depth,
//!   batching knobs; `build` spawns the pool.
//! * [`api::Client`] — cloneable submit handle; `submit` returns a typed
//!   [`api::Pending`] ticket that ALWAYS resolves (success or a
//!   per-request [`api::ServeError`] — no hung receivers), and
//!   `generate` returns a streaming [`api::GenTicket`] over per-token
//!   events from the continuous-batching decode loop.
//! * an engine pool — N worker threads, each owning its own PJRT engine
//!   (the handles are not `Send`), tasks pinned to workers by stable
//!   hash, bounded admission with `Overloaded` try-again backpressure.
//! * [`api::Metrics`] — per-worker counters plus a pool aggregate.
//!
//! # Adapter lifecycle
//!
//! A long-lived pool runs every adapter through one loop — deployment
//! onto the (drifting) analog substrate, service, modeled decay, and a
//! digital-side refresh that never touches the arrays. The scheduler is
//! *coupled* to that loop: it reads the refresh lifecycle through a
//! shared [`refresh::RefreshHandle`] and shapes batches so hot-swaps
//! land between batches instead of under them:
//!
//! ```text
//!              SharedRegistry::deploy (version v, Arc snapshot)
//!                   │
//!      ┌────────────▼────────────┐   drift pressure (trigger_at,
//!      │          SERVE          │◄──refit-in-flight) read via
//!      │  (batches pin task+v)   │   RefreshHandle: fills shrink,
//!      └────────────┬────────────┘   deadlines tighten ahead of the
//!                   │ time passes    swap; fills extend just after it
//!                   │ on the pool Clock          ▲
//!      ┌────────────▼────────────┐               │ staggered trigger +
//!      │          DRIFT          │               │ adaptive window/hold
//!      │ RefreshPolicy predicts  │  ┌────────────┴────────────┐
//!      │ decay from drift age    │  │       COORDINATE        │
//!      └────────────┬────────────┘  │ RefreshCoordinator      │
//!                   │ decay ≥ tol   │ re-phases trigger_at    │
//!                   │ (staggered:   │ (≤ max_concurrent_holds │
//!                   │  the coord-   │ shards hold at once);   │
//!                   │  inator may   │ window ← EWMA(swap_gap) │
//!                   │  pull the     │ hold ← measured refit   │
//!                   │  trigger      │ budget (observed_budget)│
//!                   │  EARLIER)     └────────────▲────────────┘
//!      ┌────────────▼────────────┐               │ swap-gap + refit
//!      │         REFRESH         │───────────────┘ timings feed back
//!      │  (bounded step budget)  │ Refitter re-fits LoRA against the
//!      └────────────┬────────────┘ drifted meta-weights (Trainer);
//!                   │              coupled workers drain small batches
//!                   │              while the refit runs
//!                   │ deploy_if_version(v) — CAS: a concurrent manual
//!                   ▼              deploy wins, the stale refit is dropped
//!              HOT-SWAP (version v+1, O(pointer)) ──► back to SERVE
//!                   (first post-swap batch serves v+1 immediately;
//!                    Metrics::swap_gap_ns records the handoff gap,
//!                    Metrics::concurrent_holds_peak how many shards
//!                    ever stalled together)
//!
//!       ┌───────────────────────── CACHE ────────────────────────┐
//!       │  bounded adapter residency (capacity = DPU memory)     │
//!       │                                                        │
//!       │  miss ──► load (serialized upload channel; queue full  │
//!       │   │       ⇒ typed AdapterCold shed) ──► resident       │
//!       │   │                                       │ LRU,       │
//!       │   │            restore at SAME version    │ unpinned   │
//!       │   │            (drift anchor preserved)   ▼            │
//!       │   └◄──────────────────────────────── evicted           │
//!       │        bytes kept host-side; version counter retained  │
//!       │                                                        │
//!       │  ──► REFRESH: eviction sets the tracked task's evicted │
//!       │      flag — due()/is_stale skip it (no refit of a      │
//!       │      paged-out adapter, no stale debt it cannot act    │
//!       │      on) and the coordinator stops staggering it;      │
//!       │      reload at the retained version re-anchors NOTHING │
//!       │      — deployed_at survives, so the adapter comes back │
//!       │      with its FULL drift age and refits immediately    │
//!       │      if it is due (the substrate drifted while the     │
//!       │      digital adapter was paged out)                    │
//!       │  ──► SCHEDULE: the prefetcher reads per-task arrival   │
//!       │      EWMAs (BatchScheduler::arrival_rates) and starts  │
//!       │      page-ins for tasks whose predicted next arrival   │
//!       │      is within the horizon — cold-start p99 is the     │
//!       │      number it exists to cut                           │
//!       └────────────────────────────────────────────────────────┘
//!
//!       ┌──────────────────────── DECODE ────────────────────────┐
//!       │  step-batch (continuous batching, one lane per task)   │
//!       │                                                        │
//!       │  join ──► [ row0: tok tok tok ▸            ]           │
//!       │           [ row1: tok ▸                    ]◄── join   │
//!       │           [ row2: tok tok EOS ] ─► retire, row frees   │
//!       │                 │step│step│step│                       │
//!       │                 ▼    ▼    ▼    ▼  every boundary:      │
//!       │   fresh registry snapshot + RefreshHandle consult      │
//!       │   (due swap? → step_gate Holds ≤ hold budget, the      │
//!       │    swap lands BETWEEN steps: a sequence starts on v    │
//!       │    and finishes on v+1 — no drain, zero stale steps;   │
//!       │    Metrics::mid_seq_swaps counts the crossings)        │
//!       │   re-balance: modeled fill cost = table lookup into    │
//!       │   the scheduler's committed sweep (no re-sweep)        │
//!       └────────────────────────────────────────────────────────┘
//!
//!       ┌─────────────────────── BACKENDS ───────────────────────┐
//!       │  hal::Backend — the substrate seam behind the pool     │
//!       │                                                        │
//!       │   deploy      forward        drift_model   cost_model  │
//!       │   (page-in    (per-worker    (feeds the    (feeds the  │
//!       │    latency)    executor)      REFRESH box)  scheduler  │
//!       │                                             + routing) │
//!       │                                                        │
//!       │  ONE backend (default PcmPjrt): no router — tasks      │
//!       │  hash across all workers, bit-identical pre-HAL path   │
//!       │  N backends: contiguous worker span per backend;       │
//!       │  hal::Router places each task on the backend with the  │
//!       │  lowest modeled service + tolerance-maintenance cost   │
//!       │  (sticky on first use; pin_task overrides); REFRESH    │
//!       │  and CACHE then read that task's drift model and       │
//!       │  deploy latency from ITS backend                       │
//!       └────────────────────────────────────────────────────────┘
//!
//!       ┌──────────────────────── COMPILE ───────────────────────┐
//!       │  runtime::compile — the staged forward-graph pipeline  │
//!       │  manifest → graph IR (role segments) → passes (shape   │
//!       │  inference · input-segment layout validation · dead-   │
//!       │  output elision) → lowering → per-(key, batch) PJRT    │
//!       │  compile cache                                         │
//!       │                                                        │
//!       │  build time: each worker reads its backend scheduler's │
//!       │  fill commitment (committed_fills — the per-request-   │
//!       │  latency frontier of the SAME cost table that closes   │
//!       │  batches) and AOT-specializes its executor for exactly │
//!       │  those fills:                                          │
//!       │    exact-shape sibling artifact → compiled directly    │
//!       │      (zero padding, zero re-pack)                      │
//!       │    fill == graph batch → pass-through (zero copy)      │
//!       │    otherwise → persistent prepacked buffer (tail       │
//!       │      zeroed ONCE at build, not per batch)              │
//!       │  odd fills fall back to the padded max-shape path;     │
//!       │  every path is bit-identical (compile_golden pins it)  │
//!       │  ──► SCHEDULE: the fill set COMPILE specializes IS the │
//!       │      scheduler's commitment — one table, no disagree   │
//!       └────────────────────────────────────────────────────────┘
//!
//!       ┌─────────────────────── REBALANCE ──────────────────────┐
//!       │  hal::RebalanceRunner — cadenced adaptive placement    │
//!       │  (opt-in via ServerBuilder::rebalance, ≥ 2 backends)   │
//!       │                                                        │
//!       │  every tick: retire idle tasks, then re-route against  │
//!       │  measured arrival EWMAs under the HYSTERESIS gate —    │
//!       │  a move fires only when (cost_from − cost_to) over one │
//!       │  cooldown of traffic repays h × the destination's      │
//!       │  deploy latency, AND the task's cooldown expired       │
//!       │  (stationary traffic ⇒ ZERO moves after convergence)   │
//!       │                                                        │
//!       │  approved move = drain-free 3-step handoff:            │
//!       │   1 freeze ─► RefreshHandle::set_migrating: the old    │
//!       │     span's scheduler serves the queue out at the next  │
//!       │     batch boundary (drain mode, outranks holds); the   │
//!       │     worker clears the flag at queue-empty              │
//!       │   2 carry ──► drift physics re-read from the NEW       │
//!       │     backend WITHOUT re-anchoring deployed_at           │
//!       │     (set_task_decay: accumulated drift age survives);  │
//!       │     cache page-in re-priced to the new deploy cost;    │
//!       │     residency + EWMAs are task-keyed and follow free   │
//!       │   3 flip ───► Router::apply_move: new submissions land │
//!       │     on the destination span; in-flight tickets resolve │
//!       │     on the old span exactly once                       │
//!       │                                                        │
//!       │  (SimPool-only: span_resize follows traffic share —    │
//!       │   the real pool's executors are thread-bound)          │
//!       └────────────────────────────────────────────────────────┘
//! ```
//!
//! # Streaming tickets
//!
//! Generative requests enter through [`api::Client::generate`], which
//! admits against the same bounded budget as `submit` and returns an
//! [`api::GenTicket`]. The ticket is an iterator-shaped receiver over
//! [`decode::TokenEvent`]s: `try_next()` polls without blocking,
//! `next_event()` blocks for one token, `wait_all()` collects the full
//! [`decode::Generation`] (tokens plus the first/last adapter versions
//! it was served at — unequal versions mean the sequence crossed a
//! drain-free hot-swap). Exactly one terminal arrives on every path:
//! the `done` token event, or a typed [`api::ServeError`]. Mid-stream
//! failures surface as `ServeError::Shed { streamed, .. }` and are
//! deliberately NOT retryable — a partially-streamed generation is
//! never silently replayed from token 0 (see
//! [`api::Client::generate_with_retry`]).
//!
//! Supporting pieces:
//!
//! * [`registry`] — thread-safe adapter registry handing out
//!   `Arc<ParamStore>` snapshots (hot-swap is O(pointer) on the request
//!   path); with a capacity tier attached, a registry entry means
//!   "resident on the DPUs",
//! * [`cache`]    — bounded adapter residency over the registry: LRU
//!   eviction with pinned hot tasks, a serialized modeled load channel
//!   with a bounded queue (beyond it, cold requests shed with the
//!   retryable [`api::ServeError::AdapterCold`] — see its
//!   retryability docs), predictive prefetch from the scheduler's
//!   arrival EWMAs, and refresh integration (evicted tasks are never
//!   refit, and page back in with their full drift age),
//! * [`batcher`]  — per-task dynamic batching with a max-wait deadline
//!   (batches never mix tasks: a task switch costs an adapter swap),
//! * [`sched`]    — pipeline-aware batch scheduling: the Fig. 4
//!   AIMC ⇄ PMCA balancing model picks the token parallelism and the
//!   modeled-optimal batch fill per task, with an optional
//!   refresh-coupling policy ([`sched::RefreshCoupling`]) that shapes
//!   fills and deadlines around drift refreshes,
//! * [`refresh`]  — drift-aware adapter refresh: per-task drift-age
//!   tracking on the pool clock, decay prediction (closed-form or
//!   Monte-Carlo through the device model), bounded LoRA refits, and
//!   versioned hot-swaps, publishing per-task phase through the shared
//!   [`refresh::RefreshHandle`],
//! * [`coord`]    — pool-level refresh coordination: staggers modeled
//!   triggers across tasks/shards (bounding simultaneous hold windows
//!   at `max_concurrent_holds`) and adapts each task's coupling window
//!   (from observed swap gaps) and hold (from the refitter's measured
//!   step budget), feeding decisions back through the same
//!   [`refresh::RefreshHandle`] the schedulers already read,
//! * [`decode`]   — the continuous-batching step engine: fixed-shape
//!   step-batches where sequences join at step boundaries and retire at
//!   EOS, plus the step-boundary refresh gate ([`decode::step_gate`]).
//!   Offline eval ([`crate::experiments::llm::batched_greedy`]) and
//!   live serving decode through this one engine, so the PAD layout,
//!   argmax tie-break, and stop rules cannot diverge,
//! * [`hal`]      — the hardware abstraction behind the pool: a
//!   [`hal::Backend`] trait over deploy / forward / drift-model /
//!   cost-model, the [`hal::PcmPjrt`] reference substrate (the exact
//!   pre-HAL path; [`hal::PcmPjrt::conservative`] is a slow-drift
//!   retention-tuned bank), the feature-gated [`hal::DigitalRef`]
//!   (drift-free, with optional [`crate::pcm::PcmModel`] quantization/
//!   programming-noise numerics), the [`hal::Router`] that places
//!   tasks on heterogeneous pools by modeled service +
//!   tolerance-maintenance cost, and the cadenced
//!   [`hal::RebalanceRunner`] that keeps placement tracking measured
//!   traffic under a hysteresis gate with live span migration. At
//!   build, [`api::ServerBuilder`] feeds each backend scheduler's
//!   [`sched::BatchScheduler::committed_fills`] into
//!   [`hal::Forward::specialize`] so the COMPILE stage above pre-lowers
//!   exactly the fills the scheduler will close.
//!
//! (The deprecated `serve::router` / `serve::server` shims from the
//! pre-builder API are gone; [`api`] is the only serving surface.)
//!
//! # Testing on the virtual clock
//!
//! Every timestamp in the pool — enqueue stamps, scheduler deadlines,
//! drift ages, refresh triggers — flows through one [`sched::Clock`].
//! Swap in a [`sched::VirtualClock`] and the whole
//! deploy → serve → drift → refresh → hot-swap cycle becomes a
//! deterministic, sleep-free state machine the test advances manually:
//!
//! ```text
//! let clock = Arc::new(VirtualClock::new());
//! let mut batcher = Batcher::with_clock(8, max_wait, clock.clone());
//! let mut sched   = BatchScheduler::new(cfg, 8, max_wait)
//!                       .with_refresh(runner.policy().handle());
//! clock.advance(dt);            // time moves ONLY here
//! runner.tick(clock.now());     // refresh check, exactly when you say
//! match sched.pick(&batcher, clock.now()) { ... }
//! ```
//!
//! Because scheduler and refresh share the clock, assertions like
//! "zero requests served at a stale version" or "no batch spans a
//! version bump" are exact, not probabilistic. The conformance suite
//! for the coupling lives in `tests/refresh_sched_e2e.rs`, the
//! cross-worker coordination suite in `tests/coord_conformance.rs`, and
//! the continuous-batching decode suite in `tests/decode_conformance.rs`
//! (all on the shared `tests/common/refresh_sim.rs` harness); the
//! scheduler-policy property tests in `tests/sched_properties.rs`; the
//! capacity-tier conformance suite in `tests/cache_conformance.rs`; the
//! backend-HAL suite (mixed-pool routing, default-backend equivalence,
//! adaptive-rebalance hysteresis properties, migration safety, and the
//! DigitalRef-numerics digital-vs-analog comparison) in
//! `tests/hal_conformance.rs`.

pub mod api;
pub mod batcher;
pub mod cache;
pub mod coord;
pub mod decode;
pub mod hal;
mod pool;
pub mod refresh;
pub mod registry;
pub mod sched;

pub use api::{
    aggregate, submit_wave, submit_wave_results, BuildError, Client, ErrorClass, GenTicket,
    Metrics, MetricsSnapshot, Pending, Response, ServeError, ServeResult, Server, ServerBuilder,
};
#[cfg(feature = "digital-ref")]
pub use hal::DigitalRef;
pub use hal::{
    drift_free, Backend, BackendProfile, CostModel, Forward, PcmPjrt, PlannedMove,
    RebalanceConfig, RebalanceRunner, Router, TaskProfile,
};
pub use cache::{AdapterCache, CacheConfig, CacheLookup};
pub use decode::{
    greedy_chunks, step_gate, GenConfig, Generation, StepEmit, StepEngine, StepGate, TokenEvent,
};
pub use coord::{stagger_assign, CoordConfig, RefreshCoordinator, StaggerEntry};
pub use refresh::{
    BudgetMeter, DecayModel, FnRefitter, Refit, Refitter, RefreshConfig, RefreshEvent,
    RefreshHandle, RefreshPolicy, RefreshRunner, RefreshView, TrainerRefitter,
};
pub use sched::{
    ArrivalRate, BatchScheduler, Clock, Decision, RealClock, RefreshCoupling, SchedConfig,
    VirtualClock,
};
