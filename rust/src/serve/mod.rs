//! Multi-task serving (Table III's deployment story), production-shaped.
//!
//! ONE analog base model (weight-stationary on the AIMC tiles — here, a
//! fixed meta store evaluated through the compiled forward graph) serves
//! N tasks by hot-swapping N small LoRA adapter sets on the DPUs. The
//! public surface is [`api`]:
//!
//! * [`api::ServerBuilder`] — variant, graph, worker count, queue depth,
//!   batching knobs; `build` spawns the pool.
//! * [`api::Client`] — cloneable submit handle; `submit` returns a typed
//!   [`api::Pending`] ticket that ALWAYS resolves (success or a
//!   per-request [`api::ServeError`] — no hung receivers).
//! * an engine pool — N worker threads, each owning its own PJRT engine
//!   (the handles are not `Send`), tasks pinned to workers by stable
//!   hash, bounded admission with `Overloaded` try-again backpressure.
//! * [`api::Metrics`] — per-worker counters plus a pool aggregate.
//!
//! Supporting pieces:
//!
//! * [`registry`] — thread-safe adapter registry handing out
//!   `Arc<ParamStore>` snapshots (hot-swap is O(pointer) on the request
//!   path),
//! * [`batcher`]  — per-task dynamic batching with a max-wait deadline
//!   (batches never mix tasks: a task switch costs an adapter swap),
//! * [`sched`]    — pipeline-aware batch scheduling: the Fig. 4
//!   AIMC ⇄ PMCA balancing model picks the token parallelism and the
//!   modeled-optimal batch fill per task, and every timestamp flows
//!   through a [`sched::Clock`] (real or virtual) so timing behaviour
//!   is testable without sleeps,
//! * [`router`] / [`server`] — deprecated shims over [`api`]. The old
//!   call shapes (`Server::start`, `server.router`, raw `Msg` channels,
//!   `Router::submit` returning a bare receiver) are gone; the shims
//!   only point migrating code at the replacements.

pub mod api;
pub mod batcher;
mod pool;
pub mod registry;
pub mod router;
pub mod sched;
pub mod server;

pub use api::{
    aggregate, submit_wave, submit_wave_results, Client, Metrics, MetricsSnapshot, Pending,
    Response, ServeError, ServeResult, Server, ServerBuilder,
};
pub use sched::{BatchScheduler, Clock, RealClock, SchedConfig, VirtualClock};
