//! The public serving surface: builder → server → cloneable client.
//!
//! ```text
//! Server::builder("tiny")          ServerBuilder (knobs)
//!     .workers(4)                      │ build(meta, registry)
//!     .queue_depth(256)                ▼
//!     .build(meta, registry)       Server ──── shutdown() drains + joins
//!         │ client()                   │
//!         ▼                            ▼
//!     Client::submit(task, toks)   WorkerPool: N threads, each owning
//!         │                        its OWN PJRT engine + batcher
//!         ▼                        (task → worker by stable hash)
//!     Pending::wait() ── ALWAYS resolves: Ok(Response) or ServeError
//! ```
//!
//! Design invariants:
//!
//! * **Every admitted request gets exactly one terminal result.** Batch
//!   failures, missing adapters, worker-init failures and shutdown all
//!   answer with a typed [`ServeError`]; a [`Pending`] ticket can never
//!   hang a receiver.
//! * **Bounded admission.** Each worker has a `queue_depth` in-flight
//!   budget; when it is exhausted `submit` fails fast with
//!   [`ServeError::Overloaded`] (try-again backpressure) instead of
//!   growing an unbounded queue.
//! * **Sharded engines.** PJRT handles are not `Send`, so each worker
//!   thread builds its own engine from ONE shared manifest load and
//!   tasks are pinned to workers by a stable hash — per-worker batchers
//!   keep the "batches never mix tasks" rule and minimise adapter swaps.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::model::params::ParamStore;
use crate::util::stats;

use super::cache::{AdapterCache, CacheConfig, CacheLookup};
use super::coord::{CoordConfig, RefreshCoordinator};
use super::decode::{GenConfig, Generation, TokenEvent};
use super::hal::{
    drift_free, spawn_rebalance_worker, Backend, BackendProfile, PcmPjrt, PlannedMove,
    RebalanceConfig, RebalanceRunner, Router,
};
use super::pool::{self, GenRequest, Job, WorkRequest, WorkerHandle};
use super::refresh::{spawn_refresh_worker, RefreshConfig, RefreshEvent, RefreshRunner};
use super::registry::SharedRegistry;
use super::sched::{Clock, RealClock, SchedConfig};

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Every way a request (or the server itself) can fail, as data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServeError {
    /// Request token count does not match the serving graph's sequence.
    BadShape { got: usize, want: usize },
    /// No adapter deployed under this task name at submit time.
    UnknownTask { task: String, known: Vec<String> },
    /// Generation prompt is empty or leaves no room in the context
    /// window (decode needs ≥ 1 free position for the first new token).
    BadPrompt { got: usize, max: usize },
    /// The target worker's in-flight budget is exhausted — try again.
    Overloaded { worker: usize, depth: usize },
    /// The task is known but its adapter is paged out of the bounded
    /// capacity tier ([`super::cache`]). `loading: true` means a page-in
    /// is already on the upload channel (retry after roughly the cache's
    /// load latency); `false` means the load queue itself was full and
    /// the request was shed before a load could even be queued.
    AdapterCold { task: String, loading: bool },
    /// An in-flight generation was shed MID-STREAM (shutdown drain
    /// expired, adapter vanished, or the decode step failed) after
    /// `streamed` tokens already reached the client. Deliberately
    /// non-retryable: replaying it would restart from token 0.
    Shed { task: String, streamed: usize },
    /// Adapter disappeared between admission and execution.
    AdapterMissing { task: String },
    /// The forward batch failed in the engine (or by injected fault).
    Batch { task: String, detail: String },
    /// The worker could not bring up its PJRT engine.
    WorkerInit { worker: usize, detail: String },
    /// Server-level startup/configuration failure.
    Init { detail: String },
    /// The server is shutting down; no new work is admitted.
    ShuttingDown,
    /// A response channel closed without a terminal result. Guarded
    /// against by the pool; surfaced only if a worker is killed hard or
    /// an admission races shutdown past the drain grace window.
    Lost,
}

/// Coarse classification of a [`ServeError`] — the ONE source of truth
/// for how a client should react. Before this existed, `AdapterCold`,
/// `Shed{streamed}`, and `Overloaded` each grew their own ad-hoc retry
/// rule; now every variant maps to exactly one class
/// ([`ServeError::class`]), and the full table is pinned by a unit test
/// so a new variant cannot ship unclassified.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorClass {
    /// Transient PRE-ADMISSION backpressure: no work started, retrying
    /// is free ([`Client::submit_with_retry`] keys off this class).
    Retryable,
    /// This request cannot succeed as issued (bad shape/prompt/task) or
    /// its work was irrecoverably lost mid-flight (`Shed`, `Batch`) —
    /// a blind retry would be wrong, but the server is healthy.
    NonRetryable,
    /// The server or worker itself is unusable (init failure, shutdown,
    /// a hard-killed worker): stop sending traffic here.
    Fatal,
}

impl ServeError {
    /// Classify this error (see [`ErrorClass`]). Exhaustive by
    /// construction — adding a `ServeError` variant forces a decision
    /// here.
    pub fn class(&self) -> ErrorClass {
        match self {
            // pre-admission bounces: no work started, retrying is free.
            // `Overloaded` = worker queue full; `AdapterCold` = page-in
            // in flight (retry after roughly the cache's load latency).
            ServeError::Overloaded { .. } | ServeError::AdapterCold { .. } => {
                ErrorClass::Retryable
            }
            // the request itself is malformed or names nothing servable
            ServeError::BadShape { .. }
            | ServeError::UnknownTask { .. }
            | ServeError::BadPrompt { .. }
            | ServeError::AdapterMissing { .. } => ErrorClass::NonRetryable,
            // mid-flight losses: tokens/work may already have reached
            // the client ([`ServeError::Shed`] counts them), so a blind
            // replay would silently restart from token 0 — streaming
            // re-issue is the caller's decision, never the retry
            // helpers'
            ServeError::Shed { .. } | ServeError::Batch { .. } => ErrorClass::NonRetryable,
            // the serving process itself is in trouble
            ServeError::WorkerInit { .. }
            | ServeError::Init { .. }
            | ServeError::ShuttingDown
            | ServeError::Lost => ErrorClass::Fatal,
        }
    }

    /// `true` exactly when [`ServeError::class`] is
    /// [`ErrorClass::Retryable`].
    pub fn is_retryable(&self) -> bool {
        self.class() == ErrorClass::Retryable
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::BadShape { got, want } => {
                write!(f, "request has {got} tokens, serving graph expects {want}")
            }
            ServeError::UnknownTask { task, known } => {
                write!(f, "unknown task '{task}' (deployed: {known:?})")
            }
            ServeError::BadPrompt { got, max } => {
                write!(f, "prompt has {got} tokens, generation needs 1..={max}")
            }
            ServeError::Overloaded { worker, depth } => {
                write!(f, "worker {worker} at queue depth {depth}, try again")
            }
            ServeError::AdapterCold { task, loading } => {
                if *loading {
                    write!(f, "adapter for task '{task}' is paged out, load in flight")
                } else {
                    write!(f, "adapter for task '{task}' is paged out, load queue full")
                }
            }
            ServeError::Shed { task, streamed } => {
                write!(
                    f,
                    "generation for task '{task}' shed mid-stream after {streamed} tokens"
                )
            }
            ServeError::AdapterMissing { task } => {
                write!(f, "no adapter deployed for task '{task}'")
            }
            ServeError::Batch { task, detail } => {
                write!(f, "batch for task '{task}' failed: {detail}")
            }
            ServeError::WorkerInit { worker, detail } => {
                write!(f, "worker {worker} failed to initialise: {detail}")
            }
            ServeError::Init { detail } => write!(f, "server init failed: {detail}"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Lost => write!(f, "response channel closed without a result"),
        }
    }
}

impl std::error::Error for ServeError {}

pub type ServeResult<T> = Result<T, ServeError>;

// ---------------------------------------------------------------------------
// Responses and tickets
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub task: String,
    /// Worker that executed the batch (shard of the engine pool).
    pub worker: usize,
    /// Per-example logits row from the task head.
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
    pub adapter_version: u64,
}

/// Ticket for one admitted request. Always resolves to a terminal
/// `ServeResult` — the pool guarantees exactly one send per admission.
#[derive(Debug)]
pub struct Pending {
    pub id: u64,
    pub worker: usize,
    pub(crate) rx: Receiver<ServeResult<Response>>,
}

impl Pending {
    /// Block until the terminal result arrives.
    pub fn wait(self) -> ServeResult<Response> {
        self.rx.recv().unwrap_or(Err(ServeError::Lost))
    }

    /// Non-blocking poll; `None` while the request is still in flight.
    pub fn try_wait(&self) -> Option<ServeResult<Response>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => Some(Err(ServeError::Lost)),
        }
    }
}

/// Streaming ticket for one admitted generation
/// ([`Client::generate`]). Events arrive per token as the worker's
/// step-batch advances; the stream ALWAYS terminates — with a
/// [`TokenEvent`] whose `done` flag is set, or with exactly one typed
/// [`ServeError`] ([`ServeError::Shed`] for a mid-stream shed, which is
/// never auto-retried).
#[derive(Debug)]
pub struct GenTicket {
    pub id: u64,
    pub worker: usize,
    pub task: String,
    rx: Receiver<ServeResult<TokenEvent>>,
    done: bool,
    streamed: usize,
}

impl GenTicket {
    /// Non-blocking poll for the next per-token event. `None` while
    /// the next token is still decoding — and forever after the
    /// terminal event has been delivered.
    pub fn try_next(&mut self) -> Option<ServeResult<TokenEvent>> {
        if self.done {
            return None;
        }
        match self.rx.try_recv() {
            Ok(ev) => Some(self.absorb(ev)),
            Err(TryRecvError::Empty) => None,
            Err(TryRecvError::Disconnected) => {
                self.done = true;
                Some(Err(ServeError::Lost))
            }
        }
    }

    /// Block for the next per-token event; `None` once the stream has
    /// delivered its terminal event.
    pub fn next_event(&mut self) -> Option<ServeResult<TokenEvent>> {
        if self.done {
            return None;
        }
        match self.rx.recv() {
            Ok(ev) => Some(self.absorb(ev)),
            Err(_) => {
                self.done = true;
                Some(Err(ServeError::Lost))
            }
        }
    }

    /// Drain the stream and assemble the whole [`Generation`]. On error
    /// the partial tokens are dropped — check
    /// [`Self::tokens_streamed`] before deciding whether a re-issue is
    /// safe ([`ServeError::Shed`] reports the worker-side count too).
    pub fn wait_all(mut self) -> ServeResult<Generation> {
        let mut tokens = Vec::new();
        let (mut first_v, mut last_v) = (0u64, 0u64);
        while let Some(ev) = self.next_event() {
            let ev = ev?;
            if tokens.is_empty() {
                first_v = ev.adapter_version;
            }
            last_v = ev.adapter_version;
            tokens.push(ev.token);
            if ev.done {
                return Ok(Generation {
                    id: self.id,
                    task: self.task,
                    worker: self.worker,
                    tokens,
                    first_version: first_v,
                    last_version: last_v,
                });
            }
        }
        Err(ServeError::Lost)
    }

    /// Tokens received so far (a mid-stream error leaves this at the
    /// count the client actually saw).
    pub fn tokens_streamed(&self) -> usize {
        self.streamed
    }

    fn absorb(&mut self, ev: ServeResult<TokenEvent>) -> ServeResult<TokenEvent> {
        match &ev {
            Ok(t) => {
                self.streamed += 1;
                if t.done {
                    self.done = true;
                }
            }
            Err(_) => self.done = true,
        }
        ev
    }
}

// ---------------------------------------------------------------------------
// Metrics
// ---------------------------------------------------------------------------

/// Latency/batch-size percentiles are computed over a ring of the most
/// recent batches, so a long-running server's memory stays bounded.
const METRIC_SAMPLE_CAP: usize = 4096;

fn push_sample(v: &mut Vec<f64>, idx: usize, x: f64) {
    if v.len() < METRIC_SAMPLE_CAP {
        v.push(x);
    } else {
        v[idx % METRIC_SAMPLE_CAP] = x;
    }
}

/// Per-worker serving counters (lock-free on the hot path; latency and
/// batch-size samples under a mutex touched once per batch).
#[derive(Default)]
pub struct Metrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub adapter_swaps: AtomicU64,
    pub errors: AtomicU64,
    /// Admission rejections (Overloaded), counted client-side.
    pub rejected: AtomicU64,
    /// PJRT compile time paid by this worker at startup.
    pub compile_ms: AtomicU64,
    /// Drift-aware adapter refreshes completed ([`super::refresh`]).
    pub refreshes: AtomicU64,
    /// Optimizer steps spent across all refits.
    pub refresh_steps: AtomicU64,
    /// Failed refit attempts (kept separate from `errors`, which counts
    /// failed *requests*).
    pub refresh_errors: AtomicU64,
    /// Requests served at a stale adapter version — past the task's
    /// modeled refresh trigger, or after a newer version already landed
    /// in the registry. Refresh-aware scheduling
    /// ([`super::sched::RefreshCoupling`]) exists to drive this to 0.
    pub stale_batch_requests: AtomicU64,
    /// Worst observed gap (ns) between a refresh hot-swap landing in
    /// the registry and the first batch serving the refreshed version.
    pub swap_gap_ns: AtomicU64,
    /// Most shards observed deferring a batch for a pending hot-swap at
    /// once (`Decision::Hold`). The pool coordinator's trigger stagger
    /// ([`super::coord`]) exists to bound this at
    /// `CoordConfig::max_concurrent_holds`; uncoordinated pools whose
    /// tasks share a tolerance peak at the full worker count — the
    /// correlated-stall failure.
    pub concurrent_holds_peak: AtomicU64,
    /// Worst trigger re-phase (ns) the coordinator applied when
    /// staggering (0 = never staggered / coordination off).
    pub stagger_shift_ns: AtomicU64,
    /// Generations completed through the continuous-batching decode
    /// path ([`super::decode`]).
    pub generations: AtomicU64,
    /// Decode steps executed (one fixed-shape forward per step).
    pub decode_steps: AtomicU64,
    /// Tokens emitted across all generations.
    pub decode_tokens: AtomicU64,
    /// Refresh hot-swaps that landed BETWEEN steps of in-flight
    /// sequences — a sequence started on version v and finished on
    /// v+1 without draining. The step-boundary gate
    /// ([`super::decode::step_gate`]) is what makes these safe.
    pub mid_seq_swaps: AtomicU64,
    /// Requests whose adapter was resident in the capacity tier
    /// ([`super::cache`]) at lookup time.
    pub cache_hits: AtomicU64,
    /// Requests that found their adapter paged out (whether the load
    /// was then queued, already in flight, or shed).
    pub cache_misses: AtomicU64,
    /// Adapters paged out of the capacity tier (LRU evictions).
    pub cache_evictions: AtomicU64,
    /// Prefetched adapters that a demand request subsequently hit —
    /// the predictive tier's success count.
    pub cache_prefetch_hits: AtomicU64,
    /// Cold requests shed because the adapter load queue was full
    /// (typed [`ServeError::AdapterCold`] with `loading: false`).
    pub cache_shed: AtomicU64,
    /// Span migrations applied by the cadenced rebalancer
    /// ([`super::hal::RebalanceRunner`]); stays 0 when rebalance is off
    /// — and, post-convergence, under stationary traffic (hysteresis).
    pub rebalance_moves: AtomicU64,
    /// Router placements retired after the configured idle horizon
    /// ([`super::hal::RebalanceConfig::idle_retire`]).
    pub tasks_retired: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
    /// Scheduler-modeled batch latency samples (µs), recorded alongside
    /// the measured ones when pipeline-aware scheduling is active.
    modeled_us: Mutex<Vec<f64>>,
    /// Time-to-first-token samples (ns), one per generation.
    ttft_ns: Mutex<Vec<f64>>,
    /// Ring cursor for `ttft_ns`. Each ring owns its cursor: indexing a
    /// ring by an unrelated counter (the old scheme used
    /// `decode_tokens`) makes concurrent generations — which read the
    /// same counter value — stomp one slot while the rest of the ring
    /// goes stale.
    ttft_cursor: AtomicU64,
    /// Inter-token gap samples (ns) within generations.
    intertoken_ns: Mutex<Vec<f64>>,
    /// Ring cursor for `intertoken_ns` (see `ttft_cursor`).
    intertoken_cursor: AtomicU64,
    /// Per-step occupancy samples: live sequences / step-batch
    /// capacity, in 0..=1.
    step_fill: Mutex<Vec<f64>>,
    /// Cold-start wait samples (ns): first demand miss → adapter
    /// resident again ([`super::cache`]'s queue-to-page-in latency).
    cold_start_ns: Mutex<Vec<f64>>,
    /// Ring cursor for `cold_start_ns`.
    cold_start_cursor: AtomicU64,
}

impl Metrics {
    pub(crate) fn record(&self, n: usize, latency: Duration) {
        self.record_modeled(n, latency, None);
    }

    /// Record a served batch plus, when the scheduler supplied one, the
    /// cost model's predicted latency — the modeled-vs-measured pair
    /// the snapshot reports.
    pub(crate) fn record_modeled(&self, n: usize, latency: Duration, modeled: Option<Duration>) {
        self.served.fetch_add(n as u64, Ordering::Relaxed);
        let b = self.batches.fetch_add(1, Ordering::Relaxed) as usize;
        // ns-resolution µs, like the modeled sample below: as_micros()
        // truncates, which flattens every sub-µs virtual-clock latency
        // (and the fractional part of every real one) to 0
        push_sample(&mut self.latencies_us.lock().unwrap(), b, latency.as_nanos() as f64 / 1e3);
        push_sample(&mut self.batch_sizes.lock().unwrap(), b, n as f64);
        if let Some(m) = modeled {
            push_sample(&mut self.modeled_us.lock().unwrap(), b, m.as_nanos() as f64 / 1e3);
        }
    }

    /// Record one decode step: `fill` live sequences stepped in a
    /// capacity-`cap` step-batch, emitting `tokens` tokens; `modeled`
    /// is the scheduler's table-lookup latency for this step-batch size
    /// when pipeline scheduling is active. (`pub` because the
    /// virtual-clock decode sim in `tests/common` records through the
    /// same surface the pool worker does.)
    pub fn record_decode_step(&self, fill: usize, cap: usize, tokens: usize, modeled: Option<Duration>) {
        let s = self.decode_steps.fetch_add(1, Ordering::Relaxed) as usize;
        self.decode_tokens.fetch_add(tokens as u64, Ordering::Relaxed);
        push_sample(&mut self.step_fill.lock().unwrap(), s, fill as f64 / cap.max(1) as f64);
        if let Some(m) = modeled {
            push_sample(&mut self.modeled_us.lock().unwrap(), s, m.as_nanos() as f64 / 1e3);
        }
    }

    /// Time-to-first-token for one generation (worker enqueue → first
    /// token out). The ring advances by its own cursor: concurrent
    /// generations each claim a distinct slot (fetch_add), where
    /// indexing by `decode_tokens` made simultaneous recorders stomp
    /// the slot the shared counter happened to point at.
    pub fn record_ttft(&self, d: Duration) {
        let i = self.ttft_cursor.fetch_add(1, Ordering::Relaxed) as usize;
        push_sample(&mut self.ttft_ns.lock().unwrap(), i, d.as_nanos() as f64);
    }

    /// Gap between consecutive tokens of one generation (own ring
    /// cursor — see [`Metrics::record_ttft`]).
    pub fn record_intertoken(&self, d: Duration) {
        let i = self.intertoken_cursor.fetch_add(1, Ordering::Relaxed) as usize;
        push_sample(&mut self.intertoken_ns.lock().unwrap(), i, d.as_nanos() as f64);
    }

    /// Cold-start wait for one paged-out adapter: first demand miss →
    /// resident again ([`super::cache`] records this when the load
    /// lands).
    pub fn record_cold_start(&self, d: Duration) {
        let i = self.cold_start_cursor.fetch_add(1, Ordering::Relaxed) as usize;
        push_sample(&mut self.cold_start_ns.lock().unwrap(), i, d.as_nanos() as f64);
    }

    pub fn snapshot(&self, label: &str) -> MetricsSnapshot {
        let lat = self.latencies_us.lock().unwrap();
        let bs = self.batch_sizes.lock().unwrap();
        let modeled = self.modeled_us.lock().unwrap();
        let ttft = self.ttft_ns.lock().unwrap();
        let itl = self.intertoken_ns.lock().unwrap();
        let fill = self.step_fill.lock().unwrap();
        let cold = self.cold_start_ns.lock().unwrap();
        MetricsSnapshot {
            label: label.to_string(),
            served: self.served.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            adapter_swaps: self.adapter_swaps.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            compile_ms: self.compile_ms.load(Ordering::Relaxed),
            refreshes: self.refreshes.load(Ordering::Relaxed),
            refresh_steps: self.refresh_steps.load(Ordering::Relaxed),
            refresh_errors: self.refresh_errors.load(Ordering::Relaxed),
            stale_batch_requests: self.stale_batch_requests.load(Ordering::Relaxed),
            swap_gap_ns: self.swap_gap_ns.load(Ordering::Relaxed),
            concurrent_holds_peak: self.concurrent_holds_peak.load(Ordering::Relaxed),
            stagger_shift_ns: self.stagger_shift_ns.load(Ordering::Relaxed),
            generations: self.generations.load(Ordering::Relaxed),
            decode_steps: self.decode_steps.load(Ordering::Relaxed),
            decode_tokens: self.decode_tokens.load(Ordering::Relaxed),
            mid_seq_swaps: self.mid_seq_swaps.load(Ordering::Relaxed),
            cache_hits: self.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.cache_evictions.load(Ordering::Relaxed),
            cache_prefetch_hits: self.cache_prefetch_hits.load(Ordering::Relaxed),
            cache_shed: self.cache_shed.load(Ordering::Relaxed),
            rebalance_moves: self.rebalance_moves.load(Ordering::Relaxed),
            tasks_retired: self.tasks_retired.load(Ordering::Relaxed),
            cold_start_p99_ms: stats::percentile(&cold, 99.0) / 1e6,
            batch_mean: stats::mean(&bs),
            lat_p50_ms: stats::percentile(&lat, 50.0) / 1e3,
            lat_p95_ms: stats::percentile(&lat, 95.0) / 1e3,
            modeled_p50_ms: stats::percentile(&modeled, 50.0) / 1e3,
            ttft_p50_ms: stats::percentile(&ttft, 50.0) / 1e6,
            intertoken_p50_ms: stats::percentile(&itl, 50.0) / 1e6,
            step_occupancy_mean: stats::mean(&fill),
        }
    }

    pub fn summary(&self) -> String {
        self.snapshot("").to_string()
    }

    pub fn p50_latency_ms(&self) -> f64 {
        stats::percentile(&self.latencies_us.lock().unwrap(), 50.0) / 1e3
    }
}

/// Point-in-time view of one worker's (or the whole pool's) counters.
#[derive(Clone, Debug, Default)]
pub struct MetricsSnapshot {
    pub label: String,
    pub served: u64,
    pub batches: u64,
    pub adapter_swaps: u64,
    pub errors: u64,
    pub rejected: u64,
    pub compile_ms: u64,
    /// Drift-aware adapter refreshes completed (0 when refresh is off).
    pub refreshes: u64,
    /// Optimizer steps spent across all refits.
    pub refresh_steps: u64,
    /// Failed refit attempts (distinct from `errors`: those count
    /// failed requests).
    pub refresh_errors: u64,
    /// Requests served at a stale adapter version (0 when refresh is
    /// off or the coupled scheduler kept every batch fresh).
    pub stale_batch_requests: u64,
    /// Worst observed registry-swap → first-serve gap, ns (0 until a
    /// refreshed version has served a batch).
    pub swap_gap_ns: u64,
    /// Most shards simultaneously holding for a pending swap (0 when
    /// nothing was ever held; the coordinator bounds it at
    /// `max_concurrent_holds`).
    pub concurrent_holds_peak: u64,
    /// Worst coordinator trigger re-phase, ns (0 = no staggering).
    pub stagger_shift_ns: u64,
    /// Generations completed on the decode path (0 = no generative
    /// traffic).
    pub generations: u64,
    /// Decode steps executed across all generations.
    pub decode_steps: u64,
    /// Tokens emitted across all generations.
    pub decode_tokens: u64,
    /// Hot-swaps that landed mid-sequence, between decode steps.
    pub mid_seq_swaps: u64,
    /// Capacity-tier lookups that found the adapter resident (0 when
    /// no cache is configured).
    pub cache_hits: u64,
    /// Lookups that found the adapter paged out.
    pub cache_misses: u64,
    /// LRU evictions performed by the capacity tier.
    pub cache_evictions: u64,
    /// Prefetched adapters later hit by demand traffic.
    pub cache_prefetch_hits: u64,
    /// Cold requests shed with a full load queue.
    pub cache_shed: u64,
    /// Span migrations applied by the cadenced rebalancer (0 when
    /// rebalance is off or placement has converged).
    pub rebalance_moves: u64,
    /// Router placements retired after the idle horizon.
    pub tasks_retired: u64,
    /// p99 cold-start wait, ms: first demand miss → resident again (0
    /// when nothing ever went cold).
    pub cold_start_p99_ms: f64,
    pub batch_mean: f64,
    pub lat_p50_ms: f64,
    pub lat_p95_ms: f64,
    /// Scheduler-modeled p50 batch latency (0 when the pipeline-aware
    /// scheduler is off). The model predicts on-target AIMC/PMCA time,
    /// so on the simulation host it is a shape reference, not a match.
    pub modeled_p50_ms: f64,
    /// p50 time-to-first-token across generations (0 = no decode).
    pub ttft_p50_ms: f64,
    /// p50 gap between consecutive tokens within generations.
    pub intertoken_p50_ms: f64,
    /// Mean step-batch occupancy (live sequences / capacity, 0..=1) —
    /// the number continuous join exists to keep high.
    pub step_occupancy_mean: f64,
}

impl MetricsSnapshot {
    /// Capacity-tier hit fraction in 0..=1, or 0.0 when no lookups
    /// happened (guarded: never divides by zero).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if !self.label.is_empty() {
            write!(f, "{}: ", self.label)?;
        }
        write!(
            f,
            "served={} batches={} swaps={} errors={} rejected={} batch_mean={:.1} lat_p50={:.1}ms lat_p95={:.1}ms compile={}ms",
            self.served,
            self.batches,
            self.adapter_swaps,
            self.errors,
            self.rejected,
            self.batch_mean,
            self.lat_p50_ms,
            self.lat_p95_ms,
            self.compile_ms,
        )?;
        if self.modeled_p50_ms > 0.0 {
            write!(f, " model_p50={:.3}ms", self.modeled_p50_ms)?;
        }
        if self.refreshes > 0 || self.refresh_errors > 0 {
            write!(
                f,
                " refreshes={} refit_steps={} refit_errors={}",
                self.refreshes, self.refresh_steps, self.refresh_errors
            )?;
        }
        if self.stale_batch_requests > 0 || self.swap_gap_ns > 0 {
            write!(
                f,
                " stale_reqs={} swap_gap={:.1}µs",
                self.stale_batch_requests,
                self.swap_gap_ns as f64 / 1e3
            )?;
        }
        if self.concurrent_holds_peak > 0 || self.stagger_shift_ns > 0 {
            write!(
                f,
                " holds_peak={} stagger_shift={:.1}µs",
                self.concurrent_holds_peak,
                self.stagger_shift_ns as f64 / 1e3
            )?;
        }
        if self.decode_steps > 0 {
            write!(
                f,
                " gens={} steps={} tokens={} occ={:.0}% ttft_p50={:.2}ms itl_p50={:.2}ms",
                self.generations,
                self.decode_steps,
                self.decode_tokens,
                self.step_occupancy_mean * 100.0,
                self.ttft_p50_ms,
                self.intertoken_p50_ms,
            )?;
            if self.mid_seq_swaps > 0 {
                write!(f, " mid_seq_swaps={}", self.mid_seq_swaps)?;
            }
        }
        if self.rebalance_moves > 0 || self.tasks_retired > 0 {
            write!(
                f,
                " rebalance_moves={} tasks_retired={}",
                self.rebalance_moves, self.tasks_retired
            )?;
        }
        if self.cache_hits + self.cache_misses > 0 {
            write!(
                f,
                " cache_hit_rate={:.0}% evictions={} prefetch_hits={} cold_shed={} cold_p99={:.2}ms",
                self.cache_hit_rate() * 100.0,
                self.cache_evictions,
                self.cache_prefetch_hits,
                self.cache_shed,
                self.cold_start_p99_ms,
            )?;
        }
        Ok(())
    }
}

/// Merge per-worker metrics into one pool-level snapshot (counters sum;
/// percentiles computed over the union of latency samples).
pub fn aggregate<'a>(workers: impl IntoIterator<Item = &'a Metrics>) -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        label: "pool".to_string(),
        ..MetricsSnapshot::default()
    };
    let mut lat = Vec::new();
    let mut bs = Vec::new();
    let mut modeled = Vec::new();
    let mut ttft = Vec::new();
    let mut itl = Vec::new();
    let mut fill = Vec::new();
    let mut cold = Vec::new();
    for m in workers {
        out.served += m.served.load(Ordering::Relaxed);
        out.batches += m.batches.load(Ordering::Relaxed);
        out.adapter_swaps += m.adapter_swaps.load(Ordering::Relaxed);
        out.errors += m.errors.load(Ordering::Relaxed);
        out.rejected += m.rejected.load(Ordering::Relaxed);
        out.compile_ms += m.compile_ms.load(Ordering::Relaxed);
        out.refreshes += m.refreshes.load(Ordering::Relaxed);
        out.refresh_steps += m.refresh_steps.load(Ordering::Relaxed);
        out.refresh_errors += m.refresh_errors.load(Ordering::Relaxed);
        out.stale_batch_requests += m.stale_batch_requests.load(Ordering::Relaxed);
        out.generations += m.generations.load(Ordering::Relaxed);
        out.decode_steps += m.decode_steps.load(Ordering::Relaxed);
        out.decode_tokens += m.decode_tokens.load(Ordering::Relaxed);
        out.mid_seq_swaps += m.mid_seq_swaps.load(Ordering::Relaxed);
        out.cache_hits += m.cache_hits.load(Ordering::Relaxed);
        out.cache_misses += m.cache_misses.load(Ordering::Relaxed);
        out.cache_evictions += m.cache_evictions.load(Ordering::Relaxed);
        out.cache_prefetch_hits += m.cache_prefetch_hits.load(Ordering::Relaxed);
        out.cache_shed += m.cache_shed.load(Ordering::Relaxed);
        out.rebalance_moves += m.rebalance_moves.load(Ordering::Relaxed);
        out.tasks_retired += m.tasks_retired.load(Ordering::Relaxed);
        // the gap is a worst-case, not a flow: max, not sum — and so are
        // the hold peak (each worker records the pool-wide count it saw)
        // and the stagger shift
        out.swap_gap_ns = out.swap_gap_ns.max(m.swap_gap_ns.load(Ordering::Relaxed));
        out.concurrent_holds_peak = out
            .concurrent_holds_peak
            .max(m.concurrent_holds_peak.load(Ordering::Relaxed));
        out.stagger_shift_ns = out
            .stagger_shift_ns
            .max(m.stagger_shift_ns.load(Ordering::Relaxed));
        lat.extend_from_slice(&m.latencies_us.lock().unwrap());
        bs.extend_from_slice(&m.batch_sizes.lock().unwrap());
        modeled.extend_from_slice(&m.modeled_us.lock().unwrap());
        ttft.extend_from_slice(&m.ttft_ns.lock().unwrap());
        itl.extend_from_slice(&m.intertoken_ns.lock().unwrap());
        fill.extend_from_slice(&m.step_fill.lock().unwrap());
        cold.extend_from_slice(&m.cold_start_ns.lock().unwrap());
    }
    out.batch_mean = stats::mean(&bs);
    out.lat_p50_ms = stats::percentile(&lat, 50.0) / 1e3;
    out.lat_p95_ms = stats::percentile(&lat, 95.0) / 1e3;
    out.modeled_p50_ms = stats::percentile(&modeled, 50.0) / 1e3;
    out.ttft_p50_ms = stats::percentile(&ttft, 50.0) / 1e6;
    out.intertoken_p50_ms = stats::percentile(&itl, 50.0) / 1e6;
    out.step_occupancy_mean = stats::mean(&fill);
    out.cold_start_p99_ms = stats::percentile(&cold, 99.0) / 1e6;
    out
}

// ---------------------------------------------------------------------------
// Build-time errors
// ---------------------------------------------------------------------------

/// Every way [`ServerBuilder::build`] can refuse to stand a pool up, as
/// data. Before this existed every build failure collapsed into
/// `ServeError::Init { detail }` and callers string-matched; now each
/// misconfiguration is a variant, and the cross-config implications the
/// builder enforces are spelled out where they are checked:
///
/// * **coupling requires refresh** — a
///   [`SchedConfig::coupling`](super::sched::SchedConfig::coupling)
///   policy reacts to refresh lifecycle state; without
///   [`ServerBuilder::refresh`] there is no runner to couple to and the
///   policy would be silently inert.
/// * **coordination requires coupling** — the pool-level coordinator
///   ([`ServerBuilder::coordination`]) staggers triggers and adapts
///   window/hold FOR the coupled schedulers; without a coupled
///   scheduler and a refresh runner its outputs have no consumer.
/// * **each backend needs a worker** — heterogeneous routing partitions
///   the worker pool across backends; an empty span would make a
///   backend unroutable.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BuildError {
    /// Manifest load failed or the variant is unknown.
    Manifest { detail: String },
    /// The serving graph is missing or has no `[batch, seq]` data input.
    Graph { graph: String, detail: String },
    /// [`RefreshConfig::validate`] rejected the refresh knobs.
    Refresh { detail: String },
    /// `CacheConfig::validate` rejected the capacity-tier knobs.
    Cache { detail: String },
    /// A scheduler coupling policy was configured without
    /// [`ServerBuilder::refresh`].
    CouplingWithoutRefresh,
    /// [`ServerBuilder::coordination`] without a coupled scheduler and
    /// a refresh runner for it to coordinate.
    CoordinationWithoutCoupling,
    /// Backend registration is inconsistent (duplicate names, more
    /// backends than workers, or a pin to an unregistered backend).
    Backends { detail: String },
    /// Spawning a worker or the refresh worker failed (OS thread error).
    Spawn { what: String, detail: String },
}

impl fmt::Display for BuildError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BuildError::Manifest { detail } => write!(f, "manifest: {detail}"),
            BuildError::Graph { graph, detail } => {
                write!(f, "serving graph '{graph}': {detail}")
            }
            BuildError::Refresh { detail } => write!(f, "refresh config: {detail}"),
            BuildError::Cache { detail } => write!(f, "adapter cache config: {detail}"),
            BuildError::CouplingWithoutRefresh => write!(
                f,
                "scheduler coupling configured without .refresh(..): \
                 there is no refresh runner to couple to"
            ),
            BuildError::CoordinationWithoutCoupling => write!(
                f,
                "(.coordination(..)) requires a scheduler with a coupling \
                 policy AND .refresh(..): the coordinator staggers triggers \
                 for coupled schedulers"
            ),
            BuildError::Backends { detail } => write!(f, "backends: {detail}"),
            BuildError::Spawn { what, detail } => write!(f, "spawning {what}: {detail}"),
        }
    }
}

impl std::error::Error for BuildError {}

/// Build failures remain representable as [`ServeError`] for callers
/// that funnel every serving-layer error into one type.
impl From<BuildError> for ServeError {
    fn from(e: BuildError) -> ServeError {
        ServeError::Init {
            detail: e.to_string(),
        }
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Configuration for a serving pool; `build` spawns the workers.
#[derive(Clone)]
pub struct ServerBuilder {
    variant: String,
    graph: Option<String>,
    manifest: Option<crate::config::manifest::Manifest>,
    workers: usize,
    queue_depth: usize,
    max_batch: usize,
    max_wait: Duration,
    hw: [f32; 5],
    fail_every: u64,
    sched: Option<SchedConfig>,
    refresh: Option<RefreshConfig>,
    coord: Option<CoordConfig>,
    no_coord: bool,
    cache: Option<CacheConfig>,
    backends: Vec<Arc<dyn Backend>>,
    pins: BTreeMap<String, usize>,
    rebalance: Option<RebalanceConfig>,
    clock: Arc<dyn Clock>,
}

impl fmt::Debug for ServerBuilder {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let backends: Vec<&str> = self.backends.iter().map(|b| b.name()).collect();
        f.debug_struct("ServerBuilder")
            .field("variant", &self.variant)
            .field("graph", &self.graph)
            .field("workers", &self.workers)
            .field("queue_depth", &self.queue_depth)
            .field("max_batch", &self.max_batch)
            .field("max_wait", &self.max_wait)
            .field("hw", &self.hw)
            .field("fail_every", &self.fail_every)
            .field("sched", &self.sched)
            .field("refresh", &self.refresh)
            .field("coord", &self.coord)
            .field("no_coord", &self.no_coord)
            .field("cache", &self.cache)
            .field("backends", &backends)
            .field("pins", &self.pins)
            .field("rebalance", &self.rebalance)
            .finish_non_exhaustive()
    }
}

impl ServerBuilder {
    pub fn new(variant: &str) -> ServerBuilder {
        ServerBuilder {
            variant: variant.to_string(),
            graph: None,
            manifest: None,
            workers: 1,
            queue_depth: 64,
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            // inference hardware vector: quantizers active, no in-graph noise
            hw: [0.0, 0.0, 127.0, 127.0, 0.0],
            fail_every: 0,
            sched: None,
            refresh: None,
            coord: None,
            no_coord: false,
            cache: None,
            backends: Vec::new(),
            pins: BTreeMap::new(),
            rebalance: None,
            clock: Arc::new(RealClock),
        }
    }

    /// Serving graph key; defaults to `"{variant}/fwd_cls"`.
    pub fn graph(mut self, key: &str) -> Self {
        self.graph = Some(key.to_string());
        self
    }

    /// Reuse an already-parsed manifest (e.g. from an experiment `Ctx`)
    /// instead of re-reading `artifacts/` from disk.
    pub fn manifest(mut self, m: crate::config::manifest::Manifest) -> Self {
        self.manifest = Some(m);
        self
    }

    /// Number of worker threads, each owning its own PJRT engine.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Per-worker in-flight budget; beyond it `submit` returns
    /// [`ServeError::Overloaded`].
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n.max(1);
        self
    }

    pub fn max_batch(mut self, n: usize) -> Self {
        self.max_batch = n.max(1);
        self
    }

    pub fn max_wait(mut self, d: Duration) -> Self {
        self.max_wait = d;
        self
    }

    pub fn hw(mut self, hw: [f32; 5]) -> Self {
        self.hw = hw;
        self
    }

    /// Chaos knob: make every `every`-th batch fail inside the worker
    /// (0 disables). Exercises the error path end to end — admitted
    /// requests must still resolve with [`ServeError::Batch`].
    pub fn inject_batch_failure(mut self, every: u64) -> Self {
        self.fail_every = every;
        self
    }

    /// Enable pipeline-aware batch scheduling: workers pick batch fills
    /// from the AIMC/PMCA cost model ([`super::sched`]) instead of the
    /// fixed size/deadline policy. A `seq_len` of 0 inherits the serving
    /// graph's sequence length. With a
    /// [`SchedConfig::coupling`](super::sched::SchedConfig::coupling)
    /// policy AND [`Self::refresh`] configured, the schedulers become
    /// refresh-aware: fills shrink and deadlines tighten ahead of a
    /// modeled drift trigger so hot-swaps land between batches (the
    /// `stale_batch_requests` / `swap_gap_ns` metrics report how well
    /// that works).
    pub fn scheduler(mut self, cfg: SchedConfig) -> Self {
        self.sched = Some(cfg);
        self
    }

    /// Drift-aware adapter refresh ([`super::refresh`]): a background
    /// worker tracks each deployed task's drift age on the pool clock,
    /// predicts accuracy decay from the PCM drift model, and when a
    /// task crosses its tolerance re-fits its LoRA against the drifted
    /// meta-weights (bounded step budget) and hot-swaps it through the
    /// registry — versioned, monotone, torn-read-free.
    pub fn refresh(mut self, cfg: RefreshConfig) -> Self {
        self.refresh = Some(cfg);
        self
    }

    /// Customise pool-level refresh coordination ([`super::coord`]):
    /// trigger staggering across tasks/shards and adaptive coupling
    /// window/hold bounds. A coordinator with the default
    /// [`CoordConfig`] is wired automatically whenever both
    /// [`Self::scheduler`] and [`Self::refresh`] are configured; this
    /// overrides its knobs.
    pub fn coordination(mut self, cfg: CoordConfig) -> Self {
        self.coord = Some(cfg);
        self.no_coord = false;
        self
    }

    /// Opt out of pool-level refresh coordination (each worker couples
    /// to the refresh runner independently, the pre-coordinator
    /// behaviour). The serve-demo CLI and the serving examples expose
    /// this as `--no-coord`.
    pub fn no_coordination(mut self) -> Self {
        self.no_coord = true;
        self.coord = None;
        self
    }

    /// Bounded adapter residency ([`super::cache`]): at most
    /// `capacity` adapters stay resident (registry entry = resident on
    /// the DPUs); the LRU unpinned one is paged out to a host-side
    /// backing store when a cold task's load lands, and cold requests
    /// get the typed, retryable [`ServeError::AdapterCold`] while the
    /// page-in is in flight (or the load queue is full). With a
    /// scheduler configured, workers also prefetch adapters whose
    /// predicted next arrival (per-task EWMAs) is imminent. The
    /// snapshot reports hit rate, evictions, prefetch hits, and
    /// cold-start p99.
    pub fn adapter_cache(mut self, cfg: CacheConfig) -> Self {
        self.cache = Some(cfg);
        self
    }

    /// Register a hardware backend ([`super::hal::Backend`]). Repeat to
    /// build a heterogeneous pool: workers are partitioned into one
    /// contiguous span per backend (registration order; each backend
    /// needs at least one worker) and tasks are routed to the backend
    /// whose modeled service + tolerance-maintenance cost is lowest
    /// ([`super::hal::Router`]), sticky on first use. With zero or one
    /// registration the pool keeps the single-substrate fast path: no
    /// router, tasks hash across ALL workers, bit-identical to the
    /// pre-HAL pool (the implicit default backend is
    /// [`super::hal::PcmPjrt`]).
    ///
    /// On a heterogeneous pool, [`Self::refresh`] and
    /// [`Self::adapter_cache`] consume per-backend physics through the
    /// trait: each routed task's drift model comes from its OWN backend
    /// (drift-free backends never trigger a refit) and its page-in cost
    /// is that backend's [`Backend::deploy_latency`].
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backends.push(backend);
        self
    }

    /// Pin `task` to the backend at registration index `idx`,
    /// overriding the cost-model routing decision (validated against
    /// the registered backends at [`Self::build`]).
    pub fn pin_task(mut self, task: &str, idx: usize) -> Self {
        self.pins.insert(task.to_string(), idx);
        self
    }

    /// Adaptive placement ([`super::hal::RebalanceRunner`]): a cadenced
    /// background pass re-runs routing against the measured arrival
    /// EWMAs and migrates tasks between backend spans live — gated by
    /// hysteresis (a move must save a configurable multiple of the
    /// destination's deploy latency) and a per-task cooldown so
    /// placement never flaps. Requires at least two registered
    /// [`Self::backend`]s (a single-substrate pool has nothing to
    /// rebalance — [`Self::build`] rejects the combination).
    pub fn rebalance(mut self, cfg: RebalanceConfig) -> Self {
        self.rebalance = Some(cfg);
        self
    }

    /// Time source for enqueue stamps, deadline math, and latency
    /// metrics. Production keeps [`RealClock`]. Note the workers'
    /// *channel waits* are wall-clock either way — deterministic-clock
    /// tests drive [`super::batcher::Batcher`] and
    /// [`super::sched::BatchScheduler`] directly on a
    /// [`super::sched::VirtualClock`] instead of standing up a pool.
    pub fn clock(mut self, clock: Arc<dyn Clock>) -> Self {
        self.clock = clock;
        self
    }

    /// Load the manifest ONCE, validate variant + graph + cross-config
    /// implications (see [`BuildError`]), and spawn the worker pool
    /// (each worker re-uses the parsed manifest for its engine — no
    /// duplicate manifest loads).
    pub fn build(
        self,
        meta: ParamStore,
        registry: SharedRegistry,
    ) -> std::result::Result<Server, BuildError> {
        // cross-config implications first: they are pure configuration
        // mistakes and should fail before any I/O happens
        if matches!(&self.sched, Some(s) if s.coupling.is_some()) && self.refresh.is_none() {
            return Err(BuildError::CouplingWithoutRefresh);
        }
        if self.coord.is_some()
            && (self.refresh.is_none() || !matches!(&self.sched, Some(s) if s.coupling.is_some()))
        {
            return Err(BuildError::CoordinationWithoutCoupling);
        }
        if self.rebalance.is_some() && self.backends.len() < 2 {
            return Err(BuildError::Backends {
                detail: format!(
                    "rebalance configured with {} backend(s); adaptive placement \
                     needs at least two (a single-substrate pool has no router)",
                    self.backends.len()
                ),
            });
        }

        // hardware backends: zero registrations = the implicit PCM+PJRT
        // default, the substrate every pre-HAL pool ran on
        let backends: Vec<Arc<dyn Backend>> = if self.backends.is_empty() {
            vec![Arc::new(PcmPjrt::default())]
        } else {
            self.backends.clone()
        };
        let n_backends = backends.len();
        if n_backends > self.workers {
            return Err(BuildError::Backends {
                detail: format!(
                    "{n_backends} backends but only {} workers \
                     (each backend needs at least one worker)",
                    self.workers
                ),
            });
        }
        for (i, b) in backends.iter().enumerate() {
            if backends[..i].iter().any(|o| o.name() == b.name()) {
                return Err(BuildError::Backends {
                    detail: format!("duplicate backend name '{}'", b.name()),
                });
            }
        }
        for (task, &idx) in &self.pins {
            if idx >= n_backends {
                return Err(BuildError::Backends {
                    detail: format!(
                        "task '{task}' pinned to backend {idx}, \
                         but only {n_backends} registered"
                    ),
                });
            }
        }

        let init = |e: anyhow::Error| BuildError::Manifest { detail: format!("{e:#}") };
        let manifest = match self.manifest {
            Some(m) => m,
            None => crate::config::manifest::Manifest::load(
                crate::config::manifest::default_artifacts_dir(),
            )
            .map_err(init)?,
        };
        manifest.variant(&self.variant).map_err(init)?;
        let graph_key = self
            .graph
            .clone()
            .unwrap_or_else(|| format!("{}/fwd_cls", self.variant));
        // admission validates against the GRAPH's sequence length, so a
        // `.graph()` override can never admit tokens the workers would
        // re-segment differently
        let seq = manifest
            .graph(&graph_key)
            .map_err(|e| BuildError::Graph {
                graph: graph_key.clone(),
                detail: format!("{e:#}"),
            })?
            .inputs_with_role(crate::config::manifest::Role::Data)
            .next()
            .filter(|io| io.shape.len() == 2)
            .map(|io| io.shape[1])
            .ok_or_else(|| BuildError::Graph {
                graph: graph_key.clone(),
                detail: "no [batch, seq] data input".to_string(),
            })?;

        // the scheduler models whole request sequences: resolve the
        // "inherit from graph" sentinel against the admission seq
        let sched = self.sched.map(|mut s| {
            if s.seq_len == 0 {
                s.seq_len = seq;
            }
            s
        });

        // AOT shape specialization (`runtime::compile`): each worker
        // specializes its forward executor for the batch fills its
        // backend's scheduler can ever commit to — the per-request-
        // latency frontier of the backend-adapted cost table. The
        // worker's BatchScheduler reads the SAME table (adapt_sched →
        // latency_table), so this set is the scheduler's actual
        // commitment, not a guess. Without cost-based scheduling there
        // is no commitment and the executors keep the padded path.
        let committed: Vec<Vec<usize>> = backends
            .iter()
            .map(|b| match &sched {
                Some(s) => b.cost_model(s, self.max_batch).committed_fills(),
                None => Vec::new(),
            })
            .collect();

        // one contiguous worker span per backend, registration order;
        // the remainder pads the front spans so every span is non-empty
        let base = self.workers / n_backends;
        let rem = self.workers % n_backends;
        let mut ranges = Vec::with_capacity(n_backends);
        let mut start = 0;
        for i in 0..n_backends {
            let len = base + usize::from(i < rem);
            ranges.push((start, start + len));
            start += len;
        }

        // heterogeneous pools route through cost models; a
        // single-backend pool has NO router and keeps the pre-HAL
        // task→worker hash across all workers, bit for bit
        let router = if n_backends > 1 {
            let layer = sched.unwrap_or_else(|| {
                let mut l = SchedConfig::for_layer(128, 128, 8);
                l.seq_len = seq;
                l
            });
            let profiles = backends
                .iter()
                .map(|b| BackendProfile::of(b.as_ref(), &layer, self.max_batch))
                .collect();
            let (tolerance, tolerances) = match &self.refresh {
                Some(r) => (r.tolerance, r.task_tolerances().clone()),
                None => (1.0, BTreeMap::new()),
            };
            let router = Arc::new(Router::new(
                profiles,
                ranges.clone(),
                tolerance,
                tolerances,
                self.pins.clone(),
                self.clock.clone(),
            ));
            // place everything already deployed NOW (cold tasks route on
            // saturation cost) so refresh and cache can take per-task
            // parameters from the owning backend below
            for task in registry.tasks() {
                router.backend_of(&task);
            }
            Some(router)
        } else {
            None
        };

        // the read-only base model is shared, not copied, across workers
        let meta = Arc::new(meta);

        // drift-aware refresh: the runner (and its shared lifecycle
        // handle) is built BEFORE the workers so each worker's
        // scheduler can couple to it; everything deployed now starts
        // its drift clock now, later deploys reset it through the
        // version race guard (`SharedRegistry::deploy_if_version`)
        let refresh_state = match self.refresh {
            Some(mut rcfg) => {
                // heterogeneous pools: each routed task drifts — and
                // refits — on ITS backend's physics; a backend with no
                // drift model (digital reference) never triggers
                if let Some(rt) = &router {
                    for (task, b) in rt.assignments() {
                        let decay = backends[b].drift_model().unwrap_or_else(drift_free);
                        rcfg = rcfg.task_decay(&task, decay);
                    }
                }
                // a tolerance at or below the decay model's age-0 floor
                // would refit on every tick, forever
                rcfg.validate().map_err(|detail| BuildError::Refresh { detail })?;
                let check_every = rcfg.check_every;
                let metrics = Arc::new(Metrics::default());
                let mut runner =
                    RefreshRunner::new(rcfg, registry.clone(), meta.clone(), metrics.clone())
                        // the pool clock brackets refits (adaptive hold)
                        // and anchors swaps at their landing instant
                        .with_clock(self.clock.clone());
                runner.track_deployed(self.clock.now());
                // pool-level coordination: staggered triggers + adaptive
                // window/hold, wired automatically when the pool also
                // schedules (the coupling is what consumes the staggered
                // state); `.no_coordination()` opts out
                if !self.no_coord && (sched.is_some() || self.coord.is_some()) {
                    let coordinator = Arc::new(RefreshCoordinator::new(
                        self.coord.unwrap_or_default(),
                        runner.policy().handle(),
                        metrics.clone(),
                    ));
                    runner.set_coordinator(coordinator);
                }
                Some((runner, metrics, check_every))
            }
            None => None,
        };
        let lifecycle = refresh_state.as_ref().map(|(r, _, _)| r.policy().handle());

        // bounded adapter residency: built AFTER refresh (evictions
        // must be able to suppress refits via the lifecycle handle) and
        // BEFORE the workers (each worker polls loads + prefetches).
        // Creation adopts everything already deployed, evicting down to
        // capacity immediately.
        let cache = match self.cache {
            Some(mut ccfg) => {
                // a page-in costs what the OWNING backend's deploy costs
                if let Some(rt) = &router {
                    for (task, b) in rt.assignments() {
                        ccfg = ccfg.task_load_latency(&task, backends[b].deploy_latency());
                    }
                }
                ccfg.validate().map_err(|detail| BuildError::Cache { detail })?;
                let metrics = Arc::new(Metrics::default());
                let cache =
                    AdapterCache::new(ccfg, registry.clone(), self.clock.clone(), metrics);
                if let Some(h) = &lifecycle {
                    cache.set_refresh(h.clone());
                }
                Some(cache)
            }
            None => None,
        };

        let accepting = Arc::new(AtomicBool::new(true));
        let mut shards = Vec::with_capacity(self.workers);
        let mut worker_metrics = Vec::with_capacity(self.workers);
        let mut joins = Vec::with_capacity(self.workers);
        for w in 0..self.workers {
            let owner = ranges
                .iter()
                .position(|&(s, e)| (s..e).contains(&w))
                .expect("worker ranges cover the pool");
            let backend = backends[owner].clone();
            let cfg = pool::WorkerConfig {
                worker: w,
                graph_key: graph_key.clone(),
                seq,
                max_batch: self.max_batch,
                max_wait: self.max_wait,
                hw: self.hw,
                fail_every: self.fail_every,
                specialize: committed[owner].clone(),
                // the backend re-shapes the scheduler's hardware model
                // (e.g. the digital reference's integration-time
                // slowdown); identity for PcmPjrt
                sched: sched.map(|s| backend.adapt_sched(s)),
                refresh: lifecycle.clone(),
                cache: cache.clone(),
                clock: self.clock.clone(),
                backend,
            };
            let (handle, join) = pool::spawn_worker(
                cfg,
                manifest.clone(),
                meta.clone(),
                registry.clone(),
                self.queue_depth,
            )
            .map_err(|e| BuildError::Spawn {
                what: format!("worker {w}"),
                detail: e.to_string(),
            })?;
            worker_metrics.push(handle.metrics.clone());
            shards.push(handle);
            joins.push(join);
        }

        let client = Client {
            shards: Arc::new(shards),
            next_id: Arc::new(AtomicU64::new(1)),
            accepting,
            registry: registry.clone(),
            cache: cache.clone(),
            router,
            seq,
        };

        let refresh = match refresh_state {
            Some((runner, metrics, check_every)) => {
                let runner = Arc::new(Mutex::new(runner));
                let (stop, join) =
                    spawn_refresh_worker(runner.clone(), self.clock.clone(), check_every)
                        .map_err(|e| BuildError::Spawn {
                            what: "refresh worker".to_string(),
                            detail: e.to_string(),
                        })?;
                Some(RefreshState {
                    runner,
                    metrics,
                    stop,
                    join: Some(join),
                })
            }
            None => None,
        };

        // adaptive placement: spawned LAST — it reads the router the
        // client routes through and carries migrations through the
        // refresh and cache surfaces built above
        let rebalance = match (self.rebalance, &client.router) {
            (Some(rcfg), Some(rt)) => {
                let cadence = rcfg.tick_cadence();
                let metrics = Arc::new(Metrics::default());
                let mut runner = RebalanceRunner::new(rcfg, rt.clone(), backends.clone())
                    .with_metrics(metrics.clone());
                if let (Some(h), Some(rs)) = (&lifecycle, &refresh) {
                    runner = runner.with_refresh(h.clone(), rs.runner.clone());
                }
                if let Some(c) = &cache {
                    runner = runner.with_cache(c.clone());
                }
                let runner = Arc::new(runner);
                let (stop, join) =
                    spawn_rebalance_worker(runner.clone(), self.clock.clone(), cadence).map_err(
                        |e| BuildError::Spawn {
                            what: "rebalance worker".to_string(),
                            detail: e.to_string(),
                        },
                    )?;
                Some(RebalanceState {
                    runner,
                    metrics,
                    stop,
                    join: Some(join),
                })
            }
            _ => None,
        };

        Ok(Server {
            client,
            registry,
            worker_metrics,
            joins,
            clock: self.clock,
            refresh,
            rebalance,
            cache,
        })
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// Cloneable submission handle. Validates, stamps ids, applies bounded
/// admission, and routes to the task's pinned worker.
#[derive(Clone)]
pub struct Client {
    shards: Arc<Vec<WorkerHandle>>,
    next_id: Arc<AtomicU64>,
    accepting: Arc<AtomicBool>,
    registry: SharedRegistry,
    /// Capacity tier, when the builder configured one: turns a registry
    /// miss on a KNOWN task into the typed, retryable
    /// [`ServeError::AdapterCold`] (and queues the page-in) instead of
    /// [`ServeError::UnknownTask`].
    cache: Option<Arc<AdapterCache>>,
    /// Heterogeneous pools route task → backend → worker span through
    /// the HAL cost models; `None` = single backend, hash across all
    /// workers (the pre-HAL path, unchanged).
    router: Option<Arc<Router>>,
    /// Sequence length the serving graph expects.
    pub seq: usize,
}

impl Client {
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Classify a registry miss at admission. With a capacity tier the
    /// task may merely be paged out: the lookup queues the page-in and
    /// the caller sheds with the typed cold error — retryable, because
    /// no work started. Returns `None` when the lookup found the
    /// adapter resident after all (a deploy or load raced admission):
    /// the caller proceeds.
    fn classify_miss(&self, task: &str) -> Option<ServeError> {
        if let Some(cache) = &self.cache {
            match cache.lookup(task, cache.now(), 1) {
                CacheLookup::Hit => return None,
                CacheLookup::Loading { .. } | CacheLookup::Queued { .. } => {
                    return Some(ServeError::AdapterCold {
                        task: task.to_string(),
                        loading: true,
                    })
                }
                CacheLookup::Shed => {
                    return Some(ServeError::AdapterCold {
                        task: task.to_string(),
                        loading: false,
                    })
                }
                CacheLookup::Unknown => {}
            }
        }
        Some(ServeError::UnknownTask {
            task: task.to_string(),
            known: self.registry.tasks(),
        })
    }

    /// Stable task → worker pinning. Single-backend pools hash across
    /// all workers (FNV-1a); heterogeneous pools first route the task
    /// to its cost-minimising backend ([`super::hal::Router`], sticky
    /// on first use), then hash across that backend's worker span.
    /// Either way one task stays on one worker, which preserves
    /// per-task batching and minimises adapter swaps.
    pub fn shard_for(&self, task: &str) -> usize {
        match &self.router {
            Some(r) => r.worker_for(task),
            None => (fnv1a(task) % self.shards.len() as u64) as usize,
        }
    }

    /// Submit one request. Fails fast with a typed error; on success
    /// the returned [`Pending`] always resolves.
    pub fn submit(&self, task: &str, tokens: &[i32]) -> ServeResult<Pending> {
        if tokens.len() != self.seq {
            return Err(ServeError::BadShape {
                got: tokens.len(),
                want: self.seq,
            });
        }
        // validated against the LIVE registry: tasks deployed after the
        // server started are immediately routable (the old Router froze
        // its task list at startup).
        if !self.registry.contains(task) {
            if let Some(e) = self.classify_miss(task) {
                return Err(e);
            }
        }
        if !self.accepting.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown);
        }
        let w = self.shard_for(task);
        let h = &self.shards[w];
        // admission: reserve an in-flight slot or bounce
        let prev = h.inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= h.queue_depth {
            h.inflight.fetch_sub(1, Ordering::AcqRel);
            h.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded {
                worker: w,
                depth: h.queue_depth,
            });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = channel();
        let req = WorkRequest {
            id,
            task: task.to_string(),
            tokens: tokens.to_vec(),
            resp: resp_tx,
        };
        if h.tx.send(Job::Req(req)).is_err() {
            h.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(ServeError::ShuttingDown);
        }
        Ok(Pending {
            id,
            worker: w,
            rx: resp_rx,
        })
    }

    /// Submit with bounded retry through the retryable pre-admission
    /// bounces ([`ServeError::Overloaded`], and with a capacity tier
    /// [`ServeError::AdapterCold`] while the page-in lands) — the
    /// cooperative client side of the try-again protocol.
    ///
    /// The retry loop covers ADMISSION only: once a ticket exists, an
    /// error arriving on it is terminal and is never replayed by this
    /// helper (for one-shot requests a replay would merely duplicate
    /// work; for streaming tickets it would restart a partially
    /// streamed generation from token 0 — see
    /// [`Self::generate_with_retry`]).
    pub fn submit_with_retry(
        &self,
        task: &str,
        tokens: &[i32],
        deadline: Duration,
    ) -> ServeResult<Pending> {
        let t0 = Instant::now();
        loop {
            match self.submit(task, tokens) {
                Err(e) if e.is_retryable() && t0.elapsed() < deadline => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                other => return other,
            }
        }
    }

    /// Start a generation: the prompt joins the task's worker
    /// step-batch at the next step boundary and tokens stream back on
    /// the returned [`GenTicket`] as the batch advances. Requires a
    /// generative serving graph (`.graph("{variant}/fwd_lm")`); on a
    /// classify graph the worker answers with [`ServeError::Batch`].
    pub fn generate(&self, task: &str, prompt: &[i32], cfg: GenConfig) -> ServeResult<GenTicket> {
        // decode appends into the context window: admission checks the
        // engine's truncation bound (≥ 1 free slot), not the exact-seq
        // rule one-shot submits use
        if prompt.is_empty() || prompt.len() > self.seq - 1 {
            return Err(ServeError::BadPrompt {
                got: prompt.len(),
                max: self.seq - 1,
            });
        }
        if !self.registry.contains(task) {
            if let Some(e) = self.classify_miss(task) {
                return Err(e);
            }
        }
        if !self.accepting.load(Ordering::Acquire) {
            return Err(ServeError::ShuttingDown);
        }
        let w = self.shard_for(task);
        let h = &self.shards[w];
        // a generation holds its in-flight slot from admission to its
        // terminal event, like any other request
        let prev = h.inflight.fetch_add(1, Ordering::AcqRel);
        if prev >= h.queue_depth {
            h.inflight.fetch_sub(1, Ordering::AcqRel);
            h.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err(ServeError::Overloaded {
                worker: w,
                depth: h.queue_depth,
            });
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = channel();
        let req = GenRequest {
            id,
            task: task.to_string(),
            prompt: prompt.to_vec(),
            cfg,
            resp: resp_tx,
        };
        if h.tx.send(Job::Gen(req)).is_err() {
            h.inflight.fetch_sub(1, Ordering::AcqRel);
            return Err(ServeError::ShuttingDown);
        }
        Ok(GenTicket {
            id,
            worker: w,
            task: task.to_string(),
            rx: resp_rx,
            done: false,
            streamed: 0,
        })
    }

    /// [`Self::generate`] with bounded retry through PRE-ADMISSION
    /// backpressure ([`ServeError::Overloaded`]) only — safe because an
    /// admission bounce means no token was ever produced. Once a
    /// [`GenTicket`] exists, errors arriving on it
    /// ([`ServeError::Shed`], `Batch`, `Lost`) are terminal: a
    /// partially-streamed generation is NEVER silently replayed from
    /// token 0; deciding whether a re-issue is safe (idempotent
    /// consumer, no tokens surfaced yet) belongs to the caller.
    pub fn generate_with_retry(
        &self,
        task: &str,
        prompt: &[i32],
        cfg: GenConfig,
        deadline: Duration,
    ) -> ServeResult<GenTicket> {
        let t0 = Instant::now();
        loop {
            match self.generate(task, prompt, cfg.clone()) {
                Err(e) if e.is_retryable() && t0.elapsed() < deadline => {
                    std::thread::sleep(Duration::from_micros(200));
                }
                other => return other,
            }
        }
    }
}

pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------------
// Server
// ---------------------------------------------------------------------------

/// The drift-refresh worker attached to a pool: its runner (policy +
/// event log), counters, and stop/join plumbing. (The *shared* per-task
/// lifecycle view the schedulers read is
/// [`super::refresh::RefreshHandle`], handed to workers at build time.)
struct RefreshState {
    runner: Arc<Mutex<RefreshRunner>>,
    metrics: Arc<Metrics>,
    stop: Sender<()>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// The adaptive-placement worker attached to a heterogeneous pool: the
/// cadenced [`RebalanceRunner`] plus its counters and stop/join
/// plumbing (same shutdown discipline as [`RefreshState`]).
struct RebalanceState {
    runner: Arc<RebalanceRunner>,
    metrics: Arc<Metrics>,
    stop: Sender<()>,
    join: Option<std::thread::JoinHandle<()>>,
}

/// Handle to a running pool: hands out clients, reports metrics, and
/// owns graceful shutdown (drain everything, join every worker).
pub struct Server {
    client: Client,
    registry: SharedRegistry,
    worker_metrics: Vec<Arc<Metrics>>,
    joins: Vec<std::thread::JoinHandle<ServeResult<()>>>,
    clock: Arc<dyn Clock>,
    refresh: Option<RefreshState>,
    rebalance: Option<RebalanceState>,
    cache: Option<Arc<AdapterCache>>,
}

impl Server {
    pub fn builder(variant: &str) -> ServerBuilder {
        ServerBuilder::new(variant)
    }

    pub fn client(&self) -> Client {
        self.client.clone()
    }

    pub fn registry(&self) -> &SharedRegistry {
        &self.registry
    }

    pub fn workers(&self) -> usize {
        self.worker_metrics.len()
    }

    /// Per-worker counters (index = worker id).
    pub fn worker_metrics(&self) -> &[Arc<Metrics>] {
        &self.worker_metrics
    }

    /// The capacity tier, when one was configured.
    pub fn cache(&self) -> Option<&Arc<AdapterCache>> {
        self.cache.as_ref()
    }

    /// The heterogeneous task→backend router, when more than one
    /// backend was registered (`None` = single-substrate pool).
    pub fn router(&self) -> Option<&Arc<Router>> {
        self.client.router.as_ref()
    }

    /// Sticky task → backend-index assignments made so far; empty for a
    /// single-backend pool (which routes by hash, not by cost model).
    pub fn routing(&self) -> Vec<(String, usize)> {
        self.client
            .router
            .as_ref()
            .map(|r| r.assignments())
            .unwrap_or_default()
    }

    /// Pool-level aggregate (includes the refresh worker's and the
    /// capacity tier's counters).
    pub fn metrics(&self) -> MetricsSnapshot {
        aggregate(
            self.worker_metrics
                .iter()
                .chain(self.refresh.as_ref().map(|r| &r.metrics))
                .chain(self.rebalance.as_ref().map(|r| &r.metrics))
                .chain(self.cache.as_ref().map(|c| c.metrics()))
                .map(|m| m.as_ref()),
        )
    }

    /// Multi-line report: one line per worker plus the aggregate.
    pub fn metrics_report(&self) -> String {
        let mut out = String::new();
        for (w, m) in self.worker_metrics.iter().enumerate() {
            out.push_str(&m.snapshot(&format!("worker{w}")).to_string());
            out.push('\n');
        }
        if let Some(r) = &self.refresh {
            out.push_str(&r.metrics.snapshot("refresh").to_string());
            out.push('\n');
        }
        if let Some(r) = &self.rebalance {
            out.push_str(&r.metrics.snapshot("rebalance").to_string());
            out.push('\n');
        }
        if let Some(c) = &self.cache {
            out.push_str(&c.metrics().snapshot("cache").to_string());
            out.push('\n');
        }
        out.push_str(&self.metrics().to_string());
        out
    }

    /// Force an immediate refresh-policy evaluation on the pool clock
    /// (the background worker does this every `check_every`). Returns
    /// the refreshes performed; empty when refresh is not configured.
    pub fn refresh_tick_now(&self) -> Vec<RefreshEvent> {
        match &self.refresh {
            Some(r) => r.runner.lock().unwrap().tick(self.clock.now()),
            None => Vec::new(),
        }
    }

    /// Force an immediate rebalance pass on the pool clock (the
    /// background worker does this every
    /// [`RebalanceConfig::tick_cadence`]). Returns the span migrations
    /// applied; empty when rebalance is not configured, placement has
    /// converged, or every candidate move failed the hysteresis gate.
    pub fn rebalance_tick_now(&self) -> Vec<PlannedMove> {
        match &self.rebalance {
            Some(r) => r.runner.tick(self.clock.now()),
            None => Vec::new(),
        }
    }

    /// Refresh activity so far (trigger age, pre/post predicted decay,
    /// steps spent, swap version per event). Empty when refresh is off.
    pub fn refresh_events(&self) -> Vec<RefreshEvent> {
        self.refresh
            .as_ref()
            .map(|r| r.runner.lock().unwrap().events().to_vec())
            .unwrap_or_default()
    }

    /// Graceful shutdown: stop the refresh worker, stop admission, drain
    /// every queue (all pending tickets resolve), join all workers.
    /// Returns the first worker error, if any.
    pub fn shutdown(mut self) -> ServeResult<()> {
        self.stop_rebalance();
        self.stop_refresh();
        self.begin_shutdown();
        let mut first_err = None;
        for j in self.joins.drain(..) {
            match j.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err.get_or_insert(ServeError::Init {
                        detail: "worker panicked".to_string(),
                    });
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    fn begin_shutdown(&self) {
        self.client.accepting.store(false, Ordering::Release);
        for h in self.client.shards.iter() {
            let _ = h.tx.send(Job::Shutdown);
        }
    }

    fn stop_refresh(&mut self) {
        if let Some(r) = self.refresh.as_mut() {
            let _ = r.stop.send(());
            if let Some(j) = r.join.take() {
                let _ = j.join();
            }
        }
    }

    /// Stopped BEFORE refresh: a mid-shutdown migration would re-flag
    /// tasks on spans whose workers are about to drain for good.
    fn stop_rebalance(&mut self) {
        if let Some(r) = self.rebalance.as_mut() {
            let _ = r.stop.send(());
            if let Some(j) = r.join.take() {
                let _ = j.join();
            }
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // if `shutdown` was not called, still stop the workers so
        // lingering Client clones cannot keep threads alive forever.
        self.stop_rebalance();
        self.stop_refresh();
        if !self.joins.is_empty() {
            self.begin_shutdown();
            for j in self.joins.drain(..) {
                let _ = j.join();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Wave helpers (experiments / examples / demo CLI)
// ---------------------------------------------------------------------------

/// How long wave helpers keep retrying one job through `Overloaded`
/// backpressure before giving up on it.
pub const WAVE_RETRY_DEADLINE: Duration = Duration::from_secs(30);

/// Submit many requests (retrying through backpressure for up to
/// [`WAVE_RETRY_DEADLINE`] each), wait for every ticket, and return
/// per-request terminal results in job order. Callers needing a
/// different retry budget drive [`Client::submit_with_retry`] directly.
pub fn submit_wave_results(
    client: &Client,
    jobs: &[(String, Vec<i32>)],
) -> Vec<ServeResult<Response>> {
    let tickets: Vec<ServeResult<Pending>> = jobs
        .iter()
        .map(|(task, tokens)| client.submit_with_retry(task, tokens, WAVE_RETRY_DEADLINE))
        .collect();
    tickets
        .into_iter()
        .map(|ticket| ticket.and_then(Pending::wait))
        .collect()
}

/// Convenience used by the serving experiments: all-or-nothing wave.
pub fn submit_wave(client: &Client, jobs: &[(String, Vec<i32>)]) -> ServeResult<Vec<Response>> {
    submit_wave_results(client, jobs).into_iter().collect()
}

// ---------------------------------------------------------------------------
// Tests (no PJRT needed: mock workers behind the same channel protocol)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;
    use std::sync::atomic::AtomicUsize;
    use std::sync::mpsc::Sender;

    fn registry_with(tasks: &[&str]) -> SharedRegistry {
        let reg = SharedRegistry::new();
        for t in tasks {
            reg.deploy(t, ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]));
        }
        reg
    }

    /// The FULL variant → class table, pinned. Adding a `ServeError`
    /// variant without deciding its class fails `class()`'s exhaustive
    /// match; changing a decision fails here.
    #[test]
    fn error_class_table_is_pinned() {
        use ErrorClass::*;
        let table: [(ServeError, ErrorClass); 12] = [
            (ServeError::BadShape { got: 1, want: 2 }, NonRetryable),
            (
                ServeError::UnknownTask { task: "t".into(), known: vec![] },
                NonRetryable,
            ),
            (ServeError::BadPrompt { got: 0, max: 3 }, NonRetryable),
            (ServeError::Overloaded { worker: 0, depth: 1 }, Retryable),
            (
                ServeError::AdapterCold { task: "t".into(), loading: true },
                Retryable,
            ),
            (
                ServeError::AdapterCold { task: "t".into(), loading: false },
                Retryable,
            ),
            (ServeError::Shed { task: "t".into(), streamed: 3 }, NonRetryable),
            (ServeError::AdapterMissing { task: "t".into() }, NonRetryable),
            (
                ServeError::Batch { task: "t".into(), detail: "x".into() },
                NonRetryable,
            ),
            (
                ServeError::WorkerInit { worker: 0, detail: "x".into() },
                Fatal,
            ),
            (ServeError::Init { detail: "x".into() }, Fatal),
            (ServeError::ShuttingDown, Fatal),
        ];
        for (err, class) in table {
            assert_eq!(err.class(), class, "{err:?}");
            assert_eq!(err.is_retryable(), class == Retryable, "{err:?}");
        }
        assert_eq!(ServeError::Lost.class(), Fatal);
    }

    #[test]
    fn build_errors_display_and_convert() {
        let e = BuildError::CouplingWithoutRefresh;
        assert!(e.to_string().contains("refresh"));
        let as_serve: ServeError = e.into();
        assert!(matches!(as_serve, ServeError::Init { .. }));
        let e = BuildError::Backends { detail: "duplicate backend name 'x'".into() };
        assert!(e.to_string().contains("duplicate"));
    }

    /// Client over hand-built worker handles; returns the raw job
    /// receivers so tests can play the worker role.
    fn mock_client(
        workers: usize,
        queue_depth: usize,
        seq: usize,
        registry: SharedRegistry,
    ) -> (Client, Vec<Receiver<Job>>) {
        let mut shards = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..workers {
            let (tx, rx): (Sender<Job>, Receiver<Job>) = channel();
            shards.push(WorkerHandle {
                tx,
                inflight: Arc::new(AtomicUsize::new(0)),
                queue_depth,
                metrics: Arc::new(Metrics::default()),
            });
            rxs.push(rx);
        }
        let client = Client {
            shards: Arc::new(shards),
            next_id: Arc::new(AtomicU64::new(1)),
            accepting: Arc::new(AtomicBool::new(true)),
            registry,
            cache: None,
            router: None,
            seq,
        };
        (client, rxs)
    }

    #[test]
    fn validates_shape_and_task() {
        let (c, _rxs) = mock_client(1, 8, 4, registry_with(&["sst2"]));
        assert!(c.submit("sst2", &[1, 2, 3, 4]).is_ok());
        assert_eq!(
            c.submit("sst2", &[1]).unwrap_err(),
            ServeError::BadShape { got: 1, want: 4 }
        );
        assert!(matches!(
            c.submit("nope", &[1, 2, 3, 4]).unwrap_err(),
            ServeError::UnknownTask { .. }
        ));
    }

    #[test]
    fn late_deployed_tasks_are_routable() {
        let reg = registry_with(&[]);
        let (c, _rxs) = mock_client(1, 8, 2, reg.clone());
        assert!(matches!(
            c.submit("t", &[0, 0]).unwrap_err(),
            ServeError::UnknownTask { .. }
        ));
        reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]));
        assert!(c.submit("t", &[0, 0]).is_ok());
    }

    #[test]
    fn ids_are_unique_across_clones() {
        let (c1, _rxs) = mock_client(1, 8, 2, registry_with(&["t"]));
        let c2 = c1.clone();
        let a = c1.submit("t", &[0, 0]).unwrap();
        let b = c2.submit("t", &[0, 0]).unwrap();
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn bounded_admission_returns_overloaded() {
        let (c, rxs) = mock_client(1, 2, 1, registry_with(&["t"]));
        let _p1 = c.submit("t", &[0]).unwrap();
        let _p2 = c.submit("t", &[0]).unwrap();
        assert_eq!(
            c.submit("t", &[0]).unwrap_err(),
            ServeError::Overloaded { worker: 0, depth: 2 }
        );
        assert_eq!(c.shards[0].metrics.rejected.load(Ordering::Relaxed), 1);
        // play the worker: answer one request, slot frees up
        let Job::Req(r) = rxs[0].recv().unwrap() else {
            panic!("expected a request")
        };
        let _ = r.resp.send(Err(ServeError::Lost));
        c.shards[0].inflight.fetch_sub(1, Ordering::AcqRel);
        assert!(c.submit("t", &[0]).is_ok());
    }

    #[test]
    fn shard_pinning_is_stable_and_covers_workers() {
        let (c, _rxs) = mock_client(4, 8, 1, registry_with(&["t"]));
        let mut covered = [false; 4];
        for i in 0..64 {
            let name = format!("task{i}");
            let w = c.shard_for(&name);
            assert_eq!(w, c.shard_for(&name), "pinning must be stable");
            covered[w] = true;
        }
        assert!(covered.iter().all(|&x| x), "64 tasks should hit all 4 workers");
        // the shards used by the integration tests (2 workers)
        let (c2, _r2) = mock_client(2, 8, 1, registry_with(&["t"]));
        assert_ne!(c2.shard_for("SST-2"), c2.shard_for("QNLI"));
    }

    #[test]
    fn pending_resolves_even_if_worker_dies() {
        let (c, rxs) = mock_client(1, 8, 1, registry_with(&["t"]));
        let p = c.submit("t", &[0]).unwrap();
        drop(rxs); // worker vanishes without answering
        assert!(matches!(p.wait(), Err(ServeError::Lost)));
    }

    #[test]
    fn try_wait_polls_without_blocking() {
        let (c, rxs) = mock_client(1, 8, 1, registry_with(&["t"]));
        let p = c.submit("t", &[0]).unwrap();
        assert!(p.try_wait().is_none());
        let Job::Req(r) = rxs[0].recv().unwrap() else {
            panic!("expected a request")
        };
        r.resp
            .send(Err(ServeError::Batch {
                task: "t".into(),
                detail: "x".into(),
            }))
            .unwrap();
        assert!(matches!(p.try_wait(), Some(Err(ServeError::Batch { .. }))));
    }

    #[test]
    fn shutdown_flag_rejects_new_work() {
        let (c, _rxs) = mock_client(1, 8, 1, registry_with(&["t"]));
        c.accepting.store(false, Ordering::Release);
        assert_eq!(c.submit("t", &[0]).unwrap_err(), ServeError::ShuttingDown);
    }

    #[test]
    fn aggregate_merges_counters_and_percentiles() {
        let a = Metrics::default();
        let b = Metrics::default();
        a.record(2, Duration::from_millis(2));
        b.record(4, Duration::from_millis(4));
        a.errors.fetch_add(1, Ordering::Relaxed);
        let agg = aggregate([&a, &b]);
        assert_eq!(agg.served, 6);
        assert_eq!(agg.batches, 2);
        assert_eq!(agg.errors, 1);
        assert!((agg.batch_mean - 3.0).abs() < 1e-9);
        assert!(agg.lat_p95_ms > agg.lat_p50_ms);
    }

    #[test]
    fn modeled_samples_flow_into_snapshots() {
        let m = Metrics::default();
        m.record_modeled(2, Duration::from_millis(3), Some(Duration::from_micros(80)));
        let s = m.snapshot("w");
        assert!((s.modeled_p50_ms - 0.08).abs() < 1e-9, "{}", s.modeled_p50_ms);
        assert!(s.to_string().contains("model_p50"));
        let agg = aggregate([&m]);
        assert!((agg.modeled_p50_ms - 0.08).abs() < 1e-9);
        // without a scheduler the column stays silent
        let plain = Metrics::default();
        plain.record(1, Duration::from_millis(1));
        assert!(!plain.snapshot("w").to_string().contains("model_p50"));
    }

    #[test]
    fn refresh_counters_flow_into_snapshots() {
        let m = Metrics::default();
        m.refreshes.fetch_add(2, Ordering::Relaxed);
        m.refresh_steps.fetch_add(32, Ordering::Relaxed);
        let s = m.snapshot("refresh");
        assert_eq!(s.refreshes, 2);
        assert_eq!(s.refresh_steps, 32);
        assert!(s.to_string().contains("refreshes=2 refit_steps=32"));
        let agg = aggregate([&m, &Metrics::default()]);
        assert_eq!(agg.refreshes, 2);
        assert_eq!(agg.refresh_steps, 32);
        // pools without refresh activity stay silent
        let quiet = Metrics::default().snapshot("w").to_string();
        assert!(!quiet.contains("refreshes"));
    }

    #[test]
    fn stale_and_swap_gap_counters_flow_into_snapshots() {
        let m = Metrics::default();
        m.stale_batch_requests.fetch_add(3, Ordering::Relaxed);
        m.swap_gap_ns.fetch_max(2_500, Ordering::Relaxed);
        let s = m.snapshot("w");
        assert_eq!(s.stale_batch_requests, 3);
        assert_eq!(s.swap_gap_ns, 2_500);
        assert!(s.to_string().contains("stale_reqs=3"));
        let n = Metrics::default();
        n.swap_gap_ns.fetch_max(9_000, Ordering::Relaxed);
        let agg = aggregate([&m, &n]);
        assert_eq!(agg.stale_batch_requests, 3, "stale requests sum across workers");
        assert_eq!(agg.swap_gap_ns, 9_000, "swap gap aggregates as the worst case");
        // pools that never served stale stay silent
        let quiet = Metrics::default().snapshot("w").to_string();
        assert!(!quiet.contains("stale_reqs"));
    }

    #[test]
    fn hold_peak_and_stagger_counters_flow_into_snapshots() {
        let m = Metrics::default();
        m.concurrent_holds_peak.fetch_max(3, Ordering::Relaxed);
        m.stagger_shift_ns.fetch_max(4_200, Ordering::Relaxed);
        let s = m.snapshot("w");
        assert_eq!(s.concurrent_holds_peak, 3);
        assert_eq!(s.stagger_shift_ns, 4_200);
        assert!(s.to_string().contains("holds_peak=3"));
        let n = Metrics::default();
        n.concurrent_holds_peak.fetch_max(5, Ordering::Relaxed);
        let agg = aggregate([&m, &n]);
        assert_eq!(agg.concurrent_holds_peak, 5, "peak aggregates as the worst case");
        assert_eq!(agg.stagger_shift_ns, 4_200);
        // uncoordinated pools stay silent
        let quiet = Metrics::default().snapshot("w").to_string();
        assert!(!quiet.contains("holds_peak"));
    }

    #[test]
    fn error_display_is_actionable() {
        let e = ServeError::Overloaded { worker: 3, depth: 64 };
        assert!(e.to_string().contains("worker 3"));
        assert!(e.is_retryable());
        assert!(!ServeError::ShuttingDown.is_retryable());
    }

    fn event(id: u64, token: i32, index: usize, done: bool, version: u64) -> TokenEvent {
        TokenEvent {
            id,
            task: "t".into(),
            worker: 0,
            token,
            index,
            done,
            adapter_version: version,
            step_fill: 1,
        }
    }

    #[test]
    fn generate_validates_prompt_task_and_shutdown() {
        let (c, _rxs) = mock_client(1, 8, 4, registry_with(&["t"]));
        assert_eq!(
            c.generate("t", &[], GenConfig::default()).unwrap_err(),
            ServeError::BadPrompt { got: 0, max: 3 }
        );
        // decode needs ≥ 1 free slot: a full-seq prompt is rejected
        assert_eq!(
            c.generate("t", &[1, 2, 3, 4], GenConfig::default()).unwrap_err(),
            ServeError::BadPrompt { got: 4, max: 3 }
        );
        assert!(matches!(
            c.generate("nope", &[1], GenConfig::default()).unwrap_err(),
            ServeError::UnknownTask { .. }
        ));
        c.accepting.store(false, Ordering::Release);
        assert_eq!(
            c.generate("t", &[1], GenConfig::default()).unwrap_err(),
            ServeError::ShuttingDown
        );
    }

    #[test]
    fn generate_admission_is_bounded_like_submit() {
        let (c, _rxs) = mock_client(1, 1, 4, registry_with(&["t"]));
        let _g1 = c.generate("t", &[1], GenConfig::default()).unwrap();
        assert_eq!(
            c.generate("t", &[1], GenConfig::default()).unwrap_err(),
            ServeError::Overloaded { worker: 0, depth: 1 }
        );
        assert_eq!(c.shards[0].metrics.rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn gen_ticket_streams_to_the_terminal_event_then_goes_quiet() {
        let (c, rxs) = mock_client(1, 8, 8, registry_with(&["t"]));
        let mut ticket = c.generate("t", &[1, 2], GenConfig::new(2)).unwrap();
        assert!(ticket.try_next().is_none(), "nothing decoded yet");
        let Job::Gen(g) = rxs[0].recv().unwrap() else {
            panic!("expected a generation")
        };
        assert_eq!(g.prompt, vec![1, 2]);
        g.resp.send(Ok(event(g.id, 7, 0, false, 1))).unwrap();
        g.resp.send(Ok(event(g.id, 9, 1, true, 2))).unwrap();
        let first = ticket.try_next().unwrap().unwrap();
        assert_eq!((first.token, first.done), (7, false));
        assert_eq!(ticket.tokens_streamed(), 1);
        let last = ticket.next_event().unwrap().unwrap();
        assert!(last.done);
        // after the terminal event the stream is silent forever
        assert!(ticket.try_next().is_none());
        assert!(ticket.next_event().is_none());
        assert_eq!(ticket.tokens_streamed(), 2);
    }

    #[test]
    fn wait_all_assembles_the_generation_with_version_span() {
        let (c, rxs) = mock_client(1, 8, 8, registry_with(&["t"]));
        let ticket = c.generate("t", &[1], GenConfig::new(3)).unwrap();
        let Job::Gen(g) = rxs[0].recv().unwrap() else {
            panic!("expected a generation")
        };
        g.resp.send(Ok(event(g.id, 5, 0, false, 3))).unwrap();
        g.resp.send(Ok(event(g.id, 6, 1, false, 4))).unwrap();
        g.resp.send(Ok(event(g.id, 2, 2, true, 4))).unwrap();
        let gen = ticket.wait_all().unwrap();
        assert_eq!(gen.tokens, vec![5, 6, 2]);
        // the sequence crossed a drain-free hot-swap: v3 → v4
        assert_eq!((gen.first_version, gen.last_version), (3, 4));
    }

    #[test]
    fn gen_ticket_resolves_lost_if_worker_dies_mid_stream() {
        let (c, rxs) = mock_client(1, 8, 8, registry_with(&["t"]));
        let mut ticket = c.generate("t", &[1], GenConfig::default()).unwrap();
        let Job::Gen(g) = rxs[0].recv().unwrap() else {
            panic!("expected a generation")
        };
        g.resp.send(Ok(event(g.id, 5, 0, false, 1))).unwrap();
        drop(g); // worker vanishes without a terminal event
        drop(rxs);
        assert_eq!(ticket.next_event().unwrap().unwrap().token, 5);
        assert_eq!(ticket.next_event().unwrap().unwrap_err(), ServeError::Lost);
        assert!(ticket.next_event().is_none(), "Lost is terminal, delivered once");
        assert_eq!(ticket.tokens_streamed(), 1, "partial progress stays visible");
    }

    #[test]
    fn shed_is_terminal_and_never_retryable() {
        let shed = ServeError::Shed { task: "t".into(), streamed: 3 };
        assert!(!shed.is_retryable(), "a mid-stream shed must not be auto-replayed");
        assert!(shed.to_string().contains("after 3 tokens"));
        assert!(!ServeError::BadPrompt { got: 0, max: 7 }.is_retryable());
    }

    #[test]
    fn decode_counters_flow_into_snapshots() {
        let m = Metrics::default();
        // 2 steps: full batch, then half after a retirement
        m.record_decode_step(4, 4, 4, Some(Duration::from_micros(50)));
        m.record_decode_step(2, 4, 2, None);
        m.generations.fetch_add(2, Ordering::Relaxed);
        m.mid_seq_swaps.fetch_add(1, Ordering::Relaxed);
        m.record_ttft(Duration::from_millis(2));
        m.record_intertoken(Duration::from_millis(1));
        let s = m.snapshot("w");
        assert_eq!(s.decode_steps, 2);
        assert_eq!(s.decode_tokens, 6);
        assert_eq!(s.generations, 2);
        assert_eq!(s.mid_seq_swaps, 1);
        assert!((s.step_occupancy_mean - 0.75).abs() < 1e-9, "{}", s.step_occupancy_mean);
        assert!((s.ttft_p50_ms - 2.0).abs() < 1e-9);
        assert!((s.intertoken_p50_ms - 1.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("gens=2"));
        assert!(text.contains("mid_seq_swaps=1"));
        let n = Metrics::default();
        n.record_decode_step(4, 4, 4, None);
        let agg = aggregate([&m, &n]);
        assert_eq!(agg.decode_steps, 3);
        assert_eq!(agg.decode_tokens, 10);
        assert!((agg.step_occupancy_mean - (0.75 + 0.5 + 1.0) / 3.0).abs() < 1e-9);
        // pools with no generative traffic stay silent
        let quiet = Metrics::default().snapshot("w").to_string();
        assert!(!quiet.contains("gens="));
    }

    #[test]
    fn sub_microsecond_latencies_survive_the_ring() {
        // regression: record_modeled used as_micros(), which truncates
        // every sub-µs virtual-clock latency to 0 — aggregating a batch
        // of 250ns samples reported p50 = 0
        let m = Metrics::default();
        for _ in 0..8 {
            m.record(1, Duration::from_nanos(250));
        }
        let s = m.snapshot("w");
        assert!(
            (s.lat_p50_ms - 0.00025).abs() < 1e-12,
            "250ns must survive as 0.25µs, got {}ms",
            s.lat_p50_ms
        );
        let agg = aggregate([&m]);
        assert!((agg.lat_p50_ms - 0.00025).abs() < 1e-12);
        assert!((agg.lat_p95_ms - 0.00025).abs() < 1e-12);
    }

    #[test]
    fn concurrent_ttft_recorders_claim_distinct_ring_slots() {
        // regression: record_ttft/record_intertoken indexed their rings
        // by decode_tokens — past wrap-around, concurrent generations
        // (which all read the same counter value) stomp one slot while
        // the rest of the ring goes stale. Each ring now owns a
        // fetch_add cursor, so N recorders claim N distinct slots.
        let m = Metrics::default();
        let fill_ns = 1e6; // 1ms
        for _ in 0..METRIC_SAMPLE_CAP {
            m.record_ttft(Duration::from_nanos(fill_ns as u64));
        }
        // decode_tokens never moved: the old scheme would aim every
        // post-wrap sample at slot 0
        assert_eq!(m.decode_tokens.load(Ordering::Relaxed), 0);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..256u64 {
                        m.record_ttft(Duration::from_millis(10 + t * 256 + i));
                    }
                });
            }
        });
        let ring = m.ttft_ns.lock().unwrap();
        assert_eq!(ring.len(), METRIC_SAMPLE_CAP, "ring stays bounded");
        let replaced = ring.iter().filter(|&&x| x != fill_ns).count();
        assert_eq!(
            replaced, 1024,
            "4×256 concurrent recordings must land in 1024 distinct slots"
        );
    }

    #[test]
    fn intertoken_ring_has_its_own_cursor() {
        let m = Metrics::default();
        for _ in 0..METRIC_SAMPLE_CAP {
            m.record_intertoken(Duration::from_millis(1));
        }
        for i in 0..4 {
            m.record_intertoken(Duration::from_millis(20 + i));
        }
        let ring = m.intertoken_ns.lock().unwrap();
        let replaced = ring.iter().filter(|&&x| x >= 2e7).count();
        assert_eq!(replaced, 4, "post-wrap samples claim consecutive slots");
    }

    #[test]
    fn aggregate_of_empty_rings_is_all_zeros_not_nan() {
        let s = aggregate([&Metrics::default(), &Metrics::default()]);
        for v in [
            s.batch_mean,
            s.lat_p50_ms,
            s.lat_p95_ms,
            s.modeled_p50_ms,
            s.ttft_p50_ms,
            s.intertoken_p50_ms,
            s.step_occupancy_mean,
            s.cold_start_p99_ms,
        ] {
            assert_eq!(v, 0.0, "empty rings must aggregate to 0, not NaN");
        }
        assert_eq!(s.cache_hit_rate(), 0.0, "hit rate guards the 0/0 case");
        // and a snapshot of an untouched Metrics likewise
        let quiet = Metrics::default().snapshot("w");
        assert_eq!(quiet.lat_p50_ms, 0.0);
        assert!(!quiet.to_string().contains("cache_hit_rate"));
    }

    #[test]
    fn rings_past_wrap_around_stay_bounded_and_aggregate_sanely() {
        let m = Metrics::default();
        // 2× capacity: the counter keeps the truth, the ring stays CAP
        for i in 0..(2 * METRIC_SAMPLE_CAP) {
            m.record(1, Duration::from_micros(1 + (i % 7) as u64));
        }
        assert_eq!(m.batches.load(Ordering::Relaxed) as usize, 2 * METRIC_SAMPLE_CAP);
        assert_eq!(m.latencies_us.lock().unwrap().len(), METRIC_SAMPLE_CAP);
        let s = m.snapshot("w");
        assert_eq!(s.batches as usize, 2 * METRIC_SAMPLE_CAP);
        assert!(s.lat_p50_ms > 0.0 && s.lat_p50_ms < 0.008, "{}", s.lat_p50_ms);
        let agg = aggregate([&m]);
        assert!((agg.lat_p50_ms - s.lat_p50_ms).abs() < 1e-12);
        assert!((agg.batch_mean - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cache_counters_flow_into_snapshots() {
        let m = Metrics::default();
        m.cache_hits.fetch_add(9, Ordering::Relaxed);
        m.cache_misses.fetch_add(1, Ordering::Relaxed);
        m.cache_evictions.fetch_add(2, Ordering::Relaxed);
        m.cache_prefetch_hits.fetch_add(3, Ordering::Relaxed);
        m.record_cold_start(Duration::from_millis(4));
        let s = m.snapshot("cache");
        assert!((s.cache_hit_rate() - 0.9).abs() < 1e-9);
        assert!((s.cold_start_p99_ms - 4.0).abs() < 1e-9);
        let text = s.to_string();
        assert!(text.contains("cache_hit_rate=90%"));
        assert!(text.contains("prefetch_hits=3"));
        let agg = aggregate([&m, &Metrics::default()]);
        assert_eq!(agg.cache_hits, 9);
        assert_eq!(agg.cache_evictions, 2);
        assert!((agg.cold_start_p99_ms - 4.0).abs() < 1e-9);
        // pools without a cache stay silent
        assert!(!Metrics::default().snapshot("w").to_string().contains("cache_hit_rate"));
    }

    #[test]
    fn cold_tasks_shed_typed_and_retryable_not_unknown() {
        use crate::serve::sched::VirtualClock;
        let reg = registry_with(&["a", "b"]);
        let clock = Arc::new(VirtualClock::new());
        let cache = AdapterCache::new(
            CacheConfig::new(1).load_latency(Duration::from_millis(1)),
            reg.clone(),
            clock.clone(),
            Arc::new(Metrics::default()),
        );
        cache.poll(cache.now()); // adopt a,b → capacity 1 keeps only b
        assert!(reg.is_evicted("a"));
        let (c, _rxs) = mock_client(1, 8, 2, reg.clone());
        let c = Client {
            cache: Some(cache.clone()),
            ..c
        };
        // paged-out ≠ unknown: typed cold error, retryable, load queued
        let err = c.submit("a", &[0, 0]).unwrap_err();
        assert_eq!(
            err,
            ServeError::AdapterCold { task: "a".into(), loading: true }
        );
        assert!(err.is_retryable(), "cold is a pre-admission bounce");
        assert!(err.to_string().contains("paged out"));
        // genuinely unknown tasks still report UnknownTask
        assert!(matches!(
            c.submit("zzz", &[0, 0]).unwrap_err(),
            ServeError::UnknownTask { .. }
        ));
        // generate() takes the same cold path
        assert!(matches!(
            c.generate("a", &[1], GenConfig::default()).unwrap_err(),
            ServeError::AdapterCold { .. }
        ));
        // once the page-in lands the task is admittable again
        clock.advance(Duration::from_millis(2));
        cache.poll(clock.now());
        assert!(c.submit("a", &[0, 0]).is_ok());
    }
}
