//! Request admission and routing.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::server::{Msg, Response};

/// One inference request: a single example's tokens for a named task.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub task: String,
    pub tokens: Vec<i32>,
    pub resp: Sender<Response>,
}

/// Client-side handle: validates, stamps ids, and forwards to the
/// worker. Cheap to clone; usable from many client threads.
#[derive(Clone)]
pub struct Router {
    tx: Sender<Msg>,
    next_id: Arc<AtomicU64>,
    pub seq: usize,
    known_tasks: Arc<Vec<String>>,
}

impl Router {
    pub fn new(tx: Sender<Msg>, seq: usize, tasks: Vec<String>) -> Router {
        Router {
            tx,
            next_id: Arc::new(AtomicU64::new(1)),
            seq,
            known_tasks: Arc::new(tasks),
        }
    }

    /// Submit one request; returns (id, receiver for the response).
    pub fn submit(&self, task: &str, tokens: Vec<i32>) -> Result<(u64, std::sync::mpsc::Receiver<Response>)> {
        if tokens.len() != self.seq {
            return Err(anyhow!(
                "request has {} tokens, serving graph expects {}",
                tokens.len(),
                self.seq
            ));
        }
        if !self.known_tasks.iter().any(|t| t == task) {
            return Err(anyhow!(
                "unknown task '{task}' (deployed: {:?})",
                self.known_tasks
            ));
        }
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (resp_tx, resp_rx) = std::sync::mpsc::channel();
        self.tx
            .send(Msg::Req(Request {
                id,
                task: task.to_string(),
                tokens,
                resp: resp_tx,
            }))
            .map_err(|_| anyhow!("server is down"))?;
        Ok((id, resp_rx))
    }

    /// Ask the worker to stop after draining its queues.
    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn validates_shape_and_task() {
        let (tx, _rx) = channel();
        let r = Router::new(tx, 4, vec!["sst2".into()]);
        assert!(r.submit("sst2", vec![1, 2, 3, 4]).is_ok());
        assert!(r.submit("sst2", vec![1]).is_err());
        assert!(r.submit("nope", vec![1, 2, 3, 4]).is_err());
    }

    #[test]
    fn ids_are_unique_across_clones() {
        let (tx, _rx) = channel();
        let r1 = Router::new(tx, 2, vec!["t".into()]);
        let r2 = r1.clone();
        let (a, _) = r1.submit("t", vec![0, 0]).unwrap();
        let (b, _) = r2.submit("t", vec![0, 0]).unwrap();
        assert_ne!(a, b);
    }
}
