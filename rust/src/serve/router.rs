//! Deprecated shim — request admission moved to [`super::api::Client`].
//!
//! The old `Router` exposed a raw `mpsc` receiver that could block
//! forever if the worker dropped a batch. [`super::api::Client::submit`]
//! returns a typed [`super::api::Pending`] ticket that always resolves,
//! and applies bounded admission ([`super::api::ServeError::Overloaded`])
//! instead of growing an unbounded queue.

// NOTE: no module-wide `allow(deprecated)` here — the shim itself only
// *defines* deprecated items, so callers get their `#[deprecated]`
// warnings while this module stays clean under `-D warnings`.

pub use super::api::{Client, Pending};

/// Deprecated alias for the new cloneable client handle.
#[deprecated(since = "0.2.0", note = "use serve::api::Client")]
pub type Router = Client;
