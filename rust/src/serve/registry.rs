//! Thread-safe adapter registry shared between clients (deploys) and
//! the worker pool (reads) — the serving-side view of `model::lora`.
//!
//! Reads hand out `Arc<ParamStore>` snapshots, so the request path pays
//! O(pointer) per batch (the paper's hot-swap claim: switching tasks
//! must never cost a copy of the adapter, let alone the base model).
//! A redeploy installs a fresh `Arc` + bumped version; batches already
//! in flight finish on the snapshot they grabbed.

use std::sync::{Arc, RwLock};

use anyhow::{anyhow, Result};

use crate::model::lora::AdapterRegistry;
use crate::model::params::ParamStore;

#[derive(Clone, Default)]
pub struct SharedRegistry(Arc<RwLock<AdapterRegistry>>);

impl SharedRegistry {
    pub fn new() -> SharedRegistry {
        SharedRegistry(Arc::new(RwLock::new(AdapterRegistry::new())))
    }

    /// Hot-swap deployment: O(adapter size) once, never touches the base
    /// model (the paper's on-chip task-switching claim). Returns the new
    /// monotone version.
    pub fn deploy(&self, task: &str, params: ParamStore) -> u64 {
        self.0.write().unwrap().deploy(task, params)
    }

    /// Compare-and-swap deploy: install only if the live version is
    /// still `expected` (0 = not deployed). Returns the new monotone
    /// version, or `None` when a concurrent deploy won — used by the
    /// drift-refresh worker so a refit computed against a stale adapter
    /// never clobbers a newer manual deployment.
    pub fn deploy_if_version(
        &self,
        task: &str,
        params: ParamStore,
        expected: u64,
    ) -> Option<u64> {
        self.0.write().unwrap().deploy_if_version(task, params, expected)
    }

    /// O(pointer) snapshot of the current adapter set. One read path:
    /// this is [`SharedRegistry::snapshot`] minus the version.
    pub fn get(&self, task: &str) -> Result<Arc<ParamStore>> {
        self.snapshot(task)
            .map(|(p, _)| p)
            .ok_or_else(|| anyhow!("no adapter deployed for task '{task}'"))
    }

    /// Adapter + version under ONE lock acquisition, so a concurrent
    /// redeploy can never pair an old adapter with a new version number.
    pub fn snapshot(&self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        self.0.read().unwrap().snapshot(task)
    }

    pub fn contains(&self, task: &str) -> bool {
        self.0.read().unwrap().contains(task)
    }

    pub fn version(&self, task: &str) -> Option<u64> {
        self.0.read().unwrap().info(task).map(|i| i.version)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.0.read().unwrap().tasks()
    }

    pub fn total_params(&self) -> usize {
        self.0.read().unwrap().total_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;

    #[test]
    fn concurrent_deploy_and_read() {
        let reg = SharedRegistry::new();
        let mut handles = vec![];
        for i in 0..4 {
            let r = reg.clone();
            handles.push(std::thread::spawn(move || {
                let p = ParamStore::from_tensors(vec![Tensor::zeros("a", &[i + 1])]);
                r.deploy(&format!("task{i}"), p);
                r.get(&format!("task{i}")).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.tasks().len(), 4);
    }

    #[test]
    fn version_tracks_redeploys() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        reg.deploy("t", p());
        reg.deploy("t", p());
        assert_eq!(reg.version("t"), Some(2));
        assert_eq!(reg.version("missing"), None);
    }

    #[test]
    fn cas_deploy_refuses_stale_expectations() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        assert_eq!(reg.deploy_if_version("t", p(), 0), Some(1));
        reg.deploy("t", p()); // concurrent manual redeploy -> v2
        assert_eq!(reg.deploy_if_version("t", p(), 1), None, "stale CAS must lose");
        assert_eq!(reg.deploy_if_version("t", p(), 2), Some(3));
    }

    #[test]
    fn get_is_pointer_cheap() {
        let reg = SharedRegistry::new();
        reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[64])]));
        let a = reg.get("t").unwrap();
        let b = reg.get("t").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "get must not deep-copy the adapter");
    }

    #[test]
    fn snapshot_version_is_consistent_under_redeploy() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        reg.deploy("t", p());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let (reg, stop) = (reg.clone(), stop.clone());
            std::thread::spawn(move || {
                for _ in 0..200 {
                    reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]));
                }
                stop.store(true, std::sync::atomic::Ordering::Release);
            })
        };
        let mut last = 0u64;
        while !stop.load(std::sync::atomic::Ordering::Acquire) {
            let (_, v) = reg.snapshot("t").unwrap();
            assert!(v >= last, "versions observed monotonically");
            last = v;
        }
        writer.join().unwrap();
        assert_eq!(reg.version("t"), Some(201));
    }
}
