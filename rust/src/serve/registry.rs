//! Thread-safe adapter registry shared between the router (deploys) and
//! the worker (reads) — the serving-side view of `model::lora`.

use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::model::lora::AdapterRegistry;
use crate::model::params::ParamStore;

#[derive(Clone, Default)]
pub struct SharedRegistry(Arc<RwLock<AdapterRegistry>>);

impl SharedRegistry {
    pub fn new() -> SharedRegistry {
        SharedRegistry(Arc::new(RwLock::new(AdapterRegistry::new())))
    }

    /// Hot-swap deployment: O(adapter size), never touches the base
    /// model (the paper's on-chip task-switching claim).
    pub fn deploy(&self, task: &str, params: ParamStore) -> u64 {
        self.0.write().unwrap().deploy(task, params)
    }

    pub fn get(&self, task: &str) -> Result<ParamStore> {
        Ok(self.0.read().unwrap().get(task)?.clone())
    }

    pub fn version(&self, task: &str) -> Option<u64> {
        self.0.read().unwrap().info(task).map(|i| i.version)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.0.read().unwrap().tasks()
    }

    pub fn total_params(&self) -> usize {
        self.0.read().unwrap().total_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;

    #[test]
    fn concurrent_deploy_and_read() {
        let reg = SharedRegistry::new();
        let mut handles = vec![];
        for i in 0..4 {
            let r = reg.clone();
            handles.push(std::thread::spawn(move || {
                let p = ParamStore::from_tensors(vec![Tensor::zeros("a", &[i + 1])]);
                r.deploy(&format!("task{i}"), p);
                r.get(&format!("task{i}")).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.tasks().len(), 4);
    }

    #[test]
    fn version_tracks_redeploys() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        reg.deploy("t", p());
        reg.deploy("t", p());
        assert_eq!(reg.version("t"), Some(2));
        assert_eq!(reg.version("missing"), None);
    }
}
