//! Thread-safe adapter registry shared between clients (deploys) and
//! the worker pool (reads) — the serving-side view of `model::lora`.
//!
//! Reads hand out `Arc<ParamStore>` snapshots, so the request path pays
//! O(pointer) per batch (the paper's hot-swap claim: switching tasks
//! must never cost a copy of the adapter, let alone the base model).
//! A redeploy installs a fresh `Arc` + bumped version; batches already
//! in flight finish on the snapshot they grabbed.
//!
//! Residency: with a `serve::cache` capacity tier attached, an entry in
//! the registry means "resident on the DPUs" — eviction removes the
//! entry (readers miss) while the underlying [`AdapterRegistry`] retains
//! the task's version counter, and [`SharedRegistry::restore`] pages the
//! same bytes back in at the same version. A deploy hook lets the cache
//! observe every successful deployment (manual or refresh CAS) without
//! polling, so its host-side backing copies never go stale.

use std::sync::{Arc, RwLock};

use anyhow::{anyhow, Result};

use crate::model::lora::AdapterRegistry;
use crate::model::params::ParamStore;

/// Observer invoked after every successful deploy (task, params, new
/// version). Called OUTSIDE the registry lock: the hook may re-enter the
/// registry (e.g. to evict over-capacity tasks) without deadlocking.
pub type DeployHook = Arc<dyn Fn(&str, &Arc<ParamStore>, u64) + Send + Sync>;

#[derive(Default)]
struct Inner {
    adapters: RwLock<AdapterRegistry>,
    hook: RwLock<Option<DeployHook>>,
}

#[derive(Clone, Default)]
pub struct SharedRegistry(Arc<Inner>);

impl SharedRegistry {
    pub fn new() -> SharedRegistry {
        SharedRegistry::default()
    }

    fn notify(&self, task: &str, version: u64) {
        let hook = self.0.hook.read().unwrap().clone();
        if let Some(hook) = hook {
            if let Some((params, v)) = self.snapshot(task) {
                // Only report the deployment we made; if a concurrent
                // deploy already replaced it the hook fires again for
                // that one with the newer version.
                if v == version {
                    hook(task, &params, version);
                }
            }
        }
    }

    /// Register the single deploy observer (the adapter cache). Replaces
    /// any previous hook.
    pub fn set_deploy_hook(&self, hook: DeployHook) {
        *self.0.hook.write().unwrap() = Some(hook);
    }

    /// Hot-swap deployment: O(adapter size) once, never touches the base
    /// model (the paper's on-chip task-switching claim). Returns the new
    /// monotone version.
    pub fn deploy(&self, task: &str, params: ParamStore) -> u64 {
        let version = self.0.adapters.write().unwrap().deploy(task, params);
        self.notify(task, version);
        version
    }

    /// Compare-and-swap deploy: install only if the live version is
    /// still `expected` (0 = not deployed). Returns the new monotone
    /// version, or `None` when a concurrent deploy won — used by the
    /// drift-refresh worker so a refit computed against a stale adapter
    /// never clobbers a newer manual deployment. An EVICTED task always
    /// loses (see [`AdapterRegistry::deploy_if_version`]): refresh must
    /// never resurrect an adapter behind the capacity tier's back.
    pub fn deploy_if_version(
        &self,
        task: &str,
        params: ParamStore,
        expected: u64,
    ) -> Option<u64> {
        let version = self
            .0
            .adapters
            .write()
            .unwrap()
            .deploy_if_version(task, params, expected)?;
        self.notify(task, version);
        Some(version)
    }

    /// Page an adapter out (capacity eviction): the entry disappears for
    /// readers, the version counter is retained. Returns the evicted
    /// bytes + version for the cache's host-side backing store.
    pub fn evict(&self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        self.0.adapters.write().unwrap().evict(task)
    }

    /// Page a previously evicted adapter back in at its ORIGINAL version
    /// (a reload is not a redeploy — the drift tracker relies on the
    /// stable version to keep the task's drift anchor). Returns `false`
    /// when a concurrent deploy won or the bytes are stale; does not
    /// fire the deploy hook (the cache initiates restores itself).
    pub fn restore(&self, task: &str, params: Arc<ParamStore>, version: u64) -> bool {
        self.0.adapters.write().unwrap().restore(task, params, version)
    }

    /// Task was deployed at some point and is currently paged out.
    pub fn is_evicted(&self, task: &str) -> bool {
        self.0.adapters.read().unwrap().is_evicted(task)
    }

    /// O(pointer) snapshot of the current adapter set. One read path:
    /// this is [`SharedRegistry::snapshot`] minus the version.
    pub fn get(&self, task: &str) -> Result<Arc<ParamStore>> {
        self.snapshot(task)
            .map(|(p, _)| p)
            .ok_or_else(|| anyhow!("no adapter deployed for task '{task}'"))
    }

    /// Adapter + version under ONE lock acquisition, so a concurrent
    /// redeploy can never pair an old adapter with a new version number.
    pub fn snapshot(&self, task: &str) -> Option<(Arc<ParamStore>, u64)> {
        self.0.adapters.read().unwrap().snapshot(task)
    }

    pub fn contains(&self, task: &str) -> bool {
        self.0.adapters.read().unwrap().contains(task)
    }

    pub fn version(&self, task: &str) -> Option<u64> {
        self.0.adapters.read().unwrap().info(task).map(|i| i.version)
    }

    pub fn tasks(&self) -> Vec<String> {
        self.0.adapters.read().unwrap().tasks()
    }

    pub fn total_params(&self) -> usize {
        self.0.adapters.read().unwrap().total_params()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;

    #[test]
    fn concurrent_deploy_and_read() {
        let reg = SharedRegistry::new();
        let mut handles = vec![];
        for i in 0..4 {
            let r = reg.clone();
            handles.push(std::thread::spawn(move || {
                let p = ParamStore::from_tensors(vec![Tensor::zeros("a", &[i + 1])]);
                r.deploy(&format!("task{i}"), p);
                r.get(&format!("task{i}")).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(reg.tasks().len(), 4);
    }

    #[test]
    fn version_tracks_redeploys() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        reg.deploy("t", p());
        reg.deploy("t", p());
        assert_eq!(reg.version("t"), Some(2));
        assert_eq!(reg.version("missing"), None);
    }

    #[test]
    fn cas_deploy_refuses_stale_expectations() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        assert_eq!(reg.deploy_if_version("t", p(), 0), Some(1));
        reg.deploy("t", p()); // concurrent manual redeploy -> v2
        assert_eq!(reg.deploy_if_version("t", p(), 1), None, "stale CAS must lose");
        assert_eq!(reg.deploy_if_version("t", p(), 2), Some(3));
    }

    #[test]
    fn deploy_hook_observes_manual_and_cas_deploys_but_not_restores() {
        use std::sync::Mutex;
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        let seen: Arc<Mutex<Vec<(String, u64)>>> = Arc::new(Mutex::new(Vec::new()));
        let log = seen.clone();
        reg.set_deploy_hook(Arc::new(move |task, _params, version| {
            log.lock().unwrap().push((task.to_string(), version));
        }));
        reg.deploy("t", p());
        assert_eq!(reg.deploy_if_version("t", p(), 1), Some(2));
        assert_eq!(reg.deploy_if_version("t", p(), 1), None, "failed CAS is silent");
        let (bytes, v) = reg.evict("t").unwrap();
        assert!(reg.restore("t", bytes, v), "restore is cache-initiated: no hook");
        assert_eq!(
            seen.lock().unwrap().clone(),
            vec![("t".to_string(), 1), ("t".to_string(), 2)]
        );
    }

    #[test]
    fn evict_restore_roundtrip_preserves_snapshot_identity() {
        let reg = SharedRegistry::new();
        reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[8])]));
        let (before, v) = reg.snapshot("t").unwrap();
        let (evicted, ev) = reg.evict("t").unwrap();
        assert!(Arc::ptr_eq(&before, &evicted));
        assert_eq!(v, ev);
        assert!(reg.is_evicted("t"));
        assert!(reg.snapshot("t").is_none(), "readers miss while paged out");
        assert!(reg.restore("t", evicted, ev));
        let (after, v2) = reg.snapshot("t").unwrap();
        assert!(Arc::ptr_eq(&before, &after), "same bytes page back in");
        assert_eq!(v2, v, "reload keeps the version — not a new deployment");
        assert!(!reg.is_evicted("t"));
    }

    #[test]
    fn get_is_pointer_cheap() {
        let reg = SharedRegistry::new();
        reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[64])]));
        let a = reg.get("t").unwrap();
        let b = reg.get("t").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "get must not deep-copy the adapter");
    }

    #[test]
    fn snapshot_version_is_consistent_under_redeploy() {
        let reg = SharedRegistry::new();
        let p = || ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]);
        reg.deploy("t", p());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let writer = {
            let (reg, stop) = (reg.clone(), stop.clone());
            std::thread::spawn(move || {
                for _ in 0..200 {
                    reg.deploy("t", ParamStore::from_tensors(vec![Tensor::zeros("a", &[2])]));
                }
                stop.store(true, std::sync::atomic::Ordering::Release);
            })
        };
        let mut last = 0u64;
        while !stop.load(std::sync::atomic::Ordering::Acquire) {
            let (_, v) = reg.snapshot("t").unwrap();
            assert!(v >= last, "versions observed monotonically");
            last = v;
        }
        writer.join().unwrap();
        assert_eq!(reg.version("t"), Some(201));
    }
}
