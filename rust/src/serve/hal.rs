//! Backend hardware-abstraction layer (HAL) behind the serving pool.
//!
//! The pool hard-wired one substrate: PCM tiles programmed with the
//! meta-weights, a PJRT forward graph, the PCM drift statistics feeding
//! [`super::refresh`], and the Fig. 4 pipeline-balance cost model
//! feeding [`super::sched`]. [`Backend`] captures those four seams —
//! **deploy**, **forward**, **drift model**, **cost model** — as one
//! trait so a pool can mix substrates and place each task where its
//! tolerance is cheapest to maintain.
//!
//! ```text
//!             ┌───────────────── Backend ─────────────────┐
//!             │ deploy      adapter → substrate (latency)  │
//!             │ forward     batched execution (Forward)    │
//!             │ drift_model DecayModel | None (drift-free) │
//!             │ cost_model  CostModel  (balance table)     │
//!             └────────────────────────────────────────────┘
//!               ▲                                ▲
//!     PcmPjrt (default: PCM drift +      DigitalRef (feature
//!     PJRT graph, bit-identical to        "digital-ref": in-process,
//!     the pre-HAL pool)                   drift-free, slowdown× cost)
//! ```
//!
//! # Implementations
//!
//! * [`PcmPjrt`] — the existing path, verbatim: `runtime::Engine` +
//!   PJRT forward, [`PcmModel`] drift. A single-backend pool built
//!   through it is **bit-identical** to the pre-HAL pool (same engine
//!   calls, same seeds, same scheduler table).
//! * [`DigitalRef`] (feature `digital-ref`, on by default; disabled in
//!   `--no-default-features` lean builds) — an in-process drift-free
//!   digital reference. Its forward is a deterministic hash of
//!   (tokens, adapter, seed), so it serves real traffic hermetically —
//!   no artifacts, no XLA — which is what makes the HAL plumbing
//!   testable end-to-end in CI. Its cost model is the same balance
//!   table scaled by a configurable `slowdown` (digital MVMs instead
//!   of analog tiles), and its maintenance cost is zero.
//!
//! # Routing
//!
//! A heterogeneous pool partitions its workers across backends and
//! routes each task once, on first use ([`Router`]), by minimising
//!
//! ```text
//! placement_cost = service + maintenance
//!   service      = batch_ns(fill*) / fill*      (fill* = smallest
//!                  sustainable fill at the task's arrival EWMA)
//!   maintenance  = refit_ns · gap_secs / trigger_age(tolerance)
//!                  (0 on a drift-free backend)
//! ```
//!
//! i.e. the modeled per-request service latency plus the per-request
//! share of keeping the task inside its drift tolerance on that
//! substrate (refresh cadence × refit budget). Fast-drifting tight
//! tolerances route to the cheap-refresh backend; relaxed tolerances
//! stay on the fastest substrate. The service column reads the SAME
//! [`crate::pipeline::balance::latency_table`] the per-backend
//! [`super::sched::BatchScheduler`] batches on, so placement and
//! batch-close decisions can never disagree about the hardware model.
//!
//! The pure decision functions ([`route_one`], [`route_tasks`]) are
//! deterministic and side-effect free — `tests/hal_conformance.rs`
//! property-tests them directly.
//!
//! # Adaptive rebalance
//!
//! First-use routing guesses from whatever arrival evidence exists at
//! that instant — often none. The cadenced rebalancer
//! ([`RebalanceRunner`], spawned by `ServerBuilder::rebalance` the same
//! way the refresh runner is) periodically re-prices every placed task
//! against its **measured** arrival EWMA and migrates it when — and
//! only when — the move pays for itself:
//!
//! ```text
//! move t: from → to  fires iff
//!   (cost_from − cost_to) · (cooldown_ns / gap_ns)
//!        ≥ hysteresis · deploy_ns(to)
//!   AND now − moved_at(t) ≥ cooldown
//! ```
//!
//! i.e. the modeled per-request saving, accumulated over one cooldown
//! horizon of traffic at the task's observed rate, must repay the
//! destination's deploy latency `hysteresis` times over — and a task
//! that just moved cannot move again inside the cooldown. Under
//! stationary traffic the EWMAs converge, the saving of any further
//! move drops below the gate, and placement reaches a fixed point:
//! zero moves, no flapping (the conformance suite pins this).
//!
//! A migration is drain-free: the task is flagged as migrating through
//! [`super::refresh::RefreshHandle`] (its old span serves out the
//! queue at the next batch boundary, in drain mode), its drift physics
//! and page-in cost are re-parameterized for the destination substrate
//! *without touching the drift anchor* — a migration is not a
//! redeploy — and only then does the routing table flip, so new
//! submissions land on the new span from one instant on.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

#[cfg(feature = "digital-ref")]
use anyhow::anyhow;
use anyhow::Result;

#[cfg(feature = "digital-ref")]
use crate::config::manifest::Role;
use crate::config::manifest::Manifest;
use crate::model::params::ParamStore;
use crate::pcm::PcmModel;
use crate::pipeline::balance::latency_table;
use crate::pmca::cluster::SnitchCluster;
use crate::pmca::redmule::RedMulE;

use super::refresh::DecayModel;
use super::sched::{Clock, SchedConfig};

// ---------------------------------------------------------------------------
// The Forward executor
// ---------------------------------------------------------------------------

/// A backend's batched forward executor.
///
/// Deliberately **not** `Send`: the PJRT implementation wraps a loaded
/// executable whose handles must stay on the thread that created them,
/// so the pool constructs one `Forward` per worker thread via
/// [`Backend::forward`] (the `Backend` itself is `Send + Sync` and
/// shared).
pub trait Forward {
    /// `[batch, seq]` shape of the forward graph.
    fn batch_shape(&self) -> (usize, usize);

    /// LM vocabulary size when the graph emits `[b, s, vocab]` logits
    /// (decode lanes need it); `None` for classification graphs.
    fn vocab(&self) -> Option<usize>;

    /// Milliseconds spent compiling/bringing up this executor.
    fn compile_ms(&self) -> u64;

    /// Ahead-of-time shape-specialize for the batch fills the
    /// scheduler commits to
    /// ([`super::sched::BatchScheduler::committed_fills`]), so those
    /// fills execute without per-batch padding or re-pack
    /// (`runtime::compile`). Every specialized path must stay
    /// bit-identical to the padded reference — `compile_golden` pins
    /// it. Fills the executor cannot specialize (larger than the graph
    /// batch) are skipped, not errors; a zero fill is a caller bug and
    /// errors. The default is a no-op: substrates serve correctly
    /// without specialization, just slower.
    fn specialize(&mut self, fills: &[usize]) -> Result<()> {
        let _ = fills;
        Ok(())
    }

    /// The fills this executor was specialized for (ascending; empty
    /// until [`Forward::specialize`] runs).
    fn specialized_fills(&self) -> Vec<usize> {
        Vec::new()
    }

    /// Classification logit rows for `tokens` (one row of class logits
    /// per `seq`-length request).
    fn cls_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<Vec<f32>>>;

    /// Full-sequence LM logits for an exact `[b, s]` token buffer.
    fn lm_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------------------
// The Backend trait — the four seams
// ---------------------------------------------------------------------------

/// One serving substrate: how adapters are deployed onto it, how it
/// executes a batch, how its weights drift, and what a batch costs.
pub trait Backend: Send + Sync {
    /// Stable identifier (unique within one pool).
    fn name(&self) -> &str;

    /// Drift model for adapters deployed on this substrate; `None`
    /// means drift-free (never triggers a refresh). The pool threads
    /// this into [`super::refresh::RefreshConfig`] per task.
    fn drift_model(&self) -> Option<DecayModel>;

    /// Modeled deploy latency: programming the adapter onto the
    /// substrate (tile conductance programming for PCM, a memcpy for a
    /// digital substrate). The pool threads this into
    /// [`super::cache::CacheConfig`] as the per-task page-in latency.
    fn deploy_latency(&self) -> Duration;

    /// Modeled cost of one adapter refit on this substrate, ns. Feeds
    /// the tolerance-maintenance column of the placement cost.
    fn refit_ns(&self) -> f64;

    /// Rewrite the layer/hardware model a scheduler on this backend
    /// should batch against (identity for the reference substrate; a
    /// slower substrate scales its integration time). The pool applies
    /// this to each worker's [`SchedConfig`] before building its
    /// [`super::sched::BatchScheduler`].
    fn adapt_sched(&self, cfg: SchedConfig) -> SchedConfig {
        cfg
    }

    /// Batch-latency table for placement decisions. The default reads
    /// the shared [`latency_table`] through [`Self::adapt_sched`], so
    /// it is — by construction — the same table this backend's
    /// scheduler batches on.
    fn cost_model(&self, layer: &SchedConfig, max_batch: usize) -> CostModel {
        CostModel::from_layer(&self.adapt_sched(*layer), max_batch)
    }

    /// Bring up a per-worker forward executor for `graph_key`.
    fn forward(&self, manifest: &Manifest, graph_key: &str) -> Result<Box<dyn Forward>>;
}

/// The drift model of a drift-free substrate: the ideal (noise-free)
/// PCM model, whose decay is 0 at every age and whose trigger age is
/// `+inf` for every tolerance — tracked tasks are simply never due.
pub fn drift_free() -> DecayModel {
    DecayModel::analytic(PcmModel::ideal())
}

// ---------------------------------------------------------------------------
// Cost model
// ---------------------------------------------------------------------------

/// A backend's modeled batch-latency table: `batch_ns(b)` = modeled
/// steady-state latency of serving a batch of `b` requests, `b` in
/// `1..=max_batch`. Built from the shared
/// [`crate::pipeline::balance::latency_table`].
#[derive(Clone, Debug)]
pub struct CostModel {
    modeled_ns: Vec<f64>,
}

impl CostModel {
    /// Wrap an explicit table (`modeled_ns[b-1]` = latency of fill `b`).
    pub fn from_table(modeled_ns: Vec<f64>) -> CostModel {
        let modeled_ns = if modeled_ns.is_empty() {
            vec![1.0]
        } else {
            modeled_ns
        };
        CostModel { modeled_ns }
    }

    /// Tabulate the pipeline-balance model for `layer` on the paper's
    /// default Snitch cluster + RedMulE — the exact table
    /// [`super::sched::BatchScheduler`] commits to for that layer.
    pub fn from_layer(layer: &SchedConfig, max_batch: usize) -> CostModel {
        let (_, table) = latency_table(
            layer.m,
            layer.n,
            layer.r,
            layer.t_int_ns,
            layer.seq_len.max(1),
            max_batch.max(1),
            &SnitchCluster::default(),
            &RedMulE::default(),
        );
        CostModel::from_table(table)
    }

    /// Largest fill the table models.
    pub fn max_batch(&self) -> usize {
        self.modeled_ns.len()
    }

    /// Modeled latency of a batch of `fill` requests, ns (clamped to
    /// the tabulated range, like the scheduler's lookup).
    pub fn batch_ns(&self, fill: usize) -> f64 {
        self.modeled_ns[fill.clamp(1, self.modeled_ns.len()) - 1]
    }

    /// Smallest fill whose per-request service time keeps up with one
    /// request every `interarrival_ns`; `None` if no tabulated fill
    /// sustains that rate.
    pub fn sustainable_fill(&self, interarrival_ns: f64) -> Option<usize> {
        (1..=self.modeled_ns.len()).find(|&b| self.batch_ns(b) / b as f64 <= interarrival_ns)
    }

    /// Whether any tabulated fill sustains the arrival rate.
    pub fn can_sustain(&self, interarrival_ns: f64) -> bool {
        self.sustainable_fill(interarrival_ns).is_some()
    }

    /// The fills a scheduler batching on this table can ever commit a
    /// batch at ([`crate::pipeline::balance::frontier_fills`]): what
    /// `ServerBuilder::build` AOT shape-specializes each worker's
    /// forward executor for. Reads the SAME table the backend's
    /// [`super::sched::BatchScheduler`] batches on, so the specialized
    /// set and the scheduler's commitment cannot disagree.
    pub fn committed_fills(&self) -> Vec<usize> {
        crate::pipeline::balance::frontier_fills(&self.modeled_ns)
    }

    /// Uniformly scaled copy (a substrate `factor`× slower per batch).
    pub fn scaled(&self, factor: f64) -> CostModel {
        let f = if factor.is_finite() && factor > 0.0 {
            factor
        } else {
            1.0
        };
        CostModel {
            modeled_ns: self.modeled_ns.iter().map(|ns| ns * f).collect(),
        }
    }
}

// ---------------------------------------------------------------------------
// Placement
// ---------------------------------------------------------------------------

/// The routing-relevant surface of one backend, snapshotted at pool
/// build time so placement decisions need no trait calls.
#[derive(Clone, Debug)]
pub struct BackendProfile {
    pub name: String,
    pub cost: CostModel,
    /// `None` = drift-free.
    pub drift: Option<DecayModel>,
    pub refit_ns: f64,
    /// Modeled adapter deploy latency onto this substrate — what one
    /// migration ONTO it costs. The rebalance hysteresis gate prices
    /// every move against the destination's deploy latency.
    pub deploy_latency: Duration,
}

impl BackendProfile {
    /// Snapshot `backend` for the (seq-resolved) layer model.
    pub fn of(backend: &dyn Backend, layer: &SchedConfig, max_batch: usize) -> BackendProfile {
        BackendProfile {
            name: backend.name().to_string(),
            cost: backend.cost_model(layer, max_batch),
            drift: backend.drift_model(),
            refit_ns: backend.refit_ns(),
            deploy_latency: backend.deploy_latency(),
        }
    }

    /// Per-request cost of keeping a task inside `tolerance` on this
    /// substrate: the refit budget amortised over the requests served
    /// per refresh cycle (`trigger_age / gap`). Zero when the substrate
    /// never drifts past the tolerance; `+inf` when the tolerance sits
    /// at/below the model's floor (every batch would be stale).
    pub fn maintenance_ns(&self, gap_ns: f64, tolerance: f64) -> f64 {
        let Some(drift) = &self.drift else {
            return 0.0;
        };
        let trigger = drift.trigger_age(tolerance.clamp(1e-6, 1.0));
        if trigger.is_infinite() {
            0.0
        } else if trigger <= 0.0 {
            f64::INFINITY
        } else {
            self.refit_ns * (gap_ns / 1e9) / trigger
        }
    }

    /// Total modeled per-request cost of placing a task with arrival
    /// EWMA `interarrival_ns` and drift `tolerance` here (see the
    /// module docs for the formula). A cold task (`+inf` EWMA) is
    /// costed at saturation — back-to-back single-request batches —
    /// so placement is defined before the first arrival.
    pub fn placement_cost(&self, interarrival_ns: f64, tolerance: f64) -> f64 {
        let gap = if interarrival_ns.is_finite() && interarrival_ns > 0.0 {
            interarrival_ns
        } else {
            self.cost.batch_ns(1)
        };
        let fill = self
            .cost
            .sustainable_fill(gap)
            .unwrap_or_else(|| self.cost.max_batch());
        let service = self.cost.batch_ns(fill) / fill as f64;
        service + self.maintenance_ns(gap, tolerance)
    }
}

/// The routing-relevant surface of one task.
#[derive(Clone, Debug)]
pub struct TaskProfile {
    pub task: String,
    /// Drift tolerance the refresh policy maintains for this task.
    pub tolerance: f64,
    /// Observed inter-arrival EWMA, ns (`+inf` until measured).
    pub interarrival_ns: f64,
    /// Operator override: always place on this backend index.
    pub pinned: Option<usize>,
}

/// Pick the backend minimising [`BackendProfile::placement_cost`].
/// Backends that can sustain the task's arrival rate are preferred
/// over ones that cannot (if none can, all compete on cost alone);
/// ties break toward the lower index. Pure and deterministic.
pub fn route_one(backends: &[BackendProfile], interarrival_ns: f64, tolerance: f64) -> usize {
    assert!(!backends.is_empty(), "route_one: no backends");
    let sustaining: Vec<usize> = (0..backends.len())
        .filter(|&i| backends[i].cost.can_sustain(interarrival_ns))
        .collect();
    let candidates: Vec<usize> = if sustaining.is_empty() {
        (0..backends.len()).collect()
    } else {
        sustaining
    };
    let mut best = candidates[0];
    let mut best_cost = backends[best].placement_cost(interarrival_ns, tolerance);
    for &i in &candidates[1..] {
        let cost = backends[i].placement_cost(interarrival_ns, tolerance);
        if cost < best_cost {
            best = i;
            best_cost = cost;
        }
    }
    best
}

/// Route every task ([`route_one`] per task).
///
/// # Precondition
///
/// Every pin must be a valid backend index. `ServerBuilder::build`
/// rejects out-of-range pins with `BuildError::Backends`, so a pin
/// that gets here out of range is a caller bug: debug builds panic on
/// it (the [`assignment_cost`] idiom); release builds clamp to the
/// last backend so a typo'd operator pin degrades to a real substrate
/// rather than a crash — but no longer silently, since the debug lane
/// catches it first.
pub fn route_tasks(backends: &[BackendProfile], tasks: &[TaskProfile]) -> Vec<usize> {
    tasks
        .iter()
        .map(|t| match t.pinned {
            Some(p) => {
                debug_assert!(
                    p < backends.len(),
                    "route_tasks: task '{}' pinned to backend {p}, but only {} exist",
                    t.task,
                    backends.len()
                );
                p.min(backends.len().saturating_sub(1))
            }
            None => route_one(backends, t.interarrival_ns, t.tolerance),
        })
        .collect()
}

/// Total modeled per-request cost of an explicit `assignment`
/// (`assignment[i]` = backend index of `tasks[i]`) — what
/// `hal_conformance` compares routed vs naive placements on.
///
/// # Precondition
///
/// Every `assignment[i]` must be a valid backend index
/// (`assignment[i] < backends.len()`). An out-of-range index is a
/// caller bug: debug builds panic on it; release builds clamp to the
/// last backend so a malformed operator assignment degrades to a
/// costed placement rather than a crash. The routing property suite
/// pins that every assignment produced by [`route_tasks`] and
/// [`Router`] satisfies this invariant.
pub fn assignment_cost(
    backends: &[BackendProfile],
    tasks: &[TaskProfile],
    assignment: &[usize],
) -> f64 {
    tasks
        .iter()
        .zip(assignment)
        .map(|(t, &b)| {
            debug_assert!(
                b < backends.len(),
                "assignment_cost: backend index {b} out of range ({} backends)",
                backends.len()
            );
            backends[b.min(backends.len() - 1)].placement_cost(t.interarrival_ns, t.tolerance)
        })
        .sum()
}

// ---------------------------------------------------------------------------
// Router — runtime task→backend state for a heterogeneous pool
// ---------------------------------------------------------------------------

/// EWMA of one task's inter-arrival gap, kept pool-side (the
/// per-worker scheduler estimators only see their own shard's slice).
#[derive(Clone, Copy, Debug, Default)]
struct RouterArrival {
    last: Option<Instant>,
    ewma_ns: Option<f64>,
}

#[derive(Default)]
struct RouterState {
    /// Sticky task→backend decisions (route-on-first-use).
    table: BTreeMap<String, usize>,
    arrivals: BTreeMap<String, RouterArrival>,
    /// When each task last migrated (the rebalance cooldown clock).
    moved_at: BTreeMap<String, Instant>,
}

// ---------------------------------------------------------------------------
// Rebalance configuration
// ---------------------------------------------------------------------------

/// Knobs for the cadenced adaptive rebalancer (builder-style setters,
/// wired through `ServerBuilder::rebalance`). See the module docs for
/// the hysteresis gate the defaults parameterize.
#[derive(Clone, Debug)]
pub struct RebalanceConfig {
    cadence: Duration,
    hysteresis: f64,
    cooldown: Duration,
    idle_retire: Option<Duration>,
    max_moves_per_tick: usize,
    resize_spans: bool,
}

impl Default for RebalanceConfig {
    fn default() -> RebalanceConfig {
        RebalanceConfig {
            cadence: Duration::from_millis(250),
            hysteresis: 2.0,
            cooldown: Duration::from_secs(2),
            idle_retire: Some(Duration::from_secs(60)),
            max_moves_per_tick: 4,
            resize_spans: false,
        }
    }
}

impl RebalanceConfig {
    pub fn new() -> RebalanceConfig {
        RebalanceConfig::default()
    }

    /// How often the background rebalance tick fires.
    pub fn cadence(mut self, d: Duration) -> Self {
        if !d.is_zero() {
            self.cadence = d;
        }
        self
    }

    /// Hysteresis multiple: a move fires only when the modeled saving
    /// over one cooldown horizon exceeds `h ×` the destination's
    /// deploy latency. Higher = stickier placement.
    pub fn hysteresis(mut self, h: f64) -> Self {
        if h.is_finite() && h >= 0.0 {
            self.hysteresis = h;
        }
        self
    }

    /// Per-task cooldown: a task that just migrated cannot migrate
    /// again before this much pool-clock time passes. Doubles as the
    /// payback horizon the hysteresis gate amortises savings over.
    pub fn cooldown(mut self, d: Duration) -> Self {
        if !d.is_zero() {
            self.cooldown = d;
        }
        self
    }

    /// Retire tasks whose last arrival is older than this horizon from
    /// the router's arrival/table maps (they re-route on next use).
    /// `None` disables retirement.
    pub fn idle_retire(mut self, horizon: Option<Duration>) -> Self {
        self.idle_retire = horizon.filter(|d| !d.is_zero());
        self
    }

    /// Migration budget per tick: at most this many moves fire per
    /// rebalance pass (best savings first).
    pub fn max_moves_per_tick(mut self, n: usize) -> Self {
        self.max_moves_per_tick = n.max(1);
        self
    }

    /// Re-size worker spans proportionally to routed traffic share
    /// after each tick that moved tasks. Only safe for pools whose
    /// workers can re-bind to a new backend (the Sim harness); the
    /// real pool's forward executors are thread-bound, so it leaves
    /// this off.
    pub fn span_resize(mut self, on: bool) -> Self {
        self.resize_spans = on;
        self
    }

    pub fn tick_cadence(&self) -> Duration {
        self.cadence
    }

    pub fn cooldown_horizon(&self) -> Duration {
        self.cooldown
    }

    pub fn idle_horizon(&self) -> Option<Duration> {
        self.idle_retire
    }

    pub fn move_budget(&self) -> usize {
        self.max_moves_per_tick
    }

    pub fn resizes_spans(&self) -> bool {
        self.resize_spans
    }
}

/// One hysteresis-approved placement move, with the modeled
/// per-request costs that justified it (`cost_to < cost_from` always —
/// the property suite pins that every applied move is cost-improving).
#[derive(Clone, Debug)]
pub struct PlannedMove {
    pub task: String,
    pub from: usize,
    pub to: usize,
    /// Modeled per-request cost on the current backend.
    pub cost_from: f64,
    /// Modeled per-request cost on the destination backend.
    pub cost_to: f64,
}

/// Task→backend routing for a pool with more than one backend.
///
/// A task is routed ONCE, on first use, with whatever arrival evidence
/// exists at that instant (none → costed at saturation), and the
/// decision sticks — the task's drift tracking and cache residency
/// live on that backend's workers. [`Router::rebalance`] re-evaluates
/// unpinned tasks against their measured EWMAs and returns the moves
/// it applied, for operators that want periodic re-placement.
///
/// A single-backend pool has no `Router` at all: requests hash across
/// all workers exactly as before the HAL existed.
pub struct Router {
    profiles: Vec<BackendProfile>,
    /// `ranges[i]` = contiguous `[start, end)` worker span of backend
    /// `i`. Behind a lock so [`Router::resize_spans`] can follow
    /// routed traffic share at runtime.
    ranges: Mutex<Vec<(usize, usize)>>,
    default_tolerance: f64,
    tolerances: BTreeMap<String, f64>,
    pins: BTreeMap<String, usize>,
    clock: Arc<dyn Clock>,
    state: Mutex<RouterState>,
}

impl Router {
    pub fn new(
        profiles: Vec<BackendProfile>,
        ranges: Vec<(usize, usize)>,
        default_tolerance: f64,
        tolerances: BTreeMap<String, f64>,
        pins: BTreeMap<String, usize>,
        clock: Arc<dyn Clock>,
    ) -> Router {
        assert_eq!(profiles.len(), ranges.len(), "one worker range per backend");
        assert!(!profiles.is_empty(), "router needs at least one backend");
        assert!(
            ranges.iter().all(|&(s, e)| e > s),
            "every backend needs at least one worker"
        );
        Router {
            profiles,
            ranges: Mutex::new(ranges),
            default_tolerance,
            tolerances,
            pins,
            clock,
            state: Mutex::new(RouterState::default()),
        }
    }

    pub fn profiles(&self) -> &[BackendProfile] {
        &self.profiles
    }

    /// Current worker spans, `(start, end)` per backend (snapshot —
    /// [`Router::resize_spans`] may change them between reads).
    pub fn ranges(&self) -> Vec<(usize, usize)> {
        self.ranges.lock().expect("router ranges").clone()
    }

    fn tolerance_of(&self, task: &str) -> f64 {
        self.tolerances
            .get(task)
            .copied()
            .unwrap_or(self.default_tolerance)
    }

    fn decide(&self, task: &str, interarrival_ns: f64) -> usize {
        if let Some(&p) = self.pins.get(task) {
            // out-of-range pins are rejected at build; see route_tasks
            debug_assert!(
                p < self.profiles.len(),
                "router: task '{task}' pinned to backend {p}, but only {} exist",
                self.profiles.len()
            );
            return p.min(self.profiles.len() - 1);
        }
        route_one(&self.profiles, interarrival_ns, self.tolerance_of(task))
    }

    /// Record an arrival of `task` (feeds the routing EWMA).
    pub fn note_arrival(&self, task: &str, now: Instant) {
        let mut st = self.state.lock().expect("router state");
        let a = st.arrivals.entry(task.to_string()).or_default();
        if let Some(last) = a.last {
            let dt = now.saturating_duration_since(last).as_nanos() as f64;
            a.ewma_ns = Some(crate::util::stats::ewma(a.ewma_ns, dt));
        }
        a.last = Some(now);
    }

    /// The backend `task` is (or becomes, on first use) placed on.
    pub fn backend_of(&self, task: &str) -> usize {
        let mut st = self.state.lock().expect("router state");
        if let Some(&b) = st.table.get(task) {
            return b;
        }
        let gap = st
            .arrivals
            .get(task)
            .and_then(|a| a.ewma_ns)
            .unwrap_or(f64::INFINITY);
        drop(st);
        let b = self.decide(task, gap);
        let mut st = self.state.lock().expect("router state");
        *st.table.entry(task.to_string()).or_insert(b)
    }

    /// Worker index for one request of `task`: note the arrival, then
    /// hash the task across its backend's worker span (same FNV spread
    /// a homogeneous pool uses across all workers).
    pub fn worker_for(&self, task: &str) -> usize {
        self.note_arrival(task, self.clock.now());
        self.worker_of(task)
    }

    /// Worker index `task` currently maps to WITHOUT recording an
    /// arrival — introspection and migration handoff (the destination
    /// worker of an applied move, with no EWMA perturbation).
    pub fn worker_of(&self, task: &str) -> usize {
        let (start, end) = self.ranges.lock().expect("router ranges")[self.backend_of(task)];
        start + (super::api::fnv1a(task) % (end - start) as u64) as usize
    }

    /// Measured inter-arrival EWMA of `task`, ns (`None` until two
    /// arrivals have been observed).
    pub fn arrival_ewma_ns(&self, task: &str) -> Option<f64> {
        let st = self.state.lock().expect("router state");
        st.arrivals.get(task).and_then(|a| a.ewma_ns)
    }

    /// Current sticky assignments, `(task, backend index)`.
    pub fn assignments(&self) -> Vec<(String, usize)> {
        let st = self.state.lock().expect("router state");
        st.table.iter().map(|(t, &b)| (t.clone(), b)).collect()
    }

    /// Re-route every unpinned task against its measured EWMA; apply
    /// and return the moves as `(task, from, to)`. This is the FORCED
    /// variant — no hysteresis, no cooldown — for operators that want
    /// an immediate re-placement. The cadenced loop goes through
    /// [`Router::plan_rebalance`] instead.
    pub fn rebalance(&self) -> Vec<(String, usize, usize)> {
        let mut st = self.state.lock().expect("router state");
        let snapshot: Vec<(String, usize, f64)> = st
            .table
            .iter()
            .map(|(t, &b)| {
                let gap = st
                    .arrivals
                    .get(t)
                    .and_then(|a| a.ewma_ns)
                    .unwrap_or(f64::INFINITY);
                (t.clone(), b, gap)
            })
            .collect();
        let mut moves = Vec::new();
        for (task, from, gap) in snapshot {
            let to = self.decide(&task, gap);
            if to != from {
                st.table.insert(task.clone(), to);
                moves.push((task, from, to));
            }
        }
        moves
    }

    /// Plan one hysteresis-gated rebalance pass at `now` WITHOUT
    /// touching the routing table (pure read — the caller migrates
    /// per-task state and then flips each move via
    /// [`Router::apply_move`]). A move survives the gate when:
    ///
    /// * the task is unpinned and has a measured arrival EWMA (a cold
    ///   task has no traffic to amortise a deploy against),
    /// * its cooldown has expired (`now − moved_at ≥ cooldown`),
    /// * the destination strictly improves the modeled per-request
    ///   cost, and
    /// * the saving over one cooldown horizon of traffic repays
    ///   `hysteresis ×` the destination's deploy latency (module docs).
    ///
    /// At most [`RebalanceConfig::move_budget`] moves are returned,
    /// best absolute saving first (ties → task name order, from the
    /// sorted snapshot).
    pub fn plan_rebalance(&self, cfg: &RebalanceConfig, now: Instant) -> Vec<PlannedMove> {
        let st = self.state.lock().expect("router state");
        let cooldown_ns = cfg.cooldown.as_nanos() as f64;
        let mut planned: Vec<PlannedMove> = Vec::new();
        for (task, &from) in &st.table {
            if self.pins.contains_key(task) {
                continue;
            }
            if let Some(&moved) = st.moved_at.get(task) {
                if now.saturating_duration_since(moved) < cfg.cooldown {
                    continue;
                }
            }
            let Some(gap) = st.arrivals.get(task).and_then(|a| a.ewma_ns) else {
                continue;
            };
            if !gap.is_finite() || gap <= 0.0 {
                continue;
            }
            let tolerance = self.tolerance_of(task);
            let to = route_one(&self.profiles, gap, tolerance);
            if to == from {
                continue;
            }
            let cost_from = self.profiles[from].placement_cost(gap, tolerance);
            let cost_to = self.profiles[to].placement_cost(gap, tolerance);
            if !(cost_to < cost_from) {
                continue;
            }
            let saving = (cost_from - cost_to) * (cooldown_ns / gap);
            let deploy_ns = self.profiles[to].deploy_latency.as_nanos() as f64;
            if saving < cfg.hysteresis * deploy_ns {
                continue;
            }
            planned.push(PlannedMove {
                task: task.clone(),
                from,
                to,
                cost_from,
                cost_to,
            });
        }
        planned.sort_by(|a, b| {
            let sa = a.cost_from - a.cost_to;
            let sb = b.cost_from - b.cost_to;
            sb.partial_cmp(&sa).unwrap_or(std::cmp::Ordering::Equal)
        });
        planned.truncate(cfg.max_moves_per_tick);
        planned
    }

    /// Flip `task`'s routing-table entry to backend `to` and stamp its
    /// cooldown clock. New submissions route to the new span from this
    /// call on; requests already queued on the old span drain there.
    pub fn apply_move(&self, task: &str, to: usize, now: Instant) {
        assert!(to < self.profiles.len(), "apply_move: backend {to} out of range");
        let mut st = self.state.lock().expect("router state");
        st.table.insert(task.to_string(), to);
        st.moved_at.insert(task.to_string(), now);
    }

    /// Plan + apply one hysteresis-gated pass (tests and pools without
    /// per-task migration state use this directly; `RebalanceRunner`
    /// interleaves the state carry between plan and apply).
    pub fn rebalance_with(&self, cfg: &RebalanceConfig, now: Instant) -> Vec<PlannedMove> {
        let planned = self.plan_rebalance(cfg, now);
        for m in &planned {
            self.apply_move(&m.task, m.to, now);
        }
        planned
    }

    /// Retire tasks whose last observed arrival predates `now −
    /// horizon`: their arrival EWMA, sticky table entry, and cooldown
    /// stamp are dropped (bounding all three maps under task churn) —
    /// a retired task that comes back simply re-routes on first use.
    /// Build-time placements that never saw an arrival are kept: they
    /// are bounded by the deployed task set, not by traffic. Returns
    /// the retired task names.
    pub fn retire_idle(&self, horizon: Duration, now: Instant) -> Vec<String> {
        let mut st = self.state.lock().expect("router state");
        let idle: Vec<String> = st
            .arrivals
            .iter()
            .filter(|(_, a)| {
                a.last
                    .map(|l| now.saturating_duration_since(l) >= horizon)
                    .unwrap_or(false)
            })
            .map(|(t, _)| t.clone())
            .collect();
        for task in &idle {
            st.arrivals.remove(task);
            st.table.remove(task);
            st.moved_at.remove(task);
        }
        idle
    }

    /// `(table entries, arrival EWMAs)` — what the churn regression
    /// test bounds.
    pub fn map_sizes(&self) -> (usize, usize) {
        let st = self.state.lock().expect("router state");
        (st.table.len(), st.arrivals.len())
    }

    /// Re-size the contiguous worker spans proportionally to each
    /// backend's routed traffic share (Σ of its tasks' arrival rates,
    /// `1/ewma`). Every backend keeps at least one worker; the total
    /// worker count and the backend order are preserved; leftover
    /// workers go to the largest fractional remainders (ties → lower
    /// index). With no measured traffic at all the spans are left
    /// untouched. Returns the spans now in effect.
    ///
    /// Only pools whose workers can re-bind to a backend should call
    /// this (see [`RebalanceConfig::span_resize`]).
    pub fn resize_spans(&self) -> Vec<(usize, usize)> {
        let n = self.profiles.len();
        let mut weights = vec![0.0f64; n];
        {
            let st = self.state.lock().expect("router state");
            for (task, &b) in &st.table {
                if let Some(ewma) = st.arrivals.get(task).and_then(|a| a.ewma_ns) {
                    if ewma.is_finite() && ewma > 0.0 {
                        weights[b] += 1.0 / ewma;
                    }
                }
            }
        }
        let total_weight: f64 = weights.iter().sum();
        let mut ranges = self.ranges.lock().expect("router ranges");
        if total_weight <= 0.0 {
            return ranges.clone();
        }
        let workers: usize = ranges.iter().map(|&(s, e)| e - s).sum();
        // one guaranteed worker each; the rest follow traffic share
        let spare = workers - n;
        let ideal: Vec<f64> = weights
            .iter()
            .map(|w| spare as f64 * w / total_weight)
            .collect();
        let mut sizes: Vec<usize> = ideal.iter().map(|&x| 1 + x.floor() as usize).collect();
        let mut leftover = workers - sizes.iter().sum::<usize>();
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            let fa = ideal[a] - ideal[a].floor();
            let fb = ideal[b] - ideal[b].floor();
            fb.partial_cmp(&fa)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut next = 0usize;
        while leftover > 0 {
            sizes[order[next % n]] += 1;
            leftover -= 1;
            next += 1;
        }
        let mut start = 0;
        for (i, size) in sizes.iter().enumerate() {
            ranges[i] = (start, start + size);
            start += size;
        }
        ranges.clone()
    }
}

// ---------------------------------------------------------------------------
// RebalanceRunner — the cadenced adaptive loop over a Router
// ---------------------------------------------------------------------------

/// Executes the plan → migrate → flip cycle over a [`Router`] on a
/// cadence (spawned by `ServerBuilder::rebalance` exactly like the
/// refresh runner: wall-clock ticks for stop promptness, pool-clock
/// decisions). Each approved move runs the drain-free handoff:
///
/// 1. **freeze** — the task is flagged migrating through the
///    [`RefreshHandle`](super::refresh::RefreshHandle); its old span's
///    scheduler serves out the queue at the next batch boundary in
///    drain mode, and the worker clears the flag once the queue is
///    empty.
/// 2. **carry** — drift physics move to the destination backend's
///    [`DecayModel`] *without re-anchoring* `deployed_at` (a migration
///    is not a redeploy: the substrate the adapter came from kept
///    drifting, and the destination inherits that age), and the
///    capacity tier's page-in latency is re-priced to the
///    destination's deploy cost. Cache residency is task-keyed and
///    survives untouched.
/// 3. **flip** — [`Router::apply_move`] redirects all new submissions
///    to the destination span and stamps the cooldown clock.
pub struct RebalanceRunner {
    cfg: RebalanceConfig,
    router: Arc<Router>,
    backends: Vec<Arc<dyn Backend>>,
    refresh: Option<super::refresh::RefreshHandle>,
    refresh_runner: Option<Arc<Mutex<super::refresh::RefreshRunner>>>,
    cache: Option<Arc<super::cache::AdapterCache>>,
    metrics: Option<Arc<super::api::Metrics>>,
}

impl RebalanceRunner {
    pub fn new(cfg: RebalanceConfig, router: Arc<Router>, backends: Vec<Arc<dyn Backend>>) -> RebalanceRunner {
        assert_eq!(
            router.profiles().len(),
            backends.len(),
            "one backend per routed profile"
        );
        RebalanceRunner {
            cfg,
            router,
            backends,
            refresh: None,
            refresh_runner: None,
            cache: None,
            metrics: None,
        }
    }

    /// Attach the refresh surfaces: the shared handle carries the
    /// migrating flag, the runner re-parameterizes the migrated task's
    /// decay physics (anchor-preserving).
    pub fn with_refresh(
        mut self,
        handle: super::refresh::RefreshHandle,
        runner: Arc<Mutex<super::refresh::RefreshRunner>>,
    ) -> RebalanceRunner {
        self.refresh = Some(handle);
        self.refresh_runner = Some(runner);
        self
    }

    /// Attach the capacity tier so a migrated task's page-in latency
    /// follows it to the destination substrate.
    pub fn with_cache(mut self, cache: Arc<super::cache::AdapterCache>) -> RebalanceRunner {
        self.cache = Some(cache);
        self
    }

    /// Attach a metrics sink (`rebalance_moves` / `tasks_retired`).
    pub fn with_metrics(mut self, metrics: Arc<super::api::Metrics>) -> RebalanceRunner {
        self.metrics = Some(metrics);
        self
    }

    pub fn config(&self) -> &RebalanceConfig {
        &self.cfg
    }

    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// One rebalance pass at `now`: retire idle tasks, plan under the
    /// hysteresis gate, run the three-step handoff per approved move,
    /// then (when enabled) follow traffic share with the worker spans.
    /// Returns the applied moves.
    pub fn tick(&self, now: Instant) -> Vec<PlannedMove> {
        if let Some(horizon) = self.cfg.idle_retire {
            let retired = self.router.retire_idle(horizon, now);
            if let (Some(m), false) = (&self.metrics, retired.is_empty()) {
                m.tasks_retired
                    .fetch_add(retired.len() as u64, std::sync::atomic::Ordering::Relaxed);
            }
        }
        let planned = self.router.plan_rebalance(&self.cfg, now);
        for mv in &planned {
            self.migrate(mv, now);
        }
        if self.cfg.resize_spans && !planned.is_empty() {
            self.router.resize_spans();
        }
        planned
    }

    fn migrate(&self, mv: &PlannedMove, now: Instant) {
        // 1. freeze: old-span schedulers drain the task at the next
        // batch boundary; the worker clears the flag at queue-empty
        if let Some(h) = &self.refresh {
            h.set_migrating(&mv.task, true);
        }
        // 2. carry: destination drift physics (anchor preserved) and
        // destination page-in cost
        if let Some(rr) = &self.refresh_runner {
            let decay = self.backends[mv.to].drift_model().unwrap_or_else(drift_free);
            rr.lock()
                .expect("refresh runner")
                .policy_mut()
                .set_task_decay(&mv.task, decay);
        }
        if let Some(c) = &self.cache {
            c.set_task_load_latency(&mv.task, self.backends[mv.to].deploy_latency());
        }
        // 3. flip: new submissions land on the destination span
        self.router.apply_move(&mv.task, mv.to, now);
        if let Some(m) = &self.metrics {
            m.rebalance_moves
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        }
    }
}

/// Spawn the cadenced rebalance thread (same stop/tick discipline as
/// `spawn_refresh_worker`: the stop channel doubles as the tick timer
/// so shutdown is prompt even under a virtual pool clock).
pub(crate) fn spawn_rebalance_worker(
    runner: Arc<RebalanceRunner>,
    clock: Arc<dyn Clock>,
    cadence: Duration,
) -> std::io::Result<(std::sync::mpsc::Sender<()>, std::thread::JoinHandle<()>)> {
    use std::sync::mpsc::{channel, RecvTimeoutError};
    let (stop_tx, stop_rx) = channel::<()>();
    let join = std::thread::Builder::new()
        .name("ahwa-rebalance".to_string())
        .spawn(move || loop {
            match stop_rx.recv_timeout(cadence) {
                Ok(()) | Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {
                    runner.tick(clock.now());
                }
            }
        })?;
    Ok((stop_tx, join))
}

// ---------------------------------------------------------------------------
// PcmPjrt — the reference substrate (existing path, verbatim)
// ---------------------------------------------------------------------------

/// PCM tiles + PJRT forward: the pre-HAL pool's exact execution path.
/// `forward` is `runtime::Engine::new` + `Engine::load`; logits flow
/// through the same `eval::drift_eval` entry points with the same
/// seeds, so a single-`PcmPjrt` pool is bit-identical to the pre-HAL
/// pool.
#[derive(Clone, Debug)]
pub struct PcmPjrt {
    name: String,
    model: PcmModel,
    g_rel: f32,
    deploy_latency: Duration,
    refit_ns: f64,
    /// Integration-time multiplier for the scheduler/cost model
    /// (1.0 = the reference tile bank, bit-identical to the pre-HAL
    /// pool; a conservative bank integrates longer per MVM).
    t_int_scale: f64,
}

impl Default for PcmPjrt {
    fn default() -> Self {
        PcmPjrt {
            name: "pcm-pjrt".to_string(),
            model: PcmModel::default(),
            g_rel: 0.5,
            // tile conductance programming dominates adapter page-in;
            // matches the pre-HAL CacheConfig::load_latency default
            deploy_latency: Duration::from_micros(500),
            // one bounded-budget LoRA refit on the PMCA, modeled ns
            refit_ns: 5.0e6,
            t_int_scale: 1.0,
        }
    }
}

impl PcmPjrt {
    pub fn new() -> PcmPjrt {
        PcmPjrt::default()
    }

    /// A conservative slow-drift tile bank: programmed for retention
    /// over speed. Its drift dispersion is scaled down (`noise_scale
    /// 0.4`) and its drift reference time stretched (`t0` 60 s), so
    /// tolerance-crossing ages are much longer — at the price of a
    /// 1.5× integration time, a slower (more careful) programming
    /// pass, and a costlier refit. The third profile in a three-way
    /// routed pool: middle tolerance bands land here when the default
    /// bank's refresh upkeep outweighs the slowdown.
    pub fn conservative() -> PcmPjrt {
        PcmPjrt {
            name: "pcm-conservative".to_string(),
            model: PcmModel {
                t0: 60.0,
                noise_scale: 0.4,
                ..PcmModel::default()
            },
            g_rel: 0.5,
            deploy_latency: Duration::from_micros(800),
            refit_ns: 8.0e6,
            t_int_scale: 1.5,
        }
    }

    /// Override the pool-unique backend name (two tile banks of the
    /// same kind need distinct names to coexist in one pool).
    pub fn named(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// Override the drift statistics (e.g. a fast-drifting tile bank).
    pub fn model(mut self, model: PcmModel) -> Self {
        self.model = model;
        self
    }

    /// Representative relative conductance for the decay dispersion.
    pub fn g_rel(mut self, g_rel: f32) -> Self {
        self.g_rel = g_rel.clamp(0.0, 1.0);
        self
    }

    pub fn deploy_latency(mut self, d: Duration) -> Self {
        self.deploy_latency = d;
        self
    }

    pub fn refit_ns(mut self, ns: f64) -> Self {
        self.refit_ns = ns.max(0.0);
        self
    }

    /// Integration-time multiplier (> 0) applied through
    /// [`Backend::adapt_sched`]; 1.0 leaves the scheduler model — and
    /// the default pool's bit-identity — untouched.
    pub fn t_int_scale(mut self, s: f64) -> Self {
        if s.is_finite() && s > 0.0 {
            self.t_int_scale = s;
        }
        self
    }
}

/// The PJRT executor behind [`PcmPjrt`]: the staged compile pipeline
/// (`runtime::compile`), which owns the engine, the graph IR, the
/// max-shape base executable, and any per-fill shape specializations.
struct PjrtForward {
    pipe: crate::runtime::compile::FwdPipeline,
}

impl Forward for PjrtForward {
    fn batch_shape(&self) -> (usize, usize) {
        (self.pipe.ir().batch, self.pipe.ir().seq)
    }

    fn vocab(&self) -> Option<usize> {
        self.pipe
            .base()
            .spec
            .outputs
            .first()
            .filter(|o| o.shape.len() == 3)
            .map(|o| o.shape[2])
    }

    /// Total compile time so far — grows when [`Forward::specialize`]
    /// compiles exact-shape siblings, so the pool reads it AFTER
    /// specialization and the metric covers the whole bring-up.
    fn compile_ms(&self) -> u64 {
        self.pipe.compile_ms() as u64
    }

    fn specialize(&mut self, fills: &[usize]) -> Result<()> {
        self.pipe.specialize(fills)
    }

    fn specialized_fills(&self) -> Vec<usize> {
        self.pipe.specialized_fills()
    }

    fn cls_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<Vec<f32>>> {
        self.pipe.cls_logits(meta, adapter, tokens, hw, seed)
    }

    fn lm_logits(
        &self,
        meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<f32>> {
        self.pipe.lm_logits(meta, adapter, tokens, hw, seed)
    }
}

impl Backend for PcmPjrt {
    fn name(&self) -> &str {
        &self.name
    }

    fn drift_model(&self) -> Option<DecayModel> {
        Some(DecayModel::Analytic {
            model: self.model.clone(),
            g_rel: self.g_rel,
        })
    }

    fn deploy_latency(&self) -> Duration {
        self.deploy_latency
    }

    fn refit_ns(&self) -> f64 {
        self.refit_ns
    }

    /// Identity at the reference scale (`t_int_scale == 1.0`, the
    /// bit-identical default); a conservative bank stretches the
    /// integration time its scheduler and cost model price.
    fn adapt_sched(&self, cfg: SchedConfig) -> SchedConfig {
        if self.t_int_scale == 1.0 {
            cfg
        } else {
            let t = cfg.t_int_ns * self.t_int_scale;
            cfg.t_int(t)
        }
    }

    fn forward(&self, manifest: &Manifest, graph_key: &str) -> Result<Box<dyn Forward>> {
        let pipe = crate::runtime::compile::FwdPipeline::compile(manifest.clone(), graph_key)?;
        Ok(Box::new(PjrtForward { pipe }))
    }
}

// ---------------------------------------------------------------------------
// DigitalRef — in-process drift-free reference (feature "digital-ref")
// ---------------------------------------------------------------------------

/// Drift-free digital reference substrate: deterministic in-process
/// logits (a hash of tokens + adapter + seed), zero maintenance cost,
/// and the balance-model cost table scaled by `slowdown` (digital MVMs
/// instead of analog tiles). Needs only graph *shapes* from the
/// manifest — no compiled artifacts — so a `DigitalRef` pool serves
/// hermetically in CI.
#[cfg(feature = "digital-ref")]
#[derive(Clone, Debug)]
pub struct DigitalRef {
    slowdown: f64,
    deploy_latency: Duration,
    /// Numerics knobs ([`PcmModel`]): `noise_scale` scales a
    /// deterministic programming-noise perturbation of every logit
    /// (σ from `prog_coeff` at the logit's own magnitude), `q_s_max`
    /// is the quantization grid the perturbed logits snap to, and
    /// `nu_clip.1` bounds the total per-logit deviation — the same
    /// "how wrong can one device be" clamp the analog drift model
    /// uses. The default is [`PcmModel::ideal`] (`noise_scale` 0):
    /// numerics off, logits bit-identical to the clean reference —
    /// which is exactly the analog path at drift age 0.
    model: PcmModel,
}

#[cfg(feature = "digital-ref")]
impl Default for DigitalRef {
    fn default() -> Self {
        DigitalRef {
            // digital MVMs for the full layer instead of analog tiles
            slowdown: 4.0,
            // adapter deploy is a memcpy, not conductance programming
            deploy_latency: Duration::from_micros(50),
            // numerics off: the clean deterministic-hash reference
            model: PcmModel::ideal(),
        }
    }
}

#[cfg(feature = "digital-ref")]
impl DigitalRef {
    pub fn new() -> DigitalRef {
        DigitalRef::default()
    }

    /// Per-batch latency multiplier vs the analog reference (> 0).
    pub fn slowdown(mut self, factor: f64) -> Self {
        if factor.is_finite() && factor > 0.0 {
            self.slowdown = factor;
        }
        self
    }

    pub fn deploy_latency(mut self, d: Duration) -> Self {
        self.deploy_latency = d;
        self
    }

    /// Install the full numerics model (quantization grid, noise
    /// polynomial, deviation clamp — see the `model` field docs).
    pub fn model(mut self, model: PcmModel) -> Self {
        self.model = model;
        self
    }

    /// Convenience: scale the numerics perturbation without replacing
    /// the whole model. `0.0` restores exact clean-reference logits.
    pub fn noise_scale(mut self, scale: f32) -> Self {
        if scale.is_finite() && scale >= 0.0 {
            self.model.noise_scale = scale;
        }
        self
    }
}

#[cfg(feature = "digital-ref")]
struct DigitalForward {
    batch: usize,
    seq: usize,
    /// Output tensor shape of the graph (`[b, classes]` or
    /// `[b, s, vocab]`) — logit buffers mirror its element count.
    out: Vec<usize>,
    /// Numerics model (see [`DigitalRef`]'s `model` field).
    model: PcmModel,
    /// Fills accepted by [`Forward::specialize`]. The row-wise hash
    /// forward is already exact-shape at every fill (no padding to
    /// elide), so this only records the commitment — and validates it,
    /// which is what keeps a bad committed-fill set from reaching the
    /// analog substrates unnoticed in hermetic CI.
    specialized: Vec<usize>,
}

#[cfg(feature = "digital-ref")]
impl DigitalForward {
    /// Stable fingerprint of an adapter's contents, so logits change
    /// deterministically when a refit hot-swaps the adapter.
    fn fingerprint(store: &ParamStore) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for t in &store.tensors {
            for b in t.name.as_bytes() {
                h = (h ^ *b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
            for v in &t.data {
                h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    fn hw_bits(hw: [f32; 5]) -> u64 {
        hw.iter()
            .fold(0u64, |acc, v| splitmix(acc ^ v.to_bits() as u64))
    }

    /// One logit through the numerics model: a deterministic
    /// programming-noise draw (σ from the `prog_coeff` polynomial at
    /// the logit's own magnitude, scaled by `noise_scale`), snapped to
    /// the `q_s_max` quantization grid, with the total deviation
    /// clamped to `nu_clip.1`. With `noise_scale == 0` the clean logit
    /// passes through BIT-IDENTICALLY — no grid, no clamp — which is
    /// what makes the digital substrate exactly equal the analog path
    /// at drift age 0.
    fn emit(&self, clean: f32, h: u64) -> f32 {
        let m = &self.model;
        if m.noise_scale == 0.0 {
            return clean;
        }
        // prog_sigma already folds in noise_scale; the draw is a
        // deterministic unit sample keyed off the logit's own hash
        let sigma = crate::pcm::programming::prog_sigma(m, clean.abs() * m.g_max);
        let draw = unit_logit(splitmix(h ^ 0x5109_c0de));
        let noisy = clean + sigma * draw;
        let grid = m.q_s_max.max(1e-6);
        let quant = (noisy / grid).round() * grid;
        clean + (quant - clean).clamp(-m.nu_clip.1, m.nu_clip.1)
    }
}

/// SplitMix64 finalizer — the cheap stateless mix behind the digital
/// reference's deterministic logits.
#[cfg(feature = "digital-ref")]
fn splitmix(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Map a hash to a logit in (-1, 1).
#[cfg(feature = "digital-ref")]
fn unit_logit(h: u64) -> f32 {
    ((h >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
}

#[cfg(feature = "digital-ref")]
impl Forward for DigitalForward {
    fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq)
    }

    fn vocab(&self) -> Option<usize> {
        if self.out.len() == 3 {
            Some(self.out[2])
        } else {
            None
        }
    }

    fn compile_ms(&self) -> u64 {
        0
    }

    fn specialize(&mut self, fills: &[usize]) -> Result<()> {
        for &f in fills {
            if f == 0 {
                return Err(anyhow!(
                    "digital-ref: cannot specialize a zero batch fill"
                ));
            }
        }
        // already exact-shape row-wise; record fills ≤ the graph batch
        // (larger fills chunk, exactly like the padded reference)
        self.specialized = fills.iter().copied().filter(|&f| f <= self.batch).collect();
        self.specialized.sort_unstable();
        self.specialized.dedup();
        Ok(())
    }

    fn specialized_fills(&self) -> Vec<usize> {
        self.specialized.clone()
    }

    fn cls_logits(
        &self,
        _meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<Vec<f32>>> {
        let s = self.seq.max(1);
        let classes = self.out.get(1).copied().unwrap_or(1);
        let base = splitmix(Self::fingerprint(adapter) ^ Self::hw_bits(hw) ^ seed);
        let rows = tokens.len() / s;
        let mut result = Vec::with_capacity(rows);
        for r in 0..rows {
            let mut h = base;
            for &t in &tokens[r * s..(r + 1) * s] {
                h = splitmix(h ^ t as u64);
            }
            result.push(
                (0..classes)
                    .map(|c| {
                        let hc = splitmix(h ^ c as u64);
                        self.emit(unit_logit(hc), hc)
                    })
                    .collect(),
            );
        }
        Ok(result)
    }

    fn lm_logits(
        &self,
        _meta: &ParamStore,
        adapter: &ParamStore,
        tokens: &[i32],
        hw: [f32; 5],
        seed: u64,
    ) -> Result<Vec<f32>> {
        let expect = self.batch * self.seq;
        if tokens.len() != expect {
            return Err(anyhow!(
                "digital-ref lm forward: got {} tokens, graph is [{}, {}]",
                tokens.len(),
                self.batch,
                self.seq
            ));
        }
        let base = splitmix(Self::fingerprint(adapter) ^ Self::hw_bits(hw) ^ seed);
        // fold each row's tokens once, then stream its logits
        let per_row: usize = self.out.iter().product::<usize>() / self.batch.max(1);
        let mut out = Vec::with_capacity(self.out.iter().product());
        for r in 0..self.batch {
            let mut h = base ^ (r as u64).wrapping_mul(0x517c);
            for (i, &t) in tokens[r * self.seq..(r + 1) * self.seq].iter().enumerate() {
                // position-sensitive fold: the logits after step k
                // depend on every token up to k
                h = splitmix(h ^ (t as u64).wrapping_add((i as u64) << 32));
            }
            for i in 0..per_row {
                let hi = splitmix(h ^ i as u64);
                out.push(self.emit(unit_logit(hi), hi));
            }
        }
        Ok(out)
    }
}

#[cfg(feature = "digital-ref")]
impl Backend for DigitalRef {
    fn name(&self) -> &str {
        "digital-ref"
    }

    fn drift_model(&self) -> Option<DecayModel> {
        None
    }

    fn deploy_latency(&self) -> Duration {
        self.deploy_latency
    }

    fn refit_ns(&self) -> f64 {
        0.0
    }

    /// A `slowdown`× slower substrate: scale the modeled integration
    /// time, so this backend's scheduler batches (and its cost model
    /// prices) against the slower hardware.
    fn adapt_sched(&self, cfg: SchedConfig) -> SchedConfig {
        let t = cfg.t_int_ns * self.slowdown;
        cfg.t_int(t)
    }

    fn forward(&self, manifest: &Manifest, graph_key: &str) -> Result<Box<dyn Forward>> {
        let spec = manifest
            .graphs
            .get(graph_key)
            .ok_or_else(|| anyhow!("digital-ref: manifest has no graph '{graph_key}'"))?;
        let io = spec
            .inputs_with_role(Role::Data)
            .next()
            .ok_or_else(|| anyhow!("digital-ref: graph '{graph_key}' has no data input"))?;
        if io.shape.len() < 2 {
            return Err(anyhow!(
                "digital-ref: graph '{graph_key}' data input is not [batch, seq]"
            ));
        }
        let out = spec
            .outputs
            .first()
            .ok_or_else(|| anyhow!("digital-ref: graph '{graph_key}' has no outputs"))?;
        Ok(Box::new(DigitalForward {
            batch: io.shape[0],
            seq: io.shape[1],
            out: out.shape.clone(),
            model: self.model.clone(),
            specialized: Vec::new(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sched::BatchScheduler;

    fn layer() -> SchedConfig {
        SchedConfig::for_layer(128, 128, 8).seq(320)
    }

    #[test]
    fn cost_model_matches_scheduler_table() {
        let cfg = layer();
        let cm = CostModel::from_layer(&cfg, 8);
        let sched = BatchScheduler::new(cfg, 8, Duration::from_millis(5));
        for fill in 0..=10 {
            assert_eq!(
                cm.batch_ns(fill),
                sched.modeled_batch_ns(fill),
                "fill {fill}"
            );
        }
    }

    #[test]
    fn sustainable_fill_is_smallest_keeping_up() {
        let cm = CostModel::from_table(vec![100.0, 150.0, 240.0]);
        // per-request: 100, 75, 80
        assert_eq!(cm.sustainable_fill(100.0), Some(1));
        assert_eq!(cm.sustainable_fill(80.0), Some(2));
        assert_eq!(cm.sustainable_fill(75.0), Some(2));
        assert_eq!(cm.sustainable_fill(74.0), None);
        assert!(cm.can_sustain(f64::INFINITY));
        assert!(!cm.can_sustain(1.0));
        assert_eq!(cm.scaled(2.0).batch_ns(1), 200.0);
    }

    #[test]
    fn drift_free_never_triggers() {
        let d = drift_free();
        assert_eq!(d.predicted_decay(1e9), 0.0);
        assert!(d.trigger_age(0.01).is_infinite());
    }

    #[test]
    fn maintenance_cost_shapes() {
        let cm = CostModel::from_table(vec![1000.0]);
        let drifty = BackendProfile {
            name: "pcm".into(),
            cost: cm.clone(),
            drift: Some(DecayModel::analytic(PcmModel::default())),
            refit_ns: 1e6,
            deploy_latency: Duration::from_micros(500),
        };
        let free = BackendProfile {
            name: "digital".into(),
            cost: cm,
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_micros(50),
        };
        assert_eq!(free.maintenance_ns(1e6, 0.01), 0.0);
        // tighter tolerance → shorter trigger age → higher upkeep
        let loose = drifty.maintenance_ns(1e6, 0.20);
        let tight = drifty.maintenance_ns(1e6, 0.02);
        assert!(tight > loose, "tight {tight} loose {loose}");
        // a zero tolerance clamps to the tightest finite one — upkeep
        // explodes but stays ordered
        assert!(drifty.maintenance_ns(1e6, 0.0) >= tight);
    }

    #[test]
    fn routing_prefers_sustaining_backend() {
        let slow = BackendProfile {
            name: "slow".into(),
            cost: CostModel::from_table(vec![1000.0, 1800.0]),
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_micros(50),
        };
        let fast = BackendProfile {
            name: "fast".into(),
            cost: CostModel::from_table(vec![400.0, 700.0]),
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_micros(50),
        };
        let backends = [slow, fast];
        // gap 500ns: only `fast` sustains (400 ≤ 500)
        assert_eq!(route_one(&backends, 500.0, 0.1), 1);
        // gap 2000ns: both sustain; fast is cheaper per request
        assert_eq!(route_one(&backends, 2000.0, 0.1), 1);
        // no backend sustains 10ns: cost decides (fast still cheaper)
        assert_eq!(route_one(&backends, 10.0, 0.1), 1);
    }

    #[test]
    fn tight_tolerance_routes_to_cheap_refresh_backend() {
        let cfg = layer();
        let pcm = BackendProfile::of(&PcmPjrt::default(), &cfg, 8);
        #[cfg(feature = "digital-ref")]
        {
            let dig = BackendProfile::of(&DigitalRef::default(), &cfg, 8);
            let backends = [pcm.clone(), dig];
            // relaxed tolerance on slow traffic: analog service wins
            let relaxed = route_one(&backends, 1e9, 0.5);
            assert_eq!(relaxed, 0, "relaxed tolerance should stay on PCM");
            // a tolerance at the drift floor makes PCM infinitely
            // expensive to maintain → the drift-free backend wins
            let tight = route_one(&backends, 1e9, 1e-6);
            assert_eq!(tight, 1, "floor tolerance should move to digital");
        }
        let _ = pcm;
    }

    fn pin_profile() -> BackendProfile {
        BackendProfile {
            name: "only".into(),
            cost: CostModel::from_table(vec![100.0]),
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_micros(50),
        }
    }

    fn pinned_task(name: &str, pin: usize) -> TaskProfile {
        TaskProfile {
            task: name.into(),
            tolerance: 0.1,
            interarrival_ns: f64::INFINITY,
            pinned: Some(pin),
        }
    }

    #[test]
    fn pinned_tasks_are_respected() {
        let backends = [pin_profile(), pin_profile()];
        let tasks = vec![pinned_task("a", 1), pinned_task("b", 0)];
        assert_eq!(route_tasks(&backends, &tasks), vec![1, 0]);
    }

    /// An out-of-range pin is rejected by `ServerBuilder::build`; a
    /// pin that reaches routing out of range anyway is a caller bug
    /// the debug lane must catch loudly (release clamps — covered by
    /// the release-only branch below).
    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "pinned to backend 99")]
    fn out_of_range_pin_panics_in_debug() {
        let backends = [pin_profile(), pin_profile()];
        route_tasks(&backends, &[pinned_task("typo", 99)]);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn out_of_range_pin_clamps_in_release() {
        let backends = [pin_profile(), pin_profile()];
        assert_eq!(route_tasks(&backends, &[pinned_task("typo", 99)]), vec![1]);
    }

    #[test]
    fn committed_fills_match_scheduler_commitment() {
        let cfg = layer();
        let cm = CostModel::from_layer(&cfg, 8);
        let sched = BatchScheduler::new(cfg, 8, Duration::from_millis(5));
        assert_eq!(
            cm.committed_fills(),
            sched.committed_fills(),
            "placement and batching must agree on the committed fill set"
        );
        assert_eq!(cm.committed_fills().last(), Some(&8));
        // an adapted (slower) table commits the same frontier SHAPE
        // guarantees: max fill present, all fills within range
        let scaled = cm.scaled(4.0);
        let fills = scaled.committed_fills();
        assert!(fills.iter().all(|&f| f >= 1 && f <= 8));
        assert_eq!(fills.last(), Some(&8));
    }

    #[test]
    fn router_is_sticky_and_stays_in_range() {
        use crate::serve::sched::VirtualClock;
        let profile = |ns: f64| BackendProfile {
            name: format!("b{ns}"),
            cost: CostModel::from_table(vec![ns]),
            drift: None,
            refit_ns: 0.0,
            deploy_latency: Duration::from_micros(50),
        };
        let clock = Arc::new(VirtualClock::new());
        let r = Router::new(
            vec![profile(100.0), profile(900.0)],
            vec![(0, 2), (2, 3)],
            0.1,
            BTreeMap::new(),
            BTreeMap::from([("pinme".to_string(), 1usize)]),
            clock,
        );
        let w = r.worker_for("hot");
        assert!(w < 2, "cheap backend owns workers 0..2, got {w}");
        assert_eq!(r.backend_of("hot"), 0);
        // sticky: repeated lookups never move
        for _ in 0..5 {
            assert_eq!(r.worker_for("hot"), w);
        }
        assert_eq!(r.backend_of("pinme"), 1);
        assert_eq!(r.worker_for("pinme"), 2);
        let asg = r.assignments();
        assert!(asg.contains(&("hot".to_string(), 0)));
        assert!(asg.contains(&("pinme".to_string(), 1)));
        // rebalance with no new evidence moves nothing
        assert!(r.rebalance().is_empty());
    }

    #[cfg(feature = "digital-ref")]
    mod digital {
        use super::*;
        use crate::config::manifest::{GraphSpec, IoSpec};
        use crate::model::params::Tensor;

        fn cls_spec() -> GraphSpec {
            GraphSpec {
                key: "base/fwd_cls".into(),
                kind: "fwd_cls".into(),
                variant: "base".into(),
                file: String::new(),
                inputs: vec![IoSpec {
                    name: "data/tokens".into(),
                    role: Role::Data,
                    shape: vec![4, 16],
                    dtype: "i32".into(),
                }],
                outputs: vec![IoSpec {
                    name: "logits".into(),
                    role: Role::Logits,
                    shape: vec![4, 3],
                    dtype: "f32".into(),
                }],
            }
        }

        fn manifest() -> Manifest {
            Manifest {
                root: std::path::PathBuf::from("unused"),
                hw: crate::config::manifest::HwDefaults {
                    weight_noise: 0.0,
                    adc_noise: 0.0,
                    clip_sigma: 127.0,
                    dac_bits: 8,
                    adc_bits: 8,
                    g_max_us: 25.0,
                    t0_seconds: 20.0,
                },
                grpo_group: 1,
                variants: BTreeMap::new(),
                graphs: BTreeMap::from([("base/fwd_cls".to_string(), cls_spec())]),
            }
        }

        fn adapter(tag: f32) -> ParamStore {
            let mut t = Tensor::zeros("train/a", &[2, 2]);
            t.data[0] = tag;
            ParamStore::from_tensors(vec![t])
        }

        #[test]
        fn forward_is_deterministic_and_adapter_sensitive() {
            let be = DigitalRef::default();
            let fwd = be.forward(&manifest(), "base/fwd_cls").unwrap();
            assert_eq!(fwd.batch_shape(), (4, 16));
            assert_eq!(fwd.vocab(), None);
            let meta = ParamStore::default();
            let tokens: Vec<i32> = (0..32).collect(); // two rows
            let hw = [0.0, 0.0, 127.0, 127.0, 0.0];
            let a = fwd.cls_logits(&meta, &adapter(1.0), &tokens, hw, 7).unwrap();
            let b = fwd.cls_logits(&meta, &adapter(1.0), &tokens, hw, 7).unwrap();
            let c = fwd.cls_logits(&meta, &adapter(2.0), &tokens, hw, 7).unwrap();
            assert_eq!(a.len(), 2);
            assert_eq!(a[0].len(), 3);
            assert!(a[0].iter().all(|v| v.is_finite() && v.abs() <= 1.0));
            assert_eq!(a, b, "same inputs must reproduce");
            assert_ne!(a, c, "a refit adapter must change the logits");
        }

        #[test]
        fn specialize_records_fills_and_keeps_logits_bit_identical() {
            let be = DigitalRef::default();
            let meta = ParamStore::default();
            let hw = [0.0, 0.0, 127.0, 127.0, 0.0];
            let plain = be.forward(&manifest(), "base/fwd_cls").unwrap();
            let mut spec = be.forward(&manifest(), "base/fwd_cls").unwrap();
            assert!(spec.specialized_fills().is_empty());
            // graph batch is 4; 8 exceeds it and is skipped, not an error
            spec.specialize(&[1, 2, 4, 8]).unwrap();
            assert_eq!(spec.specialized_fills(), vec![1, 2, 4]);
            for fill in 1..=4usize {
                let tokens: Vec<i32> = (0..(fill * 16) as i32).collect();
                let a = plain.cls_logits(&meta, &adapter(1.0), &tokens, hw, 7).unwrap();
                let b = spec.cls_logits(&meta, &adapter(1.0), &tokens, hw, 7).unwrap();
                assert_eq!(a, b, "fill {fill} must be bit-identical after specialization");
            }
            assert!(spec.specialize(&[0]).is_err(), "zero fill is a caller bug");
        }

        #[test]
        fn adapt_sched_scales_integration_time() {
            let be = DigitalRef::default().slowdown(3.0);
            let cfg = be.adapt_sched(SchedConfig::for_layer(128, 128, 8));
            assert_eq!(cfg.t_int_ns, 256.0 * 3.0);
            // and the cost model prices the slower substrate
            let base = CostModel::from_layer(&SchedConfig::for_layer(128, 128, 8).seq(320), 4);
            let slow = be.cost_model(&SchedConfig::for_layer(128, 128, 8).seq(320), 4);
            for f in 1..=4 {
                assert!(slow.batch_ns(f) > base.batch_ns(f), "fill {f}");
            }
        }

        #[test]
        fn unknown_graph_is_an_error() {
            let be = DigitalRef::default();
            assert!(be.forward(&manifest(), "nope").is_err());
        }
    }
}
