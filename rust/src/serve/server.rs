//! The serving worker: owns the PJRT engine (not Send) on its own
//! thread, drains the dynamic batcher, and answers requests through the
//! compiled forward graph with the task's LoRA adapter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::eval::drift_eval::cls_logits;
use crate::model::params::ParamStore;
use crate::util::stats;

use super::batcher::Batcher;
use super::registry::SharedRegistry;
use super::router::{Request, Router};

#[derive(Debug)]
pub struct Response {
    pub id: u64,
    pub task: String,
    /// Per-example logits row from the task head.
    pub logits: Vec<f32>,
    pub latency: Duration,
    pub batch_size: usize,
    pub adapter_version: u64,
}

pub enum Msg {
    Req(Request),
    Shutdown,
}

#[derive(Default)]
pub struct Metrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub adapter_swaps: AtomicU64,
    pub errors: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
    batch_sizes: Mutex<Vec<f64>>,
}

impl Metrics {
    fn record(&self, n: usize, latency: Duration) {
        self.served.fetch_add(n as u64, Ordering::Relaxed);
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.latencies_us.lock().unwrap().push(latency.as_micros() as f64);
        self.batch_sizes.lock().unwrap().push(n as f64);
    }

    pub fn summary(&self) -> String {
        let lat = self.latencies_us.lock().unwrap();
        let bs = self.batch_sizes.lock().unwrap();
        format!(
            "served={} batches={} swaps={} errors={} batch_mean={:.1} lat_p50={:.1}ms lat_p95={:.1}ms",
            self.served.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.adapter_swaps.load(Ordering::Relaxed),
            self.errors.load(Ordering::Relaxed),
            stats::mean(&bs),
            stats::percentile(&lat, 50.0) / 1e3,
            stats::percentile(&lat, 95.0) / 1e3,
        )
    }

    pub fn p50_latency_ms(&self) -> f64 {
        stats::percentile(&self.latencies_us.lock().unwrap(), 50.0) / 1e3
    }
}

#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Serving variant (its fwd_cls graph is the execution engine).
    pub variant: String,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Inference hardware vector (quantizers active, no in-graph noise).
    pub hw: [f32; 5],
}

impl ServeConfig {
    pub fn new(variant: &str) -> ServeConfig {
        ServeConfig {
            variant: variant.to_string(),
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            hw: [0.0, 0.0, 127.0, 127.0, 0.0],
        }
    }
}

pub struct Server {
    pub router: Router,
    pub metrics: Arc<Metrics>,
    pub registry: SharedRegistry,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
}

impl Server {
    /// Start the worker with a base (meta) model — conceptually the
    /// weights programmed once into the AIMC tiles — and a registry of
    /// task adapters.
    pub fn start(cfg: ServeConfig, meta: ParamStore, registry: SharedRegistry) -> Result<Server> {
        let (tx, rx) = channel::<Msg>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let reg2 = registry.clone();
        let cfg2 = cfg.clone();

        // resolve the sequence length up front for router validation
        let manifest = crate::config::manifest::Manifest::load(
            crate::config::manifest::default_artifacts_dir(),
        )?;
        let seq = manifest.variant(&cfg.variant)?.seq;
        let tasks = registry.tasks();

        let worker = std::thread::Builder::new()
            .name("ahwa-serve-worker".into())
            .spawn(move || worker_loop(cfg2, meta, reg2, rx, m2))?;

        Ok(Server {
            router: Router::new(tx, seq, tasks),
            metrics,
            registry,
            worker: Some(worker),
        })
    }

    /// Graceful shutdown: drain queues, join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.router.shutdown();
        if let Some(w) = self.worker.take() {
            w.join().expect("worker panicked")?;
        }
        Ok(())
    }
}

fn worker_loop(
    cfg: ServeConfig,
    meta: ParamStore,
    registry: SharedRegistry,
    rx: Receiver<Msg>,
    metrics: Arc<Metrics>,
) -> Result<()> {
    // PJRT handles are not Send: the engine is created *here*.
    let engine = crate::runtime::Engine::from_artifacts()?;
    let graph = engine.load(&format!("{}/fwd_cls", cfg.variant))?;
    let seq = crate::eval::drift_eval::fwd_batch_shape(&graph).1;

    let mut batcher: Batcher<Request> = Batcher::new(cfg.max_batch, cfg.max_wait);
    let mut last_task: Option<String> = None;
    let mut open = true;

    while open || batcher.pending() > 0 {
        // admit work (bounded wait so deadlines fire)
        match rx.recv_timeout(Duration::from_micros(500)) {
            Ok(Msg::Req(r)) => batcher.push(&r.task.clone(), r),
            Ok(Msg::Shutdown) => open = false,
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => open = false,
        }

        let now = Instant::now();
        let ready = if open {
            batcher.pop_ready(now)
        } else {
            // drain mode: everything goes
            batcher.pop_ready(now + cfg.max_wait + Duration::from_millis(1))
        };
        let Some((task, reqs)) = ready else { continue };

        let t0 = Instant::now();
        let adapter = match registry.get(&task) {
            Ok(a) => a,
            Err(_) => {
                metrics.errors.fetch_add(reqs.len() as u64, Ordering::Relaxed);
                continue;
            }
        };
        if last_task.as_deref() != Some(task.as_str()) {
            metrics.adapter_swaps.fetch_add(1, Ordering::Relaxed);
            last_task = Some(task.clone());
        }
        let version = registry.version(&task).unwrap_or(0);

        let mut tokens = Vec::with_capacity(reqs.len() * seq);
        for r in &reqs {
            tokens.extend_from_slice(&r.tokens);
        }
        match cls_logits(&graph, &meta, &adapter, &tokens, cfg.hw, t0.elapsed().as_nanos() as u64) {
            Ok(rows) => {
                let latency = t0.elapsed();
                metrics.record(reqs.len(), latency);
                let bsz = reqs.len();
                for (r, row) in reqs.into_iter().zip(rows) {
                    let _ = r.resp.send(Response {
                        id: r.id,
                        task: task.clone(),
                        logits: row,
                        latency,
                        batch_size: bsz,
                        adapter_version: version,
                    });
                }
            }
            Err(e) => {
                eprintln!("[serve] batch failed: {e:#}");
                metrics.errors.fetch_add(reqs.len() as u64, Ordering::Relaxed);
            }
        }
    }
    Ok(())
}

/// Convenience used by the serving experiments: submit many requests
/// from client threads, wait for all responses.
pub fn submit_wave(
    router: &Router,
    jobs: &[(String, Vec<i32>)],
) -> Result<Vec<Response>> {
    let mut rxs = Vec::with_capacity(jobs.len());
    for (task, toks) in jobs {
        let (_, rx) = router.submit(task, toks.clone())?;
        rxs.push(rx);
    }
    let mut out = Vec::with_capacity(rxs.len());
    for rx in rxs {
        out.push(rx.recv().map_err(|_| anyhow::anyhow!("response channel closed"))?);
    }
    Ok(out)
}
