//! Deprecated shim — the worker loop lives in `serve::pool`, the public
//! surface in [`super::api`].
//!
//! What changed and why:
//!
//! * `Server::start(cfg, …)` → [`api::ServerBuilder`] (worker count,
//!   queue depth and batching knobs in one place);
//! * the raw `Msg` channel protocol is private to the pool — clients
//!   hold a typed [`api::Client`];
//! * a failed or unroutable batch now answers every request with a
//!   typed [`api::ServeError`] instead of silently dropping it (the old
//!   worker leaked the whole batch and left `submit_wave` blocked on
//!   `rx.recv()` forever).

// NOTE: no module-wide `allow(deprecated)` — only the two items that
// must *reference* the deprecated `ServeConfig` carry a targeted
// `#[allow(deprecated)]`, so the shim compiles clean under
// `-D warnings` while every external use still warns.

use std::time::Duration;

use crate::model::params::ParamStore;

use super::api;
use super::registry::SharedRegistry;

pub use super::api::{submit_wave, Metrics, MetricsSnapshot, Response, ServeError, Server};

/// Deprecated: the knobs live on [`api::ServerBuilder`].
#[deprecated(since = "0.2.0", note = "use serve::api::ServerBuilder")]
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Serving variant (its fwd_cls graph is the execution engine).
    pub variant: String,
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Inference hardware vector (quantizers active, no in-graph noise).
    pub hw: [f32; 5],
}

#[allow(deprecated)] // shim impl of the deprecated config type itself
impl ServeConfig {
    pub fn new(variant: &str) -> ServeConfig {
        ServeConfig {
            variant: variant.to_string(),
            max_batch: 8,
            max_wait: Duration::from_millis(5),
            hw: [0.0, 0.0, 127.0, 127.0, 0.0],
        }
    }

    /// Forward to the new builder.
    pub fn into_builder(self) -> api::ServerBuilder {
        api::Server::builder(&self.variant)
            .max_batch(self.max_batch)
            .max_wait(self.max_wait)
            .hw(self.hw)
    }
}

/// Deprecated: single-worker pool via the old entry point.
#[deprecated(since = "0.2.0", note = "use serve::api::ServerBuilder::build")]
#[allow(deprecated)] // the signature must keep naming the deprecated ServeConfig
pub fn start(
    cfg: ServeConfig,
    meta: ParamStore,
    registry: SharedRegistry,
) -> api::ServeResult<api::Server> {
    cfg.into_builder().build(meta, registry)
}
