//! Drift-aware adapter refresh: keep a long-lived serving pool accurate
//! as the analog substrate drifts under it.
//!
//! The paper's deployment premise is that the analog meta-weights stay
//! fixed while small LoRA adapters absorb hardware *and* task
//! adaptation. But PCM conductances relax over time —
//! `g(t) = g_prog·((t+t₀)/t₀)^(−ν)` ([`crate::pcm::drift`]) — so an
//! adapter fitted at deployment time slowly loses accuracy against the
//! drifted substrate. Global drift compensation restores the *mean*
//! conductance scale; what remains is the device-to-device dispersion,
//! and the paper's answer to it is digital-side re-adaptation: re-fit
//! the task's LoRA against the drifted weights and hot-swap it, never
//! touching the arrays.
//!
//! This module automates that loop for the serving pool:
//!
//! * [`DecayModel`] predicts accuracy-relevant decay at a drift age —
//!   either closed-form from the PCM statistics
//!   ([`crate::pcm::compensation::residual_decay`]) or by Monte-Carlo
//!   reads through a programmed
//!   [`AnalogDeployment`](crate::eval::drift_eval::AnalogDeployment)
//!   (drift → read noise → GDC, the full device model).
//! * [`RefreshPolicy`] tracks each task's deployment age on the pool's
//!   [`Clock`] (virtual in tests — the whole trigger path is testable
//!   with zero real sleeps) and reports which tasks have crossed their
//!   per-task tolerance, plus the *modeled* instant a task will cross it
//!   ([`RefreshPolicy::trigger_at`]). Its per-task state lives behind a
//!   cloneable [`RefreshHandle`] rather than runner-private storage, so
//!   the pool's batch schedulers ([`super::sched::BatchScheduler`]) read
//!   the same trigger times / refit-in-flight flags the runner writes
//!   and can shrink fills ahead of a hot-swap (refresh-aware
//!   scheduling — see [`super::sched`]'s coupling docs).
//! * [`Refitter`] re-fits one adapter against the drifted meta-weights.
//!   [`TrainerRefitter`] drives [`Trainer`] with a bounded step budget;
//!   [`struct@FnRefitter`] wraps a closure for tests and cheap demos.
//! * [`RefreshRunner`] executes the cycle: predict → refit → hot-swap
//!   through [`SharedRegistry::deploy_if_version`] (versioned, monotone,
//!   torn-read-free: in-flight batches finish on the `Arc` snapshot they
//!   grabbed, and a refit that lost a race against a concurrent manual
//!   redeploy is discarded instead of clobbering the newer adapter).
//!
//! Production wiring: [`ServerBuilder::refresh`] spawns a background
//! worker that calls [`RefreshRunner::tick`] every
//! [`RefreshConfig::check_every`]; [`Server::refresh_tick_now`] forces
//! an evaluation. Refresh activity lands in the pool's
//! [`Metrics`]/`MetricsSnapshot` (`refreshes`, `refresh_steps`,
//! `refresh_errors`) and in the per-event [`RefreshEvent`] log.
//!
//! [`ServerBuilder::refresh`]: super::api::ServerBuilder::refresh
//! [`Server::refresh_tick_now`]: super::api::Server::refresh_tick_now

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::Ordering;
use std::sync::{Arc, RwLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::manifest::Manifest;
use crate::config::run::TrainConfig;
use crate::eval::drift_eval::AnalogDeployment;
use crate::model::params::ParamStore;
use crate::pcm::{compensation, PcmModel};
use crate::train::{OwnedBatch, Trainer};
use crate::util::rng::Pcg64;

use super::api::Metrics;
use super::registry::SharedRegistry;
use super::sched::Clock;

// ---------------------------------------------------------------------------
// Decay prediction
// ---------------------------------------------------------------------------

/// Longest drift age the sampled trigger search considers (10 years —
/// the far end of the paper's drift grid).
const MAX_TRIGGER_AGE_SECS: f64 = 315_360_000.0;

/// Crossing instants further out than this (~31M years of pool clock)
/// are treated as "never" — `Duration::from_secs_f64` would panic on
/// the astronomical ages a near-1 tolerance produces.
const MAX_DUE_SECS: f64 = 1e15;

/// Predicts accuracy-relevant decay as a function of drift age.
#[derive(Clone)]
pub enum DecayModel {
    /// Closed-form post-GDC residual model from the PCM drift
    /// statistics, evaluated at a representative relative conductance
    /// (see [`compensation::residual_decay`]). Zero at age 0; exactly
    /// invertible, so modeled trigger times are exact.
    Analytic {
        model: PcmModel,
        /// Representative relative conductance (0‥1) for the dispersion.
        g_rel: f32,
    },
    /// Monte-Carlo relative weight deviation read through a programmed
    /// deployment (drift → read noise → GDC). Carries a
    /// programming-noise floor at age 0 — tolerances must sit above
    /// [`DecayModel::predicted_decay`]`(0.0)` or the policy re-triggers
    /// forever.
    Sampled {
        deployment: Arc<AnalogDeployment>,
        trials: usize,
        seed: u64,
    },
}

impl DecayModel {
    /// Analytic model at the mid-range conductance (`g_rel` = 0.5).
    pub fn analytic(model: PcmModel) -> DecayModel {
        DecayModel::Analytic { model, g_rel: 0.5 }
    }

    pub fn sampled(deployment: Arc<AnalogDeployment>, trials: usize, seed: u64) -> DecayModel {
        DecayModel::Sampled {
            deployment,
            trials: trials.max(1),
            seed,
        }
    }

    /// Predicted decay fraction at drift age `age_seconds`.
    pub fn predicted_decay(&self, age_seconds: f64) -> f64 {
        match self {
            DecayModel::Analytic { model, g_rel } => {
                compensation::residual_decay(model, *g_rel, age_seconds)
            }
            DecayModel::Sampled {
                deployment,
                trials,
                seed,
            } => deployment.relative_deviation(age_seconds, *trials, true, *seed),
        }
    }

    /// Modeled drift age (seconds) at which decay first crosses
    /// `tolerance`; `f64::INFINITY` if it never does. Closed-form for
    /// the analytic model; bisection on the (statistically monotone)
    /// sampled curve otherwise.
    pub fn trigger_age(&self, tolerance: f64) -> f64 {
        match self {
            DecayModel::Analytic { model, g_rel } => {
                compensation::residual_decay_inverse(model, *g_rel, tolerance)
            }
            DecayModel::Sampled { .. } => {
                if self.predicted_decay(MAX_TRIGGER_AGE_SECS) < tolerance {
                    return f64::INFINITY;
                }
                let (mut lo, mut hi) = (0.0f64, MAX_TRIGGER_AGE_SECS);
                for _ in 0..32 {
                    let mid = 0.5 * (lo + hi);
                    if self.predicted_decay(mid) < tolerance {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                0.5 * (lo + hi)
            }
        }
    }
}

impl fmt::Debug for DecayModel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DecayModel::Analytic { g_rel, .. } => {
                f.debug_struct("Analytic").field("g_rel", g_rel).finish_non_exhaustive()
            }
            DecayModel::Sampled { trials, seed, .. } => f
                .debug_struct("Sampled")
                .field("trials", trials)
                .field("seed", seed)
                .finish_non_exhaustive(),
        }
    }
}

// ---------------------------------------------------------------------------
// Refitters
// ---------------------------------------------------------------------------

/// Outcome of one adapter re-fit.
#[derive(Clone, Debug)]
pub struct Refit {
    /// The refreshed adapter (LoRA + head) to hot-swap in.
    pub params: ParamStore,
    /// Optimizer steps actually spent.
    pub steps: usize,
}

/// Thread-safe EWMA of observed refit wall durations — the "measured
/// step budget" channel [`Refitter::observed_budget`] publishes and the
/// pool coordinator ([`super::coord`]) turns into an adaptive hold
/// bound. Stored as nanoseconds; zero means "nothing observed yet".
#[derive(Debug, Default)]
pub struct BudgetMeter {
    ewma_ns: std::sync::atomic::AtomicU64,
}

impl BudgetMeter {
    pub fn record(&self, d: Duration) {
        // a zero-length refit still counts as an observation (1 ns), so
        // `observed()` can distinguish "instant" from "never measured"
        let x = (d.as_nanos() as u64).max(1);
        let prev = self.ewma_ns.load(Ordering::Relaxed);
        let next = ewma_update((prev != 0).then_some(prev as f64), x as f64).round() as u64;
        self.ewma_ns.store(next.max(1), Ordering::Relaxed);
    }

    /// Smoothed refit duration; `None` until the first observation.
    pub fn observed(&self) -> Option<Duration> {
        match self.ewma_ns.load(Ordering::Relaxed) {
            0 => None,
            ns => Some(Duration::from_nanos(ns)),
        }
    }
}

/// Re-fits one task's adapter against the drifted meta-weights.
pub trait Refitter: Send + Sync {
    /// `current` is the live adapter snapshot the refresh is replacing;
    /// `drifted_meta` the substrate as the drift model reads it today;
    /// `step_budget` the hard cap on optimizer steps.
    fn refit(
        &self,
        task: &str,
        current: &ParamStore,
        drifted_meta: &ParamStore,
        step_budget: usize,
    ) -> Result<Refit>;

    /// Measured wall time one refit realistically needs (smoothed over
    /// past calls, across all tasks this refitter serves).
    /// [`TrainerRefitter`] and [`struct@FnRefitter`] self-time every
    /// successful `refit` call through a [`BudgetMeter`]. The refresh
    /// runner prefers its own per-task pool-clock bracket and falls
    /// back to this refitter-wide estimate only when it has no clock —
    /// either way the pool coordinator derives the adaptive hold bound
    /// from the result (Trainer refits take seconds, closure refits
    /// microseconds — a fixed hold duration fits neither).
    fn observed_budget(&self) -> Option<Duration> {
        None
    }
}

/// Closure refitter for tests, benches, and cheap demos. Construct with
/// the function-call form `FnRefitter(closure)` (a constructor function
/// keeps the historical tuple-struct syntax while the struct itself
/// carries a self-timing [`BudgetMeter`]).
pub struct FnRefitter<F> {
    f: F,
    meter: BudgetMeter,
}

/// Constructor matching the original `FnRefitter(closure)` tuple-struct
/// syntax used throughout the tests, benches, and examples.
#[allow(non_snake_case)]
pub fn FnRefitter<F>(f: F) -> FnRefitter<F>
where
    F: Fn(&str, &ParamStore, &ParamStore, usize) -> Result<Refit> + Send + Sync,
{
    FnRefitter {
        f,
        meter: BudgetMeter::default(),
    }
}

impl<F> Refitter for FnRefitter<F>
where
    F: Fn(&str, &ParamStore, &ParamStore, usize) -> Result<Refit> + Send + Sync,
{
    fn refit(
        &self,
        task: &str,
        current: &ParamStore,
        drifted_meta: &ParamStore,
        step_budget: usize,
    ) -> Result<Refit> {
        let t0 = Instant::now();
        let out = (self.f)(task, current, drifted_meta, step_budget);
        // failed refits don't teach the budget: a fast error must not
        // drag the adaptive hold bound toward zero
        if out.is_ok() {
            self.meter.record(t0.elapsed());
        }
        out
    }

    fn observed_budget(&self) -> Option<Duration> {
        self.meter.observed()
    }
}

/// Production refitter: continue training the task's LoRA against the
/// drifted meta-weights with [`Trainer`], capped at the step budget.
///
/// PJRT handles are not `Send`, so the engine is built fresh inside the
/// refresh worker's call — refreshes happen on the drift timescale
/// (hours to months), so the bring-up cost amortises to nothing.
pub struct TrainerRefitter {
    manifest: Manifest,
    step_graph: String,
    cfg: TrainConfig,
    /// Produces one training batch for `(task, step)`.
    #[allow(clippy::type_complexity)]
    batches: Arc<dyn Fn(&str, usize, &mut Pcg64) -> OwnedBatch + Send + Sync>,
    /// Self-timed refit durations (engine bring-up + bounded training),
    /// published through [`Refitter::observed_budget`].
    meter: BudgetMeter,
}

impl TrainerRefitter {
    #[allow(clippy::type_complexity)]
    pub fn new(
        manifest: Manifest,
        step_graph: &str,
        cfg: TrainConfig,
        batches: Arc<dyn Fn(&str, usize, &mut Pcg64) -> OwnedBatch + Send + Sync>,
    ) -> TrainerRefitter {
        TrainerRefitter {
            manifest,
            step_graph: step_graph.to_string(),
            cfg,
            batches,
            meter: BudgetMeter::default(),
        }
    }
}

impl Refitter for TrainerRefitter {
    fn refit(
        &self,
        task: &str,
        current: &ParamStore,
        drifted_meta: &ParamStore,
        step_budget: usize,
    ) -> Result<Refit> {
        let t0 = Instant::now();
        let engine = crate::runtime::Engine::new(self.manifest.clone())?;
        let mut trainer = Trainer::new(
            &engine,
            &self.step_graph,
            drifted_meta.clone(),
            current.clone(),
            self.cfg.clone(),
        )?;
        let task_name = task.to_string();
        let batches = self.batches.clone();
        trainer.run_steps(step_budget, move |step, rng| batches(&task_name, step, rng))?;
        self.meter.record(t0.elapsed());
        Ok(Refit {
            params: trainer.train.clone(),
            steps: trainer.step_idx,
        })
    }

    fn observed_budget(&self) -> Option<Duration> {
        self.meter.observed()
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Refresh policy knobs, passed to `ServerBuilder::refresh`.
#[derive(Clone)]
pub struct RefreshConfig {
    /// Default predicted-decay tolerance (fraction; refresh fires when
    /// the prediction crosses it).
    pub tolerance: f64,
    /// Per-task tolerance overrides.
    per_task: BTreeMap<String, f64>,
    /// Background evaluation cadence (wall clock; decisions themselves
    /// read the pool clock).
    pub check_every: Duration,
    /// Modeled drift seconds per clock second (1.0 = real time; demos
    /// and benches accelerate).
    pub time_scale: f64,
    /// Hard cap on optimizer steps per refit.
    pub step_budget: usize,
    pub decay: DecayModel,
    /// Per-task decay-model overrides. Heterogeneous pools
    /// ([`ServerBuilder::backend`](super::api::ServerBuilder::backend))
    /// install each routed task's OWN backend physics here — a task on
    /// a drift-free digital backend never triggers a refit while its
    /// PCM-routed neighbours keep their drift clocks.
    per_task_decay: BTreeMap<String, DecayModel>,
    pub refitter: Arc<dyn Refitter>,
}

impl RefreshConfig {
    pub fn new(decay: DecayModel, refitter: Arc<dyn Refitter>) -> RefreshConfig {
        RefreshConfig {
            tolerance: 0.05,
            per_task: BTreeMap::new(),
            check_every: Duration::from_secs(1),
            time_scale: 1.0,
            step_budget: 50,
            decay,
            per_task_decay: BTreeMap::new(),
            refitter,
        }
    }

    pub fn tolerance(mut self, tol: f64) -> Self {
        self.tolerance = tol;
        self
    }

    /// Override the tolerance for one task.
    pub fn task_tolerance(mut self, task: &str, tol: f64) -> Self {
        self.per_task.insert(task.to_string(), tol);
        self
    }

    /// Override the decay model for one task (its substrate's physics;
    /// see the `per_task_decay` field docs).
    pub fn task_decay(mut self, task: &str, decay: DecayModel) -> Self {
        self.per_task_decay.insert(task.to_string(), decay);
        self
    }

    pub fn check_every(mut self, d: Duration) -> Self {
        self.check_every = d;
        self
    }

    pub fn time_scale(mut self, scale: f64) -> Self {
        self.time_scale = scale.max(f64::MIN_POSITIVE);
        self
    }

    pub fn step_budget(mut self, steps: usize) -> Self {
        self.step_budget = steps.max(1);
        self
    }

    pub fn tolerance_for(&self, task: &str) -> f64 {
        self.per_task.get(task).copied().unwrap_or(self.tolerance)
    }

    /// The per-task tolerance override map (read by the HAL router,
    /// which weighs tolerance-maintenance cost per backend).
    pub fn task_tolerances(&self) -> &BTreeMap<String, f64> {
        &self.per_task
    }

    /// The decay model governing `task`: its override when one is
    /// installed, the pool default otherwise.
    pub fn decay_for(&self, task: &str) -> &DecayModel {
        self.per_task_decay.get(task).unwrap_or(&self.decay)
    }

    /// Reject tolerances at or below the decay model's age-0 floor.
    ///
    /// A [`DecayModel::Sampled`] floor is the programming noise, which
    /// never decays away — a tolerance under it would make every tick
    /// refit (with [`TrainerRefitter`]: a fresh engine build plus
    /// training steps every `check_every`, forever). The builder calls
    /// this before spawning the refresh worker.
    pub fn validate(&self) -> std::result::Result<(), String> {
        let check = |task: &str, tol: f64, decay: &DecayModel| {
            let floor = decay.predicted_decay(0.0);
            if tol <= floor {
                return Err(format!(
                    "refresh tolerance {tol} for '{task}' is at or below the decay \
                     model's age-0 floor {floor}: every tick would refit forever"
                ));
            }
            Ok(())
        };
        check("default", self.tolerance, &self.decay)?;
        // every task with EITHER override is checked against its
        // effective (tolerance, decay) pair
        let tasks: std::collections::BTreeSet<&str> = self
            .per_task
            .keys()
            .chain(self.per_task_decay.keys())
            .map(String::as_str)
            .collect();
        for task in tasks {
            check(task, self.tolerance_for(task), self.decay_for(task))?;
        }
        Ok(())
    }
}

// manual Debug: the refitter is an opaque trait object
impl fmt::Debug for RefreshConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RefreshConfig")
            .field("tolerance", &self.tolerance)
            .field("per_task", &self.per_task)
            .field("check_every", &self.check_every)
            .field("time_scale", &self.time_scale)
            .field("step_budget", &self.step_budget)
            .field("decay", &self.decay)
            .field("per_task_decay", &self.per_task_decay)
            .finish_non_exhaustive()
    }
}

// ---------------------------------------------------------------------------
// Policy
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct TrackedTask {
    deployed_at: Instant,
    version: u64,
    /// Modeled tolerance-crossing instant, cached at track time so the
    /// per-tick due check is O(1) — for a Sampled model an on-demand
    /// prediction would be a full Monte-Carlo read of every programmed
    /// tensor, every tick. `None` = never decays past tolerance.
    due_at: Option<Instant>,
    /// A refit for this task is currently in flight.
    refitting: bool,
    /// When (and to which version) the last *refresh-driven* hot-swap
    /// landed; the scheduler's post-swap fill extension keys off this.
    swapped_at: Option<(Instant, u64)>,
    /// Coordinator-assigned re-phased trigger ([`super::coord`]): always
    /// at or before `due_at`, so staggering never sacrifices freshness.
    /// Cleared on every re-track — a stagger computed for one deployment
    /// must never carry over to the next (the drift clock re-anchors).
    staggered_at: Option<Instant>,
    /// Coordinator-derived coupling window for this task (EWMA of the
    /// observed swap gap); `None` = use the fixed `RefreshCoupling`
    /// window. Survives re-tracks: it is a learned task property.
    adaptive_window: Option<Duration>,
    /// Coordinator-derived hold bound (from the refitter's measured
    /// step budget); `None` = fixed `RefreshCoupling` hold.
    adaptive_hold: Option<Duration>,
    /// EWMA of observed registry-swap → first-serve gaps (ns), fed by
    /// the pool workers through [`RefreshHandle::observe_swap_gap`].
    gap_ewma_ns: Option<f64>,
    /// EWMA of measured refit durations (ns), fed by the refresh runner
    /// (its per-task pool-clock bracket; the refitter's self-timed
    /// [`Refitter::observed_budget`] stands in on clockless runners).
    refit_ewma_ns: Option<f64>,
    /// The task's shard is currently deferring it for a pending swap
    /// (the scheduler returned `Decision::Hold`).
    holding: bool,
    /// The adapter is paged out by the capacity tier (`serve::cache`).
    /// The task stays tracked — `deployed_at` keeps anchoring its drift
    /// age, because the SUBSTRATE keeps drifting while the digital
    /// adapter sits in host memory — but it is skipped by the due check
    /// (nothing resident to refit), excluded from staleness accounting
    /// (debt it cannot act on), and ignored by the coordinator's
    /// stagger. A reload at the same version clears the flag and leaves
    /// the anchor untouched: the adapter comes back owing its full
    /// accumulated drift age, not a fresh-looking clock.
    evicted: bool,
    /// The task is mid-migration between backend worker spans
    /// (`serve::hal::RebalanceRunner`): its OLD span's scheduler must
    /// serve out the queue at the next batch boundary (drain mode,
    /// outranking holds), and the worker clears the flag once that
    /// queue is empty. Placement state, not deployment state — it
    /// survives re-tracks.
    migrating: bool,
}

/// Cloneable, thread-safe view of the per-task refresh lifecycle.
///
/// The [`RefreshRunner`] (via its [`RefreshPolicy`]) is the writer; the
/// pool's batch schedulers and workers are readers. This is what makes
/// the scheduler refresh-aware: instead of runner-private state, the
/// modeled `trigger_at`, the refit-in-flight flag, and the last swap
/// instant are published here, on the same pool [`Clock`] both
/// subsystems run on — so the coupling is deterministically testable on
/// a [`VirtualClock`](super::sched::VirtualClock) end to end.
#[derive(Clone, Default)]
pub struct RefreshHandle {
    tracked: Arc<RwLock<BTreeMap<String, TrackedTask>>>,
}

impl RefreshHandle {
    pub fn new() -> RefreshHandle {
        RefreshHandle::default()
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<String, TrackedTask>> {
        self.tracked.read().unwrap()
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, BTreeMap<String, TrackedTask>> {
        self.tracked.write().unwrap()
    }

    /// Tasks currently on the drift watch.
    pub fn tasks(&self) -> Vec<String> {
        self.read().keys().cloned().collect()
    }

    /// Registry version the refresh policy last saw for `task`.
    pub fn tracked_version(&self, task: &str) -> Option<u64> {
        self.read().get(task).map(|t| t.version)
    }

    /// Modeled pool-clock instant at which `task` crosses its
    /// tolerance (`None` when untracked or never crossing).
    pub fn trigger_at(&self, task: &str) -> Option<Instant> {
        self.read().get(task)?.due_at
    }

    /// `true` while a refit for `task` is in flight.
    pub fn refit_in_flight(&self, task: &str) -> bool {
        self.read().get(task).map(|t| t.refitting).unwrap_or(false)
    }

    /// Instant and installed version of the last refresh-driven
    /// hot-swap for `task`.
    pub fn last_swap(&self, task: &str) -> Option<(Instant, u64)> {
        self.read().get(task)?.swapped_at
    }

    /// One consistent read of a task's whole refresh state — a single
    /// lock acquisition, so a scheduling decision can never pair a
    /// refit flag from one instant with a trigger from another (and
    /// the worker's per-pick cost stays at one read per task).
    pub fn view(&self, task: &str) -> Option<RefreshView> {
        self.read().get(task).map(|t| RefreshView {
            version: t.version,
            trigger_at: t.due_at,
            refit_in_flight: t.refitting,
            last_swap: t.swapped_at,
            staggered_at: t.staggered_at,
            window: t.adaptive_window,
            hold: t.adaptive_hold,
            migrating: t.migrating,
        })
    }

    /// Would a batch serving `task` at adapter `version` be stale at
    /// `now`? True when a newer version is already tracked (the swap
    /// landed but this batch grabbed the older snapshot), or when the
    /// tracked version's modeled decay has crossed tolerance (the swap
    /// is overdue). Used by the pool's `stale_batch_requests` metric.
    pub fn is_stale(&self, task: &str, version: u64, now: Instant) -> bool {
        match self.read().get(task) {
            // an evicted task cannot act on staleness (nothing resident
            // to refit): it accumulates drift age, never stale *debt*
            Some(t) if t.evicted => false,
            Some(t) if version < t.version => true,
            Some(t) if version == t.version => {
                t.due_at.map(|d| now >= d).unwrap_or(false)
            }
            _ => false,
        }
    }

    /// Flag `task` as paged out by / back into the capacity tier
    /// (`serve::cache`). Eviction clears any coordinator stagger (the
    /// slot should go to a task that can actually use it) but keeps the
    /// drift anchor: a reload at the same version resumes the watch
    /// with the full accumulated drift age. No-op for untracked tasks —
    /// a task evicted before it was ever tracked simply joins the watch
    /// (conservatively fresh) when it is reloaded.
    pub fn set_evicted(&self, task: &str, evicted: bool) {
        if let Some(t) = self.write().get_mut(task) {
            t.evicted = evicted;
            if evicted {
                t.staggered_at = None;
            }
        }
    }

    /// `true` while the capacity tier has `task` paged out.
    pub fn is_evicted(&self, task: &str) -> bool {
        self.read().get(task).map(|t| t.evicted).unwrap_or(false)
    }

    /// Flag `task` as migrating between backend worker spans (set by
    /// the rebalance runner before the routing-table flip, cleared by
    /// the old span's worker once it has drained the task's queue —
    /// see [`RefreshView::migrating`]). No-op for untracked tasks.
    pub fn set_migrating(&self, task: &str, migrating: bool) {
        if let Some(t) = self.write().get_mut(task) {
            t.migrating = migrating;
        }
    }

    /// `true` while `task` is mid-migration between worker spans.
    pub fn is_migrating(&self, task: &str) -> bool {
        self.read().get(task).map(|t| t.migrating).unwrap_or(false)
    }

    /// The pool-clock instant `task` was (re-)deployed at — its drift
    /// anchor. Migration conformance pins that this survives a span
    /// move bit-identically.
    pub fn deployed_at(&self, task: &str) -> Option<Instant> {
        self.read().get(task).map(|t| t.deployed_at)
    }

    pub(crate) fn begin_refit(&self, task: &str) {
        if let Some(t) = self.write().get_mut(task) {
            t.refitting = true;
        }
    }

    pub(crate) fn end_refit(&self, task: &str) {
        if let Some(t) = self.write().get_mut(task) {
            t.refitting = false;
        }
    }

    // -- coordinator surface (see `super::coord`) ------------------------

    /// Coordinator-staggered trigger for `task` (`None` = not re-phased;
    /// the modeled [`Self::trigger_at`] applies unchanged).
    pub fn staggered_at(&self, task: &str) -> Option<Instant> {
        self.read().get(task)?.staggered_at
    }

    /// Coordinator-adapted coupling window for `task`, when one has
    /// been derived from observed swap gaps.
    pub fn adaptive_window(&self, task: &str) -> Option<Duration> {
        self.read().get(task)?.adaptive_window
    }

    /// Coordinator-adapted hold bound for `task`, when one has been
    /// derived from the refitter's measured step budget.
    pub fn adaptive_hold(&self, task: &str) -> Option<Duration> {
        self.read().get(task)?.adaptive_hold
    }

    /// Feed one observed registry-swap → first-serve gap into the
    /// task's EWMA (the pool workers call this right where they record
    /// `Metrics::swap_gap_ns`). The coordinator turns the EWMA into the
    /// task's adaptive coupling window on its next rebalance.
    pub fn observe_swap_gap(&self, task: &str, gap: Duration) {
        if let Some(t) = self.write().get_mut(task) {
            t.gap_ewma_ns = Some(ewma_update(t.gap_ewma_ns, gap.as_nanos() as f64));
        }
    }

    /// Smoothed observed swap gap for `task` (`None` until the first
    /// observation).
    pub fn swap_gap_ewma(&self, task: &str) -> Option<Duration> {
        self.read()
            .get(task)?
            .gap_ewma_ns
            .map(|ns| Duration::from_nanos(ns.max(0.0).round() as u64))
    }

    /// Feed one measured refit duration into the task's EWMA (the
    /// refresh runner calls this with its per-task pool-clock bracket,
    /// or with [`Refitter::observed_budget`] when it has no clock). The
    /// coordinator turns the EWMA into the task's adaptive hold bound.
    pub fn observe_refit_duration(&self, task: &str, dur: Duration) {
        if let Some(t) = self.write().get_mut(task) {
            t.refit_ewma_ns = Some(ewma_update(t.refit_ewma_ns, dur.as_nanos() as f64));
        }
    }

    /// Smoothed measured refit duration for `task`.
    pub fn refit_duration_ewma(&self, task: &str) -> Option<Duration> {
        self.read()
            .get(task)?
            .refit_ewma_ns
            .map(|ns| Duration::from_nanos(ns.max(0.0).round() as u64))
    }

    /// Mark `task` as held / released by its shard's scheduler and
    /// return the number of held tasks pool-wide. Callers (the worker
    /// loop, the test harness) flag at most ONE task per shard at a
    /// time and call only on transitions, so the returned count is a
    /// count of stalled *shards* — what the workers feed into
    /// `Metrics::concurrent_holds_peak`, the quantity the
    /// coordinator's stagger exists to bound.
    pub fn set_holding(&self, task: &str, holding: bool) -> usize {
        let mut map = self.write();
        if let Some(t) = map.get_mut(task) {
            t.holding = holding;
        }
        map.values().filter(|t| t.holding).count()
    }

    /// Tasks currently deferred (`Decision::Hold`) across the pool —
    /// one per stalled shard under the callers' one-flag-per-shard
    /// discipline (see [`Self::set_holding`]).
    pub fn holding_count(&self) -> usize {
        self.read().values().filter(|t| t.holding).count()
    }

    /// One consistent snapshot of everything the coordinator needs to
    /// rebalance: `(task, modeled due_at, refitting, gap EWMA, refit
    /// EWMA)` per tracked task, under a single lock read.
    pub(crate) fn coord_entries(&self) -> Vec<CoordEntry> {
        self.read()
            .iter()
            .map(|(task, t)| CoordEntry {
                task: task.clone(),
                due_at: t.due_at,
                staggered_at: t.staggered_at,
                adaptive_window: t.adaptive_window,
                adaptive_hold: t.adaptive_hold,
                refitting: t.refitting,
                gap_ewma_ns: t.gap_ewma_ns,
                refit_ewma_ns: t.refit_ewma_ns,
                evicted: t.evicted,
            })
            .collect()
    }

    /// Apply one rebalance's decisions under a single write lock, so a
    /// scheduler can never observe task A re-phased but task B not.
    pub(crate) fn apply_coord(&self, decisions: &[(String, CoordDecision)]) {
        let mut map = self.write();
        for (task, d) in decisions {
            if let Some(t) = map.get_mut(task) {
                t.staggered_at = d.staggered_at;
                t.adaptive_window = d.window;
                t.adaptive_hold = d.hold;
            }
        }
    }
}

// EWMA step for every observed-duration series in this module (swap
// gaps, refit durations, BudgetMeter) — the one smoothing shared with
// the scheduler's arrival estimator (util::stats::EWMA_ALPHA).
use crate::util::stats::ewma as ewma_update;

/// Coordinator-facing row of [`RefreshHandle::coord_entries`].
#[derive(Clone, Debug)]
pub(crate) struct CoordEntry {
    pub task: String,
    pub due_at: Option<Instant>,
    pub staggered_at: Option<Instant>,
    /// Currently PUBLISHED adaptive bounds (for rebalance change
    /// detection — an unchanged decision set skips the write lock).
    pub adaptive_window: Option<Duration>,
    pub adaptive_hold: Option<Duration>,
    pub refitting: bool,
    pub gap_ewma_ns: Option<f64>,
    pub refit_ewma_ns: Option<f64>,
    /// Paged out by the capacity tier: the coordinator must not spend a
    /// stagger slot (or count a hold span) on a task nothing can refit.
    pub evicted: bool,
}

/// One task's rebalance outcome, written back through
/// [`RefreshHandle::apply_coord`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(crate) struct CoordDecision {
    pub staggered_at: Option<Instant>,
    pub window: Option<Duration>,
    pub hold: Option<Duration>,
}

/// Snapshot of one task's refresh lifecycle, read atomically from the
/// [`RefreshHandle`] (see [`RefreshHandle::view`]).
#[derive(Clone, Copy, Debug)]
pub struct RefreshView {
    /// Registry version the policy is watching.
    pub version: u64,
    /// Modeled tolerance-crossing instant (`None` = never crosses).
    pub trigger_at: Option<Instant>,
    /// A refit is currently in flight for this task.
    pub refit_in_flight: bool,
    /// Instant and version of the last refresh-driven hot-swap.
    pub last_swap: Option<(Instant, u64)>,
    /// Coordinator-staggered trigger, always ≤ `trigger_at` (see
    /// [`super::coord`]); `None` when the pool runs uncoordinated.
    pub staggered_at: Option<Instant>,
    /// Coordinator-adapted coupling window (overrides the fixed
    /// [`RefreshCoupling::window`](super::sched::RefreshCoupling)).
    pub window: Option<Duration>,
    /// Coordinator-adapted hold bound (overrides the fixed
    /// [`RefreshCoupling::hold`](super::sched::RefreshCoupling)).
    pub hold: Option<Duration>,
    /// Mid-migration between backend worker spans: the scheduler must
    /// serve this task's queue out NOW (drain mode), outranking holds,
    /// so the span handoff completes at the next batch boundary.
    pub migrating: bool,
}

impl RefreshView {
    /// The trigger the scheduler (and the refresh runner's due check)
    /// should act on: the staggered instant when the coordinator
    /// re-phased this task, the modeled one otherwise.
    pub fn effective_trigger(&self) -> Option<Instant> {
        self.staggered_at.or(self.trigger_at)
    }
}

/// Tracks per-task deployment age on the pool clock and decides when
/// each task's predicted decay has crossed its tolerance. State lives
/// in a [`RefreshHandle`] so the scheduler coupling reads the same
/// instants the runner writes.
pub struct RefreshPolicy {
    cfg: RefreshConfig,
    tracked: RefreshHandle,
}

impl RefreshPolicy {
    pub fn new(cfg: RefreshConfig) -> RefreshPolicy {
        RefreshPolicy {
            cfg,
            tracked: RefreshHandle::new(),
        }
    }

    pub fn config(&self) -> &RefreshConfig {
        &self.cfg
    }

    /// The shared per-task lifecycle view ([`RefreshHandle`]) — clone
    /// it into anything that needs to observe refresh phases (the
    /// refresh-aware scheduler, the pool workers' stale accounting).
    pub fn handle(&self) -> RefreshHandle {
        self.tracked.clone()
    }

    /// Start (or restart) the drift clock for `task` at `now` —
    /// deployment onto the substrate at registry `version`. The modeled
    /// tolerance-crossing instant is computed here, once per
    /// deployment (for a Sampled model this is the expensive part).
    pub fn track(&mut self, task: &str, now: Instant, version: u64) {
        let age = self.cfg.decay_for(task).trigger_age(self.cfg.tolerance_for(task));
        let scaled = age / self.cfg.time_scale;
        let due_at = (scaled.is_finite() && scaled < MAX_DUE_SECS)
            .then(|| now + Duration::from_secs_f64(scaled));
        // a re-track is a fresh deployment: any in-flight refit flag —
        // and any coordinator stagger computed for the PREVIOUS
        // deployment's trigger — is stale (a surviving stagger would
        // run the new adapter's drift clock against the old anchor).
        // The last swap instant survives (the post-swap fill extension
        // spans the re-anchor the swap itself performs), and so do the
        // learned swap-gap / refit-duration EWMAs, the adaptive
        // window/hold derived from them, and the shard's holding flag:
        // those are task/shard properties, not deployment properties.
        // ONE write lock for the whole read-modify-insert, so a worker
        // racing in through set_holding / observe_* can never have its
        // update resurrected from a stale pre-read snapshot.
        let mut map = self.tracked.write();
        let prev = map.get(task).cloned();
        map.insert(
            task.to_string(),
            TrackedTask {
                deployed_at: now,
                version,
                due_at,
                refitting: false,
                swapped_at: prev.as_ref().and_then(|t| t.swapped_at),
                staggered_at: None,
                adaptive_window: prev.as_ref().and_then(|t| t.adaptive_window),
                adaptive_hold: prev.as_ref().and_then(|t| t.adaptive_hold),
                gap_ewma_ns: prev.as_ref().and_then(|t| t.gap_ewma_ns),
                refit_ewma_ns: prev.as_ref().and_then(|t| t.refit_ewma_ns),
                holding: prev.as_ref().map(|t| t.holding).unwrap_or(false),
                // a (re-)track is a deployment: the adapter is resident
                evicted: false,
                // placement state: a redeploy mid-migration must not
                // stall the old span's drain
                migrating: prev.map(|t| t.migrating).unwrap_or(false),
            },
        );
    }

    pub fn forget(&mut self, task: &str) {
        self.tracked.write().remove(task);
    }

    /// Swap `task`'s drift physics in place — the span-migration carry.
    ///
    /// Unlike [`RefreshPolicy::track`], this does NOT re-anchor
    /// `deployed_at`: a migration moves the adapter between substrates
    /// without reprogramming it, so the drift clock keeps its
    /// accumulated age and only the model mapping that age to decay
    /// changes. The cached tolerance-crossing instant is recomputed
    /// from the SURVIVING anchor under the new physics; a coordinator
    /// stagger computed for the old physics is cleared (the
    /// coordinator re-phases against the new trigger on its next
    /// pass). Version, EWMAs, holds, and flags are untouched.
    pub fn set_task_decay(&mut self, task: &str, decay: DecayModel) {
        let age = decay.trigger_age(self.cfg.tolerance_for(task));
        let scaled = age / self.cfg.time_scale;
        self.cfg.per_task_decay.insert(task.to_string(), decay);
        let mut map = self.tracked.write();
        if let Some(t) = map.get_mut(task) {
            t.due_at = (scaled.is_finite() && scaled < MAX_DUE_SECS)
                .then(|| t.deployed_at + Duration::from_secs_f64(scaled));
            t.staggered_at = None;
        }
    }

    pub fn tasks(&self) -> Vec<String> {
        self.tracked.tasks()
    }

    /// Registry version this policy last saw for `task`.
    pub fn tracked_version(&self, task: &str) -> Option<u64> {
        self.tracked.tracked_version(task)
    }

    /// Modeled drift age of `task` at `now`, in (scaled) seconds.
    pub fn drift_age_secs(&self, task: &str, now: Instant) -> Option<f64> {
        self.tracked.read().get(task).map(|t| {
            now.saturating_duration_since(t.deployed_at).as_secs_f64() * self.cfg.time_scale
        })
    }

    /// Predicted decay of `task` at `now`.
    pub fn predicted_decay(&self, task: &str, now: Instant) -> Option<f64> {
        self.drift_age_secs(task, now)
            .map(|age| self.cfg.decay_for(task).predicted_decay(age))
    }

    /// Modeled drift age (scaled seconds) at which `task` crosses its
    /// tolerance; `None` when untracked or when the model never decays
    /// that far.
    pub fn trigger_age_secs(&self, task: &str) -> Option<f64> {
        if !self.tracked.read().contains_key(task) {
            return None;
        }
        let age = self.cfg.decay_for(task).trigger_age(self.cfg.tolerance_for(task));
        age.is_finite().then_some(age)
    }

    /// Modeled pool-clock instant at which `task` crosses its tolerance.
    pub fn trigger_at(&self, task: &str) -> Option<Instant> {
        self.tracked.trigger_at(task)
    }

    /// Tasks whose *effective* trigger has passed at `now` — the
    /// coordinator-staggered instant when one is assigned (so staggered
    /// refreshes actually fire early), the modeled crossing otherwise.
    /// Still an O(tasks) comparison against cached instants: no decay
    /// evaluation on the tick path.
    pub fn due(&self, now: Instant) -> Vec<String> {
        self.tracked
            .read()
            .iter()
            // an evicted task is never due: there is nothing resident to
            // refit, and refitting the host-side copy would waste the
            // step budget on bytes that may never page back in
            .filter(|(_, t)| !t.evicted)
            .filter(|(_, t)| {
                t.staggered_at
                    .or(t.due_at)
                    .map(|d| now >= d)
                    .unwrap_or(false)
            })
            .map(|(task, _)| task.clone())
            .collect()
    }

    fn on_refreshed(&mut self, task: &str, now: Instant, version: u64) {
        self.track(task, now, version);
        if let Some(t) = self.tracked.write().get_mut(task) {
            t.swapped_at = Some((now, version));
        }
    }
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

/// One completed refresh cycle, as recorded in the event log.
#[derive(Clone, Debug)]
pub struct RefreshEvent {
    pub task: String,
    /// Modeled drift age (seconds) at trigger time.
    pub drift_age_secs: f64,
    /// Predicted decay right before the refresh.
    pub pre_decay: f64,
    /// Predicted decay immediately after the hot-swap (fresh age).
    pub post_decay: f64,
    /// Optimizer steps the refit spent.
    pub steps: usize,
    /// Registry version the hot-swap installed.
    pub version: u64,
    /// Pool-clock instant the refresh ran at.
    pub at: Instant,
}

/// Executes the predict → refit → hot-swap cycle over a registry.
pub struct RefreshRunner {
    policy: RefreshPolicy,
    registry: SharedRegistry,
    /// Clean meta store the pool serves with. The sampled decay model
    /// reads the drifted substrate directly; the analytic model
    /// synthesizes drifted weights from this store
    /// ([`analytic_drifted_meta`]).
    meta: Arc<ParamStore>,
    metrics: Arc<Metrics>,
    events: Vec<RefreshEvent>,
    rng: Pcg64,
    /// Pool clock for bracketing refits (`None` = report zero-length
    /// brackets and anchor swaps at the tick instant, the historical
    /// behaviour). `ServerBuilder::build` always attaches the pool
    /// clock; virtual-clock tests whose refitters advance the clock
    /// attach it explicitly so the bracket measures the advance.
    clock: Option<Arc<dyn Clock>>,
    /// Pool-level refresh coordinator ([`super::coord`]): rebalanced at
    /// the top of every tick so staggered triggers and adaptive
    /// window/hold bounds track the live task set.
    coordinator: Option<Arc<super::coord::RefreshCoordinator>>,
}

impl RefreshRunner {
    pub fn new(
        cfg: RefreshConfig,
        registry: SharedRegistry,
        meta: Arc<ParamStore>,
        metrics: Arc<Metrics>,
    ) -> RefreshRunner {
        RefreshRunner {
            policy: RefreshPolicy::new(cfg),
            registry,
            meta,
            metrics,
            events: Vec::new(),
            rng: Pcg64::with_stream(0x5e_f7e5, 0xd71f7),
            clock: None,
            coordinator: None,
        }
    }

    /// Attach the pool clock so refits are bracketed on it (feeds the
    /// adaptive hold) and swaps anchor at their true landing instant
    /// even when a refit consumes (virtual) time.
    pub fn with_clock(mut self, clock: Arc<dyn Clock>) -> RefreshRunner {
        self.clock = Some(clock);
        self
    }

    /// Attach the pool-level coordinator; every tick rebalances it
    /// before evaluating due tasks.
    pub fn set_coordinator(&mut self, coordinator: Arc<super::coord::RefreshCoordinator>) {
        self.coordinator = Some(coordinator);
    }

    /// The attached coordinator, if any.
    pub fn coordinator(&self) -> Option<&Arc<super::coord::RefreshCoordinator>> {
        self.coordinator.as_ref()
    }

    /// Track every task currently deployed in the registry as "deployed
    /// at `now`" (the builder calls this at pool start).
    pub fn track_deployed(&mut self, now: Instant) {
        for task in self.registry.tasks() {
            if let Some(v) = self.registry.version(&task) {
                self.policy.track(&task, now, v);
            }
        }
    }

    pub fn policy(&self) -> &RefreshPolicy {
        &self.policy
    }

    pub fn policy_mut(&mut self) -> &mut RefreshPolicy {
        &mut self.policy
    }

    pub fn events(&self) -> &[RefreshEvent] {
        &self.events
    }

    /// Reconcile the policy with the live registry: start tracking
    /// tasks deployed after the pool came up, re-anchor tasks whose
    /// version changed through a manual deploy, and forget undeployed
    /// ones. Anchoring is conservative — at `now`, so a task's drift
    /// age is only ever under-estimated, by at most one check interval.
    fn reconcile(&mut self, now: Instant) {
        for task in self.registry.tasks() {
            let live = self.registry.version(&task);
            if let Some(v) = live {
                if self.policy.tracked_version(&task) != Some(v) {
                    self.policy.track(&task, now, v);
                }
            }
        }
        for task in self.policy.tasks() {
            // an EVICTED task is absent from the registry but must stay
            // tracked: its drift anchor is the only record of how long
            // the substrate has drifted under it, and forgetting it
            // would hand the adapter a fresh-looking clock on reload
            if !self.registry.contains(&task) && !self.policy.tracked.is_evicted(&task) {
                self.policy.forget(&task);
            }
        }
    }

    /// Evaluate the policy at `now` and run every due refresh to
    /// completion. Reconciles with the registry first, so live-deployed
    /// tasks join the drift watch and manual redeploys reset their
    /// task's drift clock within one check interval. Returns the
    /// refreshes performed this tick. Errors from a refit are counted
    /// in `Metrics::refresh_errors` (separate from the pool's
    /// per-request `errors`) and retried on the next tick; a refresh
    /// that lost a version race against a concurrent manual deploy is
    /// dropped (the manual deploy already reset that task's drift
    /// clock to the newer adapter).
    pub fn tick(&mut self, now: Instant) -> Vec<RefreshEvent> {
        self.reconcile(now);
        // rebalance AFTER reconciling: newly tracked / re-anchored tasks
        // get their stagger and adaptive bounds before the due check
        // below reads them
        if let Some(c) = &self.coordinator {
            c.rebalance(now);
        }
        let mut out = Vec::new();
        for task in self.policy.due(now) {
            match self.refresh_one(&task, now) {
                Ok(Some(ev)) => out.push(ev),
                Ok(None) => {}
                Err(e) => {
                    self.metrics.refresh_errors.fetch_add(1, Ordering::Relaxed);
                    eprintln!("[refresh] task '{task}': {e:#}");
                }
            }
        }
        out
    }

    fn refresh_one(&mut self, task: &str, now: Instant) -> Result<Option<RefreshEvent>> {
        let Some((current, seen_version)) = self.registry.snapshot(task) else {
            // evicted between the due check and here: keep the watch
            // (and its drift anchor) — the capacity tier will page the
            // adapter back in at the same version
            if self.policy.tracked.is_evicted(task) {
                return Ok(None);
            }
            // undeployed mid-flight: stop watching it
            self.policy.forget(task);
            return Ok(None);
        };
        // a manual redeploy since the last tick reset the task's real
        // drift exposure: re-anchor the drift clock on it (conservatively
        // at `now` — age can only be under-estimated) and skip the refit
        if self.policy.tracked_version(task) != Some(seen_version) {
            self.policy.track(task, now, seen_version);
            return Ok(None);
        }
        let age = self.policy.drift_age_secs(task, now).unwrap_or(0.0);
        let pre = self.policy.cfg.decay_for(task).predicted_decay(age);

        // the substrate the refit trains against: the drifted
        // meta-weights, under the TASK's decay model (its backend's
        // physics on a heterogeneous pool)
        let drifted = match self.policy.cfg.decay_for(task) {
            DecayModel::Sampled { deployment, .. } => deployment.meta_at(age, true, &mut self.rng),
            DecayModel::Analytic { model, g_rel } => {
                analytic_drifted_meta(&self.meta, model, *g_rel, age, &mut self.rng)
            }
        };
        // the in-flight flag is what saturates the scheduler's drift
        // pressure for this task, so coupled workers drain small batches
        // while the refit runs and the swap lands between batches
        self.policy.tracked.begin_refit(task);
        let bracket_start = self.clock.as_ref().map(|c| c.now());
        let refit = self
            .policy
            .cfg
            .refitter
            .refit(task, &current, &drifted, self.policy.cfg.step_budget);
        // the swap lands AFTER the refit: when the pool clock advanced
        // under the refit (real pools always; virtual tests when the
        // refitter models a step budget), anchor on the landing instant
        let landed = self
            .clock
            .as_ref()
            .map(|c| c.now())
            .unwrap_or(now)
            .max(now);
        self.policy.tracked.end_refit(task);
        let refit = refit?;
        // feed the adaptive hold — only from SUCCESSFUL refits (a
        // fast-failing refit would drag the learned hold toward zero,
        // then under-hold the first real refit after recovery). The
        // pool-clock bracket is measured PER TASK; the refitter's
        // self-timed [`Refitter::observed_budget`] is one meter across
        // all tasks, so it only stands in when no clock is attached and
        // the bracket cannot be measured — otherwise one heavy task's
        // budget would inflate every other task's hold bound.
        let budget = match bracket_start {
            Some(t0) => landed.saturating_duration_since(t0),
            None => self
                .policy
                .cfg
                .refitter
                .observed_budget()
                .unwrap_or(Duration::ZERO),
        };
        if budget > Duration::ZERO {
            self.policy.tracked.observe_refit_duration(task, budget);
        }

        let Some(version) = self
            .registry
            .deploy_if_version(task, refit.params, seen_version)
        else {
            // a manual deploy won the race mid-refit: adopt its version
            // and restart the drift clock from it
            if let Some(v) = self.registry.version(task) {
                self.policy.track(task, landed, v);
            }
            return Ok(None);
        };
        self.policy.on_refreshed(task, landed, version);
        let post = self.policy.predicted_decay(task, landed).unwrap_or(0.0);
        let ev = RefreshEvent {
            task: task.to_string(),
            drift_age_secs: age,
            pre_decay: pre,
            post_decay: post,
            steps: refit.steps,
            version,
            at: landed,
        };
        self.metrics.refreshes.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .refresh_steps
            .fetch_add(refit.steps as u64, Ordering::Relaxed);
        self.events.push(ev.clone());
        Ok(Some(ev))
    }
}

/// Synthesize post-GDC drifted meta-weights under the analytic model:
/// every mappable weight is scaled by `exp(−(ν_i−μ_ν)·ln((t+t₀)/t₀))`
/// with `ν_i − μ_ν ~ N(0, σ_ν)` — the device-to-device dispersion GDC
/// cannot remove, which is exactly the error the refit must absorb.
/// (The sampled model reads the real programmed substrate instead.)
fn analytic_drifted_meta(
    meta: &ParamStore,
    model: &PcmModel,
    g_rel: f32,
    age_secs: f64,
    rng: &mut Pcg64,
) -> ParamStore {
    let mut out = meta.clone();
    if age_secs <= 0.0 || model.noise_scale == 0.0 {
        return out;
    }
    let log_ratio = ((age_secs + model.t0) / model.t0).ln() as f32;
    let sigma = compensation::drift_dispersion(model, g_rel) as f32;
    for t in out.tensors.iter_mut() {
        if crate::aimc::tile::is_mappable(&t.name) {
            for w in t.data.iter_mut() {
                *w *= (-sigma * rng.normal_f32() * log_ratio).exp();
            }
        }
    }
    out
}

/// Spawn the background refresh worker: evaluates `runner` every
/// `check_every` until `stop` fires. The wait is wall-clock (so
/// shutdown is prompt even under a [`VirtualClock`]); the policy
/// decisions read the pool `clock`.
///
/// [`VirtualClock`]: super::sched::VirtualClock
pub(crate) fn spawn_refresh_worker(
    runner: Arc<std::sync::Mutex<RefreshRunner>>,
    clock: Arc<dyn Clock>,
    check_every: Duration,
) -> std::io::Result<(
    std::sync::mpsc::Sender<()>,
    std::thread::JoinHandle<()>,
)> {
    use std::sync::mpsc::{channel, RecvTimeoutError};
    let (stop_tx, stop_rx) = channel::<()>();
    let join = std::thread::Builder::new()
        .name("ahwa-refresh".to_string())
        .spawn(move || loop {
            match stop_rx.recv_timeout(check_every) {
                Ok(()) | Err(RecvTimeoutError::Disconnected) => break,
                Err(RecvTimeoutError::Timeout) => {
                    runner.lock().unwrap().tick(clock.now());
                }
            }
        })?;
    Ok((stop_tx, join))
}

// ---------------------------------------------------------------------------
// Tests (hermetic — no PJRT, no sleeps: everything on the virtual clock)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;
    use crate::serve::sched::VirtualClock;

    fn adapter(tag: f32) -> ParamStore {
        ParamStore::from_tensors(vec![Tensor {
            name: "lora.a".to_string(),
            shape: vec![1],
            data: vec![tag],
        }])
    }

    fn noop_refitter() -> Arc<dyn Refitter> {
        Arc::new(FnRefitter(
            |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> Result<Refit> {
                Ok(Refit {
                    params: adapter(99.0),
                    steps: budget,
                })
            },
        ))
    }

    fn analytic_cfg() -> RefreshConfig {
        RefreshConfig::new(DecayModel::analytic(PcmModel::default()), noop_refitter())
            .tolerance(0.05)
            .step_budget(16)
    }

    #[test]
    fn config_builder_and_per_task_tolerance() {
        let cfg = analytic_cfg()
            .task_tolerance("fragile", 0.01)
            .time_scale(100.0)
            .check_every(Duration::from_millis(10));
        assert_eq!(cfg.tolerance_for("fragile"), 0.01);
        assert_eq!(cfg.tolerance_for("anything-else"), 0.05);
        assert_eq!(cfg.time_scale, 100.0);
        assert!(format!("{cfg:?}").contains("tolerance"));
    }

    #[test]
    fn policy_predicts_trigger_time_exactly() {
        let clock = VirtualClock::new();
        let mut p = RefreshPolicy::new(analytic_cfg());
        let t0 = clock.now();
        p.track("t", t0, 1);

        let age_star = p.trigger_age_secs("t").unwrap();
        assert!(age_star > 0.0 && age_star.is_finite());
        // closed-form round trip: decay at the trigger age is the tolerance
        let model = PcmModel::default();
        assert!(
            (compensation::residual_decay(&model, 0.5, age_star) - 0.05).abs() < 1e-9
        );
        assert_eq!(p.trigger_at("t").unwrap(), t0 + Duration::from_secs_f64(age_star));

        // just before: not due; just after: due
        clock.advance(Duration::from_secs_f64(age_star * 0.99));
        assert!(p.due(clock.now()).is_empty());
        clock.advance(Duration::from_secs_f64(age_star * 0.02));
        assert_eq!(p.due(clock.now()), vec!["t".to_string()]);
        assert!(p.predicted_decay("t", clock.now()).unwrap() >= 0.05);
    }

    #[test]
    fn time_scale_compresses_the_trigger() {
        let clock = VirtualClock::new();
        let mut p = RefreshPolicy::new(analytic_cfg().time_scale(1000.0));
        p.track("t", clock.now(), 1);
        let age_star = p.trigger_age_secs("t").unwrap();
        // the same modeled age arrives 1000x sooner on the clock
        clock.advance(Duration::from_secs_f64(age_star / 1000.0 * 1.01));
        assert_eq!(p.due(clock.now()), vec!["t".to_string()]);
    }

    #[test]
    fn runner_refreshes_once_and_resets_the_drift_clock() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            analytic_cfg(),
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics.clone(),
        );
        runner.track_deployed(clock.now());
        let age_star = runner.policy().trigger_age_secs("t").unwrap();

        clock.advance(Duration::from_secs_f64(age_star * 0.9));
        assert!(runner.tick(clock.now()).is_empty(), "below tolerance: no refresh");
        assert_eq!(registry.version("t"), Some(1));

        clock.advance(Duration::from_secs_f64(age_star * 0.2));
        let evs = runner.tick(clock.now());
        assert_eq!(evs.len(), 1);
        let ev = &evs[0];
        assert_eq!(ev.task, "t");
        assert_eq!(ev.version, 2);
        assert!(ev.pre_decay >= 0.05);
        assert!(ev.post_decay < 0.05, "fresh deployment is below tolerance");
        assert_eq!(ev.steps, 16);
        assert!((ev.drift_age_secs - age_star * 1.1).abs() < age_star * 0.01);
        assert_eq!(registry.version("t"), Some(2));
        assert_eq!(registry.get("t").unwrap().tensors[0].data[0], 99.0);

        // age reset: an immediate second tick does nothing
        assert!(runner.tick(clock.now()).is_empty());
        assert_eq!(registry.version("t"), Some(2), "version bumps exactly once");
        assert_eq!(metrics.refreshes.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.refresh_steps.load(Ordering::Relaxed), 16);
        assert_eq!(runner.events().len(), 1);
    }

    #[test]
    fn refresh_loses_version_race_gracefully() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        // refitter that simulates a concurrent manual redeploy mid-refit
        let racing = {
            let registry = registry.clone();
            Arc::new(FnRefitter(
                move |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> Result<Refit> {
                    registry.deploy("t", adapter(7.0));
                    Ok(Refit {
                        params: adapter(99.0),
                        steps: budget,
                    })
                },
            )) as Arc<dyn Refitter>
        };
        let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), racing)
            .tolerance(0.05);
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            cfg,
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics.clone(),
        );
        runner.track_deployed(clock.now());
        let age_star = runner.policy().trigger_age_secs("t").unwrap();
        clock.advance(Duration::from_secs_f64(age_star * 1.1));

        let evs = runner.tick(clock.now());
        assert!(evs.is_empty(), "the lost race must not produce an event");
        // the manual deploy's adapter survives; the stale refit is dropped
        assert_eq!(registry.version("t"), Some(2));
        assert_eq!(registry.get("t").unwrap().tensors[0].data[0], 7.0);
        assert_eq!(metrics.refreshes.load(Ordering::Relaxed), 0);
        // and the policy re-anchored on the winner's version
        assert_eq!(runner.policy().tracked_version("t"), Some(2));
        assert!(runner.tick(clock.now()).is_empty(), "drift clock restarted");
    }

    #[test]
    fn undeployed_tasks_are_forgotten() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            analytic_cfg(),
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics,
        );
        runner.track_deployed(clock.now());
        // simulate an undeploy by pointing the runner at a fresh registry
        runner.registry = SharedRegistry::new();
        let age_star = runner.policy().trigger_age_secs("t").unwrap();
        clock.advance(Duration::from_secs_f64(age_star * 1.1));
        assert!(runner.tick(clock.now()).is_empty());
        assert!(runner.policy().tasks().is_empty(), "vanished task dropped");
    }

    #[test]
    fn failed_refits_count_errors_and_retry() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        let failing = Arc::new(FnRefitter(
            |_: &str, _: &ParamStore, _: &ParamStore, _: usize| -> Result<Refit> {
                anyhow::bail!("engine unavailable")
            },
        )) as Arc<dyn Refitter>;
        let cfg =
            RefreshConfig::new(DecayModel::analytic(PcmModel::default()), failing).tolerance(0.05);
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            cfg,
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics.clone(),
        );
        runner.track_deployed(clock.now());
        let age_star = runner.policy().trigger_age_secs("t").unwrap();
        clock.advance(Duration::from_secs_f64(age_star * 1.1));
        assert!(runner.tick(clock.now()).is_empty());
        assert_eq!(metrics.refresh_errors.load(Ordering::Relaxed), 1);
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 0, "request errors untouched");
        assert_eq!(registry.version("t"), Some(1), "no swap on failure");
        // the in-flight flag must not leak past a failed refit, or the
        // coupled scheduler would hold the task's queue forever
        assert!(!runner.policy().handle().refit_in_flight("t"));
        // still due: the next tick retries
        assert!(runner.tick(clock.now()).is_empty());
        assert_eq!(metrics.refresh_errors.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn handle_exposes_the_refresh_lifecycle_to_the_scheduler() {
        use std::sync::atomic::AtomicBool;
        use std::sync::Mutex;

        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        // the refitter itself checks that the in-flight flag is visible
        // THROUGH the shared handle mid-refit (what a coupled scheduler
        // on another thread would observe)
        let slot: Arc<Mutex<Option<RefreshHandle>>> = Arc::new(Mutex::new(None));
        let seen_in_flight = Arc::new(AtomicBool::new(false));
        let refitter = {
            let (slot, seen) = (slot.clone(), seen_in_flight.clone());
            Arc::new(FnRefitter(
                move |task: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> Result<Refit> {
                    let h = slot.lock().unwrap().clone().expect("handle published");
                    seen.store(h.refit_in_flight(task), Ordering::Relaxed);
                    Ok(Refit { params: adapter(2.0), steps: budget })
                },
            )) as Arc<dyn Refitter>
        };
        let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), refitter)
            .tolerance(0.05);
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            cfg,
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics,
        );
        runner.track_deployed(clock.now());
        let h = runner.policy().handle();
        *slot.lock().unwrap() = Some(h.clone());

        // watch phase: trigger published, nothing in flight, not stale
        assert_eq!(h.tracked_version("t"), Some(1));
        let trig = h.trigger_at("t").expect("analytic model always crosses");
        assert!(!h.refit_in_flight("t"));
        assert!(h.last_swap("t").is_none());
        assert!(!h.is_stale("t", 1, clock.now()));
        assert!(!h.is_stale("unknown", 1, clock.now()));

        // past the trigger: the tracked version reads stale (overdue)
        let age_star = runner.policy().trigger_age_secs("t").unwrap();
        clock.advance(Duration::from_secs_f64(age_star * 1.01));
        assert!(h.is_stale("t", 1, clock.now()));
        assert_eq!(h.trigger_at("t"), Some(trig), "trigger stable until the swap");

        // refresh: flag visible mid-refit, cleared after; swap recorded
        let evs = runner.tick(clock.now());
        assert_eq!(evs.len(), 1);
        assert!(seen_in_flight.load(Ordering::Relaxed), "in-flight flag seen mid-refit");
        assert!(!h.refit_in_flight("t"), "flag cleared after the swap");
        let (swap_at, swap_v) = h.last_swap("t").expect("swap recorded");
        assert_eq!(swap_v, 2);
        assert_eq!(swap_at, clock.now());
        assert_eq!(h.tracked_version("t"), Some(2));
        assert!(h.trigger_at("t").unwrap() > clock.now(), "trigger re-anchored");
        // the refreshed version is fresh; the replaced one reads stale
        assert!(!h.is_stale("t", 2, clock.now()));
        assert!(h.is_stale("t", 1, clock.now()));
    }

    #[test]
    fn manual_redeploy_between_ticks_resets_the_drift_clock() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        registry.deploy("t", adapter(1.0));
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            analytic_cfg(),
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics.clone(),
        );
        runner.track_deployed(clock.now());
        let age_star = runner.policy().trigger_age_secs("t").unwrap();

        // an operator hot-swaps a fresh adapter BETWEEN ticks...
        clock.advance(Duration::from_secs_f64(age_star * 0.5));
        registry.deploy("t", adapter(5.0));

        // ...and the very next (not-yet-due) tick re-anchors on it, so
        // the new adapter's drift age never runs on the stale clock
        clock.advance(Duration::from_secs_f64(age_star * 0.1));
        assert!(runner.tick(clock.now()).is_empty());
        assert_eq!(runner.policy().tracked_version("t"), Some(2));

        // at the ORIGINAL anchor's crossing time nothing is due anymore
        clock.advance(Duration::from_secs_f64(age_star * 0.5));
        assert!(runner.tick(clock.now()).is_empty(), "stale age must not refit");
        assert_eq!(registry.version("t"), Some(2), "operator's adapter survives");
        assert_eq!(registry.get("t").unwrap().tensors[0].data[0], 5.0);
        assert_eq!(metrics.refreshes.load(Ordering::Relaxed), 0);

        // from the re-anchored clock the cycle works normally again
        clock.advance(Duration::from_secs_f64(age_star * 1.1));
        let evs = runner.tick(clock.now());
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].version, 3);
    }

    #[test]
    fn live_deployed_tasks_join_the_drift_watch() {
        let clock = VirtualClock::new();
        let registry = SharedRegistry::new();
        let metrics = Arc::new(Metrics::default());
        let mut runner = RefreshRunner::new(
            analytic_cfg(),
            registry.clone(),
            Arc::new(ParamStore::default()),
            metrics,
        );
        runner.track_deployed(clock.now());
        assert!(runner.policy().tasks().is_empty());

        // deployed AFTER the pool came up: the next tick starts its clock
        registry.deploy("late", adapter(1.0));
        assert!(runner.tick(clock.now()).is_empty());
        assert_eq!(runner.policy().tracked_version("late"), Some(1));

        let age_star = runner.policy().trigger_age_secs("late").unwrap();
        clock.advance(Duration::from_secs_f64(age_star * 1.01));
        let evs = runner.tick(clock.now());
        assert_eq!(evs.len(), 1, "live-deployed tasks refresh like any other");
        assert_eq!(evs[0].version, 2);
    }

    #[test]
    fn config_validation_rejects_tolerances_at_or_below_the_floor() {
        assert!(analytic_cfg().validate().is_ok());
        // the analytic floor is 0: a zero tolerance would always be due
        assert!(analytic_cfg().tolerance(0.0).validate().is_err());
        assert!(analytic_cfg().task_tolerance("t", 0.0).validate().is_err());
    }

    #[test]
    fn analytic_drifted_meta_perturbs_only_mappable_tensors() {
        let mut rng = Pcg64::new(31);
        let mut data = vec![0f32; 64];
        rng.fill_normal(&mut data, 0.0, 0.1);
        let meta = ParamStore::from_tensors(vec![
            Tensor {
                name: "layers.0.wq".to_string(), // mappable
                shape: vec![8, 8],
                data: data.clone(),
            },
            Tensor {
                name: "layers.0.ln_scale".to_string(), // digital
                shape: vec![8, 8],
                data: data.clone(),
            },
        ]);
        let model = PcmModel::default();
        // age 0: identity
        let at0 = analytic_drifted_meta(&meta, &model, 0.5, 0.0, &mut Pcg64::new(32));
        assert_eq!(at0.tensors[0].data, meta.tensors[0].data);
        // a year of drift: mappable weights move, digital ones do not
        let year = analytic_drifted_meta(&meta, &model, 0.5, 31_536_000.0, &mut Pcg64::new(33));
        let wq = year.get("layers.0.wq").unwrap();
        let ln = year.get("layers.0.ln_scale").unwrap();
        assert!(
            wq.data.iter().zip(&data).any(|(a, b)| (a - b).abs() > 1e-6),
            "mappable tensor must drift"
        );
        assert_eq!(ln.data, data, "digital tensors never touch the substrate");
        // the ideal substrate never drifts anything
        let ideal = analytic_drifted_meta(&meta, &PcmModel::ideal(), 0.5, 31_536_000.0, &mut rng);
        assert_eq!(ideal.tensors[0].data, meta.tensors[0].data);
    }
}
