//! Pipeline-aware batch scheduling: the AIMC ⇄ PMCA cost model on the
//! serving hot path.
//!
//! # The balancing contract
//!
//! On the target system one request batch flows through a two-stage
//! pipeline per layer: the AIMC crossbar integrates `t` tokens per MVM
//! hand-off while the PMCA (Snitch cluster + RedMulE) computes the LoRA
//! delta for the *previous* hand-off. The paper's Fig. 4 analysis shows
//! that end-to-end latency is minimised when the two stage latencies are
//! balanced and the PMCA working set fits its 128 KiB TCDM — the exact
//! objective [`crate::pipeline::balance::sweep`] +
//! [`crate::pipeline::balance::best`] encode.
//!
//! [`BatchScheduler`] lifts that offline model into the worker loop:
//!
//! * **Token parallelism.** At construction it sweeps the paper's
//!   candidate `t` values for the configured layer shape and integration
//!   time and commits to the TCDM-fitting latency optimum
//!   ([`BatchScheduler::t_opt`]). An integration test pins this to
//!   [`crate::pipeline::balance::sweep`] for every Fig. 4 configuration.
//! * **Batch-close decision.** For a request fill `b` the modeled
//!   steady-state service latency is `L(b)` (the pipeline model run over
//!   `b · seq_len` tokens at `t_opt`). The scheduler closes a batch at
//!   the smallest fill whose modeled per-request service time `L(b)/b`
//!   keeps up with the task's observed arrival rate — the throughput-
//!   sustaining fill. Slower arrivals → smaller batches (latency-
//!   optimal); faster arrivals → larger batches (the fixed hand-off and
//!   kernel-launch overheads amortise). A per-task `max_wait` deadline
//!   still bounds worst-case queueing, exactly as in the fixed batcher.
//! * **Modeled-vs-measured.** Every decision carries the model's
//!   predicted batch latency so [`super::api::Metrics`] (and
//!   `util::bench` scenarios) can report model error alongside wall
//!   time.
//!
//! # Refresh coupling
//!
//! The drift-refresh subsystem ([`super::refresh`]) hot-swaps a task's
//! adapter when its modeled decay crosses tolerance. An uncoupled
//! scheduler batches blindly through that swap: a large batch popped
//! just before the version bump runs a whole extra service cycle at the
//! stale, drift-degraded adapter. [`SchedConfig::coupling`]
//! ([`RefreshCoupling`]) closes that gap by reading the refresh
//! lifecycle through a shared [`RefreshHandle`]:
//!
//! * **Drift pressure** ([`BatchScheduler::drift_pressure`]) ramps 0→1
//!   over the `window` before a task's modeled
//!   [`trigger_at`](RefreshHandle::trigger_at) and saturates at 1 while
//!   a refit is in flight or the trigger has passed.
//! * Under pressure the target fill shrinks
//!   ([`BatchScheduler::coupled_fill`], monotone non-increasing in
//!   pressure, floored at `min_fill`) and deadlines tighten
//!   ([`BatchScheduler::coupled_deadline`], never later than the
//!   uncoupled deadline) — the queue drains in small batches so the
//!   registry swap lands *between* batches ([`Decision::Drain`]).
//! * A **span guard** refuses fills whose modeled service would cross
//!   the trigger instant when a smaller fill (or a short wait) avoids
//!   it — no batch spans a version bump.
//! * A task overdue for its swap is **held** ([`Decision::Hold`]) for at
//!   most `hold` past its (tightened) deadline, so the first post-swap
//!   batch immediately serves the refreshed version; a stuck refresh
//!   cannot starve the queue.
//! * Right after a swap, fills are briefly *extended*
//!   (`post_swap_factor` inside `post_swap_window`) to amortise the
//!   recomputed [`crate::pipeline::balance`] point over bigger batches.
//!
//! All timing flows through the [`Clock`] trait so the scheduler, the
//! [`super::batcher::Batcher`], and the worker loop are testable on a
//! [`VirtualClock`] with no wall-clock sleeps. The drift-refresh policy
//! ([`super::refresh`]) reuses the same clock for its deployment-age
//! tracking, so trigger→refit→swap cycles — and the scheduler coupling
//! above — are virtual-clock-testable end to end
//! (`tests/refresh_sched_e2e.rs` is the conformance suite).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::pipeline::balance::{latency_table, BalancePoint};
use crate::pmca::cluster::SnitchCluster;
use crate::pmca::redmule::RedMulE;

use super::batcher::Batcher;
use super::refresh::{RefreshHandle, RefreshView};

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Time source for everything in the serving pool that waits or
/// timestamps. Production uses [`RealClock`]; tests use [`VirtualClock`]
/// and advance it explicitly, so no test ever sleeps.
pub trait Clock: Send + Sync {
    fn now(&self) -> Instant;

    /// Pause for `d`. The virtual clock advances itself instead of
    /// blocking the thread.
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Wall-clock time.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealClock;

impl Clock for RealClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// Deterministic test clock: starts at an arbitrary epoch and only moves
/// when [`advance`](VirtualClock::advance) is called (or something
/// `sleep`s on it).
#[derive(Debug)]
pub struct VirtualClock {
    epoch: Instant,
    offset: Mutex<Duration>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock {
            epoch: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
        }
    }

    pub fn advance(&self, d: Duration) {
        *self.offset.lock().unwrap() += d;
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Instant {
        self.epoch + *self.offset.lock().unwrap()
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Hardware-model parameters for one serving deployment: the dominant
/// layer shape the AIMC tiles hold, the LoRA rank on the PMCA, and the
/// tile integration time.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Weight matrix rows of the modeled layer (input features).
    pub m: usize,
    /// Weight matrix cols of the modeled layer (output features).
    pub n: usize,
    /// LoRA rank.
    pub r: usize,
    /// AIMC tile integration time per MVM, ns.
    pub t_int_ns: f64,
    /// Tokens per request sequence. `0` means "inherit the serving
    /// graph's sequence length" (resolved by `ServerBuilder::build`).
    pub seq_len: usize,
    /// Refresh-coupling policy (`None` = schedule blindly through
    /// refreshes, the pre-coupling behaviour). Takes effect only when
    /// the scheduler also holds a [`RefreshHandle`]
    /// ([`BatchScheduler::with_refresh`]); `ServerBuilder::build` wires
    /// that automatically when both `.scheduler(..)` and `.refresh(..)`
    /// are configured.
    pub coupling: Option<RefreshCoupling>,
}

impl SchedConfig {
    /// Model a deployment dominated by an `m×n` layer at LoRA rank `r`,
    /// with the paper's middle integration time (256 ns) and the
    /// sequence length inherited from the serving graph.
    pub fn for_layer(m: usize, n: usize, r: usize) -> SchedConfig {
        SchedConfig {
            m: m.max(1),
            n: n.max(1),
            r: r.max(1),
            t_int_ns: 256.0,
            seq_len: 0,
            coupling: None,
        }
    }

    pub fn t_int(mut self, ns: f64) -> Self {
        self.t_int_ns = ns;
        self
    }

    pub fn seq(mut self, seq_len: usize) -> Self {
        self.seq_len = seq_len;
        self
    }

    /// Enable refresh-aware scheduling (see the module docs).
    pub fn coupling(mut self, c: RefreshCoupling) -> Self {
        self.coupling = Some(c);
        self
    }
}

/// How the scheduler reacts to the refresh lifecycle of
/// [`super::refresh`] (see the module docs for the full contract).
#[derive(Clone, Copy, Debug)]
pub struct RefreshCoupling {
    /// How long before a task's modeled `trigger_at` its drift pressure
    /// starts ramping from 0 toward 1.
    pub window: Duration,
    /// Fill floor under full drift pressure (≥ 1).
    pub min_fill: usize,
    /// Deadline tightening at full pressure, in [0, 1]: the effective
    /// wait budget is `max_wait · (1 − deadline_factor · pressure)` —
    /// deadlines only ever move *earlier* under pressure.
    pub deadline_factor: f64,
    /// How long past the (tightened) deadline a task overdue for its
    /// hot-swap may be held so the swap lands between batches, before
    /// the scheduler gives up and serves the stale version anyway.
    pub hold: Duration,
    /// Window after a hot-swap during which target fills are extended.
    pub post_swap_window: Duration,
    /// Fill multiplier inside the post-swap window (≥ 1) — amortises
    /// the freshly recomputed balance point over bigger batches.
    pub post_swap_factor: f64,
}

impl Default for RefreshCoupling {
    fn default() -> RefreshCoupling {
        RefreshCoupling {
            window: Duration::from_millis(250),
            min_fill: 1,
            deadline_factor: 0.5,
            hold: Duration::from_millis(20),
            post_swap_window: Duration::from_millis(250),
            post_swap_factor: 2.0,
        }
    }
}

impl RefreshCoupling {
    /// Smallest admissible window/hold: every setter clamps here, so no
    /// builder input (nor a coordinator-adapted value routed through
    /// [`super::coord`]) can construct a zero-width coupling phase.
    pub const MIN_PHASE: Duration = Duration::from_nanos(1);

    pub fn window(mut self, d: Duration) -> Self {
        self.window = d.max(Self::MIN_PHASE);
        self
    }

    pub fn min_fill(mut self, n: usize) -> Self {
        self.min_fill = n.max(1);
        self
    }

    pub fn deadline_factor(mut self, f: f64) -> Self {
        self.deadline_factor = f.clamp(0.0, 1.0);
        self
    }

    pub fn hold(mut self, d: Duration) -> Self {
        self.hold = d.max(Self::MIN_PHASE);
        self
    }

    pub fn post_swap_window(mut self, d: Duration) -> Self {
        self.post_swap_window = d;
        self
    }

    pub fn post_swap_factor(mut self, f: f64) -> Self {
        self.post_swap_factor = f.max(1.0);
        self
    }
}

// ---------------------------------------------------------------------------
// Arrival-rate estimation
// ---------------------------------------------------------------------------

/// EWMA of one task's request inter-arrival time.
#[derive(Clone, Debug, Default)]
struct ArrivalEstimator {
    last: Option<Instant>,
    ewma_ns: Option<f64>,
}

impl ArrivalEstimator {
    fn observe(&mut self, now: Instant) {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_nanos() as f64;
            // the shared serving-side smoothing (util::stats::EWMA_ALPHA)
            self.ewma_ns = Some(crate::util::stats::ewma(self.ewma_ns, dt));
        }
        self.last = Some(now);
    }

    /// Estimated inter-arrival time in ns; +inf until two arrivals have
    /// been seen (the EWMA seeds from the FIRST observed gap). Callers
    /// that need a usable number before that must apply their own
    /// cold-start rule — see [`BatchScheduler::interarrival_ns`].
    fn interarrival_ns(&self) -> f64 {
        self.ewma_ns.unwrap_or(f64::INFINITY)
    }
}

/// One task's arrival statistics, exported for predictive consumers
/// (the `serve::cache` prefetcher). Only produced once the EWMA has a
/// measured gap, so `predicted_next` is never built from the cold-start
/// clamp.
#[derive(Clone, Copy, Debug)]
pub struct ArrivalRate {
    /// Smoothed inter-arrival time (the EWMA the scheduler batches on).
    pub interarrival: Duration,
    /// Instant of the most recent observed arrival.
    pub last: Instant,
}

impl ArrivalRate {
    /// Predicted instant of the task's next request: one smoothed
    /// inter-arrival after the last observed one.
    pub fn predicted_next(&self) -> Instant {
        self.last + self.interarrival
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// What the worker loop should do next (see [`BatchScheduler::pick`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Pop `fill` requests of `task` and serve them now.
    Close { task: String, fill: usize },
    /// Refresh-coupled close: drift pressure shaped this fill (shrunk
    /// target and/or span guard), draining the queue in small batches
    /// so the pending hot-swap lands between batches. Served exactly
    /// like [`Decision::Close`]; the variant exists so conformance
    /// tests and metrics can tell coupled closes apart.
    Drain { task: String, fill: usize },
    /// `task` is overdue for its hot-swap (trigger passed or refit in
    /// flight): closing now would serve the stale adapter version, so
    /// the batch is deliberately deferred until `until` at the latest
    /// (deadline + [`RefreshCoupling::hold`]) — long enough for the
    /// swap to land between batches, bounded so a stuck refresh cannot
    /// starve the queue.
    Hold { task: String, until: Instant },
    /// Nothing is ready; sleep until `until` (earliest deadline) unless
    /// an arrival wakes the worker first.
    Wait { until: Instant },
    /// No queued work at all.
    Idle,
}

/// Per-task readiness verdict inside [`BatchScheduler::pick`].
enum TaskState {
    /// Pop `fill` now; `drained` = the fill was pressure-shaped.
    Ready { fill: usize, drained: bool },
    /// Not ready before `until`; `hold` = deferred for a pending swap
    /// rather than waiting on fill/deadline.
    Wake { until: Instant, hold: bool },
}

/// Cost-based batch scheduler (see the module docs for the contract).
pub struct BatchScheduler {
    cfg: SchedConfig,
    max_batch: usize,
    max_wait: Duration,
    /// Winning point of the `pipeline::balance` sweep for this layer.
    balance: BalancePoint,
    /// `modeled_ns[b-1]` = modeled steady-state latency (ns) of serving
    /// a batch of `b` requests at `t_opt`.
    modeled_ns: Vec<f64>,
    arrivals: BTreeMap<String, ArrivalEstimator>,
    /// Refresh-lifecycle view the coupling policy reads
    /// ([`Self::with_refresh`]); `None` = pressure is always 0.
    refresh: Option<RefreshHandle>,
}

impl BatchScheduler {
    /// Build against the paper's default Snitch cluster + RedMulE.
    pub fn new(cfg: SchedConfig, max_batch: usize, max_wait: Duration) -> BatchScheduler {
        Self::with_hardware(
            cfg,
            max_batch,
            max_wait,
            &SnitchCluster::default(),
            &RedMulE::default(),
        )
    }

    pub fn with_hardware(
        cfg: SchedConfig,
        max_batch: usize,
        max_wait: Duration,
        cluster: &SnitchCluster,
        engine: &RedMulE,
    ) -> BatchScheduler {
        let seq = cfg.seq_len.max(1);
        let max_batch = max_batch.max(1);
        // the ONE shared hardware cost table — identical math feeds the
        // HAL's per-backend routing CostModel (`serve::hal`), so close
        // decisions and placement decisions can never disagree
        let (balance, modeled_ns) = latency_table(
            cfg.m,
            cfg.n,
            cfg.r,
            cfg.t_int_ns,
            seq,
            max_batch,
            cluster,
            engine,
        );
        BatchScheduler {
            cfg,
            max_batch,
            max_wait,
            balance,
            modeled_ns,
            arrivals: BTreeMap::new(),
            refresh: None,
        }
    }

    /// Attach the shared refresh-lifecycle view. Without it (or without
    /// [`SchedConfig::coupling`]) drift pressure is always 0 and the
    /// scheduler behaves exactly like the uncoupled baseline.
    pub fn with_refresh(mut self, handle: RefreshHandle) -> BatchScheduler {
        self.refresh = Some(handle);
        self
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// The chosen token parallelism — identical to
    /// `balance::best(&balance::sweep(..)).t` by construction.
    pub fn t_opt(&self) -> usize {
        self.balance.t
    }

    /// The full balance point backing [`Self::t_opt`].
    pub fn balance_point(&self) -> BalancePoint {
        self.balance
    }

    /// Modeled steady-state latency for a batch of `fill` requests (ns).
    pub fn modeled_batch_ns(&self, fill: usize) -> f64 {
        self.modeled_ns[fill.clamp(1, self.modeled_ns.len()) - 1]
    }

    /// Modeled batch latency as a [`Duration`] (for metrics).
    pub fn modeled_batch(&self, fill: usize) -> Duration {
        Duration::from_nanos(self.modeled_batch_ns(fill).round() as u64)
    }

    /// The modeled-optimal fill for a task whose requests arrive every
    /// `interarrival_ns`: the smallest batch whose per-request service
    /// time keeps up with arrivals, `max_batch` if none does.
    pub fn target_fill(&self, interarrival_ns: f64) -> usize {
        for b in 1..=self.modeled_ns.len() {
            if self.modeled_batch_ns(b) / b as f64 <= interarrival_ns {
                return b;
            }
        }
        self.modeled_ns.len()
    }

    /// The fills this scheduler can ever commit a batch at: the image
    /// of [`Self::target_fill`] over every arrival rate — the
    /// per-request-latency frontier of the modeled table plus the
    /// max-batch fallback for unsustainable rates
    /// ([`crate::pipeline::balance::frontier_fills`]).
    ///
    /// Known at build time, which is what makes ahead-of-time shape
    /// specialization possible: `ServerBuilder::build` hands this set
    /// to each worker's forward executor
    /// ([`crate::serve::hal::Forward::specialize`]) so the common
    /// fills execute without per-batch padding or re-pack
    /// (`runtime::compile`). Deadline pressure and refresh coupling
    /// can shrink a batch *below* its target fill — those odd fills
    /// fall back to the padded max-shape path, bit-identically.
    pub fn committed_fills(&self) -> Vec<usize> {
        crate::pipeline::balance::frontier_fills(&self.modeled_ns)
    }

    /// Current inter-arrival estimate for a task (ns).
    ///
    /// Cold-start rule: until a task has TWO observed arrivals there is
    /// no gap to estimate and the raw EWMA reports +inf — which would
    /// make every first fill decision degenerate (an infinitely patient
    /// rate always yields the minimal fill). The scheduler therefore
    /// clamps the UNKNOWN estimate to the batching deadline `max_wait`:
    /// the most patient assumption the worker could act on anyway,
    /// since no request is held past the deadline regardless of the
    /// estimate. Known rates — including ones genuinely slower than the
    /// deadline — pass through unclamped, and the second arrival seeds
    /// the true EWMA from the first observed gap.
    pub fn interarrival_ns(&self, task: &str) -> f64 {
        let raw = self
            .arrivals
            .get(task)
            .map(|a| a.interarrival_ns())
            .unwrap_or(f64::INFINITY);
        if raw.is_finite() {
            raw
        } else {
            self.max_wait.as_nanos() as f64
        }
    }

    /// Arrival statistics for every task with a MEASURED rate (≥ 2
    /// observed arrivals), for the adapter-cache prefetcher: tasks
    /// still under the cold-start clamp are omitted rather than
    /// reported at a fabricated rate.
    pub fn arrival_rates(&self) -> Vec<(String, ArrivalRate)> {
        self.arrivals
            .iter()
            .filter_map(|(task, a)| {
                let (last, ewma) = (a.last?, a.ewma_ns?);
                Some((
                    task.clone(),
                    ArrivalRate {
                        interarrival: Duration::from_nanos(ewma.max(0.0).round() as u64),
                        last,
                    },
                ))
            })
            .collect()
    }

    /// Feed one observed arrival into the task's rate estimator.
    pub fn observe_arrival(&mut self, task: &str, now: Instant) {
        self.arrivals.entry(task.to_string()).or_default().observe(now);
    }

    /// One consistent snapshot of `task`'s refresh state (`None` when
    /// no handle is attached or the task is untracked) — a single lock
    /// read backing a whole scheduling decision.
    fn view(&self, task: &str) -> Option<RefreshView> {
        self.refresh.as_ref().and_then(|h| h.view(task))
    }

    /// Drift pressure for `task` at `now`, in [0, 1]. 0 without a
    /// coupling policy or refresh handle; 1 while a refit is in flight
    /// or past the modeled trigger; ramps linearly over
    /// [`RefreshCoupling::window`] before the trigger.
    pub fn drift_pressure(&self, task: &str, now: Instant) -> f64 {
        self.pressure_from(self.view(task).as_ref(), now)
    }

    fn pressure_from(&self, view: Option<&RefreshView>, now: Instant) -> f64 {
        let (Some(c), Some(v)) = (self.cfg.coupling, view) else {
            return 0.0;
        };
        if v.refit_in_flight {
            return 1.0;
        }
        // the pool coordinator may have re-phased this task's trigger
        // (staggered, always earlier) and adapted the ramp window from
        // its observed swap gaps; fall back to the modeled trigger and
        // the fixed coupling window when it hasn't (see `super::coord`)
        let Some(trigger) = v.effective_trigger() else {
            return 0.0;
        };
        if now >= trigger {
            return 1.0;
        }
        let window = match v.window {
            // adaptive window: the published value tracks the observed
            // swap gap, which under saturated arrivals can be shorter
            // than one full batch's modeled service — floor it locally
            // so pressure (and with it the span guard) always engages
            // before a max-fill batch could span the trigger
            Some(w) => w.max(self.modeled_batch(self.max_batch)),
            None => c.window,
        };
        let left = trigger.saturating_duration_since(now);
        if window.is_zero() || left >= window {
            0.0
        } else {
            1.0 - left.as_secs_f64() / window.as_secs_f64()
        }
    }

    /// Shrink a target fill by drift pressure: monotone non-increasing
    /// in `pressure`, floored at [`RefreshCoupling::min_fill`], never
    /// above the unshrunk target (and hence never above `max_batch`).
    pub fn coupled_fill(&self, target: usize, pressure: f64) -> usize {
        let target = target.clamp(1, self.max_batch);
        let Some(c) = self.cfg.coupling else {
            return target;
        };
        let p = pressure.clamp(0.0, 1.0);
        let shrunk = ((target as f64) * (1.0 - p)).ceil() as usize;
        shrunk.clamp(c.min_fill.clamp(1, target), target)
    }

    /// Effective deadline for a head enqueued at `head` under
    /// `pressure`: tightens toward the head as pressure rises, and is
    /// never later than the uncoupled `head + max_wait`.
    pub fn coupled_deadline(&self, head: Instant, pressure: f64) -> Instant {
        let Some(c) = self.cfg.coupling else {
            return head + self.max_wait;
        };
        let p = pressure.clamp(0.0, 1.0);
        let keep = (1.0 - c.deadline_factor.clamp(0.0, 1.0) * p).max(0.0);
        head + self.max_wait.mul_f64(keep)
    }

    /// Fill multiplier from the post-swap amortisation window (1.0
    /// outside it).
    fn boost_from(&self, view: Option<&RefreshView>, now: Instant) -> f64 {
        let (Some(c), Some(v)) = (self.cfg.coupling, view) else {
            return 1.0;
        };
        match v.last_swap {
            Some((at, _)) if now.saturating_duration_since(at) < c.post_swap_window => {
                c.post_swap_factor.max(1.0)
            }
            _ => 1.0,
        }
    }

    /// The effective target fill for `task` at `now`: the modeled
    /// throughput-sustaining fill, extended inside the post-swap
    /// window, then shrunk by drift pressure. Never exceeds
    /// `max_batch`.
    pub fn target_fill_for(&self, task: &str, now: Instant) -> usize {
        let view = self.view(task);
        let v = view.as_ref();
        self.shaped_target(task, v, now, self.pressure_from(v, now))
    }

    fn shaped_target(
        &self,
        task: &str,
        view: Option<&RefreshView>,
        now: Instant,
        pressure: f64,
    ) -> usize {
        let base = self.target_fill(self.interarrival_ns(task));
        let boosted = ((base as f64) * self.boost_from(view, now)).round() as usize;
        self.coupled_fill(boosted.clamp(1, self.max_batch), pressure)
    }

    /// Per-task readiness under the coupling policy (see module docs).
    /// The whole decision derives from ONE [`RefreshView`] snapshot, so
    /// a concurrent runner update can never make the hold gate and the
    /// fill computation disagree about the task's state.
    fn assess(&self, task: &str, len: usize, head: Instant, now: Instant) -> TaskState {
        let view = self.view(task);
        let v = view.as_ref();
        let pressure = self.pressure_from(v, now);
        let deadline = self.coupled_deadline(head, pressure);
        // mid-migration between backend worker spans: serve this queue
        // out NOW in drain mode, outranking the hold gate — the span
        // handoff completes at the next batch boundary and every
        // deferred request would otherwise resolve against the old
        // span after the router has flipped
        if v.map(|view| view.migrating).unwrap_or(false) {
            return TaskState::Ready {
                fill: len.min(self.max_batch).max(1),
                drained: true,
            };
        }
        // overdue for the swap (or mid-refit): hold the queue briefly so
        // the refreshed adapter serves the next batch; liveness bounded
        // by the hold budget past the already-tightened deadline — the
        // coordinator's adaptive hold (derived from the refitter's
        // measured step budget) when assigned, the fixed one otherwise
        if pressure >= 1.0 {
            if let Some(c) = self.cfg.coupling {
                let hold = v.and_then(|view| view.hold).unwrap_or(c.hold);
                let hold_until = deadline + hold;
                if now < hold_until {
                    return TaskState::Wake { until: hold_until, hold: true };
                }
            }
        }
        let target = self.shaped_target(task, v, now, pressure);
        if len < target && now < deadline {
            return TaskState::Wake { until: deadline, hold: false };
        }
        // ready: under pressure close at the shrunk target (drain in
        // small batches); otherwise serve everything queued, as before
        let mut fill = if pressure > 0.0 {
            len.min(target)
        } else {
            len.min(self.max_batch)
        };
        // span guard: never let a batch's modeled service cross the
        // version bump when a smaller fill (or a short wait) avoids it
        // (the staggered trigger, when assigned, IS the version bump:
        // the refresh runner fires on it)
        if pressure > 0.0 {
            if let Some(trigger) = v.and_then(|view| view.effective_trigger()) {
                if now < trigger {
                    let crosses = |f: usize| now + self.modeled_batch(f) > trigger;
                    while fill > 1 && crosses(fill) {
                        fill -= 1;
                    }
                    if crosses(fill) && now < deadline {
                        return TaskState::Wake {
                            until: deadline.min(trigger),
                            hold: true,
                        };
                    }
                }
            }
        }
        TaskState::Ready {
            fill: fill.max(1),
            drained: pressure > 0.0,
        }
    }

    /// Decide the next action over the batcher's queues. A task is
    /// ready when it reached its (pressure-shaped) target fill or its
    /// oldest request hit the (pressure-tightened) deadline; among
    /// ready tasks the oldest head wins (no starvation), matching the
    /// fixed batcher's fairness. Tasks deferred for a pending hot-swap
    /// surface as [`Decision::Hold`] when nothing else is ready.
    pub fn pick<T>(&self, batcher: &Batcher<T>, now: Instant) -> Decision {
        let mut close: Option<(String, usize, Instant, bool)> = None;
        let mut wake: Option<(Instant, Option<String>)> = None;
        for (task, len, head) in batcher.heads() {
            match self.assess(task, len, head, now) {
                TaskState::Ready { fill, drained } => {
                    let older = close.as_ref().map(|(_, _, h, _)| head < *h).unwrap_or(true);
                    if older {
                        close = Some((task.to_string(), fill, head, drained));
                    }
                }
                TaskState::Wake { until, hold } => {
                    let sooner = wake.as_ref().map(|(w, _)| until < *w).unwrap_or(true);
                    if sooner {
                        wake = Some((until, hold.then(|| task.to_string())));
                    }
                }
            }
        }
        match close {
            Some((task, fill, _, true)) => Decision::Drain { task, fill },
            Some((task, fill, _, false)) => Decision::Close { task, fill },
            None => match wake {
                Some((until, Some(task))) => Decision::Hold { task, until },
                Some((until, None)) => Decision::Wait { until },
                None => Decision::Idle,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::balance::{best, sweep};
    use std::sync::Arc;

    fn sched(max_batch: usize) -> BatchScheduler {
        // the paper's small layer at the middle integration time
        BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            max_batch,
            Duration::from_millis(10),
        )
    }

    #[test]
    fn t_opt_matches_balance_sweep() {
        let (c, e) = (SnitchCluster::default(), RedMulE::default());
        for (m, n) in [(128usize, 128usize), (512, 128)] {
            for t_int in crate::pipeline::schedule::INTEGRATION_TIMES_NS {
                let s = BatchScheduler::new(
                    SchedConfig::for_layer(m, n, 8).t_int(t_int).seq(320),
                    8,
                    Duration::from_millis(5),
                );
                let b = best(&sweep(m, n, 8, t_int, 320, &c, &e));
                assert_eq!(s.t_opt(), b.t, "{m}x{n}@{t_int}");
                assert!(s.balance_point().fits_tcdm || !b.fits_tcdm);
            }
        }
    }

    #[test]
    fn committed_fills_cover_every_target_fill() {
        let s = sched(8);
        let fills = s.committed_fills();
        assert_eq!(fills.last(), Some(&8), "max batch is always committed");
        // sweep arrival gaps across the whole modeled range: every
        // fill the scheduler can target must be in the committed set
        let mut gaps: Vec<f64> = (0..400)
            .map(|i| s.modeled_batch_ns(8) * (i as f64 / 100.0))
            .collect();
        gaps.push(f64::INFINITY);
        for gap in gaps {
            let t = s.target_fill(gap);
            assert!(fills.contains(&t), "target_fill({gap}) = {t} not committed");
        }
    }

    #[test]
    fn per_request_model_latency_amortises() {
        let s = sched(8);
        // fixed hand-off/overhead amortise: per-request cost shrinks
        let per = |b: usize| s.modeled_batch_ns(b) / b as f64;
        assert!(per(2) < per(1));
        assert!(per(8) < per(4));
        // ...so target_fill is monotone in the arrival rate
        assert_eq!(s.target_fill(f64::INFINITY), 1);
        assert_eq!(s.target_fill(per(1) + 1.0), 1);
        assert_eq!(s.target_fill(0.0), 8);
        let mid = (per(3) + per(4)) / 2.0; // sustainable at 4, not at 3
        assert_eq!(s.target_fill(mid), 4);
    }

    #[test]
    fn close_fires_exactly_at_modeled_optimal_fill() {
        let clock = Arc::new(VirtualClock::new());
        let max_wait = Duration::from_millis(10);
        let mut s = sched(8);
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);

        // arrivals paced so the modeled-optimal fill is exactly 4
        let per = |b: usize| s.modeled_batch_ns(b) / b as f64;
        let ia = Duration::from_nanos(((per(3) + per(4)) / 2.0).round() as u64);

        // prior traffic at the same cadence primes the rate estimator
        // (a cold task with an unknown rate closes immediately instead)
        s.observe_arrival("sst2", clock.now());
        clock.advance(ia);
        s.observe_arrival("sst2", clock.now());

        for i in 0..4u32 {
            clock.advance(ia);
            let now = clock.now();
            s.observe_arrival("sst2", now);
            b.push("sst2", i);
            match s.pick(&b, now) {
                Decision::Close { task, fill } => {
                    assert_eq!(i, 3, "closed early at fill {}", i + 1);
                    assert_eq!(task, "sst2");
                    assert_eq!(fill, 4, "must close at the modeled-optimal fill");
                }
                Decision::Wait { until } => {
                    assert!(i < 3, "must close once the optimal fill is reached");
                    assert!(until > now);
                }
                Decision::Idle => panic!("queue is non-empty"),
            }
        }
        let items = b.pop_task("sst2", 4).unwrap();
        assert_eq!(items, vec![0, 1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_fires_on_virtual_clock_without_fill() {
        let clock = Arc::new(VirtualClock::new());
        let max_wait = Duration::from_millis(5);
        let mut s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            8,
            max_wait,
        );
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);
        let t0 = clock.now();
        b.push("qqp", 7);

        // an unknown arrival rate must not hold requests back
        assert_eq!(
            s.pick(&b, t0),
            Decision::Close { task: "qqp".into(), fill: 1 },
            "unknown rate serves immediately (latency-optimal)"
        );

        // teach it a fast arrival rate so it wants a full batch...
        let mut obs = t0;
        for _ in 0..3 {
            s.observe_arrival("qqp", obs);
            obs += Duration::from_nanos(10);
        }
        assert_eq!(s.target_fill(s.interarrival_ns("qqp")), 8);
        // ...but only one request ever shows up: the deadline must fire
        match s.pick(&b, t0) {
            Decision::Wait { until } => assert_eq!(until, t0 + max_wait),
            other => panic!("expected Wait, got {other:?}"),
        }
        clock.advance(max_wait);
        match s.pick(&b, clock.now()) {
            Decision::Close { task, fill } => {
                assert_eq!(task, "qqp");
                assert_eq!(fill, 1, "deadline releases the partial batch");
            }
            other => panic!("expected Close, got {other:?}"),
        }
    }

    #[test]
    fn burst_traffic_closes_at_max_batch() {
        let clock = Arc::new(VirtualClock::new());
        let mut s = sched(4);
        let mut b: Batcher<u32> =
            Batcher::with_clock(4, Duration::from_millis(10), clock.clone() as Arc<dyn Clock>);
        for i in 0..6u32 {
            clock.advance(Duration::from_nanos(50)); // near-instant burst
            let now = clock.now();
            s.observe_arrival("x", now);
            b.push("x", i);
        }
        match s.pick(&b, clock.now()) {
            Decision::Close { fill, .. } => assert_eq!(fill, 4, "capped at max_batch"),
            other => panic!("expected Close, got {other:?}"),
        }
    }

    #[test]
    fn virtual_clock_sleep_advances_time() {
        let c = VirtualClock::new();
        let t0 = c.now();
        c.sleep(Duration::from_secs(3));
        assert_eq!(c.now() - t0, Duration::from_secs(3));
    }

    // -- refresh coupling ---------------------------------------------------

    use crate::model::params::ParamStore;
    use crate::pcm::PcmModel;
    use crate::serve::refresh::{
        DecayModel, FnRefitter, Refit, Refitter, RefreshConfig, RefreshPolicy,
    };

    fn noop_refitter() -> Arc<dyn Refitter> {
        Arc::new(FnRefitter(
            |_: &str, _: &ParamStore, _: &ParamStore, budget: usize| -> anyhow::Result<Refit> {
                Ok(Refit { params: ParamStore::default(), steps: budget })
            },
        ))
    }

    /// A policy tracking task "t" (v1) since `clock.now()`, plus its
    /// shared handle — the scheduler-facing refresh state.
    fn tracked_policy(clock: &VirtualClock, time_scale: f64) -> (RefreshPolicy, RefreshHandle) {
        let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), noop_refitter())
            .tolerance(0.05)
            .time_scale(time_scale);
        let mut p = RefreshPolicy::new(cfg);
        p.track("t", clock.now(), 1);
        let h = p.handle();
        (p, h)
    }

    #[test]
    fn drift_pressure_ramps_inside_the_window_and_saturates() {
        let clock = VirtualClock::new();
        let t0 = clock.now();
        let (_p, h) = tracked_policy(&clock, 1.0);
        let trigger = h.trigger_at("t").expect("analytic model crosses");
        let lead = trigger - t0;
        let window = lead / 10;

        let coupled = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8)
                .seq(320)
                .coupling(RefreshCoupling::default().window(window)),
            8,
            Duration::from_millis(5),
        )
        .with_refresh(h.clone());

        // far out: zero pressure; window edge: still zero
        assert_eq!(coupled.drift_pressure("t", t0), 0.0);
        assert_eq!(coupled.drift_pressure("t", trigger - window), 0.0);
        // mid-window: linear ramp
        let mid = coupled.drift_pressure("t", trigger - window / 2);
        assert!((mid - 0.5).abs() < 1e-3, "mid-window pressure {mid}");
        // at/past the trigger: saturated
        assert_eq!(coupled.drift_pressure("t", trigger), 1.0);
        assert_eq!(coupled.drift_pressure("t", trigger + window), 1.0);
        // a refit in flight saturates regardless of distance
        h.begin_refit("t");
        assert_eq!(coupled.drift_pressure("t", t0), 1.0);
        h.end_refit("t");
        assert_eq!(coupled.drift_pressure("t", t0), 0.0);
        // untracked tasks never feel pressure
        assert_eq!(coupled.drift_pressure("other", trigger), 0.0);

        // no coupling config => no pressure, even with the handle
        let uncoupled = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            8,
            Duration::from_millis(5),
        )
        .with_refresh(h);
        assert_eq!(uncoupled.drift_pressure("t", trigger + window), 0.0);
    }

    #[test]
    fn coupled_fill_shrinks_monotonically_to_the_floor() {
        let clock = VirtualClock::new();
        let (_p, h) = tracked_policy(&clock, 1.0);
        let s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8)
                .seq(320)
                .coupling(RefreshCoupling::default().min_fill(2)),
            8,
            Duration::from_millis(5),
        )
        .with_refresh(h);
        assert_eq!(s.coupled_fill(8, 0.0), 8);
        assert_eq!(s.coupled_fill(8, 0.5), 4);
        assert_eq!(s.coupled_fill(8, 1.0), 2, "floored at min_fill");
        let mut last = usize::MAX;
        for i in 0..=20 {
            let f = s.coupled_fill(8, i as f64 / 20.0);
            assert!(f <= last, "fill must be monotone non-increasing");
            assert!((2..=8).contains(&f));
            last = f;
        }
        // the uncoupled scheduler passes targets through untouched
        let plain = sched(8);
        assert_eq!(plain.coupled_fill(5, 1.0), 5);
    }

    #[test]
    fn coupled_deadline_only_ever_tightens() {
        let clock = VirtualClock::new();
        let (_p, h) = tracked_policy(&clock, 1.0);
        let max_wait = Duration::from_millis(10);
        let s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8)
                .seq(320)
                .coupling(RefreshCoupling::default().deadline_factor(0.5)),
            8,
            max_wait,
        )
        .with_refresh(h);
        let head = clock.now();
        let base = head + max_wait;
        assert_eq!(s.coupled_deadline(head, 0.0), base);
        assert_eq!(s.coupled_deadline(head, 1.0), head + max_wait / 2);
        let mut last = base + Duration::from_secs(1);
        for i in 0..=20 {
            let d = s.coupled_deadline(head, i as f64 / 20.0);
            assert!(d <= base, "a coupled deadline may never move later");
            assert!(d <= last, "deadline monotone non-increasing in pressure");
            last = d;
        }
    }

    #[test]
    fn overdue_task_is_held_then_released_at_the_hold_bound() {
        let clock = Arc::new(VirtualClock::new());
        // compress the modeled trigger to ~1ms of pool clock
        let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
        let (_p, h) = tracked_policy(&clock, age / 1e-3);
        let max_wait = Duration::from_millis(5);
        let hold = Duration::from_millis(3);
        let mut s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8)
                .seq(320)
                .coupling(RefreshCoupling::default().hold(hold).deadline_factor(0.0)),
            8,
            max_wait,
        )
        .with_refresh(h.clone());
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);

        // move past the trigger, then enqueue
        let trigger = h.trigger_at("t").unwrap();
        clock.advance(trigger - clock.now() + Duration::from_micros(10));
        let head = clock.now();
        s.observe_arrival("t", head);
        b.push("t", 1);

        // overdue: the queue is held for the swap, not closed
        match s.pick(&b, clock.now()) {
            Decision::Hold { task, until } => {
                assert_eq!(task, "t");
                assert_eq!(until, head + max_wait + hold, "hold is deadline + hold budget");
            }
            other => panic!("expected Hold, got {other:?}"),
        }
        // ...even at the plain deadline
        clock.advance(max_wait);
        assert!(matches!(s.pick(&b, clock.now()), Decision::Hold { .. }));
        // past the hold bound: liveness wins, the stale batch drains
        clock.advance(hold);
        match s.pick(&b, clock.now()) {
            Decision::Drain { task, fill } => {
                assert_eq!(task, "t");
                assert_eq!(fill, 1);
            }
            other => panic!("expected Drain after the hold bound, got {other:?}"),
        }
    }

    #[test]
    fn coordinator_overrides_shape_pressure_window_and_hold() {
        use crate::serve::refresh::CoordDecision;

        let clock = Arc::new(VirtualClock::new());
        let t0 = clock.now();
        let (_p, h) = tracked_policy(&clock, 1.0);
        let trigger = h.trigger_at("t").expect("analytic model crosses");
        let lead = trigger - t0;
        let window = lead / 10;
        let staggered = trigger - lead / 4;
        let max_wait = Duration::from_millis(5);
        let s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320).coupling(
                RefreshCoupling::default()
                    .window(window)
                    .hold(Duration::from_millis(20))
                    .deadline_factor(0.0),
            ),
            8,
            max_wait,
        )
        .with_refresh(h.clone());

        // before the override: pressure keys to the MODELED trigger
        assert_eq!(s.drift_pressure("t", staggered), 0.0);

        // the coordinator re-phases the trigger and adapts window/hold
        h.apply_coord(&[(
            "t".to_string(),
            CoordDecision {
                staggered_at: Some(staggered),
                window: Some(window / 2),
                hold: Some(Duration::from_millis(3)),
            },
        )]);

        // pressure now saturates at the STAGGERED instant (the modeled
        // trigger is still far in the future)...
        assert_eq!(s.drift_pressure("t", staggered), 1.0);
        // ...ramps over the ADAPTIVE window...
        let mid = s.drift_pressure("t", staggered - window / 4);
        assert!((mid - 0.5).abs() < 1e-3, "adaptive-window midpoint: {mid}");
        assert_eq!(s.drift_pressure("t", staggered - window), 0.0);

        // ...and an overdue queue is held for the ADAPTIVE hold bound,
        // not the fixed one
        clock.advance(staggered - clock.now() + Duration::from_micros(10));
        let head = clock.now();
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);
        b.push("t", 1);
        match s.pick(&b, clock.now()) {
            Decision::Hold { task, until } => {
                assert_eq!(task, "t");
                assert_eq!(
                    until,
                    head + max_wait + Duration::from_millis(3),
                    "hold bound comes from the coordinator, not the fixed coupling"
                );
            }
            other => panic!("expected Hold at the staggered trigger, got {other:?}"),
        }
    }

    #[test]
    fn pressure_shrinks_fills_and_a_swap_restores_then_boosts_them() {
        use crate::serve::api::Metrics;
        use crate::serve::refresh::RefreshRunner;
        use crate::serve::registry::SharedRegistry;

        let clock = Arc::new(VirtualClock::new());
        let registry = SharedRegistry::new();
        registry.deploy(
            "t",
            ParamStore::from_tensors(vec![crate::model::params::Tensor::zeros("a", &[1])]),
        );
        let age = DecayModel::analytic(PcmModel::default()).trigger_age(0.05);
        let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), noop_refitter())
            .tolerance(0.05)
            .time_scale(age / 10.0); // trigger at ~10s of pool clock
        let mut runner = RefreshRunner::new(
            cfg,
            registry.clone(),
            Arc::new(ParamStore::default()),
            Arc::new(Metrics::default()),
        );
        runner.track_deployed(clock.now());
        let h = runner.policy().handle();

        let window = Duration::from_secs(4);
        let post_swap = Duration::from_secs(2);
        let mut s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320).coupling(
                RefreshCoupling::default()
                    .window(window)
                    .post_swap_window(post_swap)
                    .post_swap_factor(2.0),
            ),
            8,
            Duration::from_millis(5),
        )
        .with_refresh(h.clone());

        // teach a cadence whose modeled-optimal fill is exactly 4
        let per = |b: usize| s.modeled_batch_ns(b) / b as f64;
        let ia = Duration::from_nanos(((per(3) + per(4)) / 2.0).round() as u64);
        s.observe_arrival("t", clock.now());
        clock.advance(ia);
        s.observe_arrival("t", clock.now());
        assert_eq!(s.target_fill_for("t", clock.now()), 4, "baseline fill");

        let trigger = h.trigger_at("t").unwrap();
        // half-way into the window the target has shrunk
        let half = trigger - window / 2;
        assert!(s.target_fill_for("t", half) < 4, "pressure shrinks the fill");
        assert_eq!(s.target_fill_for("t", trigger), 1, "saturated pressure hits the floor");

        // run the refresh: swap lands, trigger re-anchors, pressure drops
        clock.advance(trigger - clock.now() + Duration::from_millis(1));
        let evs = runner.tick(clock.now());
        assert_eq!(evs.len(), 1);
        let now = clock.now();
        assert_eq!(s.drift_pressure("t", now), 0.0, "fresh deployment: no pressure");
        // inside the post-swap window fills are extended (4 -> 8)...
        assert_eq!(s.target_fill_for("t", now), 8, "post-swap amortisation boost");
        // ...and revert once it closes
        assert_eq!(s.target_fill_for("t", now + post_swap), 4);
    }
}
