//! Pipeline-aware batch scheduling: the AIMC ⇄ PMCA cost model on the
//! serving hot path.
//!
//! # The balancing contract
//!
//! On the target system one request batch flows through a two-stage
//! pipeline per layer: the AIMC crossbar integrates `t` tokens per MVM
//! hand-off while the PMCA (Snitch cluster + RedMulE) computes the LoRA
//! delta for the *previous* hand-off. The paper's Fig. 4 analysis shows
//! that end-to-end latency is minimised when the two stage latencies are
//! balanced and the PMCA working set fits its 128 KiB TCDM — the exact
//! objective [`crate::pipeline::balance::sweep`] + [`best`] encode.
//!
//! [`BatchScheduler`] lifts that offline model into the worker loop:
//!
//! * **Token parallelism.** At construction it sweeps the paper's
//!   candidate `t` values for the configured layer shape and integration
//!   time and commits to the TCDM-fitting latency optimum
//!   ([`BatchScheduler::t_opt`]). An integration test pins this to
//!   [`crate::pipeline::balance::sweep`] for every Fig. 4 configuration.
//! * **Batch-close decision.** For a request fill `b` the modeled
//!   steady-state service latency is `L(b)` (the pipeline model run over
//!   `b · seq_len` tokens at `t_opt`). The scheduler closes a batch at
//!   the smallest fill whose modeled per-request service time `L(b)/b`
//!   keeps up with the task's observed arrival rate — the throughput-
//!   sustaining fill. Slower arrivals → smaller batches (latency-
//!   optimal); faster arrivals → larger batches (the fixed hand-off and
//!   kernel-launch overheads amortise). A per-task `max_wait` deadline
//!   still bounds worst-case queueing, exactly as in the fixed batcher.
//! * **Modeled-vs-measured.** Every decision carries the model's
//!   predicted batch latency so [`super::api::Metrics`] (and
//!   `util::bench` scenarios) can report model error alongside wall
//!   time.
//!
//! All timing flows through the [`Clock`] trait so the scheduler, the
//! [`super::batcher::Batcher`], and the worker loop are testable on a
//! [`VirtualClock`] with no wall-clock sleeps. The drift-refresh policy
//! ([`super::refresh`]) reuses the same clock for its deployment-age
//! tracking, so trigger→refit→swap cycles are virtual-clock-testable
//! end to end.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::pipeline::balance::{best, sweep, BalancePoint};
use crate::pipeline::schedule::pipeline_latency;
use crate::pmca::cluster::SnitchCluster;
use crate::pmca::kernels::LoraWorkload;
use crate::pmca::redmule::RedMulE;

use super::batcher::Batcher;

// ---------------------------------------------------------------------------
// Clock
// ---------------------------------------------------------------------------

/// Time source for everything in the serving pool that waits or
/// timestamps. Production uses [`RealClock`]; tests use [`VirtualClock`]
/// and advance it explicitly, so no test ever sleeps.
pub trait Clock: Send + Sync {
    fn now(&self) -> Instant;

    /// Pause for `d`. The virtual clock advances itself instead of
    /// blocking the thread.
    fn sleep(&self, d: Duration) {
        std::thread::sleep(d);
    }
}

/// Wall-clock time.
#[derive(Clone, Copy, Debug, Default)]
pub struct RealClock;

impl Clock for RealClock {
    fn now(&self) -> Instant {
        Instant::now()
    }
}

/// Deterministic test clock: starts at an arbitrary epoch and only moves
/// when [`advance`](VirtualClock::advance) is called (or something
/// `sleep`s on it).
#[derive(Debug)]
pub struct VirtualClock {
    epoch: Instant,
    offset: Mutex<Duration>,
}

impl VirtualClock {
    pub fn new() -> VirtualClock {
        VirtualClock {
            epoch: Instant::now(),
            offset: Mutex::new(Duration::ZERO),
        }
    }

    pub fn advance(&self, d: Duration) {
        *self.offset.lock().unwrap() += d;
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Instant {
        self.epoch + *self.offset.lock().unwrap()
    }

    fn sleep(&self, d: Duration) {
        self.advance(d);
    }
}

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Hardware-model parameters for one serving deployment: the dominant
/// layer shape the AIMC tiles hold, the LoRA rank on the PMCA, and the
/// tile integration time.
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Weight matrix rows of the modeled layer (input features).
    pub m: usize,
    /// Weight matrix cols of the modeled layer (output features).
    pub n: usize,
    /// LoRA rank.
    pub r: usize,
    /// AIMC tile integration time per MVM, ns.
    pub t_int_ns: f64,
    /// Tokens per request sequence. `0` means "inherit the serving
    /// graph's sequence length" (resolved by `ServerBuilder::build`).
    pub seq_len: usize,
}

impl SchedConfig {
    /// Model a deployment dominated by an `m×n` layer at LoRA rank `r`,
    /// with the paper's middle integration time (256 ns) and the
    /// sequence length inherited from the serving graph.
    pub fn for_layer(m: usize, n: usize, r: usize) -> SchedConfig {
        SchedConfig {
            m: m.max(1),
            n: n.max(1),
            r: r.max(1),
            t_int_ns: 256.0,
            seq_len: 0,
        }
    }

    pub fn t_int(mut self, ns: f64) -> Self {
        self.t_int_ns = ns;
        self
    }

    pub fn seq(mut self, seq_len: usize) -> Self {
        self.seq_len = seq_len;
        self
    }
}

// ---------------------------------------------------------------------------
// Arrival-rate estimation
// ---------------------------------------------------------------------------

/// EWMA of one task's request inter-arrival time.
#[derive(Clone, Debug, Default)]
struct ArrivalEstimator {
    last: Option<Instant>,
    ewma_ns: Option<f64>,
}

impl ArrivalEstimator {
    const ALPHA: f64 = 0.25;

    fn observe(&mut self, now: Instant) {
        if let Some(last) = self.last {
            let dt = now.saturating_duration_since(last).as_nanos() as f64;
            self.ewma_ns = Some(match self.ewma_ns {
                Some(e) => (1.0 - Self::ALPHA) * e + Self::ALPHA * dt,
                None => dt,
            });
        }
        self.last = Some(now);
    }

    /// Estimated inter-arrival time in ns; +inf until two arrivals have
    /// been seen (an unknown rate must not hold requests back).
    fn interarrival_ns(&self) -> f64 {
        self.ewma_ns.unwrap_or(f64::INFINITY)
    }
}

// ---------------------------------------------------------------------------
// Scheduler
// ---------------------------------------------------------------------------

/// What the worker loop should do next (see [`BatchScheduler::pick`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Pop `fill` requests of `task` and serve them now.
    Close { task: String, fill: usize },
    /// Nothing is ready; sleep until `until` (earliest deadline) unless
    /// an arrival wakes the worker first.
    Wait { until: Instant },
    /// No queued work at all.
    Idle,
}

/// Cost-based batch scheduler (see the module docs for the contract).
pub struct BatchScheduler {
    cfg: SchedConfig,
    max_batch: usize,
    max_wait: Duration,
    /// Winning point of the `pipeline::balance` sweep for this layer.
    balance: BalancePoint,
    /// `modeled_ns[b-1]` = modeled steady-state latency (ns) of serving
    /// a batch of `b` requests at `t_opt`.
    modeled_ns: Vec<f64>,
    arrivals: BTreeMap<String, ArrivalEstimator>,
}

impl BatchScheduler {
    /// Build against the paper's default Snitch cluster + RedMulE.
    pub fn new(cfg: SchedConfig, max_batch: usize, max_wait: Duration) -> BatchScheduler {
        Self::with_hardware(
            cfg,
            max_batch,
            max_wait,
            &SnitchCluster::default(),
            &RedMulE::default(),
        )
    }

    pub fn with_hardware(
        cfg: SchedConfig,
        max_batch: usize,
        max_wait: Duration,
        cluster: &SnitchCluster,
        engine: &RedMulE,
    ) -> BatchScheduler {
        let seq = cfg.seq_len.max(1);
        let max_batch = max_batch.max(1);
        let points = sweep(cfg.m, cfg.n, cfg.r, cfg.t_int_ns, seq, cluster, engine);
        let balance = best(&points);
        let w = LoraWorkload::new(cfg.m, cfg.n, cfg.r, balance.t);
        let modeled_ns = (1..=max_batch)
            .map(|b| pipeline_latency(&w, cfg.t_int_ns, b * seq, cluster, engine).steady_ns)
            .collect();
        BatchScheduler {
            cfg,
            max_batch,
            max_wait,
            balance,
            modeled_ns,
            arrivals: BTreeMap::new(),
        }
    }

    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// The chosen token parallelism — identical to
    /// `balance::best(&balance::sweep(..)).t` by construction.
    pub fn t_opt(&self) -> usize {
        self.balance.t
    }

    /// The full balance point backing [`Self::t_opt`].
    pub fn balance_point(&self) -> BalancePoint {
        self.balance
    }

    /// Modeled steady-state latency for a batch of `fill` requests (ns).
    pub fn modeled_batch_ns(&self, fill: usize) -> f64 {
        self.modeled_ns[fill.clamp(1, self.modeled_ns.len()) - 1]
    }

    /// Modeled batch latency as a [`Duration`] (for metrics).
    pub fn modeled_batch(&self, fill: usize) -> Duration {
        Duration::from_nanos(self.modeled_batch_ns(fill).round() as u64)
    }

    /// The modeled-optimal fill for a task whose requests arrive every
    /// `interarrival_ns`: the smallest batch whose per-request service
    /// time keeps up with arrivals, `max_batch` if none does.
    pub fn target_fill(&self, interarrival_ns: f64) -> usize {
        for b in 1..=self.modeled_ns.len() {
            if self.modeled_batch_ns(b) / b as f64 <= interarrival_ns {
                return b;
            }
        }
        self.modeled_ns.len()
    }

    /// Current inter-arrival estimate for a task (ns; +inf if unknown).
    pub fn interarrival_ns(&self, task: &str) -> f64 {
        self.arrivals
            .get(task)
            .map(|a| a.interarrival_ns())
            .unwrap_or(f64::INFINITY)
    }

    /// Feed one observed arrival into the task's rate estimator.
    pub fn observe_arrival(&mut self, task: &str, now: Instant) {
        self.arrivals.entry(task.to_string()).or_default().observe(now);
    }

    /// Decide the next action over the batcher's queues. A task is
    /// ready when it reached its modeled-optimal fill or its oldest
    /// request hit the deadline; among ready tasks the oldest head
    /// wins (no starvation), matching the fixed batcher's fairness.
    pub fn pick<T>(&self, batcher: &Batcher<T>, now: Instant) -> Decision {
        let mut close: Option<(String, usize, Instant)> = None;
        let mut wake: Option<Instant> = None;
        for (task, len, head) in batcher.heads() {
            let deadline = head + self.max_wait;
            let target = self.target_fill(self.interarrival_ns(task));
            if len >= target || now >= deadline {
                let older = close.as_ref().map(|(_, _, h)| head < *h).unwrap_or(true);
                if older {
                    close = Some((task.to_string(), len.min(self.max_batch), head));
                }
            } else {
                wake = Some(wake.map_or(deadline, |w: Instant| w.min(deadline)));
            }
        }
        match close {
            Some((task, fill, _)) => Decision::Close { task, fill },
            None => match wake {
                Some(until) => Decision::Wait { until },
                None => Decision::Idle,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn sched(max_batch: usize) -> BatchScheduler {
        // the paper's small layer at the middle integration time
        BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            max_batch,
            Duration::from_millis(10),
        )
    }

    #[test]
    fn t_opt_matches_balance_sweep() {
        let (c, e) = (SnitchCluster::default(), RedMulE::default());
        for (m, n) in [(128usize, 128usize), (512, 128)] {
            for t_int in crate::pipeline::schedule::INTEGRATION_TIMES_NS {
                let s = BatchScheduler::new(
                    SchedConfig::for_layer(m, n, 8).t_int(t_int).seq(320),
                    8,
                    Duration::from_millis(5),
                );
                let b = best(&sweep(m, n, 8, t_int, 320, &c, &e));
                assert_eq!(s.t_opt(), b.t, "{m}x{n}@{t_int}");
                assert!(s.balance_point().fits_tcdm || !b.fits_tcdm);
            }
        }
    }

    #[test]
    fn per_request_model_latency_amortises() {
        let s = sched(8);
        // fixed hand-off/overhead amortise: per-request cost shrinks
        let per = |b: usize| s.modeled_batch_ns(b) / b as f64;
        assert!(per(2) < per(1));
        assert!(per(8) < per(4));
        // ...so target_fill is monotone in the arrival rate
        assert_eq!(s.target_fill(f64::INFINITY), 1);
        assert_eq!(s.target_fill(per(1) + 1.0), 1);
        assert_eq!(s.target_fill(0.0), 8);
        let mid = (per(3) + per(4)) / 2.0; // sustainable at 4, not at 3
        assert_eq!(s.target_fill(mid), 4);
    }

    #[test]
    fn close_fires_exactly_at_modeled_optimal_fill() {
        let clock = Arc::new(VirtualClock::new());
        let max_wait = Duration::from_millis(10);
        let mut s = sched(8);
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);

        // arrivals paced so the modeled-optimal fill is exactly 4
        let per = |b: usize| s.modeled_batch_ns(b) / b as f64;
        let ia = Duration::from_nanos(((per(3) + per(4)) / 2.0).round() as u64);

        // prior traffic at the same cadence primes the rate estimator
        // (a cold task with an unknown rate closes immediately instead)
        s.observe_arrival("sst2", clock.now());
        clock.advance(ia);
        s.observe_arrival("sst2", clock.now());

        for i in 0..4u32 {
            clock.advance(ia);
            let now = clock.now();
            s.observe_arrival("sst2", now);
            b.push("sst2", i);
            match s.pick(&b, now) {
                Decision::Close { task, fill } => {
                    assert_eq!(i, 3, "closed early at fill {}", i + 1);
                    assert_eq!(task, "sst2");
                    assert_eq!(fill, 4, "must close at the modeled-optimal fill");
                }
                Decision::Wait { until } => {
                    assert!(i < 3, "must close once the optimal fill is reached");
                    assert!(until > now);
                }
                Decision::Idle => panic!("queue is non-empty"),
            }
        }
        let items = b.pop_task("sst2", 4).unwrap();
        assert_eq!(items, vec![0, 1, 2, 3]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_fires_on_virtual_clock_without_fill() {
        let clock = Arc::new(VirtualClock::new());
        let max_wait = Duration::from_millis(5);
        let mut s = BatchScheduler::new(
            SchedConfig::for_layer(128, 128, 8).seq(320),
            8,
            max_wait,
        );
        let mut b: Batcher<u32> =
            Batcher::with_clock(8, max_wait, clock.clone() as Arc<dyn Clock>);
        let t0 = clock.now();
        b.push("qqp", 7);

        // an unknown arrival rate must not hold requests back
        assert_eq!(
            s.pick(&b, t0),
            Decision::Close { task: "qqp".into(), fill: 1 },
            "unknown rate serves immediately (latency-optimal)"
        );

        // teach it a fast arrival rate so it wants a full batch...
        let mut obs = t0;
        for _ in 0..3 {
            s.observe_arrival("qqp", obs);
            obs += Duration::from_nanos(10);
        }
        assert_eq!(s.target_fill(s.interarrival_ns("qqp")), 8);
        // ...but only one request ever shows up: the deadline must fire
        match s.pick(&b, t0) {
            Decision::Wait { until } => assert_eq!(until, t0 + max_wait),
            other => panic!("expected Wait, got {other:?}"),
        }
        clock.advance(max_wait);
        match s.pick(&b, clock.now()) {
            Decision::Close { task, fill } => {
                assert_eq!(task, "qqp");
                assert_eq!(fill, 1, "deadline releases the partial batch");
            }
            other => panic!("expected Close, got {other:?}"),
        }
    }

    #[test]
    fn burst_traffic_closes_at_max_batch() {
        let clock = Arc::new(VirtualClock::new());
        let mut s = sched(4);
        let mut b: Batcher<u32> =
            Batcher::with_clock(4, Duration::from_millis(10), clock.clone() as Arc<dyn Clock>);
        for i in 0..6u32 {
            clock.advance(Duration::from_nanos(50)); // near-instant burst
            let now = clock.now();
            s.observe_arrival("x", now);
            b.push("x", i);
        }
        match s.pick(&b, clock.now()) {
            Decision::Close { fill, .. } => assert_eq!(fill, 4, "capped at max_batch"),
            other => panic!("expected Close, got {other:?}"),
        }
    }

    #[test]
    fn virtual_clock_sleep_advances_time() {
        let c = VirtualClock::new();
        let t0 = c.now();
        c.sleep(Duration::from_secs(3));
        assert_eq!(c.now() - t0, Duration::from_secs(3));
    }
}
