//! Per-task dynamic batching.
//!
//! Requests accumulate in per-task queues; a batch is released when it
//! reaches `max_batch` (the compiled graph's batch dimension) or when
//! its oldest request has waited `max_wait`. This is the standard
//! dynamic-batching policy (vLLM/Triton style) adapted to the fact that
//! task switches cost an adapter swap — batches never mix tasks.
//!
//! The fixed policy lives in [`Batcher::pop_ready`]; the pipeline-aware
//! scheduler ([`super::sched::BatchScheduler`]) drives the same queues
//! through [`Batcher::heads`] / [`Batcher::pop_task`] and replaces the
//! fixed fill with a modeled-optimal one. All enqueue timestamps come
//! from a [`Clock`](super::sched::Clock), so every timing test runs on
//! a virtual clock with no sleeps.

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::sched::{Clock, RealClock};

#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

pub struct Batcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    clock: Arc<dyn Clock>,
    queues: BTreeMap<String, VecDeque<Pending<T>>>,
}

impl<T: fmt::Debug> fmt::Debug for Batcher<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Batcher")
            .field("max_batch", &self.max_batch)
            .field("max_wait", &self.max_wait)
            .field("queues", &self.queues)
            .finish()
    }
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher<T> {
        Self::with_clock(max_batch, max_wait, Arc::new(RealClock))
    }

    /// Batcher on an explicit clock (virtual in tests).
    pub fn with_clock(max_batch: usize, max_wait: Duration, clock: Arc<dyn Clock>) -> Batcher<T> {
        Batcher {
            max_batch,
            max_wait,
            clock,
            queues: BTreeMap::new(),
        }
    }

    pub fn push(&mut self, task: &str, item: T) {
        let now = self.clock.now();
        self.queues.entry(task.to_string()).or_default().push_back(Pending {
            item,
            enqueued: now,
        });
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn pending_for(&self, task: &str) -> usize {
        self.queues.get(task).map(|q| q.len()).unwrap_or(0)
    }

    /// Non-empty queues as `(task, depth, oldest enqueue time)` — the
    /// view a scheduling policy needs to make a close/wait decision.
    pub fn heads(&self) -> impl Iterator<Item = (&str, usize, Instant)> + '_ {
        self.queues
            .iter()
            .filter(|(_, q)| !q.is_empty())
            .map(|(t, q)| (t.as_str(), q.len(), q.front().unwrap().enqueued))
    }

    /// Earliest instant at which a queued batch becomes deadline-ready
    /// (`None` when empty). Lets the worker sleep exactly that long
    /// instead of polling on a fixed tick.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|p| p.enqueued + self.max_wait)
            .min()
    }

    /// Release the most urgent ready batch, if any. Ready = full batch
    /// OR oldest item past the deadline. Among ready tasks, the one
    /// with the oldest head-of-line request wins (no task starvation).
    pub fn pop_ready(&mut self, now: Instant) -> Option<(String, Vec<T>)> {
        let mut best: Option<(&String, Instant)> = None;
        for (task, q) in &self.queues {
            if q.is_empty() {
                continue;
            }
            let head = q.front().unwrap().enqueued;
            let ready = q.len() >= self.max_batch || now.duration_since(head) >= self.max_wait;
            if ready && best.map(|(_, h)| head < h).unwrap_or(true) {
                best = Some((task, head));
            }
        }
        let task = best.map(|(t, _)| t.clone())?;
        let items = self.pop_task(&task, self.max_batch)?;
        Some((task, items))
    }

    /// Pop up to `n` items (at least one, at most `max_batch`) from one
    /// task's queue — the scheduler's close primitive.
    pub fn pop_task(&mut self, task: &str, n: usize) -> Option<Vec<T>> {
        let q = self.queues.get_mut(task)?;
        if q.is_empty() {
            return None;
        }
        let n = n.clamp(1, self.max_batch).min(q.len());
        Some(q.drain(..n).map(|p| p.item).collect())
    }

    /// Remove one task's ENTIRE queue with the enqueue stamps intact
    /// (`None` when empty) — the span-migration handoff primitive: the
    /// extracted entries re-enter the destination span's batcher
    /// through [`Batcher::adopt`], so a moved request keeps its
    /// original deadline instead of restarting its wait.
    pub fn take_task(&mut self, task: &str) -> Option<Vec<Pending<T>>> {
        let q = self.queues.remove(task)?;
        if q.is_empty() {
            return None;
        }
        Some(q.into_iter().collect())
    }

    /// Append entries previously extracted by [`Batcher::take_task`],
    /// preserving their enqueue stamps (and therefore their deadlines
    /// and head-of-line age ordering).
    pub fn adopt(&mut self, task: &str, items: Vec<Pending<T>>) {
        self.queues.entry(task.to_string()).or_default().extend(items);
    }

    /// Drain everything regardless of deadlines (shutdown path).
    pub fn drain_all(&mut self) -> Vec<(String, Vec<T>)> {
        let mut out = Vec::new();
        for (task, q) in &mut self.queues {
            while !q.is_empty() {
                let n = q.len().min(self.max_batch);
                out.push((task.clone(), q.drain(..n).map(|p| p.item).collect()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::sched::VirtualClock;

    /// Batcher on a virtual clock the test controls — no sleeps anywhere.
    fn on_virtual_clock(
        max_batch: usize,
        max_wait: Duration,
    ) -> (Batcher<u32>, Arc<VirtualClock>) {
        let clock = Arc::new(VirtualClock::new());
        let b = Batcher::with_clock(max_batch, max_wait, clock.clone() as Arc<dyn Clock>);
        (b, clock)
    }

    #[test]
    fn full_batch_releases_immediately() {
        let (mut b, clock) = on_virtual_clock(2, Duration::from_secs(60));
        b.push("sst2", 1);
        assert!(b.pop_ready(clock.now()).is_none(), "partial batch must wait");
        b.push("sst2", 2);
        let (task, items) = b.pop_ready(clock.now()).unwrap();
        assert_eq!(task, "sst2");
        assert_eq!(items, vec![1, 2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let (mut b, clock) = on_virtual_clock(8, Duration::from_millis(3));
        b.push("qqp", 7);
        assert!(b.pop_ready(clock.now()).is_none(), "deadline not reached yet");
        clock.advance(Duration::from_millis(3));
        let (task, items) = b.pop_ready(clock.now()).unwrap();
        assert_eq!(task, "qqp");
        assert_eq!(items, vec![7]);
    }

    #[test]
    fn tasks_never_mix() {
        let (mut b, clock) = on_virtual_clock(4, Duration::from_millis(0));
        b.push("a", 1);
        b.push("b", 2);
        clock.advance(Duration::from_millis(1));
        let later = clock.now();
        let (t1, i1) = b.pop_ready(later).unwrap();
        let (t2, i2) = b.pop_ready(later).unwrap();
        assert_ne!(t1, t2);
        assert_eq!(i1.len() + i2.len(), 2);
    }

    #[test]
    fn oldest_head_of_line_wins() {
        let (mut b, clock) = on_virtual_clock(4, Duration::from_millis(0));
        b.push("late", 1);
        clock.advance(Duration::from_millis(2));
        b.push("early", 2);
        clock.advance(Duration::from_millis(1));
        // "late" was enqueued first -> served first despite name order
        let (t, _) = b.pop_ready(clock.now()).unwrap();
        assert_eq!(t, "late");
    }

    #[test]
    fn batch_size_capped() {
        let (mut b, clock) = on_virtual_clock(3, Duration::from_millis(0));
        for i in 0..7 {
            b.push("x", i);
        }
        let (_, items) = b.pop_ready(clock.now()).unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn next_deadline_tracks_oldest_head() {
        let (mut b, clock) = on_virtual_clock(4, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        b.push("a", 1);
        let first = b.next_deadline().unwrap();
        assert_eq!(first, clock.now() + Duration::from_millis(10), "deadline = enqueue + max_wait");
        clock.advance(Duration::from_millis(1));
        b.push("b", 2);
        // the deadline is set by the OLDEST head across tasks
        assert_eq!(b.next_deadline().unwrap(), first);
        clock.advance(Duration::from_millis(10));
        b.pop_ready(clock.now()).unwrap();
        assert!(b.next_deadline().unwrap() > first);
    }

    #[test]
    fn heads_and_pop_task_expose_scheduler_view() {
        let (mut b, clock) = on_virtual_clock(4, Duration::from_millis(10));
        b.push("a", 1);
        clock.advance(Duration::from_millis(1));
        b.push("a", 2);
        b.push("b", 3);
        let heads: Vec<(String, usize, Instant)> = b
            .heads()
            .map(|(t, n, h)| (t.to_string(), n, h))
            .collect();
        assert_eq!(heads.len(), 2);
        let a = heads.iter().find(|(t, _, _)| t == "a").unwrap();
        let bb = heads.iter().find(|(t, _, _)| t == "b").unwrap();
        assert_eq!(a.1, 2);
        assert_eq!(bb.1, 1);
        assert!(a.2 < bb.2, "head timestamp is the OLDEST entry");

        // partial close: pop_task takes exactly the requested fill
        assert_eq!(b.pop_task("a", 1).unwrap(), vec![1]);
        assert_eq!(b.pending_for("a"), 1);
        // and clamps to max_batch / queue depth
        assert_eq!(b.pop_task("a", 99).unwrap(), vec![2]);
        assert!(b.pop_task("a", 1).is_none(), "empty queue pops nothing");
        assert!(b.pop_task("nope", 1).is_none());
    }

    #[test]
    fn drain_all_empties() {
        let (mut b, _clock) = on_virtual_clock(3, Duration::from_secs(60));
        for i in 0..5 {
            b.push("x", i);
        }
        b.push("y", 9);
        let batches = b.drain_all();
        assert_eq!(batches.iter().map(|(_, v)| v.len()).sum::<usize>(), 6);
        assert_eq!(b.pending(), 0);
    }
}
