//! Per-task dynamic batching.
//!
//! Requests accumulate in per-task queues; a batch is released when it
//! reaches `max_batch` (the compiled graph's batch dimension) or when
//! its oldest request has waited `max_wait`. This is the standard
//! dynamic-batching policy (vLLM/Triton style) adapted to the fact that
//! task switches cost an adapter swap — batches never mix tasks.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Pending<T> {
    pub item: T,
    pub enqueued: Instant,
}

#[derive(Debug)]
pub struct Batcher<T> {
    pub max_batch: usize,
    pub max_wait: Duration,
    queues: BTreeMap<String, VecDeque<Pending<T>>>,
}

impl<T> Batcher<T> {
    pub fn new(max_batch: usize, max_wait: Duration) -> Batcher<T> {
        Batcher {
            max_batch,
            max_wait,
            queues: BTreeMap::new(),
        }
    }

    pub fn push(&mut self, task: &str, item: T) {
        self.queues.entry(task.to_string()).or_default().push_back(Pending {
            item,
            enqueued: Instant::now(),
        });
    }

    pub fn pending(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn pending_for(&self, task: &str) -> usize {
        self.queues.get(task).map(|q| q.len()).unwrap_or(0)
    }

    /// Earliest instant at which a queued batch becomes deadline-ready
    /// (`None` when empty). Lets the worker sleep exactly that long
    /// instead of polling on a fixed tick.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.front())
            .map(|p| p.enqueued + self.max_wait)
            .min()
    }

    /// Release the most urgent ready batch, if any. Ready = full batch
    /// OR oldest item past the deadline. Among ready tasks, the one
    /// with the oldest head-of-line request wins (no task starvation).
    pub fn pop_ready(&mut self, now: Instant) -> Option<(String, Vec<T>)> {
        let mut best: Option<(&String, Instant)> = None;
        for (task, q) in &self.queues {
            if q.is_empty() {
                continue;
            }
            let head = q.front().unwrap().enqueued;
            let ready = q.len() >= self.max_batch || now.duration_since(head) >= self.max_wait;
            if ready && best.map(|(_, h)| head < h).unwrap_or(true) {
                best = Some((task, head));
            }
        }
        let task = best.map(|(t, _)| t.clone())?;
        let q = self.queues.get_mut(&task).unwrap();
        let n = q.len().min(self.max_batch);
        let items = q.drain(..n).map(|p| p.item).collect();
        Some((task, items))
    }

    /// Drain everything regardless of deadlines (shutdown path).
    pub fn drain_all(&mut self) -> Vec<(String, Vec<T>)> {
        let mut out = Vec::new();
        for (task, q) in &mut self.queues {
            while !q.is_empty() {
                let n = q.len().min(self.max_batch);
                out.push((task.clone(), q.drain(..n).map(|p| p.item).collect()));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn now() -> Instant {
        Instant::now()
    }

    #[test]
    fn full_batch_releases_immediately() {
        let mut b: Batcher<u32> = Batcher::new(2, Duration::from_secs(60));
        b.push("sst2", 1);
        assert!(b.pop_ready(now()).is_none(), "partial batch must wait");
        b.push("sst2", 2);
        let (task, items) = b.pop_ready(now()).unwrap();
        assert_eq!(task, "sst2");
        assert_eq!(items, vec![1, 2]);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn deadline_releases_partial_batch() {
        let mut b: Batcher<u32> = Batcher::new(8, Duration::from_millis(0));
        b.push("qqp", 7);
        let (task, items) = b.pop_ready(now() + Duration::from_millis(1)).unwrap();
        assert_eq!(task, "qqp");
        assert_eq!(items, vec![7]);
    }

    #[test]
    fn tasks_never_mix() {
        let mut b: Batcher<u32> = Batcher::new(4, Duration::from_millis(0));
        b.push("a", 1);
        b.push("b", 2);
        let later = now() + Duration::from_millis(1);
        let (t1, i1) = b.pop_ready(later).unwrap();
        let (t2, i2) = b.pop_ready(later).unwrap();
        assert_ne!(t1, t2);
        assert_eq!(i1.len() + i2.len(), 2);
    }

    #[test]
    fn oldest_head_of_line_wins() {
        let mut b: Batcher<u32> = Batcher::new(4, Duration::from_millis(0));
        b.push("late", 1);
        std::thread::sleep(Duration::from_millis(2));
        b.push("early", 2);
        // "late" was enqueued first -> served first despite name order
        let (t, _) = b.pop_ready(now() + Duration::from_millis(1)).unwrap();
        assert_eq!(t, "late");
    }

    #[test]
    fn batch_size_capped() {
        let mut b: Batcher<u32> = Batcher::new(3, Duration::from_millis(0));
        for i in 0..7 {
            b.push("x", i);
        }
        let (_, items) = b.pop_ready(now()).unwrap();
        assert_eq!(items.len(), 3);
        assert_eq!(b.pending(), 4);
    }

    #[test]
    fn next_deadline_tracks_oldest_head() {
        let mut b: Batcher<u32> = Batcher::new(4, Duration::from_millis(10));
        assert!(b.next_deadline().is_none());
        b.push("a", 1);
        let first = b.next_deadline().unwrap();
        std::thread::sleep(Duration::from_millis(1));
        b.push("b", 2);
        // the deadline is set by the OLDEST head across tasks
        assert_eq!(b.next_deadline().unwrap(), first);
        let later = now() + Duration::from_millis(11);
        b.pop_ready(later).unwrap();
        assert!(b.next_deadline().unwrap() > first);
    }

    #[test]
    fn drain_all_empties() {
        let mut b: Batcher<u32> = Batcher::new(3, Duration::from_secs(60));
        for i in 0..5 {
            b.push("x", i);
        }
        b.push("y", 9);
        let batches = b.drain_all();
        assert_eq!(batches.iter().map(|(_, v)| v.len()).sum::<usize>(), 6);
        assert_eq!(b.pending(), 0);
    }
}
