//! Bounded adapter residency — the capacity tier over
//! [`SharedRegistry`].
//!
//! The paper's Table III scenario multiplexes MANY tasks over one
//! programmed analog base by hot-swapping 1.6M-param digital LoRA sets
//! on the DPUs. "Millions of users" implies far more tasks than
//! DPU-side adapter memory, so residency must be a config knob, not a
//! memory ceiling: this module keeps at most `capacity` adapters
//! resident (registry entry = resident on the DPUs), pages the
//! least-recently-used unpinned one out when a load completes, and
//! keeps every evicted adapter's bytes in a host-side backing store so
//! a reload is a bounded-latency page-in, never a refit.
//!
//! ```text
//!                     lookup(task)
//!   resident ──hit──────────────► LRU stamp, serve
//!      ▲                             │ capacity exceeded
//!      │ poll(): load due,           ▼
//!      │ evict LRU unpinned      evicted ──► registry entry removed,
//!      │                             │       version RETAINED,
//!   loading ◄──miss: queue load──────┘       bytes kept host-side
//!      ▲        (bounded queue; full ⇒ typed AdapterCold shed)
//!      │
//!   prefetch(): predicted next arrival within horizon
//!              (per-task EWMAs from serve::sched)
//! ```
//!
//! Interaction contracts:
//!
//! * **Registry is the source of residency truth.** Eviction removes
//!   the registry entry ([`SharedRegistry::evict`] — version counter
//!   retained); reload restores the same bytes at the SAME version
//!   ([`SharedRegistry::restore`]), because a page-in is not a new
//!   deployment. Manual deploys (and refresh CAS swaps) reach the
//!   cache through the registry's deploy hook, so externally deployed
//!   tasks are admitted — and the capacity bound enforced — without
//!   polling.
//! * **Refresh skips evicted tasks but keeps their drift anchor**
//!   ([`RefreshHandle::set_evicted`]): the substrate drifts whether or
//!   not the digital adapter is resident, so an evicted task must not
//!   accumulate stale *debt* it cannot act on, and must not come back
//!   with a fresh-looking drift clock it does not deserve. Restoring
//!   at the retained version is what lets the refresh reconciler
//!   recognise the adapter and leave `deployed_at` untouched.
//! * **Loads are serialized** through one modeled DPU upload channel
//!   (`load_latency` each, FIFO): a burst of cold tasks queues, and
//!   past `load_queue` in-flight loads the request is shed with the
//!   typed [`ServeError::AdapterCold`](super::api::ServeError) — never
//!   silently dropped.
//!
//! Lock order: `state` may be held across registry calls (the registry
//! never re-enters the cache while locked — the deploy hook fires
//! after the registry lock is released, and touches only the leaf
//! `pending`/`backing` locks, never `state`).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex, Weak};
use std::time::{Duration, Instant};

use crate::model::params::ParamStore;

use super::api::Metrics;
use super::refresh::RefreshHandle;
use super::registry::SharedRegistry;
use super::sched::{ArrivalRate, Clock};

// ---------------------------------------------------------------------------
// Config
// ---------------------------------------------------------------------------

/// Knobs for the adapter capacity tier (builder-style setters, wired
/// through `ServerBuilder::adapter_cache`).
#[derive(Clone, Debug)]
pub struct CacheConfig {
    capacity: usize,
    pinned: BTreeSet<String>,
    load_queue: usize,
    load_latency: Duration,
    /// Per-task upload-latency overrides. Heterogeneous pools
    /// ([`ServerBuilder::backend`](super::api::ServerBuilder::backend))
    /// install each routed task's own backend deploy cost here, so a
    /// page-in is charged what THAT substrate's programming takes.
    per_task_load_latency: BTreeMap<String, Duration>,
    prefetch: bool,
    prefetch_horizon: Option<Duration>,
}

impl Default for CacheConfig {
    fn default() -> CacheConfig {
        CacheConfig {
            capacity: 64,
            pinned: BTreeSet::new(),
            load_queue: 16,
            // modeled DPU upload of one 1.6M-param adapter set
            load_latency: Duration::from_micros(500),
            per_task_load_latency: BTreeMap::new(),
            prefetch: true,
            prefetch_horizon: None,
        }
    }
}

impl CacheConfig {
    pub fn new(capacity: usize) -> CacheConfig {
        CacheConfig::default().capacity(capacity)
    }

    /// Maximum resident adapters (the DPU adapter-memory budget).
    pub fn capacity(mut self, n: usize) -> Self {
        self.capacity = n.max(1);
        self
    }

    /// Pin `task`: always resident once loaded, never chosen for
    /// eviction. Pins count against `capacity`.
    pub fn pin(mut self, task: &str) -> Self {
        self.pinned.insert(task.to_string());
        self
    }

    /// Bound on in-flight + queued adapter loads; beyond it cold
    /// requests are shed with the typed error.
    pub fn load_queue(mut self, n: usize) -> Self {
        self.load_queue = n.max(1);
        self
    }

    /// Modeled DPU upload time per adapter (loads serialize on one
    /// upload channel).
    pub fn load_latency(mut self, d: Duration) -> Self {
        self.load_latency = d;
        self
    }

    /// Override the upload latency for one task (its backend's deploy
    /// cost; see the `per_task_load_latency` field docs).
    pub fn task_load_latency(mut self, task: &str, d: Duration) -> Self {
        self.per_task_load_latency.insert(task.to_string(), d);
        self
    }

    /// The upload latency charged for paging `task` in.
    pub fn load_latency_for(&self, task: &str) -> Duration {
        self.per_task_load_latency
            .get(task)
            .copied()
            .unwrap_or(self.load_latency)
    }

    /// Enable/disable predictive prefetch from the scheduler's
    /// arrival-rate EWMAs.
    pub fn prefetch(mut self, on: bool) -> Self {
        self.prefetch = on;
        self
    }

    /// How far ahead a predicted arrival may be for prefetch to start
    /// the load (default: 4× `load_latency` — enough lead time for the
    /// upload to finish before the request lands).
    pub fn prefetch_horizon(mut self, d: Duration) -> Self {
        self.prefetch_horizon = Some(d);
        self
    }

    pub fn is_pinned(&self, task: &str) -> bool {
        self.pinned.contains(task)
    }

    fn horizon(&self) -> Duration {
        self.prefetch_horizon.unwrap_or(self.load_latency * 4)
    }

    /// Reject configs whose pins fill (or overflow) the capacity: with
    /// no evictable slot left, no cold task could ever be paged in.
    pub fn validate(&self) -> Result<(), String> {
        if self.pinned.len() >= self.capacity {
            return Err(format!(
                "adapter cache capacity {} must exceed the {} pinned task(s): \
                 pins are unevictable, and a full-pin cache could never page \
                 a cold adapter in",
                self.capacity,
                self.pinned.len()
            ));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Cache
// ---------------------------------------------------------------------------

/// Outcome of one residency lookup (see [`AdapterCache::lookup`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheLookup {
    /// Resident: serve now (LRU stamp bumped).
    Hit,
    /// A load is already in flight; retry after `ready_at`.
    Loading { ready_at: Instant },
    /// Miss; a load was queued on the upload channel just now.
    Queued { ready_at: Instant },
    /// Miss and the load queue is full — shed with the typed error.
    Shed,
    /// Never deployed: not the cache's task (callers report
    /// `UnknownTask`, not `AdapterCold`).
    Unknown,
}

struct Resident {
    last_used: u64,
    /// Residency was created by the prefetcher and no demand request
    /// has touched it yet — the first demand hit counts as a prefetch
    /// hit (the number the predictive tier is judged on).
    prefetched: bool,
}

struct Load {
    ready_at: Instant,
    /// First demand-miss instant: the cold-start clock. `None` for
    /// prefetch-initiated loads until a demand request arrives
    /// mid-load; pure prefetch loads record no cold-start sample.
    requested: Option<Instant>,
}

#[derive(Default)]
struct CacheState {
    resident: BTreeMap<String, Resident>,
    loading: BTreeMap<String, Load>,
    /// Runtime pin set (seeded from the config; `pin`/`unpin` mutate).
    pins: BTreeSet<String>,
    /// Monotone LRU stamp — virtual-clock traces touch many tasks at
    /// the same instant, so recency is sequenced, not timed.
    seq: u64,
    /// End of the last queued upload: loads serialize FIFO on one
    /// modeled DPU upload channel.
    last_ready: Option<Instant>,
}

/// The bounded adapter capacity tier. One per pool, shared by the
/// client (admission), every worker (miss path + prefetch), and the
/// registry's deploy hook.
pub struct AdapterCache {
    cfg: CacheConfig,
    registry: SharedRegistry,
    clock: Arc<dyn Clock>,
    metrics: Arc<Metrics>,
    refresh: Mutex<Option<RefreshHandle>>,
    state: Mutex<CacheState>,
    /// Deploys observed by the registry hook, drained into `state` on
    /// the next cache call. The hook must not take `state` (it runs
    /// re-entrantly under cache-initiated registry calls), so these two
    /// are leaf locks.
    pending: Mutex<Vec<String>>,
    /// Host-side copy of every task's latest adapter bytes + version —
    /// what an eviction keeps and a reload restores. Kept fresh by the
    /// deploy hook (manual deploys AND refresh CAS swaps land here).
    backing: Mutex<BTreeMap<String, (Arc<ParamStore>, u64)>>,
    /// Live per-task upload-latency overrides, written by the span
    /// rebalancer when a task migrates to a backend with different
    /// deploy characteristics (leaf lock; never held across `state`).
    latency_overrides: Mutex<BTreeMap<String, Duration>>,
}

impl AdapterCache {
    /// Build the tier over `registry` and register its deploy hook.
    /// Everything already deployed is adopted immediately (evicting
    /// down to `capacity`, LRU = task order for the initial set).
    pub fn new(
        cfg: CacheConfig,
        registry: SharedRegistry,
        clock: Arc<dyn Clock>,
        metrics: Arc<Metrics>,
    ) -> Arc<AdapterCache> {
        let cache = Arc::new(AdapterCache {
            state: Mutex::new(CacheState {
                pins: cfg.pinned.clone(),
                ..CacheState::default()
            }),
            cfg,
            registry: registry.clone(),
            clock,
            metrics,
            refresh: Mutex::new(None),
            pending: Mutex::new(Vec::new()),
            backing: Mutex::new(BTreeMap::new()),
            latency_overrides: Mutex::new(BTreeMap::new()),
        });
        let weak: Weak<AdapterCache> = Arc::downgrade(&cache);
        registry.set_deploy_hook(Arc::new(move |task, params, version| {
            if let Some(c) = weak.upgrade() {
                c.backing
                    .lock()
                    .unwrap()
                    .insert(task.to_string(), (params.clone(), version));
                c.pending.lock().unwrap().push(task.to_string());
            }
        }));
        cache.adopt_deployed();
        cache
    }

    /// Attach the refresh lifecycle handle: evictions suppress refits
    /// ([`RefreshHandle::set_evicted`]), reloads re-enable them with
    /// the drift anchor intact.
    pub fn set_refresh(&self, handle: RefreshHandle) {
        *self.refresh.lock().unwrap() = Some(handle);
    }

    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Pool-clock now, for callers without their own clock handle.
    pub fn now(&self) -> Instant {
        self.clock.now()
    }

    pub fn is_resident(&self, task: &str) -> bool {
        self.state.lock().unwrap().resident.contains_key(task)
    }

    pub fn resident_count(&self) -> usize {
        self.state.lock().unwrap().resident.len()
    }

    pub fn resident_tasks(&self) -> Vec<String> {
        self.state.lock().unwrap().resident.keys().cloned().collect()
    }

    pub fn loading_count(&self) -> usize {
        self.state.lock().unwrap().loading.len()
    }

    /// Task has been deployed at some point (resident or evicted): a
    /// miss on a known task is a cold start, not an unknown task.
    pub fn knows(&self, task: &str) -> bool {
        self.backing.lock().unwrap().contains_key(task)
    }

    /// Pin `task` at runtime (unevictable once resident).
    pub fn pin(&self, task: &str) {
        self.state.lock().unwrap().pins.insert(task.to_string());
    }

    pub fn unpin(&self, task: &str) {
        self.state.lock().unwrap().pins.remove(task);
    }

    pub fn is_pinned(&self, task: &str) -> bool {
        self.state.lock().unwrap().pins.contains(task)
    }

    /// One residency lookup for `task` at `now`, representing `weight`
    /// requests (hit/miss/shed counters move by `weight`; the pool
    /// calls with the batch fill, admission with 1). `weight == 0` is a
    /// warmth-only touch: the LRU stamp bumps, nothing is counted, and
    /// a missing task still queues a load (uncounted) so decode lanes
    /// keep their task paged in without inflating per-request rates.
    pub fn lookup(&self, task: &str, now: Instant, weight: usize) -> CacheLookup {
        self.drain_pending();
        let mut st = self.state.lock().unwrap();
        if st.resident.contains_key(task) {
            st.seq += 1;
            let seq = st.seq;
            let r = st.resident.get_mut(task).expect("checked resident");
            r.last_used = seq;
            if weight > 0 {
                if r.prefetched {
                    r.prefetched = false;
                    self.metrics.cache_prefetch_hits.fetch_add(1, Ordering::Relaxed);
                }
                self.metrics.cache_hits.fetch_add(weight as u64, Ordering::Relaxed);
            }
            return CacheLookup::Hit;
        }
        if let Some(load) = st.loading.get_mut(task) {
            if weight > 0 {
                self.metrics.cache_misses.fetch_add(weight as u64, Ordering::Relaxed);
                // first demand against a prefetch-initiated load starts
                // the cold-start clock: the requester waits from HERE
                if load.requested.is_none() {
                    load.requested = Some(now);
                }
            }
            return CacheLookup::Loading { ready_at: load.ready_at };
        }
        if !self.knows(task) {
            return CacheLookup::Unknown;
        }
        if weight > 0 {
            self.metrics.cache_misses.fetch_add(weight as u64, Ordering::Relaxed);
        }
        if st.loading.len() >= self.cfg.load_queue {
            if weight > 0 {
                self.metrics.cache_shed.fetch_add(weight as u64, Ordering::Relaxed);
            }
            return CacheLookup::Shed;
        }
        let ready_at = self.start_load(&mut st, task, now, (weight > 0).then_some(now));
        CacheLookup::Queued { ready_at }
    }

    /// Complete every load due at `now`: evict the LRU unpinned
    /// resident if the cache is full, page the adapter back in at its
    /// retained version, re-enable refresh for it, and record the
    /// cold-start latency for demand-initiated loads. Returns the tasks
    /// that became resident. The worker loop calls this once per pass.
    pub fn poll(&self, now: Instant) -> Vec<String> {
        self.drain_pending();
        let mut landed = Vec::new();
        let mut st = self.state.lock().unwrap();
        let due: Vec<String> = st
            .loading
            .iter()
            .filter(|(_, l)| l.ready_at <= now)
            .map(|(t, _)| t.clone())
            .collect();
        for task in due {
            let backed = self.backing.lock().unwrap().get(&task).cloned();
            let Some((params, version)) = backed else {
                st.loading.remove(&task);
                continue;
            };
            if self.registry.contains(&task) {
                // a concurrent manual deploy raced the load in: the
                // hook's pending entry admits it — drop the load
                st.loading.remove(&task);
                continue;
            }
            if !self.make_room(&mut st) {
                // every resident is pinned: leave the load queued
                break;
            }
            let load = st.loading.remove(&task).expect("due load present");
            if self.registry.restore(&task, params, version) {
                st.seq += 1;
                let seq = st.seq;
                st.resident.insert(
                    task.clone(),
                    Resident {
                        last_used: seq,
                        prefetched: load.requested.is_none(),
                    },
                );
                if let Some(h) = self.refresh.lock().unwrap().as_ref() {
                    // same version ⇒ the reconciler keeps deployed_at:
                    // the adapter resumes with its FULL drift age
                    h.set_evicted(&task, false);
                }
                if let Some(t0) = load.requested {
                    self.metrics
                        .record_cold_start(now.saturating_duration_since(t0));
                }
                landed.push(task);
            }
        }
        landed
    }

    /// Predictive paging: queue loads for known, non-resident tasks
    /// whose predicted next arrival (from the scheduler's per-task
    /// EWMAs, [`super::sched::BatchScheduler::arrival_rates`]) falls
    /// within the horizon of `now` — so the upload finishes before the
    /// request lands. Predictions far in the PAST are skipped too: a
    /// task that stopped arriving would otherwise be re-paged forever.
    /// Returns the number of loads started.
    pub fn prefetch(&self, now: Instant, rates: &[(String, ArrivalRate)]) -> usize {
        if !self.cfg.prefetch {
            return 0;
        }
        self.drain_pending();
        let horizon = self.cfg.horizon();
        let mut started = 0;
        let mut st = self.state.lock().unwrap();
        for (task, rate) in rates {
            if st.resident.contains_key(task) || st.loading.contains_key(task) {
                continue;
            }
            if st.loading.len() >= self.cfg.load_queue {
                break;
            }
            if !self.knows(task) {
                continue;
            }
            let predicted = rate.predicted_next();
            let imminent = predicted <= now + horizon && predicted + horizon >= now;
            if imminent {
                self.start_load(&mut st, task, now, None);
                started += 1;
            }
        }
        started
    }

    /// Drain the deploy-hook queue: externally deployed tasks become
    /// resident (they ARE in the registry) and the capacity bound is
    /// enforced by evicting LRU unpinned residents.
    fn drain_pending(&self) {
        let pend: Vec<String> = {
            let mut p = self.pending.lock().unwrap();
            if p.is_empty() {
                return;
            }
            std::mem::take(&mut *p)
        };
        let mut st = self.state.lock().unwrap();
        for task in pend {
            // a deploy supersedes any in-flight load of older bytes
            st.loading.remove(&task);
            st.seq += 1;
            let seq = st.seq;
            st.resident.insert(
                task.clone(),
                Resident {
                    last_used: seq,
                    prefetched: false,
                },
            );
            if let Some(h) = self.refresh.lock().unwrap().as_ref() {
                h.set_evicted(&task, false);
            }
            self.enforce_capacity(&mut st);
        }
    }

    fn adopt_deployed(&self) {
        let mut backing = BTreeMap::new();
        let mut pend = Vec::new();
        for task in self.registry.tasks() {
            if let Some((params, v)) = self.registry.snapshot(&task) {
                backing.insert(task.clone(), (params, v));
                pend.push(task);
            }
        }
        self.backing.lock().unwrap().extend(backing);
        self.pending.lock().unwrap().extend(pend);
        self.drain_pending();
    }

    fn enforce_capacity(&self, st: &mut CacheState) {
        while st.resident.len() > self.cfg.capacity {
            if !self.evict_lru(st) {
                break;
            }
        }
    }

    /// Room for one incoming adapter: spare capacity, or one LRU
    /// unpinned eviction. `false` when every resident is pinned.
    fn make_room(&self, st: &mut CacheState) -> bool {
        if st.resident.len() < self.cfg.capacity {
            return true;
        }
        self.evict_lru(st)
    }

    fn evict_lru(&self, st: &mut CacheState) -> bool {
        let victim = st
            .resident
            .iter()
            .filter(|(task, _)| !st.pins.contains(*task))
            .min_by_key(|(task, r)| (r.last_used, task.to_string()))
            .map(|(task, _)| task.clone());
        let Some(task) = victim else {
            return false;
        };
        st.resident.remove(&task);
        // the registry evict retains the version counter; the backing
        // store (kept fresh by the deploy hook) already has the bytes
        self.registry.evict(&task);
        self.metrics.cache_evictions.fetch_add(1, Ordering::Relaxed);
        if let Some(h) = self.refresh.lock().unwrap().as_ref() {
            h.set_evicted(&task, true);
        }
        true
    }

    /// Override the upload latency charged when `task` is next paged
    /// in. The span rebalancer calls this mid-migration so cache
    /// residency follows the task: a reload after the move pays the
    /// NEW backend's deploy cost, not the build-time one.
    pub fn set_task_load_latency(&self, task: &str, d: Duration) {
        self.latency_overrides
            .lock()
            .unwrap()
            .insert(task.to_string(), d);
    }

    /// The upload latency charged for paging `task` in: the live
    /// migration override when one exists, the build-time config
    /// otherwise.
    pub fn load_latency_for(&self, task: &str) -> Duration {
        self.latency_overrides
            .lock()
            .unwrap()
            .get(task)
            .copied()
            .unwrap_or_else(|| self.cfg.load_latency_for(task))
    }

    fn start_load(
        &self,
        st: &mut CacheState,
        task: &str,
        now: Instant,
        requested: Option<Instant>,
    ) -> Instant {
        // loads serialize FIFO on one modeled DPU upload channel
        let begin = match st.last_ready {
            Some(r) if r > now => r,
            _ => now,
        };
        let ready_at = begin + self.load_latency_for(task);
        st.last_ready = Some(ready_at);
        st.loading.insert(task.to_string(), Load { ready_at, requested });
        ready_at
    }
}

// ---------------------------------------------------------------------------
// Tests (virtual clock; the cross-subsystem suite is
// tests/cache_conformance.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::Tensor;
    use crate::serve::sched::VirtualClock;

    fn adapter(n: usize) -> ParamStore {
        ParamStore::from_tensors(vec![Tensor::zeros("lora.layers.0.wq_a", &[n, 8])])
    }

    fn rig(cfg: CacheConfig) -> (Arc<AdapterCache>, SharedRegistry, Arc<VirtualClock>) {
        let registry = SharedRegistry::new();
        let clock = Arc::new(VirtualClock::new());
        let cache = AdapterCache::new(
            cfg,
            registry.clone(),
            clock.clone() as Arc<dyn Clock>,
            Arc::new(Metrics::default()),
        );
        (cache, registry, clock)
    }

    #[test]
    fn deploys_admit_and_capacity_bounds_residency() {
        let (cache, registry, _clock) = rig(CacheConfig::new(2));
        for t in ["a", "b", "c", "d"] {
            registry.deploy(t, adapter(4));
        }
        // the hook queues admissions; any cache call drains them
        assert_eq!(cache.resident_count(), 0);
        cache.poll(cache.now());
        assert_eq!(cache.resident_count(), 2, "capacity bounds residency");
        assert_eq!(registry.tasks().len(), 2, "registry mirrors residency");
        // LRU on admission order: a and b were paged out for c and d
        assert!(cache.is_resident("c") && cache.is_resident("d"));
        assert!(registry.is_evicted("a") && registry.is_evicted("b"));
        assert_eq!(cache.metrics().cache_evictions.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn miss_queues_load_and_poll_pages_back_in_at_same_version() {
        let (cache, registry, clock) = rig(CacheConfig::new(1).load_latency(Duration::from_millis(1)));
        registry.deploy("a", adapter(4));
        registry.deploy("a", adapter(4)); // v2
        registry.deploy("b", adapter(4)); // evicts a
        cache.poll(cache.now());
        assert!(registry.is_evicted("a"));

        let now = clock.now();
        let got = cache.lookup("a", now, 1);
        let CacheLookup::Queued { ready_at } = got else {
            panic!("expected Queued, got {got:?}");
        };
        assert_eq!(ready_at, now + Duration::from_millis(1));
        // not due yet
        assert!(cache.poll(now).is_empty());
        clock.advance(Duration::from_millis(1));
        let landed = cache.poll(clock.now());
        assert_eq!(landed, vec!["a".to_string()]);
        assert_eq!(registry.version("a"), Some(2), "reload keeps the version");
        assert!(registry.is_evicted("b"), "LRU victim paged out for the reload");
        assert_eq!(cache.lookup("a", clock.now(), 1), CacheLookup::Hit);
    }

    #[test]
    fn bounded_load_queue_sheds_with_typed_outcome() {
        let (cache, registry, clock) = rig(CacheConfig::new(1).load_queue(1));
        for t in ["a", "b", "c"] {
            registry.deploy(t, adapter(4));
        }
        cache.poll(cache.now());
        let now = clock.now();
        assert!(matches!(cache.lookup("a", now, 1), CacheLookup::Queued { .. }));
        assert_eq!(cache.lookup("b", now, 1), CacheLookup::Shed, "queue full");
        assert_eq!(cache.metrics().cache_shed.load(Ordering::Relaxed), 1);
        assert_eq!(cache.lookup("zzz", now, 1), CacheLookup::Unknown);
    }

    #[test]
    fn pinned_tasks_are_never_evicted() {
        let (cache, registry, _clock) = rig(CacheConfig::new(2).pin("hot"));
        registry.deploy("hot", adapter(4));
        for t in ["b", "c", "d"] {
            registry.deploy(t, adapter(4));
        }
        cache.poll(cache.now());
        assert!(cache.is_resident("hot"), "pin survives an admission storm");
        assert_eq!(cache.resident_count(), 2);
    }

    #[test]
    fn prefetch_pages_in_before_the_predicted_arrival() {
        let (cache, registry, clock) =
            rig(CacheConfig::new(1).load_latency(Duration::from_millis(1)));
        registry.deploy("a", adapter(4));
        registry.deploy("b", adapter(4)); // evicts a
        cache.poll(cache.now());
        assert!(!cache.is_resident("a"));

        let now = clock.now();
        let rate = ArrivalRate {
            interarrival: Duration::from_millis(3),
            last: now,
        };
        // predicted next at now+3ms, horizon 4ms ⇒ load starts now
        assert_eq!(cache.prefetch(now, &[("a".to_string(), rate)]), 1);
        clock.advance(Duration::from_millis(1));
        assert_eq!(cache.poll(clock.now()), vec!["a".to_string()]);
        // the demand arrival is a hit — and a prefetch hit
        assert_eq!(cache.lookup("a", clock.now(), 1), CacheLookup::Hit);
        assert_eq!(cache.metrics().cache_prefetch_hits.load(Ordering::Relaxed), 1);
        assert_eq!(cache.metrics().cache_hits.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn stale_predictions_do_not_thrash_the_cache() {
        let (cache, registry, clock) =
            rig(CacheConfig::new(1).load_latency(Duration::from_millis(1)));
        registry.deploy("dead", adapter(4));
        registry.deploy("live", adapter(4));
        cache.poll(cache.now());
        let t0 = clock.now();
        clock.advance(Duration::from_secs(60));
        // "dead" last arrived a minute ago: predicted_next is ancient —
        // prefetch must NOT keep re-paging it in
        let rate = ArrivalRate {
            interarrival: Duration::from_millis(1),
            last: t0,
        };
        assert_eq!(cache.prefetch(clock.now(), &[("dead".to_string(), rate)]), 0);
    }

    #[test]
    fn cold_start_latency_is_recorded_for_demand_loads_only() {
        let (cache, registry, clock) =
            rig(CacheConfig::new(1).load_latency(Duration::from_millis(2)));
        registry.deploy("a", adapter(4));
        registry.deploy("b", adapter(4));
        cache.poll(cache.now());
        let now = clock.now();
        assert!(matches!(cache.lookup("a", now, 1), CacheLookup::Queued { .. }));
        clock.advance(Duration::from_millis(2));
        cache.poll(clock.now());
        let snap = cache.metrics().snapshot("cache");
        assert!(
            (snap.cold_start_p99_ms - 2.0).abs() < 1e-6,
            "demand load records its queue-to-resident wait, got {}",
            snap.cold_start_p99_ms
        );
    }

    #[test]
    fn validate_rejects_full_pin_configs() {
        assert!(CacheConfig::new(1).pin("a").validate().is_err());
        assert!(CacheConfig::new(2).pin("a").validate().is_ok());
    }
}
