//! Pool-level refresh coordination: staggered triggers and adaptive
//! coupling bounds.
//!
//! Per-worker refresh coupling ([`super::sched::RefreshCoupling`]) keeps
//! *one* shard's hot-swaps landing between batches, but every worker
//! couples to the single [`RefreshRunner`](super::refresh::RefreshRunner)
//! independently. Tasks that share a drift tolerance were deployed at
//! the same instant, so their modeled triggers coincide — and every
//! shard enters its hold window at once: a correlated stall across the
//! whole pool exactly when it should be absorbing traffic. The fixed
//! `window`/`hold` durations have the dual problem: a Trainer refit
//! takes seconds, a closure refit microseconds, and one constant fits
//! neither.
//!
//! [`RefreshCoordinator`] owns the global view and fixes both:
//!
//! * **Staggering** ([`stagger_assign`]): per-task triggers are
//!   re-phased *earlier* (never later — freshness is never sacrificed)
//!   within a configurable slack, so at most `max_concurrent_holds`
//!   shards ([`CoordConfig`]) can sit in a hold window at any
//!   instant. Assignment is a pure, deterministic
//!   function of the (trigger, task) set: permutation-invariant in task
//!   order and total-order-preserving on trigger times (property-tested
//!   in `tests/coord_conformance.rs`).
//! * **Adaptive window**: each task's coupling window is derived from
//!   the EWMA of its observed registry-swap → first-serve gaps
//!   ([`RefreshHandle::observe_swap_gap`]), replacing the fixed
//!   `window` of [`RefreshCoupling`](super::sched::RefreshCoupling).
//! * **Adaptive hold**: the hold bound is derived from the refitter's
//!   measured step budget ([`Refitter::observed_budget`] plus the
//!   runner's pool-clock bracket), so pools hold exactly as long as a
//!   swap realistically needs.
//!
//! Decisions flow back through the shared
//! [`RefreshHandle`](super::refresh::RefreshHandle) —
//! `staggered_at` / `adaptive window` / `adaptive hold` per task — so
//! the existing scheduler logic (`coupled_fill`, `coupled_deadline`,
//! the span guard) consumes staggered, adaptive state with **no
//! worker-side API change**. `ServerBuilder::build` wires a coordinator
//! automatically when both `.scheduler(..)` and `.refresh(..)` are
//! configured (`.no_coordination()` opts out); its activity lands in
//! [`Metrics::concurrent_holds_peak`] and [`Metrics::stagger_shift_ns`].
//!
//! [`Refitter::observed_budget`]: super::refresh::Refitter::observed_budget
//! [`RefreshHandle::observe_swap_gap`]: super::refresh::RefreshHandle::observe_swap_gap
//! [`Metrics::concurrent_holds_peak`]: super::api::Metrics::concurrent_holds_peak
//! [`Metrics::stagger_shift_ns`]: super::api::Metrics::stagger_shift_ns

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::api::Metrics;
use super::refresh::{CoordDecision, RefreshHandle};

// ---------------------------------------------------------------------------
// Configuration
// ---------------------------------------------------------------------------

/// Pool-coordination knobs, passed to `ServerBuilder::coordination`
/// (the builder applies `CoordConfig::default()` automatically when
/// both a scheduler and a refresh policy are configured).
#[derive(Clone, Copy, Debug)]
pub struct CoordConfig {
    /// Hard cap on shards simultaneously inside a hold window: the
    /// stagger re-phases triggers until no instant exceeds it.
    pub max_concurrent_holds: usize,
    /// How far before its modeled tolerance crossing a trigger may be
    /// re-phased. Staggering only ever moves triggers *earlier*, so the
    /// slack bounds extra refresh work, never staleness.
    pub slack: Duration,
    /// Multiplier on the observed swap-gap EWMA when deriving a task's
    /// adaptive coupling window.
    pub window_gain: f64,
    /// Clamp range for the adaptive window (keeps a collapsed or
    /// exploded EWMA from producing a degenerate coupling).
    pub min_window: Duration,
    pub max_window: Duration,
    /// Multiplier on the measured refit budget when deriving a task's
    /// adaptive hold bound (margin over the raw refit duration so the
    /// swap's registry write also fits).
    pub hold_gain: f64,
    /// Clamp range for the adaptive hold.
    pub min_hold: Duration,
    pub max_hold: Duration,
    /// Hold-interval length assumed for tasks with no measured refit
    /// budget yet (first cycle): used in the stagger spacing fallback.
    pub fallback_hold: Duration,
    /// Ramp-window length assumed for tasks with no observed swap gap
    /// yet — and the permanent FLOOR of the stagger spacing: a shard
    /// can start deferring (span guard) up to one ramp window — or one
    /// modeled batch, whichever is larger; the scheduler floors the
    /// consumed window there — before its trigger, so the spacing
    /// covers `max(windows, fallback_window) + hold`, not just the
    /// hold. Keep this at or above the deployment's modeled max-batch
    /// latency.
    pub fallback_window: Duration,
}

impl Default for CoordConfig {
    fn default() -> CoordConfig {
        CoordConfig {
            max_concurrent_holds: 1,
            slack: Duration::from_millis(500),
            window_gain: 1.0,
            min_window: Duration::from_micros(100),
            max_window: Duration::from_secs(10),
            hold_gain: 1.25,
            min_hold: Duration::from_micros(100),
            max_hold: Duration::from_secs(120),
            fallback_hold: Duration::from_millis(20),
            // the fixed RefreshCoupling default window
            fallback_window: Duration::from_millis(250),
        }
    }
}

impl CoordConfig {
    pub fn max_concurrent_holds(mut self, n: usize) -> Self {
        self.max_concurrent_holds = n.max(1);
        self
    }

    pub fn slack(mut self, d: Duration) -> Self {
        self.slack = d;
        self
    }

    pub fn window_gain(mut self, g: f64) -> Self {
        self.window_gain = g.max(f64::MIN_POSITIVE);
        self
    }

    pub fn window_bounds(mut self, min: Duration, max: Duration) -> Self {
        self.min_window = min.max(Duration::from_nanos(1));
        self.max_window = max.max(self.min_window);
        self
    }

    pub fn hold_gain(mut self, g: f64) -> Self {
        self.hold_gain = g.max(f64::MIN_POSITIVE);
        self
    }

    pub fn hold_bounds(mut self, min: Duration, max: Duration) -> Self {
        self.min_hold = min.max(Duration::from_nanos(1));
        self.max_hold = max.max(self.min_hold);
        self
    }

    pub fn fallback_hold(mut self, d: Duration) -> Self {
        self.fallback_hold = d.max(Duration::from_nanos(1));
        self
    }

    pub fn fallback_window(mut self, d: Duration) -> Self {
        self.fallback_window = d.max(Duration::from_nanos(1));
        self
    }
}

// ---------------------------------------------------------------------------
// Stagger assignment (pure)
// ---------------------------------------------------------------------------

/// One task's input to [`stagger_assign`].
#[derive(Clone, Debug)]
pub struct StaggerEntry {
    pub task: String,
    /// Modeled tolerance-crossing instant.
    pub trigger: Instant,
    /// How long the task's shard is expected to sit in a hold window
    /// once the trigger passes (the adaptive hold bound).
    pub span: Duration,
}

/// Re-phase triggers so at most `k` hold intervals
/// `[staggered, staggered + span)` overlap at any instant, moving each
/// trigger at most `slack` earlier (never later).
///
/// Deterministic and permutation-invariant: entries are processed in
/// `(trigger, task)` order regardless of input order. Total-order
/// preserving: if `trigger_a ≤ trigger_b` (ties broken by task name)
/// then `staggered_a ≤ staggered_b`. Best-effort at the slack boundary:
/// an assignment that would need more than `slack` of shift is clamped,
/// trading the concurrency bound for freshness (never the other way).
pub fn stagger_assign(
    entries: &[StaggerEntry],
    k: usize,
    slack: Duration,
) -> Vec<(String, Instant)> {
    stagger_assign_with_fixed(entries, &[], k, slack)
}

/// [`stagger_assign`] with additional immovable `(start, span)` hold
/// intervals (tasks already overdue or mid-refit whose stall is in
/// progress): assignable triggers are placed around them too.
pub fn stagger_assign_with_fixed(
    entries: &[StaggerEntry],
    fixed: &[(Instant, Duration)],
    k: usize,
    slack: Duration,
) -> Vec<(String, Instant)> {
    let k = k.max(1);
    let mut sorted: Vec<&StaggerEntry> = entries.iter().collect();
    sorted.sort_by(|a, b| a.trigger.cmp(&b.trigger).then_with(|| a.task.cmp(&b.task)));

    // process latest-first: the latest trigger keeps its phase, earlier
    // ones shift left past already-placed hold intervals as needed
    let mut placed: Vec<(Instant, Duration)> = fixed.to_vec();
    let mut out: Vec<(String, Instant)> = Vec::with_capacity(sorted.len());
    let mut next_assigned: Option<Instant> = None;
    for e in sorted.iter().rev() {
        let floor = slack_floor(e.trigger, slack);
        // order preservation: never later than the task after us
        let mut cand = match next_assigned {
            Some(n) => e.trigger.min(n),
            None => e.trigger,
        };
        loop {
            // placed intervals overlapping [cand, cand + span)
            let mut overlapping: Vec<Instant> = placed
                .iter()
                .filter(|(s, sp)| *s < cand + e.span && cand < *s + *sp)
                .map(|(s, _)| *s)
                .collect();
            if overlapping.len() < k {
                break;
            }
            // slide left until the earliest-starting conflicting hold no
            // longer overlaps; re-check (we may now conflict further left)
            overlapping.sort();
            let earliest = overlapping[0];
            let Some(shifted) = earliest.checked_sub(e.span) else {
                break;
            };
            if shifted < floor {
                cand = floor;
                break;
            }
            cand = shifted;
        }
        cand = cand.max(floor);
        // order preservation even in the saturated-floor regime (where
        // per-trigger floors are no longer monotone): never later than
        // the task after us. A no-op whenever the slack subtraction was
        // representable, since there floor ≤ next_assigned always.
        if let Some(n) = next_assigned {
            cand = cand.min(n);
        }
        placed.push((cand, e.span));
        next_assigned = Some(cand);
        out.push((e.task.clone(), cand));
    }
    out.reverse();
    out
}

// ---------------------------------------------------------------------------
// Coordinator
// ---------------------------------------------------------------------------

/// Pool-level view of the refresh lifecycle (see the module docs). The
/// refresh runner rebalances it at the top of every tick; workers and
/// the runner feed observations through the shared [`RefreshHandle`].
pub struct RefreshCoordinator {
    cfg: CoordConfig,
    handle: RefreshHandle,
    metrics: Arc<Metrics>,
}

impl RefreshCoordinator {
    pub fn new(cfg: CoordConfig, handle: RefreshHandle, metrics: Arc<Metrics>) -> RefreshCoordinator {
        RefreshCoordinator {
            cfg,
            handle,
            metrics,
        }
    }

    pub fn config(&self) -> &CoordConfig {
        &self.cfg
    }

    /// The shared lifecycle handle the coordinator writes through.
    pub fn handle(&self) -> RefreshHandle {
        self.handle.clone()
    }

    /// Adaptive coupling window currently assigned to `task`.
    pub fn adaptive_window(&self, task: &str) -> Option<Duration> {
        self.handle.adaptive_window(task)
    }

    /// Adaptive hold bound currently assigned to `task`.
    pub fn adaptive_hold(&self, task: &str) -> Option<Duration> {
        self.handle.adaptive_hold(task)
    }

    /// Staggered trigger currently assigned to `task`.
    pub fn staggered_at(&self, task: &str) -> Option<Instant> {
        self.handle.staggered_at(task)
    }

    /// Recompute adaptive bounds and the trigger stagger from the
    /// current tracked-task set, and publish the decisions through the
    /// handle under one write. Pure in its inputs — calling it twice at
    /// the same instant with the same state is a no-op — so the runner
    /// can invoke it every tick.
    pub fn rebalance(&self, now: Instant) {
        // evicted tasks (paged out by the capacity tier) are invisible
        // to coordination: they can neither refit nor hold a shard, so
        // giving one a stagger slot — or counting it as an obstacle —
        // would spend the pool's slack on a task nothing can serve. The
        // reload re-admits them here unchanged (same version, same
        // trigger), so their stagger is recomputed from the live set.
        let entries: Vec<_> = self
            .handle
            .coord_entries()
            .into_iter()
            .filter(|e| !e.evicted)
            .collect();
        // 1) adaptive bounds from the learned EWMAs
        let mut decisions: Vec<(String, CoordDecision)> = Vec::with_capacity(entries.len());
        let mut bounds: Vec<(Option<Duration>, Option<Duration>)> =
            Vec::with_capacity(entries.len());
        for e in &entries {
            let window = e.gap_ewma_ns.map(|ns| {
                clamp_dur(
                    mul_dur(Duration::from_nanos(ns.max(0.0).round() as u64), self.cfg.window_gain),
                    self.cfg.min_window,
                    self.cfg.max_window,
                )
            });
            let hold = e.refit_ewma_ns.map(|ns| {
                clamp_dur(
                    mul_dur(Duration::from_nanos(ns.max(0.0).round() as u64), self.cfg.hold_gain),
                    self.cfg.min_hold,
                    self.cfg.max_hold,
                )
            });
            decisions.push((
                e.task.clone(),
                CoordDecision {
                    staggered_at: e.staggered_at,
                    window,
                    hold,
                },
            ));
            bounds.push((window, hold));
        }
        // a shard can defer from one ramp window before its trigger
        // (span guard) until the hold bound expires after it. The
        // stagger intervals are anchored AT the trigger, so to keep the
        // concurrency bound sound under heterogeneous per-task windows
        // every span covers the WIDEST window in the pool (a task's
        // stall can reach that far into its predecessor's interval),
        // plus the task's own hold. `fallback_window` stays in the max
        // even once every task has a learned window: the scheduler
        // floors its deferral reach at the modeled batch latency —
        // which the coordinator cannot observe — so the configured
        // fallback doubles as the spacing floor that covers it.
        let max_window = bounds
            .iter()
            .map(|&(w, _)| w.unwrap_or(self.cfg.fallback_window))
            .max()
            .unwrap_or(self.cfg.fallback_window)
            .max(self.cfg.fallback_window);
        let mut stagger_in: Vec<StaggerEntry> = Vec::new();
        let mut fixed: Vec<(Instant, Duration)> = Vec::new();
        for (e, &(_, hold)) in entries.iter().zip(bounds.iter()) {
            let span = max_window + hold.unwrap_or(self.cfg.fallback_hold);
            let effective = e.staggered_at.or(e.due_at);
            match effective {
                // only future triggers of tasks not mid-refit are
                // re-phased; an overdue or refitting task's stall is in
                // progress — keep it as an immovable obstacle instead
                Some(at) if !e.refitting && at > now => {
                    // staggering always restarts from the MODELED
                    // trigger (pure in the tracked state, so repeated
                    // rebalances are idempotent)
                    stagger_in.push(StaggerEntry {
                        task: e.task.clone(),
                        trigger: e.due_at.unwrap_or(at),
                        span,
                    });
                }
                Some(at) => {
                    let start = at.checked_sub(span).unwrap_or(at);
                    fixed.push((start, span + span));
                }
                None => {}
            }
        }
        // 2) stagger the future triggers around the in-progress stalls
        let assigned: BTreeMap<String, Instant> = stagger_assign_with_fixed(
            &stagger_in,
            &fixed,
            self.cfg.max_concurrent_holds,
            self.cfg.slack,
        )
        .into_iter()
        .collect();
        // `decisions` was built in `entries` order: pair them back up
        // without quadratic searches
        let mut worst_shift = Duration::ZERO;
        for (e, d) in entries.iter().zip(decisions.iter_mut()) {
            let Some(&staggered) = assigned.get(&e.task) else {
                continue;
            };
            let modeled = e.due_at.unwrap_or(staggered);
            let shift = modeled.saturating_duration_since(staggered);
            worst_shift = worst_shift.max(shift);
            // publish only real re-phases; an unshifted task keeps
            // reading its modeled trigger
            d.1.staggered_at = (shift > Duration::ZERO).then_some(staggered);
        }
        // skip the write lock entirely when nothing changed (the steady
        // state of every tick between refreshes): workers' view() reads
        // on the scheduling hot path never contend with a no-op publish
        let changed = entries.iter().zip(decisions.iter()).any(|(e, (_, d))| {
            d.staggered_at != e.staggered_at
                || d.window != e.adaptive_window
                || d.hold != e.adaptive_hold
        });
        if changed {
            self.handle.apply_coord(&decisions);
        }
        if worst_shift > Duration::ZERO {
            self.metrics
                .stagger_shift_ns
                .fetch_max(worst_shift.as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

/// Earliest admissible stagger instant for `trigger` under `slack`.
/// `Instant` cannot represent times before its platform anchor (boot,
/// on Linux), so `trigger - slack` can underflow for generous slacks
/// on a recently-booted host — falling back to `trigger` there would
/// silently DISABLE staggering (the floor would forbid any earlier
/// re-phase). Instead, halve the slack until the subtraction is
/// representable: the floor saturates at (near) the clock's earliest
/// instant, preserving as much re-phase room as the platform allows.
fn slack_floor(trigger: Instant, slack: Duration) -> Instant {
    if let Some(at) = trigger.checked_sub(slack) {
        return at;
    }
    let mut d = slack;
    while !d.is_zero() {
        d /= 2;
        if let Some(at) = trigger.checked_sub(d) {
            return at;
        }
    }
    trigger
}

/// Saturating duration scale: a degenerate gain (or an exploded EWMA)
/// must clamp, never panic the refresh worker mid-rebalance. The cap
/// (~31M years) is far beyond any clamp bound a config can hold.
fn mul_dur(d: Duration, f: f64) -> Duration {
    const MAX_SECS: f64 = 1e15;
    let secs = d.as_secs_f64() * f;
    if secs.is_nan() || secs <= 0.0 {
        return Duration::ZERO;
    }
    Duration::from_secs_f64(secs.min(MAX_SECS))
}

fn clamp_dur(d: Duration, lo: Duration, hi: Duration) -> Duration {
    d.clamp(lo, hi)
}

// ---------------------------------------------------------------------------
// Tests (hermetic; the cross-worker conformance suite lives in
// tests/coord_conformance.rs)
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn entries(base: Instant, offsets_ms: &[u64], span_ms: u64) -> Vec<StaggerEntry> {
        offsets_ms
            .iter()
            .enumerate()
            .map(|(i, off)| StaggerEntry {
                task: format!("t{i}"),
                trigger: base + Duration::from_millis(*off),
                span: Duration::from_millis(span_ms),
            })
            .collect()
    }

    fn max_overlap(assigned: &[(String, Instant)], span: Duration) -> usize {
        let mut best = 0;
        for (_, s) in assigned {
            let at = *s; // overlap count at each interval start
            let n = assigned
                .iter()
                .filter(|(_, o)| *o <= at && at < *o + span)
                .count();
            best = best.max(n);
        }
        best
    }

    #[test]
    fn colliding_triggers_spread_to_the_concurrency_bound() {
        let base = Instant::now() + Duration::from_secs(60);
        let es = entries(base, &[100, 100, 100, 100], 10);
        let out = stagger_assign(&es, 1, Duration::from_secs(1));
        assert_eq!(out.len(), 4);
        assert_eq!(max_overlap(&out, Duration::from_millis(10)), 1);
        for (task, at) in &out {
            let e = es.iter().find(|e| e.task == *task).unwrap();
            assert!(*at <= e.trigger, "stagger never moves a trigger later");
            assert!(
                e.trigger - *at <= Duration::from_secs(1),
                "shift stays within slack"
            );
        }
        // with k=2, pairs may coincide but never triples
        let out2 = stagger_assign(&es, 2, Duration::from_secs(1));
        assert!(max_overlap(&out2, Duration::from_millis(10)) <= 2);
    }

    #[test]
    fn slack_clamps_best_effort() {
        let base = Instant::now() + Duration::from_secs(60);
        let es = entries(base, &[0, 0, 0, 0], 100);
        // only 50ms of slack for 100ms spans: full separation impossible,
        // but nothing moves later and nothing escapes the slack
        let out = stagger_assign(&es, 1, Duration::from_millis(50));
        for (task, at) in &out {
            let e = es.iter().find(|e| e.task == *task).unwrap();
            assert!(*at <= e.trigger && e.trigger - *at <= Duration::from_millis(50));
        }
    }

    #[test]
    fn already_spread_triggers_are_untouched() {
        let base = Instant::now() + Duration::from_secs(60);
        let es = entries(base, &[0, 500, 1000, 1500], 10);
        let out = stagger_assign(&es, 1, Duration::from_secs(1));
        for (task, at) in &out {
            let e = es.iter().find(|e| e.task == *task).unwrap();
            assert_eq!(*at, e.trigger, "no conflict, no shift");
        }
    }

    #[test]
    fn rebalance_publishes_adaptive_bounds_and_stagger_through_the_handle() {
        use crate::pcm::PcmModel;
        use crate::serve::refresh::{DecayModel, FnRefitter, Refit, RefreshConfig, RefreshPolicy};
        use crate::serve::sched::{Clock, VirtualClock};

        let clock = VirtualClock::new();
        let rcfg = RefreshConfig::new(
            DecayModel::analytic(PcmModel::default()),
            Arc::new(FnRefitter(
                |_: &str,
                 _: &crate::model::params::ParamStore,
                 _: &crate::model::params::ParamStore,
                 budget: usize|
                 -> anyhow::Result<Refit> {
                    Ok(Refit {
                        params: crate::model::params::ParamStore::default(),
                        steps: budget,
                    })
                },
            )),
        )
        .tolerance(0.05);
        let mut policy = RefreshPolicy::new(rcfg);
        let now = clock.now();
        for t in ["a", "b", "c"] {
            policy.track(t, now, 1);
        }
        let h = policy.handle();
        let metrics = Arc::new(Metrics::default());
        let coord = RefreshCoordinator::new(
            CoordConfig::default()
                .max_concurrent_holds(1)
                .slack(Duration::from_secs(1_000_000))
                .fallback_hold(Duration::from_millis(50)),
            h.clone(),
            metrics.clone(),
        );

        // same tolerance => identical triggers; rebalance must spread them
        let trig = h.trigger_at("a").unwrap();
        assert_eq!(h.trigger_at("b"), Some(trig));
        coord.rebalance(now);
        let mut staggered: Vec<Instant> = ["a", "b", "c"]
            .iter()
            .map(|t| h.staggered_at(t).unwrap_or_else(|| h.trigger_at(t).unwrap()))
            .collect();
        staggered.sort();
        assert!(staggered.windows(2).all(|w| w[1] - w[0] >= Duration::from_millis(50)));
        assert!(staggered.iter().all(|s| *s <= trig));
        assert!(metrics.stagger_shift_ns.load(Ordering::Relaxed) >= 50_000_000);

        // learned EWMAs become clamped adaptive bounds on the next pass
        h.observe_swap_gap("a", Duration::from_millis(3));
        h.observe_refit_duration("a", Duration::from_millis(7));
        coord.rebalance(now);
        assert_eq!(coord.adaptive_window("a"), Some(Duration::from_millis(3)));
        assert_eq!(
            coord.adaptive_hold("a"),
            Some(Duration::from_secs_f64(0.007 * 1.25)),
        );
        assert_eq!(coord.adaptive_window("b"), None, "no observations, no override");

        // rebalancing twice at the same instant is a no-op
        let before: Vec<_> = ["a", "b", "c"].iter().map(|t| h.staggered_at(t)).collect();
        coord.rebalance(now);
        let after: Vec<_> = ["a", "b", "c"].iter().map(|t| h.staggered_at(t)).collect();
        assert_eq!(before, after);
    }

    #[test]
    fn config_setters_clamp_to_valid_ranges() {
        let c = CoordConfig::default()
            .max_concurrent_holds(0)
            .window_gain(-1.0)
            .hold_gain(0.0)
            .window_bounds(Duration::ZERO, Duration::ZERO)
            .hold_bounds(Duration::from_secs(5), Duration::from_secs(1))
            .fallback_hold(Duration::ZERO)
            .fallback_window(Duration::ZERO);
        assert_eq!(c.max_concurrent_holds, 1);
        assert!(c.window_gain > 0.0 && c.hold_gain > 0.0);
        assert!(c.min_window > Duration::ZERO && c.max_window >= c.min_window);
        assert!(c.min_hold > Duration::ZERO && c.max_hold >= c.min_hold);
        assert!(c.fallback_hold > Duration::ZERO);
        assert!(c.fallback_window > Duration::ZERO);
    }
}
