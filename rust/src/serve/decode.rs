//! Continuous-batching decode: the step engine behind generative
//! serving AND offline greedy evaluation.
//!
//! Autoregressive requests occupy a worker for many steps, so the
//! one-shot batch rules stop applying: the step-batch is re-formed at
//! every step boundary instead of once per batch. [`StepEngine`] owns
//! that loop's state — a fixed `[b, s]` token buffer matching the
//! compiled forward graph (the zero/PAD fill rule of
//! [`crate::runtime::pack::PaddedChunks`], kept in ONE place now that
//! `experiments::llm`'s hand-rolled copy is gone) plus per-row sequence
//! bookkeeping:
//!
//! * **join** — new requests are admitted into free rows at step
//!   boundaries ([`StepEngine::admit`]), never mid-step;
//! * **retire** — a row that emits a stop token, reaches its `max_new`
//!   budget, or fills the context window finishes immediately
//!   ([`StepEngine::apply_logits`]) and its freed slot is available to
//!   the very next joiner — retirement never blocks admission;
//! * **re-balance** — the step-batch size changes every step, and with
//!   it the Fig. 4 AIMC ⇄ PMCA balance. The per-step latency model is a
//!   lookup into the scheduler's committed sweep
//!   ([`super::sched::BatchScheduler::modeled_batch`]), not a re-sweep.
//!
//! # Step-boundary refresh safety
//!
//! A generation can outlive an adapter version: the worker re-snapshots
//! the registry and consults the shared [`super::refresh::RefreshHandle`]
//! at EVERY step boundary ([`step_gate`]). A due hot-swap therefore
//! lands *between steps* of in-flight sequences — no drain, a sequence
//! may start on version v and finish on v+1 (`Metrics::mid_seq_swaps`
//! counts those), and zero steps run against a stale-past-trigger
//! snapshot: the gate defers the step (bounded hold, same liveness rule
//! as [`super::sched::Decision::Hold`]) until the swap lands or the
//! hold budget runs out.
//!
//! # One decode path
//!
//! [`greedy_chunks`] drives the same engine in static chunks for the
//! offline tables (`experiments::llm::batched_greedy` delegates here),
//! so eval and live serving cannot drift apart: identical truncation,
//! padding, argmax, and retirement rules.

use std::time::{Duration, Instant};

use crate::data::tokenizer::{EOS, ESOL, PAD};

use super::refresh::RefreshView;

// ---------------------------------------------------------------------------
// Generation config and streamed events
// ---------------------------------------------------------------------------

/// Per-request generation settings for [`super::api::Client::generate`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GenConfig {
    /// Token budget: the row retires after emitting this many tokens
    /// (always ≥ 1; the context window may retire it earlier).
    pub max_new: usize,
    /// Tokens that terminate the sequence the step they are emitted.
    pub stop_tokens: Vec<i32>,
}

impl Default for GenConfig {
    fn default() -> GenConfig {
        GenConfig {
            max_new: 16,
            stop_tokens: vec![ESOL, EOS],
        }
    }
}

impl GenConfig {
    pub fn new(max_new: usize) -> GenConfig {
        GenConfig {
            max_new: max_new.max(1),
            ..GenConfig::default()
        }
    }

    pub fn stops(mut self, toks: &[i32]) -> Self {
        self.stop_tokens = toks.to_vec();
        self
    }
}

/// One streamed token from an in-flight generation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    pub id: u64,
    pub task: String,
    /// Worker whose step-batch produced this token.
    pub worker: usize,
    pub token: i32,
    /// 0-based position within the generation.
    pub index: usize,
    /// Terminal marker: this is the generation's last event.
    pub done: bool,
    /// Adapter version the producing step ran at — changes mid-stream
    /// exactly when a refresh hot-swap landed between steps.
    pub adapter_version: u64,
    /// Live sequences in the step-batch at that step.
    pub step_fill: usize,
}

/// A completed generation, assembled by
/// [`super::api::GenTicket::wait_all`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Generation {
    pub id: u64,
    pub task: String,
    pub worker: usize,
    pub tokens: Vec<i32>,
    /// Adapter versions of the first and last step; they differ exactly
    /// when the sequence crossed a drain-free mid-sequence hot-swap.
    pub first_version: u64,
    pub last_version: u64,
}

// ---------------------------------------------------------------------------
// The step engine
// ---------------------------------------------------------------------------

#[derive(Clone, Debug)]
struct SeqState {
    id: u64,
    prompt_len: usize,
    /// Valid tokens in the row (prompt + emitted).
    len: usize,
    emitted: usize,
    max_new: usize,
    stops: Vec<i32>,
    alive: bool,
}

/// One row's outcome from a decode step.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StepEmit {
    pub row: usize,
    pub id: u64,
    pub token: i32,
    /// 0-based index of this token within the row's generation.
    pub index: usize,
    /// The row retired this step (stop token, `max_new` spent, or the
    /// sequence filled the graph's context window).
    pub finished: bool,
}

/// Fixed-shape `[b, s]` continuous-batching state for one task.
///
/// Rows hold growing sequences in the exact buffer layout the compiled
/// forward graph expects; unused rows and tails stay `PAD`. The caller
/// owns the loop: `admit` joiners, run the forward on [`inputs`],
/// [`apply_logits`], deliver/`harvest`, repeat.
///
/// [`inputs`]: StepEngine::inputs
/// [`apply_logits`]: StepEngine::apply_logits
/// [`harvest`]: StepEngine::harvest
pub struct StepEngine {
    b: usize,
    s: usize,
    vocab: usize,
    buf: Vec<i32>,
    rows: Vec<Option<SeqState>>,
}

impl StepEngine {
    pub fn new(b: usize, s: usize, vocab: usize) -> StepEngine {
        assert!(b >= 1 && s >= 2 && vocab >= 1, "degenerate decode shape");
        StepEngine {
            b,
            s,
            vocab,
            buf: vec![PAD; b * s],
            rows: (0..b).map(|_| None).collect(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.b
    }

    pub fn seq(&self) -> usize {
        self.s
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Rows still decoding (retired-but-unharvested rows do not count).
    pub fn live(&self) -> usize {
        self.rows.iter().flatten().filter(|r| r.alive).count()
    }

    /// Rows holding a sequence, live or awaiting harvest.
    pub fn occupied(&self) -> usize {
        self.rows.iter().flatten().count()
    }

    pub fn has_room(&self) -> bool {
        self.rows.iter().any(|r| r.is_none())
    }

    /// Tokens emitted so far by the sequence in `row` (0 if the row is
    /// free).
    pub fn emitted(&self, row: usize) -> usize {
        self.rows[row].as_ref().map_or(0, |r| r.emitted)
    }

    /// Join a sequence at this step boundary: claim a free row, lay the
    /// prompt down (truncated to `s - 1` so the first new token always
    /// fits), PAD the tail. Returns the row, or `None` when the
    /// step-batch is full. Empty prompts and `max_new == 0` admit as
    /// already-retired rows (they harvest an empty completion).
    pub fn admit(&mut self, id: u64, prompt: &[i32], max_new: usize, stops: &[i32]) -> Option<usize> {
        let row = self.rows.iter().position(|r| r.is_none())?;
        let l = prompt.len().min(self.s - 1);
        self.buf[row * self.s..(row + 1) * self.s].fill(PAD);
        self.buf[row * self.s..row * self.s + l].copy_from_slice(&prompt[..l]);
        self.rows[row] = Some(SeqState {
            id,
            prompt_len: l,
            len: l,
            emitted: 0,
            max_new,
            stops: stops.to_vec(),
            alive: l > 0 && max_new > 0,
        });
        Some(row)
    }

    /// The full `[b, s]` token buffer for the forward pass.
    pub fn inputs(&self) -> &[i32] {
        &self.buf
    }

    /// Advance every live row by one token from the step's `[b, s,
    /// vocab]` logits: greedy argmax at the row's last valid position,
    /// append, and retire rows that hit a stop token, their `max_new`
    /// budget, or the context window.
    pub fn apply_logits(&mut self, logits: &[f32]) -> Vec<StepEmit> {
        debug_assert_eq!(logits.len(), self.b * self.s * self.vocab);
        let mut out = Vec::new();
        for row in 0..self.b {
            let Some(st) = self.rows[row].as_mut() else {
                continue;
            };
            if !st.alive {
                continue;
            }
            let off = (row * self.s + st.len - 1) * self.vocab;
            let tok = crate::eval::metrics::argmax(&logits[off..off + self.vocab]) as i32;
            self.buf[row * self.s + st.len] = tok;
            st.len += 1;
            st.emitted += 1;
            let finished = st.stops.contains(&tok) || st.len >= self.s || st.emitted >= st.max_new;
            if finished {
                st.alive = false;
            }
            out.push(StepEmit {
                row,
                id: st.id,
                token: tok,
                index: st.emitted - 1,
                finished,
            });
        }
        out
    }

    /// Copy out a row's completion (emitted tokens only) and free the
    /// row for the next joiner. `None` if the row is free.
    pub fn harvest(&mut self, row: usize) -> Option<Vec<i32>> {
        let st = self.rows[row].take()?;
        let out = self.buf[row * self.s + st.prompt_len..row * self.s + st.len].to_vec();
        self.buf[row * self.s..(row + 1) * self.s].fill(PAD);
        Some(out)
    }

    /// Free a row without copying its completion (serving streams the
    /// tokens as they are produced, so nothing is left to collect).
    pub fn release(&mut self, row: usize) {
        if self.rows[row].take().is_some() {
            self.buf[row * self.s..(row + 1) * self.s].fill(PAD);
        }
    }

    /// Free every row and restore the all-PAD buffer.
    pub fn reset(&mut self) {
        self.buf.fill(PAD);
        self.rows.iter_mut().for_each(|r| *r = None);
    }
}

// ---------------------------------------------------------------------------
// Step-boundary refresh gate
// ---------------------------------------------------------------------------

/// Verdict of the step-boundary refresh consultation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepGate {
    /// Run the step on the snapshot at hand.
    Go,
    /// The task's effective trigger has passed but the hot-swap has not
    /// landed: defer the step so the swap lands BETWEEN steps. Re-check
    /// no later than `until` — past it, liveness wins over freshness
    /// (the same bounded-hold rule as [`super::sched::Decision::Hold`]).
    Hold { until: Instant },
}

/// Decide whether the next decode step may run against the fresh
/// registry snapshot `(task, version)` taken at this step boundary.
///
/// `held_since` is the caller's per-task hold anchor; the gate manages
/// it (set on the first deferred step, cleared on every `Go`). With the
/// refresh runner ticking on the same clock, a due swap lands while the
/// caller waits and the next boundary's snapshot serves the new version
/// — zero steps ever execute against a stale-past-trigger snapshot.
pub fn step_gate(
    view: Option<RefreshView>,
    version: u64,
    now: Instant,
    fallback_hold: Duration,
    held_since: &mut Option<Instant>,
) -> StepGate {
    let Some(v) = view else {
        *held_since = None;
        return StepGate::Go;
    };
    let due = v.effective_trigger().map_or(false, |t| now >= t);
    // a snapshot NEWER than the watched version means a swap (or a
    // manual deploy racing the policy) already landed: fresh, go
    if !due || version > v.version {
        *held_since = None;
        return StepGate::Go;
    }
    let hold = v.hold.unwrap_or(fallback_hold);
    let since = *held_since.get_or_insert(now);
    let until = since + hold;
    if now >= until {
        // the refit overran its hold budget: serve (knowingly stale —
        // the worker's stale-step accounting records it) rather than
        // starve the in-flight sequences
        *held_since = None;
        StepGate::Go
    } else {
        StepGate::Hold { until }
    }
}

// ---------------------------------------------------------------------------
// Offline greedy decoding (static chunks on the same engine)
// ---------------------------------------------------------------------------

/// Greedy-decode `prompts` in static chunks of up to `b` rows through
/// `step_fn` (one fixed-shape `[b, s]` forward per step, returning
/// `[b, s, vocab]` logits). This is the offline entry onto the shared
/// engine: `experiments::llm::batched_greedy` wraps it with the real
/// `lm_logits` forward, tests wrap it with synthetic logits. Each chunk
/// is admitted whole and run to completion — no continuous join — which
/// reproduces the legacy fixed-batch evaluation loop token for token.
pub fn greedy_chunks<F>(
    b: usize,
    s: usize,
    vocab: usize,
    prompts: &[Vec<i32>],
    max_new: usize,
    stops: &[i32],
    mut step_fn: F,
) -> anyhow::Result<Vec<Vec<i32>>>
where
    F: FnMut(&[i32]) -> anyhow::Result<Vec<f32>>,
{
    let mut engine = StepEngine::new(b, s, vocab);
    let mut out = Vec::with_capacity(prompts.len());
    for chunk in prompts.chunks(b) {
        engine.reset();
        for (i, p) in chunk.iter().enumerate() {
            engine.admit(i as u64, p, max_new, stops);
        }
        while engine.live() > 0 {
            let logits = step_fn(engine.inputs())?;
            engine.apply_logits(&logits);
        }
        for row in 0..chunk.len() {
            out.push(engine.harvest(row).expect("admitted row"));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    const B: usize = 2;
    const S: usize = 8;
    const V: usize = 8; // covers PAD(0)…EOS(5) plus two content tokens

    /// Deterministic synthetic logits: position `p` of a row continues
    /// with `(tok_at_p * 5 + p + 1) % V`, so trajectories depend on the
    /// buffer content exactly like a real model's would.
    fn fake_logits(buf: &[i32]) -> Vec<f32> {
        let mut logits = vec![0.0f32; B * S * V];
        for row in 0..B {
            for p in 0..S {
                let t = ((buf[row * S + p] as usize * 5 + p + 1) % V) as usize;
                logits[(row * S + p) * V + t] = 1.0;
            }
        }
        logits
    }

    #[test]
    fn admit_lays_out_prompt_and_pads_tail() {
        let mut e = StepEngine::new(B, S, V);
        let row = e.admit(7, &[6, 7, 6], 4, &[EOS]).unwrap();
        assert_eq!(row, 0);
        assert_eq!(&e.inputs()[..S], &[6, 7, 6, PAD, PAD, PAD, PAD, PAD]);
        assert_eq!(&e.inputs()[S..], &[PAD; S]);
        assert_eq!((e.live(), e.occupied()), (1, 1));
        assert!(e.has_room());
        // over-long prompts truncate to s-1 so the first token fits
        let long: Vec<i32> = (0..20).collect();
        let row = e.admit(8, &long, 4, &[EOS]).unwrap();
        assert_eq!(row, 1);
        assert_eq!(&e.inputs()[S..2 * S - 1], &long[..S - 1]);
        assert_eq!(e.inputs()[2 * S - 1], PAD);
        assert!(!e.has_room());
        assert!(e.admit(9, &[1], 4, &[EOS]).is_none());
    }

    #[test]
    fn apply_logits_appends_argmax_and_retires_on_stop_budget_and_window() {
        let mut e = StepEngine::new(B, S, V);
        // row continues 6 → (6*5+2+1)%8 = 1; stop set {1} retires it
        e.admit(1, &[7, 6], 9, &[1]).unwrap();
        let emits = e.apply_logits(&fake_logits(e.inputs()));
        assert_eq!(
            emits,
            vec![StepEmit { row: 0, id: 1, token: 1, index: 0, finished: true }]
        );
        assert_eq!((e.live(), e.occupied()), (0, 1));
        assert_eq!(e.harvest(0), Some(vec![1]));
        assert_eq!(e.occupied(), 0);

        // max_new budget retires after exactly that many tokens
        e.admit(2, &[7, 6], 2, &[]).unwrap();
        let a = e.apply_logits(&fake_logits(e.inputs()));
        assert!(!a[0].finished);
        let b = e.apply_logits(&fake_logits(e.inputs()));
        assert!(b[0].finished && b[0].index == 1);
        assert_eq!(e.harvest(0).unwrap().len(), 2);

        // the context window retires a row whose prompt nearly fills it
        let near: Vec<i32> = vec![6; S - 1];
        e.admit(3, &near, 99, &[]).unwrap();
        let c = e.apply_logits(&fake_logits(e.inputs()));
        assert!(c[0].finished, "len reached s");
        assert_eq!(e.emitted(0), 1);
    }

    #[test]
    fn degenerate_admissions_retire_instantly() {
        let mut e = StepEngine::new(B, S, V);
        e.admit(1, &[], 4, &[EOS]).unwrap();
        e.admit(2, &[6, 7], 0, &[EOS]).unwrap();
        assert_eq!(e.live(), 0, "nothing to decode");
        assert_eq!(e.harvest(0), Some(vec![]));
        assert_eq!(e.harvest(1), Some(vec![]));
    }

    #[test]
    fn retired_rows_free_immediately_for_joiners() {
        let mut e = StepEngine::new(B, S, V);
        e.admit(1, &[7, 6], 1, &[]).unwrap();
        e.admit(2, &[6, 6], 9, &[]).unwrap();
        assert!(!e.has_room());
        let emits = e.apply_logits(&fake_logits(e.inputs()));
        assert!(emits[0].finished && !emits[1].finished);
        e.release(emits[0].row);
        // the freed row is PAD-clean and admits the next joiner at the
        // SAME boundary — retirement never blocks the queue
        assert_eq!(&e.inputs()[..S], &[PAD; S]);
        assert_eq!(e.admit(3, &[7], 9, &[]), Some(0));
        assert_eq!(e.live(), 2);
    }

    /// The legacy `experiments::llm::batched_greedy` loop, verbatim,
    /// pinning bit-identity of the shared-engine refactor (Tables
    /// 4/5/9/10 decode through exactly this algorithm).
    fn reference_greedy<F>(
        b: usize,
        s: usize,
        vocab: usize,
        prompts: &[Vec<i32>],
        max_new: usize,
        mut step_fn: F,
    ) -> Vec<Vec<i32>>
    where
        F: FnMut(&[i32]) -> Vec<f32>,
    {
        let mut out = Vec::with_capacity(prompts.len());
        let mut done = 0;
        while done < prompts.len() {
            let take = (prompts.len() - done).min(b);
            let mut buf = vec![PAD; b * s];
            let mut len = vec![0usize; take];
            for (row, p) in prompts[done..done + take].iter().enumerate() {
                let l = p.len().min(s - 1);
                buf[row * s..row * s + l].copy_from_slice(&p[..l]);
                len[row] = l;
            }
            let mut alive = vec![true; take];
            for _ in 0..max_new {
                if !alive.iter().any(|&a| a) {
                    break;
                }
                let logits = step_fn(&buf);
                for row in 0..take {
                    if !alive[row] {
                        continue;
                    }
                    let off = (row * s + len[row] - 1) * vocab;
                    let tok = crate::eval::metrics::argmax(&logits[off..off + vocab]) as i32;
                    buf[row * s + len[row]] = tok;
                    len[row] += 1;
                    if tok == ESOL || tok == EOS || len[row] >= s {
                        alive[row] = false;
                    }
                }
            }
            for row in 0..take {
                let p = prompts[done + row].len().min(s - 1);
                out.push(buf[row * s + p..row * s + len[row]].to_vec());
            }
            done += take;
        }
        out
    }

    #[test]
    fn greedy_chunks_is_bit_identical_to_the_legacy_loop() {
        // odd prompt count forces a ragged final chunk; mixed lengths
        // exercise truncation and early stops
        let prompts: Vec<Vec<i32>> = vec![
            vec![6, 7],
            vec![7],
            vec![6, 6, 7, 6, 7, 6, 7, 6, 7],
            vec![7, 7, 6],
            vec![6],
        ];
        for max_new in [1, 3, 7, 16] {
            let got = greedy_chunks(B, S, V, &prompts, max_new, &[ESOL, EOS], |buf| {
                Ok(fake_logits(buf))
            })
            .unwrap();
            let want = reference_greedy(B, S, V, &prompts, max_new, fake_logits);
            assert_eq!(got, want, "max_new={max_new}");
        }
    }

    fn view(version: u64, trigger_in: Option<Duration>, now: Instant) -> RefreshView {
        RefreshView {
            version,
            trigger_at: trigger_in.map(|d| now + d),
            refit_in_flight: false,
            last_swap: None,
            staggered_at: None,
            window: None,
            hold: None,
            migrating: false,
        }
    }

    #[test]
    fn step_gate_goes_when_fresh_and_holds_past_trigger() {
        let now = Instant::now();
        let hold = Duration::from_millis(5);
        let mut since = None;
        // no lifecycle / trigger far away: go
        assert_eq!(step_gate(None, 1, now, hold, &mut since), StepGate::Go);
        let fresh = view(1, Some(Duration::from_secs(1)), now);
        assert_eq!(step_gate(Some(fresh), 1, now, hold, &mut since), StepGate::Go);
        assert!(since.is_none());
        // trigger passed, swap not landed: hold until the budget bound
        let due = view(1, Some(Duration::ZERO), now);
        assert_eq!(
            step_gate(Some(due), 1, now, hold, &mut since),
            StepGate::Hold { until: now + hold }
        );
        assert_eq!(since, Some(now));
        // swap lands (snapshot version advances): go, anchor cleared
        let swapped = view(1, Some(Duration::ZERO), now);
        assert_eq!(step_gate(Some(swapped), 2, now, hold, &mut since), StepGate::Go);
        assert!(since.is_none());
    }

    #[test]
    fn step_gate_hold_budget_bounds_the_deferral() {
        let now = Instant::now();
        let hold = Duration::from_millis(5);
        let mut since = None;
        let due = view(3, Some(Duration::ZERO), now);
        assert!(matches!(
            step_gate(Some(due), 3, now, hold, &mut since),
            StepGate::Hold { .. }
        ));
        // the anchor holds across re-checks; past it, liveness wins
        let later = now + hold;
        let still_due = view(3, Some(Duration::ZERO), now);
        assert_eq!(step_gate(Some(still_due), 3, later, hold, &mut since), StepGate::Go);
        assert!(since.is_none(), "expired hold resets its anchor");
        // a coordinator-adapted hold overrides the fallback
        let mut s2 = None;
        let mut adapted = view(3, Some(Duration::ZERO), now);
        adapted.hold = Some(Duration::from_millis(1));
        assert_eq!(
            step_gate(Some(adapted), 3, now, hold, &mut s2),
            StepGate::Hold { until: now + Duration::from_millis(1) }
        );
    }

    #[test]
    fn gen_config_clamps_and_builds() {
        let cfg = GenConfig::new(0);
        assert_eq!(cfg.max_new, 1);
        assert_eq!(cfg.stop_tokens, vec![ESOL, EOS]);
        let cfg = GenConfig::new(4).stops(&[EOS]);
        assert_eq!((cfg.max_new, cfg.stop_tokens.as_slice()), (4, &[EOS][..]));
    }
}
