//! Analytic training-cost model (Table II).
//!
//! The paper measured trainable parameters and GPU memory on an H100;
//! this offline image has neither the GPU nor the 25 M-parameter model,
//! so Table II is reproduced with (a) *exact* trainable-parameter
//! counts from the manifest and (b) an analytic memory model of AHWA
//! training, which captures the paper's key structural facts:
//!
//! * hardware simulation adds a large, method-independent overhead
//!   (temporary noisy weight instances + quantizer intermediates on the
//!   forward AND backward paths),
//! * gradients + Adam state scale with the TRAINABLE tree only — the
//!   term LoRA shrinks >15×,
//! * activations scale with batch/sequence and are identical across
//!   methods, hence "GPU memory usage remains largely unchanged with
//!   rank" while parameter count scales linearly.

use crate::config::manifest::{GraphSpec, Role};

pub const BYTES_F32: f64 = 4.0;

#[derive(Clone, Copy, Debug)]
pub struct MemoryModel {
    pub batch: usize,
    pub seq: usize,
    pub d_model: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    /// Activation tensors retained per layer for backward (attention
    /// scores, QKV, FFN hidden, norms…). 12 matches a BERT-family block.
    pub act_tensors_per_layer: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct MemoryBreakdown {
    pub master_weights: f64,
    pub noisy_weight_instances: f64,
    pub quantizer_buffers: f64,
    pub gradients: f64,
    pub adam_state: f64,
    /// AIHWKIT-style per-trainable analog-simulation autograd state:
    /// retained noisy instances, STE residuals and update buffers exist
    /// only for tensors that require grad. This term is what makes full
    /// AHWA training so much heavier than AHWA-LoRA (paper: 4.8 GB on
    /// MobileBERT) beyond plain grads+Adam.
    pub sim_autograd_state: f64,
    pub activations: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.master_weights
            + self.noisy_weight_instances
            + self.quantizer_buffers
            + self.gradients
            + self.adam_state
            + self.sim_autograd_state
            + self.activations
    }

    pub fn total_gb(&self) -> f64 {
        self.total() / 1e9
    }
}

/// Copies of per-trainable analog-sim state retained across fwd+bwd
/// (calibrated so the full-vs-LoRA gap lands at the paper's ~13 %).
pub const SIM_STATE_COPIES: f64 = 5.0;

/// Memory for one training configuration.
///
/// `n_total` = all model params, `n_mappable` = analog-simulated params
/// (noisy instances + quant buffers), `n_train` = trainable tree.
pub fn training_memory(
    model: &MemoryModel,
    n_total: usize,
    n_mappable: usize,
    n_train: usize,
) -> MemoryBreakdown {
    let acts = model.batch as f64
        * model.seq as f64
        * model.n_layers as f64
        * model.act_tensors_per_layer
        * (model.d_model as f64 + model.d_ff as f64 / 2.0)
        * BYTES_F32;
    MemoryBreakdown {
        master_weights: n_total as f64 * BYTES_F32,
        // fwd + bwd each materialise a perturbed instance of the
        // analog-mapped weights (AHWA's dominant overhead)
        noisy_weight_instances: 2.0 * n_mappable as f64 * BYTES_F32,
        // DAC/ADC STE residuals per mapped matrix
        quantizer_buffers: n_mappable as f64 * BYTES_F32,
        gradients: n_train as f64 * BYTES_F32,
        adam_state: 2.0 * n_train as f64 * BYTES_F32,
        sim_autograd_state: SIM_STATE_COPIES * n_train as f64 * BYTES_F32,
        activations: acts,
    }
}

/// Extract the (n_total, n_mappable, n_train) triple for a training
/// graph from the manifest.
pub fn graph_param_counts(spec: &GraphSpec) -> (usize, usize, usize) {
    let meta: usize = spec.param_count(Role::Meta);
    let train: usize = spec.param_count(Role::Train);
    let mappable: usize = spec
        .inputs_with_role(Role::Meta)
        .filter(|io| crate::aimc::tile::is_mappable(&io.name))
        .map(|io| io.numel())
        .sum();
    // In the full-AHWA regime the meta tree is duplicated inside the
    // trainable tree; total unique params = meta + heads/lora.
    let n_total = if spec.kind.contains("full") {
        train // contains meta + head
    } else {
        meta + train
    };
    (n_total, mappable, train)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> MemoryModel {
        MemoryModel {
            batch: 32,
            seq: 320,
            d_model: 512,
            d_ff: 512,
            n_layers: 24,
            act_tensors_per_layer: 6.0,
        }
    }

    #[test]
    fn lora_cuts_optimizer_memory_only() {
        let m = model();
        let full = training_memory(&m, 25_000_000, 20_000_000, 25_000_000);
        let lora = training_memory(&m, 25_000_000, 20_000_000, 1_600_000);
        assert_eq!(full.activations, lora.activations);
        assert_eq!(full.noisy_weight_instances, lora.noisy_weight_instances);
        assert!(full.gradients > 10.0 * lora.gradients);
        // paper: ~13% total reduction
        let reduction = 1.0 - lora.total() / full.total();
        assert!((0.05..0.45).contains(&reduction), "reduction={reduction}");
    }

    #[test]
    fn memory_flat_in_rank_params_linear() {
        let m = model();
        let r1 = training_memory(&m, 25_000_000, 20_000_000, 200_000);
        let r16 = training_memory(&m, 25_000_000, 20_000_000, 3_200_000);
        // memory changes by <6% while params scale 16x
        assert!(r16.total() / r1.total() < 1.06);
    }

    #[test]
    fn ahwa_overhead_vs_digital() {
        // dropping the noisy-instance + quant buffers (digital training)
        // saves a 25M-model ~240MB: matches "significantly higher than
        // standard digital training" directionally.
        let m = model();
        let ahwa = training_memory(&m, 25_000_000, 20_000_000, 25_000_000);
        let digital = MemoryBreakdown {
            noisy_weight_instances: 0.0,
            quantizer_buffers: 0.0,
            ..ahwa
        };
        assert!(ahwa.total() > digital.total() + 0.2e9);
    }
}
