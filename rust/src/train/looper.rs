//! The training loop driver (L3 side of S6 in DESIGN.md).

use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::run::TrainConfig;
use crate::model::params::ParamStore;
use crate::runtime::pack::{assemble_inputs, parse_step_outputs, DataArg};
use crate::runtime::{Engine, LoadedGraph};
use crate::util::rng::Pcg64;

/// Owned batch data (the borrowing [`DataArg`] view is built on demand).
#[derive(Clone, Debug)]
pub enum OwnedArg {
    I32(Vec<i32>),
    F32(Vec<f32>),
}

#[derive(Clone, Debug, Default)]
pub struct OwnedBatch(pub Vec<OwnedArg>);

impl OwnedBatch {
    pub fn args(&self) -> Vec<DataArg<'_>> {
        self.0
            .iter()
            .map(|a| match a {
                OwnedArg::I32(v) => DataArg::I32(v),
                OwnedArg::F32(v) => DataArg::F32(v),
            })
            .collect()
    }
}

/// Drives one AOT-compiled optimizer-step graph.
pub struct Trainer {
    pub graph: Rc<LoadedGraph>,
    pub meta: ParamStore,
    pub train: ParamStore,
    m: ParamStore,
    v: ParamStore,
    pub cfg: TrainConfig,
    pub step_idx: usize,
    pub losses: Vec<f32>,
    rng: Pcg64,
    /// Batch-sampling stream — owned by the trainer so consecutive
    /// `run_steps` calls continue it instead of replaying it.
    batch_rng: Pcg64,
}

impl Trainer {
    /// `train_init` must match the graph's trainable tree (lora+head for
    /// AHWA-LoRA graphs, meta+head for full-AHWA graphs).
    pub fn new(
        engine: &Engine,
        graph_key: &str,
        meta: ParamStore,
        train_init: ParamStore,
        cfg: TrainConfig,
    ) -> Result<Trainer> {
        let graph = engine
            .load(graph_key)
            .with_context(|| format!("loading training graph '{graph_key}'"))?;
        use crate::config::manifest::Role;
        meta.validate_against(&graph.spec, Role::Meta)?;
        train_init.validate_against(&graph.spec, Role::Train)?;
        let m = ParamStore::zeros_like_role(&graph.spec, Role::M);
        let v = ParamStore::zeros_like_role(&graph.spec, Role::V);
        let rng = Pcg64::with_stream(cfg.seed, 0x7a41);
        let batch_rng = Pcg64::with_stream(cfg.seed, 0xba7c);
        Ok(Trainer {
            graph,
            meta,
            train: train_init,
            m,
            v,
            cfg,
            step_idx: 0,
            losses: Vec::new(),
            rng,
            batch_rng,
        })
    }

    /// One optimizer step on a batch; returns the loss.
    pub fn step(&mut self, data: &[DataArg]) -> Result<f32> {
        let lr = self.cfg.lr_at(self.step_idx) as f32;
        let opt = [lr, self.cfg.weight_decay as f32, (self.step_idx + 1) as f32];
        let seed = self.rng.next_u64();
        let inputs = assemble_inputs(
            &self.graph.spec,
            &self.meta,
            &self.train,
            Some((&self.m, &self.v)),
            data,
            seed,
            self.cfg.hw_vec(),
            Some(opt),
        )?;
        let outs = self.graph.run(&inputs)?;
        let (train, m, v, loss) = parse_step_outputs(&self.graph.spec, &outs)?;
        self.train = train;
        self.m = m;
        self.v = v;
        self.step_idx += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Run the configured number of steps, pulling batches from
    /// `next_batch(step, rng)`. Returns the loss curve.
    pub fn run<F>(&mut self, next_batch: F) -> Result<Vec<f32>>
    where
        F: FnMut(usize, &mut Pcg64) -> OwnedBatch,
    {
        self.run_steps(self.cfg.steps, next_batch)
    }

    /// Run exactly `steps` further optimizer steps (bounded-budget
    /// training: adapter refits in `serve::refresh` cap their work this
    /// way regardless of what `cfg.steps` says). The batch stream and
    /// step counter live on the trainer, so consecutive calls compose:
    /// a second `run_steps` continues with fresh batches at the next
    /// global step instead of replaying the first call's. Returns the
    /// full loss curve accumulated so far.
    pub fn run_steps<F>(&mut self, steps: usize, mut next_batch: F) -> Result<Vec<f32>>
    where
        F: FnMut(usize, &mut Pcg64) -> OwnedBatch,
    {
        let total = self.step_idx + steps;
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let step = self.step_idx;
            let batch = next_batch(step, &mut self.batch_rng);
            let loss = self.step(&batch.args())?;
            if !loss.is_finite() {
                // collapse detection: the LR/noise ablations rely on this
                eprintln!("[train] step {step}: loss diverged ({loss}); stopping");
                break;
            }
            if self.cfg.log_every > 0 && (step + 1) % self.cfg.log_every == 0 {
                let avg: f32 =
                    self.losses[self.losses.len().saturating_sub(self.cfg.log_every)..]
                        .iter()
                        .sum::<f32>()
                        / self.cfg.log_every.min(self.losses.len()) as f32;
                eprintln!(
                    "[train] step {}/{} loss {:.4} ({:.0} ms/step)",
                    step + 1,
                    total,
                    avg,
                    t0.elapsed().as_millis() as f64 / (s + 1) as f64
                );
            }
        }
        Ok(self.losses.clone())
    }

    /// Mean loss over the last `n` steps (convergence diagnostics).
    pub fn tail_loss(&self, n: usize) -> f32 {
        let tail = &self.losses[self.losses.len().saturating_sub(n)..];
        if tail.is_empty() {
            f32::NAN
        } else {
            tail.iter().sum::<f32>() / tail.len() as f32
        }
    }

    /// Did training collapse (NaN/inf loss)?
    pub fn collapsed(&self) -> bool {
        self.losses.last().map(|l| !l.is_finite()).unwrap_or(false)
    }
}
