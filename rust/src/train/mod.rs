//! Training drivers.
//!
//! The entire optimizer step (noisy forward, backward through simulated
//! hardware, AdamW on the trainable tree) is ONE AOT-compiled HLO
//! executable; [`looper::Trainer`] is the thin L3 driver that streams
//! batches and shuttles parameter literals. [`memory`] is the analytic
//! training-cost model behind Table II.

pub mod looper;
pub mod memory;

pub use looper::{OwnedArg, OwnedBatch, Trainer};
