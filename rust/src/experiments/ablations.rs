//! Supplementary ablations (Tables VI–VIII): learning rate, weight
//! noise, clipping method. Collapsed runs are reported as "Collapse",
//! matching the paper's presentation.

use anyhow::Result;

use crate::config::run::{EvalConfig, TrainConfig};
use crate::data::squad::SquadTask;
use crate::train::Trainer;
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::{self, graft_head, infer_hw, pretrained_encoder, qa_drift_grid, Ctx};

struct AblationOutcome {
    label: String,
    train_loss: Option<f64>,
    grid: Option<Vec<(String, f64, f64)>>,
}

fn run_one(
    ctx: &Ctx,
    variant: &str,
    label: &str,
    cfg: TrainConfig,
    eval_hw: [f32; 5],
    ecfg: &EvalConfig,
    cache_tag: &str,
) -> Result<AblationOutcome> {
    let (meta, head) = pretrained_encoder(ctx, variant, 400)?;
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let graph_key = format!("{variant}/step_qa_lora");

    let cache = ctx.runs_dir.join(format!("{cache_tag}.train.bin"));
    let (train, loss) = if !ctx.fresh && cache.exists() {
        (crate::model::checkpoint::load(&cache)?, f64::NAN)
    } else {
        let train0 = graft_head(&ctx.init_train(&graph_key)?, &head);
        let task = SquadTask::new(v.vocab, v.seq);
        let mut trainer = Trainer::new(&ctx.engine, &graph_key, meta.clone(), train0, cfg)?;
        trainer.run(common::qa_batch_fn(task, v.train_batch))?;
        if trainer.collapsed() || !trainer.tail_loss(10).is_finite() {
            return Ok(AblationOutcome {
                label: label.to_string(),
                train_loss: None,
                grid: None,
            });
        }
        let loss = trainer.tail_loss(20) as f64;
        crate::model::checkpoint::save(&cache, &trainer.train)?;
        (trainer.train.clone(), loss)
    };
    let grid = qa_drift_grid(ctx, &format!("{variant}/fwd_qa"), meta, &train, ecfg, eval_hw)?;
    Ok(AblationOutcome {
        label: label.to_string(),
        train_loss: Some(loss),
        grid: Some(grid),
    })
}

fn render(title: &str, outcomes: &[AblationOutcome]) -> Table {
    let mut t = Table::new(
        title,
        &["config", "train loss", "0s", "1h", "1d", "1w", "1m", "1y", "10y"],
    );
    for o in outcomes {
        let mut row = vec![o.label.clone()];
        match (&o.train_loss, &o.grid) {
            (Some(l), Some(g)) => {
                row.push(if l.is_nan() { "(cached)".into() } else { f(*l, 4) });
                row.extend(g.iter().map(|(_, f1, _)| f(*f1, 2)));
            }
            _ => {
                row.push("Collapse".into());
                row.extend(std::iter::repeat_n("-".to_string(), 7));
            }
        }
        t.row(row);
    }
    t
}

/// Supp. Table VI — learning-rate ablation.
pub fn learning_rate(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 150);
    let ecfg = EvalConfig {
        trials: 2,
        examples: 160,
        ..EvalConfig::from_args(args)
    };
    let hw = infer_hw(8, 8, 3.0, 0.04);
    let mut outcomes = Vec::new();
    for lr in [5e-6, 5e-5, 2e-4, 8e-3] {
        // 8e-3 plays the paper's 8e-4 "collapse" role at proxy scale
        let cfg = TrainConfig {
            lr,
            steps,
            log_every: 0,
            ..Default::default()
        };
        outcomes.push(run_one(
            &ctx,
            &variant,
            &format!("lr={lr:.0e}"),
            cfg,
            hw,
            &ecfg,
            &format!("{variant}.ablate.lr{lr:.0e}"),
        )?);
    }
    let t = render("Supp. Table VI — learning-rate ablation (F1)", &outcomes);
    t.print();
    ctx.save_result("table6", &t.render())
}

/// Supp. Table VII — weight-noise ablation.
pub fn weight_noise(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 150);
    let ecfg = EvalConfig {
        trials: 2,
        examples: 160,
        ..EvalConfig::from_args(args)
    };
    let hw = infer_hw(8, 8, 3.0, 0.04);
    let mut outcomes = Vec::new();
    for noise in [0.02, 0.0377, 0.067, 0.075, 0.09, 0.30] {
        // 0.30 plays the paper's 0.12 "collapse" role at proxy scale
        let cfg = TrainConfig {
            weight_noise: noise,
            steps,
            log_every: 0,
            ..Default::default()
        };
        outcomes.push(run_one(
            &ctx,
            &variant,
            &format!("noise={noise}"),
            cfg,
            hw,
            &ecfg,
            &format!("{variant}.ablate.noise{noise}"),
        )?);
    }
    let t = render("Supp. Table VII — weight-noise ablation (F1)", &outcomes);
    t.print();
    ctx.save_result("table7", &t.render())
}

/// Supp. Table VIII — clipping-method ablation.
pub fn clipping(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 150);
    let ecfg = EvalConfig {
        trials: 2,
        examples: 160,
        ..EvalConfig::from_args(args)
    };
    let mut outcomes = Vec::new();
    for (label, clip) in [("3σ", 3.0), ("2.5σ", 2.5), ("2σ", 2.0), ("1σ (over-tight)", 1.0)] {
        let cfg = TrainConfig {
            clip_sigma: clip,
            steps,
            log_every: 0,
            ..Default::default()
        };
        let hw = infer_hw(8, 8, clip as f32, 0.04);
        outcomes.push(run_one(
            &ctx,
            &variant,
            label,
            cfg,
            hw,
            &ecfg,
            &format!("{variant}.ablate.clip{clip}"),
        )?);
    }
    let t = render("Supp. Table VIII — clipping ablation (F1)", &outcomes);
    t.print();
    ctx.save_result("table8", &t.render())
}
