//! Figure 3 — dynamic adaptation (a) and scalability (b).

use anyhow::Result;

use crate::config::run::{EvalConfig, TrainConfig};
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::{adapt_lora_qa, infer_hw, pretrained_encoder, qa_drift_grid, Ctx};

/// Fig. 3a — the ADC degrades from 8-bit to 6-bit in the field; weights
/// on the tiles CANNOT be retrained, but re-training only the LoRA
/// weights off-chip and reloading them ("LoRA weight reloading")
/// recovers most of the loss.
pub fn dynamic_adaptation(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 200);
    let ecfg = EvalConfig::from_args(args);
    let (meta, head) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;
    let fwd_key = format!("{variant}/fwd_qa");
    let step_key = format!("{variant}/step_qa_lora");

    // (1) adapters trained for the healthy 8-bit ADC
    let cfg8 = TrainConfig {
        steps,
        ..TrainConfig::from_args(args)
    };
    let train8 = adapt_lora_qa(&ctx, &step_key, &meta, &head, &cfg8, &format!("{variant}.fig3a.8bit"))?;

    // (2) same adapters evaluated on the degraded 6-bit ADC
    let hw8 = infer_hw(8, 8, 3.0, 0.04);
    let hw6 = infer_hw(8, 6, 3.0, 0.04);
    let grid8 = qa_drift_grid(&ctx, &fwd_key, meta.clone(), &train8, &ecfg, hw8)?;
    let grid6_stale = qa_drift_grid(&ctx, &fwd_key, meta.clone(), &train8, &ecfg, hw6)?;

    // (3) LoRA reloading: retrain ONLY the adapters at 6-bit, same meta
    let cfg6 = TrainConfig {
        steps,
        adc_bits: 6,
        ..TrainConfig::from_args(args)
    };
    let train6 = adapt_lora_qa(&ctx, &step_key, &meta, &head, &cfg6, &format!("{variant}.fig3a.6bit"))?;
    let grid6_reload = qa_drift_grid(&ctx, &fwd_key, meta.clone(), &train6, &ecfg, hw6)?;

    let mut t = Table::new(
        "Fig. 3a — dynamic adaptation to ADC degradation (F1)",
        &["config", "0s", "1h", "1d", "1w", "1m", "1y", "10y"],
    );
    for (label, grid) in [
        ("8-bit ADC (trained@8)", &grid8),
        ("6-bit ADC (stale LoRA)", &grid6_stale),
        ("6-bit ADC (LoRA reloaded*)", &grid6_reload),
    ] {
        let mut row = vec![label.to_string()];
        row.extend(grid.iter().map(|(_, f1, _)| f(*f1, 2)));
        t.row(row);
    }
    t.print();
    let recovered = grid6_reload.last().unwrap().1 - grid6_stale.last().unwrap().1;
    println!("LoRA reloading recovers {recovered:+.2} F1 at 10y (paper: 60.81 -> 74.23)\n");
    ctx.save_result("fig3a", &t.render())
}

/// Fig. 3b — scalability across the encoder family: larger models score
/// higher AND degrade less under 10-year drift.
pub fn scalability(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let steps = args.usize("steps", 200);
    let ecfg = EvalConfig::from_args(args);
    let hw = infer_hw(8, 8, 3.0, 0.04);

    let mut t = Table::new(
        "Fig. 3b — scalability (F1 over drift)",
        &["model", "params (M)", "LoRA (K)", "0s", "1y", "10y", "drop 0s->10y"],
    );
    let mut drops = Vec::new();
    for variant in ["mobilebert_proxy", "bert_base_proxy", "bert_large_proxy"] {
        let (meta, head) = pretrained_encoder(&ctx, variant, args.usize("pretrain-steps", 400))?;
        let cfg = TrainConfig {
            steps,
            ..TrainConfig::from_args(args)
        };
        let train = adapt_lora_qa(
            &ctx,
            &format!("{variant}/step_qa_lora"),
            &meta,
            &head,
            &cfg,
            &format!("{variant}.fig3b"),
        )?;
        let grid = qa_drift_grid(&ctx, &format!("{variant}/fwd_qa"), meta.clone(), &train, &ecfg, hw)?;
        let f1_at = |label: &str| grid.iter().find(|(l, _, _)| l == label).unwrap().1;
        let drop = f1_at("0s") - f1_at("10y");
        drops.push(drop);
        let spec = ctx.engine.manifest.graph(&format!("{variant}/step_qa_lora"))?;
        let total = meta.numel() + spec.param_count(crate::config::manifest::Role::Train);
        let lora: usize = spec
            .inputs_with_role(crate::config::manifest::Role::Train)
            .filter(|io| io.name.starts_with("lora."))
            .map(|io| io.numel())
            .sum();
        t.row(vec![
            variant.to_string(),
            f(total as f64 / 1e6, 2),
            f(lora as f64 / 1e3, 1),
            f(f1_at("0s"), 2),
            f(f1_at("1y"), 2),
            f(f1_at("10y"), 2),
            f(drop, 2),
        ]);
    }
    t.print();
    ctx.save_result("fig3b", &t.render())
}
