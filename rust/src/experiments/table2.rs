//! Table II — trainable parameters + training memory across methods,
//! LoRA placements, and ranks. Parameter counts are EXACT (from the
//! compiled manifest); memory comes from the analytic model in
//! `train::memory` (DESIGN.md §Substitutions: no H100 in this image),
//! scaled at the proxy's own batch/seq.

use anyhow::Result;

use crate::train::memory::{graph_param_counts, training_memory, MemoryModel};
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::Ctx;

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let mm = MemoryModel {
        batch: 32,
        seq: v.seq,
        d_model: v.d_model,
        d_ff: v.d_ff,
        n_layers: v.n_layers,
        act_tensors_per_layer: 6.0,
    };

    let rows: Vec<(&str, String)> = vec![
        ("AHWA", format!("{variant}/step_qa_full")),
        ("AHWA-LoRA", format!("{variant}/step_qa_lora")),
        ("AHWA-LoRA (FFN)", format!("{variant}/step_qa_lora@ffn")),
        ("AHWA-LoRA (QKV)", format!("{variant}/step_qa_lora@qkv")),
        ("AHWA-LoRA (r=1)", format!("{variant}/step_qa_lora@r1")),
        ("AHWA-LoRA (r=2)", format!("{variant}/step_qa_lora@r2")),
        ("AHWA-LoRA (r=4)", format!("{variant}/step_qa_lora@r4")),
        ("AHWA-LoRA (r=8)", format!("{variant}/step_qa_lora")),
        ("AHWA-LoRA (r=16)", format!("{variant}/step_qa_lora@r16")),
    ];

    let mut t = Table::new(
        "Table II — trainable parameters and training memory",
        &["Method", "Trainable Params (M)", "Memory (GB, analytic)"],
    );
    let mut lora_params = 0usize;
    let mut full_params = 0usize;
    for (name, key) in &rows {
        let spec = ctx.engine.manifest.graph(key)?;
        let (n_total, n_mappable, n_train) = graph_param_counts(spec);
        let mem = training_memory(&mm, n_total, n_mappable, n_train);
        if *name == "AHWA" {
            full_params = n_train;
        }
        if *name == "AHWA-LoRA" {
            lora_params = n_train;
        }
        t.row(vec![
            name.to_string(),
            f(n_train as f64 / 1e6, 3),
            f(mem.total_gb(), 3),
        ]);
    }
    t.print();
    let reduction = full_params as f64 / lora_params as f64;
    println!("trainable-parameter reduction: {reduction:.1}x (paper: >15x)\n");
    anyhow::ensure!(reduction > 5.0, "LoRA should cut trainable params dramatically");
    ctx.save_result("table2", &(t.render() + &format!("\nreduction: {reduction:.1}x\n")))
}
