//! Table III — multi-task GLUE inference from ONE analog base model
//! with per-task LoRA adapter sets, over drift, plus the parameter
//! accounting (>4× reduction vs one full model per task) and a live
//! serving demonstration with hot adapter swaps.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::manifest::Role;
use crate::config::run::{EvalConfig, TrainConfig};
use crate::data::glue::{ClsBatch, GlueGen, GlueTask, Metric, ALL_TASKS};
use crate::eval::drift_eval::{cls_logits, pcm_eval_hw, AnalogDeployment};
use crate::eval::metrics;
use crate::model::params::ParamStore;
use crate::pcm::drift::DRIFT_TIMES;
use crate::pcm::PcmModel;
use crate::train::{OwnedArg, OwnedBatch, Trainer};
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};

use super::common::{pretrained_encoder, Ctx};

fn cls_batch_fn(gen: GlueGen, b: usize) -> impl FnMut(usize, &mut Pcg64) -> OwnedBatch {
    move |_, rng| {
        let batch = gen.batch(b, rng);
        if gen.task.is_regression() {
            OwnedBatch(vec![OwnedArg::I32(batch.tokens), OwnedArg::F32(batch.targets)])
        } else {
            OwnedBatch(vec![OwnedArg::I32(batch.tokens), OwnedArg::I32(batch.labels)])
        }
    }
}

/// Train (or load cached) adapter for one GLUE adapter key.
fn train_adapter(
    ctx: &Ctx,
    variant: &str,
    task: GlueTask,
    meta: &ParamStore,
    cfg: &TrainConfig,
) -> Result<ParamStore> {
    let key = task.adapter_key();
    let cache = ctx.runs_dir.join(format!("{variant}.glue.{key}.train.bin"));
    if !ctx.fresh && cache.exists() {
        return Ok(crate::model::checkpoint::load(&cache)?);
    }
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let graph_key = if task.is_regression() {
        format!("{variant}/step_reg_lora")
    } else {
        format!("{variant}/step_cls_lora")
    };
    let train0 = ctx.init_train(&graph_key)?;
    let gen = GlueGen::new(task, v.vocab, v.seq);
    let mut trainer = Trainer::new(&ctx.engine, &graph_key, meta.clone(), train0, cfg.clone())?;
    trainer.run(cls_batch_fn(gen, v.train_batch))?;
    crate::model::checkpoint::save(&cache, &trainer.train)?;
    Ok(trainer.train.clone())
}

/// Score one task on one weight instance.
fn score_task(
    ctx: &Ctx,
    variant: &str,
    task: GlueTask,
    meta: &ParamStore,
    train: &ParamStore,
    eval: &ClsBatch,
    hw: [f32; 5],
    seed: u64,
) -> Result<f64> {
    let fwd = ctx.engine.load(&format!("{variant}/fwd_cls"))?;
    let rows = cls_logits(&fwd, meta, train, &eval.tokens, hw, seed)?;
    Ok(match task.metric() {
        Metric::PearsonSpearman => {
            let preds: Vec<f64> = rows.iter().map(|r| r[0] as f64).collect();
            let golds: Vec<f64> = eval.targets.iter().map(|&y| y as f64).collect();
            metrics::pearson_spearman(&preds, &golds)
        }
        m => {
            let nc = task.n_classes();
            let preds: Vec<i32> = rows.iter().map(|r| metrics::argmax(&r[..nc]) as i32).collect();
            match m {
                Metric::Accuracy => metrics::accuracy(&preds, &eval.labels),
                Metric::F1 => metrics::binary_f1(&preds, &eval.labels),
                Metric::Matthews => metrics::matthews(&preds, &eval.labels),
                Metric::PearsonSpearman => unreachable!(),
            }
        }
    })
}

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 150);
    let ecfg = EvalConfig {
        examples: args.usize("examples", 160),
        trials: args.usize("trials", 2),
        ..EvalConfig::from_args(args)
    };
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _head) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;

    // --- adapt one LoRA set per adapter key (MNLI-m/mm share) ---------
    let cfg = TrainConfig {
        steps,
        log_every: 0,
        ..TrainConfig::from_args(args)
    };
    let mut adapters: BTreeMap<&'static str, ParamStore> = BTreeMap::new();
    for task in ALL_TASKS {
        if !adapters.contains_key(task.adapter_key()) {
            eprintln!("[table3] adapting {}", task.adapter_key());
            adapters.insert(task.adapter_key(), train_adapter(&ctx, &variant, task, &meta, &cfg)?);
        }
    }

    // --- eval sets + digital scores ------------------------------------
    let mut eval_sets: BTreeMap<GlueTask, ClsBatch> = BTreeMap::new();
    for task in ALL_TASKS {
        let gen = GlueGen::new(task, v.vocab, v.seq);
        let mut rng = Pcg64::with_stream(ecfg.seed, task as u64 + 77);
        eval_sets.insert(task, gen.batch(ecfg.examples, &mut rng));
    }

    // --- program the SINGLE analog base once ---------------------------
    let mut prog_rng = Pcg64::with_stream(ecfg.seed, 0x61ce);
    let dep = AnalogDeployment::program(meta.clone(), PcmModel::default(), 3.0, &mut prog_rng);
    let hw = pcm_eval_hw(127.0, 127.0, 0.04);

    // scores[task][time] averaged over trials; column 0 = digital score
    let mut t = Table::new(
        "Table III — GLUE from one analog base + per-task LoRA (over drift)",
        &["Task", "Score(dig)", "0s", "1h", "1d", "1w", "1m", "1y", "10y"],
    );
    let mut grid_avg = vec![0.0f64; DRIFT_TIMES.len()];
    let mut digital_avg = 0.0f64;
    for task in ALL_TASKS {
        let eval = &eval_sets[&task];
        let train = &adapters[task.adapter_key()];
        let digital = score_task(&ctx, &variant, task, &meta, train, eval, [0.0; 5], ecfg.seed)?;
        let mut row = vec![task.name().to_string(), f(digital, 1)];
        for (ti, (_, secs)) in DRIFT_TIMES.iter().enumerate() {
            let mut acc = 0.0;
            for trial in 0..ecfg.trials {
                let mut rng = Pcg64::with_stream(ecfg.seed, 0x77aa ^ ((trial as u64) << 7));
                let meta_t = dep.meta_at(*secs, true, &mut rng);
                acc += score_task(&ctx, &variant, task, &meta_t, train, eval, hw, ecfg.seed ^ trial as u64)?;
            }
            let score = acc / ecfg.trials as f64;
            grid_avg[ti] += score / ALL_TASKS.len() as f64;
            row.push(f(score, 1));
        }
        digital_avg += digital / ALL_TASKS.len() as f64;
        t.row(row);
    }
    let mut avg_row = vec!["GLUE (avg)".to_string(), f(digital_avg, 1)];
    avg_row.extend(grid_avg.iter().map(|s| f(*s, 1)));
    t.row(avg_row);
    t.print();

    // --- parameter accounting (the >4x claim) ---------------------------
    let spec = ctx.engine.manifest.graph(&format!("{variant}/step_cls_lora"))?;
    let adapter_params: usize = spec.param_count(Role::Train);
    let (mappable, unmappable) = crate::aimc::tile::mappability_split(
        &meta.tensors.iter().map(|t| (t.name.clone(), t.shape.clone())).collect::<Vec<_>>(),
    );
    let n_tasks = adapters.len();
    let ours = mappable + unmappable + n_tasks * adapter_params;
    let conventional = n_tasks * (mappable + unmappable);
    let reduction = conventional as f64 / ours as f64;
    let account = format!(
        "single-base accounting: mappable {:.2}M + unmappable {:.2}M + {n_tasks}x{:.2}M adapters = {:.2}M total\n\
         conventional ({} chips): {:.2}M -> {reduction:.1}x parameter reduction (paper: >4x)\n",
        mappable as f64 / 1e6,
        unmappable as f64 / 1e6,
        adapter_params as f64 / 1e6,
        ours as f64 / 1e6,
        n_tasks,
        conventional as f64 / 1e6,
    );
    println!("{account}");

    // --- live serving demonstration (hot adapter swaps, engine pool) ----
    let serving = serve_demo(args, &ctx, &variant, &meta, &adapters, &eval_sets)?;
    println!("{serving}");

    ctx.save_result("table3", &(t.render() + "\n" + &account + "\n" + &serving))
}

/// Serve a mixed-task wave from the adapters just trained: one analog
/// base, per-task LoRA sets hot-swapped across a sharded engine pool
/// (the deployment half of Table III, via `serve::api`).
fn serve_demo(
    args: &Args,
    ctx: &Ctx,
    variant: &str,
    meta: &ParamStore,
    adapters: &BTreeMap<&'static str, ParamStore>,
    eval_sets: &BTreeMap<GlueTask, ClsBatch>,
) -> Result<String> {
    use crate::serve::registry::SharedRegistry;
    use crate::serve::{submit_wave, SchedConfig, Server};

    let n_requests = args.usize("serve-requests", 48);
    if n_requests == 0 {
        return Ok(String::new());
    }
    let workers = args.usize("serve-workers", 2);
    let t_int = args.usize("t-int", 256) as f64;

    let registry = SharedRegistry::new();
    for (key, params) in adapters {
        registry.deploy(key, params.clone());
    }
    // pipeline-aware batching: model the variant's own projection shape
    // (d_model × d_model at the trained LoRA rank) on the AIMC tiles
    let vcfg = ctx.engine.manifest.variant(variant)?.clone();
    let sched = SchedConfig::for_layer(vcfg.d_model, vcfg.d_model, vcfg.rank).t_int(t_int);
    let server = Server::builder(variant)
        .manifest(ctx.engine.manifest.clone())
        .workers(workers)
        .scheduler(sched)
        .build(meta.clone(), registry.clone())?;
    let client = server.client();

    let mut jobs = Vec::with_capacity(n_requests);
    for (i, task) in ALL_TASKS.iter().cycle().take(n_requests).enumerate() {
        let eval = &eval_sets[task];
        let row = i % eval.b;
        let tokens = eval.tokens[row * eval.seq..(row + 1) * eval.seq].to_vec();
        jobs.push((task.adapter_key().to_string(), tokens));
    }
    let t0 = std::time::Instant::now();
    let responses = submit_wave(&client, &jobs)?;
    let wall = t0.elapsed();

    // mid-flight hot swap: version bump visible to the next wave
    let key = ALL_TASKS[0].adapter_key();
    let v = registry.deploy(key, adapters[key].clone());
    let again = submit_wave(&client, &jobs[..ALL_TASKS.len().min(jobs.len())])?;

    let mut out = format!(
        "serving demo: {} requests over {} tasks in {:.1} ms ({:.0} req/s), {} workers\n",
        responses.len(),
        adapters.len(),
        wall.as_secs_f64() * 1e3,
        responses.len() as f64 / wall.as_secs_f64(),
        server.workers(),
    );
    // the balance point the workers committed to, and model vs reality;
    // seq comes from the serving graph exactly as the builder resolves
    // the SchedConfig's inherit-from-graph sentinel
    let graph_seq = ctx
        .engine
        .manifest
        .graph(&format!("{variant}/fwd_cls"))?
        .inputs_with_role(Role::Data)
        .next()
        .filter(|io| io.shape.len() == 2)
        .map(|io| io.shape[1])
        .unwrap_or(vcfg.seq);
    let bp = crate::pipeline::balance::best_point(
        vcfg.d_model,
        vcfg.d_model,
        vcfg.rank,
        t_int,
        graph_seq,
        &crate::pmca::cluster::SnitchCluster::default(),
        &crate::pmca::redmule::RedMulE::default(),
    );
    let agg = server.metrics();
    out.push_str(&format!(
        "pipeline-aware sched: t_int={t_int:.0}ns -> token parallelism t={} \
         (modeled steady overhead {:.2}%), batch latency model p50 {:.3} ms vs measured p50 {:.3} ms\n",
        bp.t,
        100.0 * bp.overhead(),
        agg.modeled_p50_ms,
        agg.lat_p50_ms,
    ));
    out.push_str(&format!(
        "hot-swap: '{key}' -> v{v}, next wave served v{}\n{}",
        again
            .iter()
            .find(|r| r.task == key)
            .map(|r| r.adapter_version)
            .unwrap_or(0),
        server.metrics_report(),
    ));
    server.shutdown()?;
    Ok(out)
}
