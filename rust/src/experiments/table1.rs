//! Table I — AHWA vs AHWA-LoRA on synthetic SQuAD (MobileBERT proxy),
//! F1/EM over conductance drift 0 s … 10 y. Also hosts the `e2e`
//! end-to-end driver used by `examples/train_e2e.rs`.

use anyhow::Result;

use crate::config::run::{EvalConfig, TrainConfig};
use crate::data::squad::SquadTask;
use crate::model::params::ParamStore;
use crate::train::Trainer;
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::{
    self, adapt_lora_qa, graft_head, infer_hw, pretrained_encoder, qa_digital, qa_drift_grid,
    split_full_tree, Ctx,
};

pub fn run(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let pre_steps = args.usize("pretrain-steps", 400);
    let steps = args.usize("steps", 200);
    let ecfg = EvalConfig::from_args(args);
    let hw = infer_hw(8, 8, 3.0, 0.04);

    let (meta, head) = pretrained_encoder(&ctx, &variant, pre_steps)?;
    let fwd_key = format!("{variant}/fwd_qa");

    // --- AHWA-LoRA: frozen meta, train LoRA + head under constraints ---
    let cfg = TrainConfig {
        steps,
        ..TrainConfig::from_args(args)
    };
    let lora_train = adapt_lora_qa(
        &ctx,
        &format!("{variant}/step_qa_lora"),
        &meta,
        &head,
        &cfg,
        &format!("{variant}.table1.lora"),
    )?;
    let (lora_digital_f1, lora_digital_em) = qa_digital(&ctx, &fwd_key, &meta, &lora_train, &ecfg)?;
    let lora_grid = qa_drift_grid(&ctx, &fwd_key, meta.clone(), &lora_train, &ecfg, hw)?;

    // --- full AHWA baseline: retrain everything under constraints ---
    let (ahwa_meta, ahwa_train) = full_ahwa(&ctx, &variant, &meta, &head, &cfg, "table1.full")?;
    let (ahwa_digital_f1, ahwa_digital_em) = qa_digital(&ctx, &fwd_key, &ahwa_meta, &ahwa_train, &ecfg)?;
    let ahwa_grid = qa_drift_grid(&ctx, &fwd_key, ahwa_meta, &ahwa_train, &ecfg, hw)?;

    let mut hdr = vec!["Training Method".to_string(), "Metric".into(), "Baseline".into()];
    hdr.extend(lora_grid.iter().map(|(l, _, _)| l.clone()));
    let mut t = Table::new(
        "Table I — AHWA vs AHWA-LoRA (synthetic SQuAD, MobileBERT proxy)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let row = |name: &str, metric: &str, base: f64, grid: &[(String, f64, f64)], which: usize| {
        let mut r = vec![name.to_string(), metric.to_string(), f(base, 2)];
        r.extend(grid.iter().map(|(_, f1, em)| f(if which == 0 { *f1 } else { *em }, 2)));
        r
    };
    t.row(row("AHWA Training", "F1", ahwa_digital_f1, &ahwa_grid, 0));
    t.row(row("AHWA Training", "EM", ahwa_digital_em, &ahwa_grid, 1));
    t.row(row("AHWA-LoRA Training", "F1", lora_digital_f1, &lora_grid, 0));
    t.row(row("AHWA-LoRA Training", "EM", lora_digital_em, &lora_grid, 1));
    t.print();
    ctx.save_result("table1", &t.render())
}

/// Full AHWA training (paper's baseline, its ref. 22): every weight is
/// retrained under simulated hardware constraints.
pub fn full_ahwa(
    ctx: &Ctx,
    variant: &str,
    meta: &ParamStore,
    head: &ParamStore,
    cfg: &TrainConfig,
    tag: &str,
) -> Result<(ParamStore, ParamStore)> {
    let meta_path = ctx.runs_dir.join(format!("{variant}.{tag}.meta.bin"));
    let head_path = ctx.runs_dir.join(format!("{variant}.{tag}.head.bin"));
    if !ctx.fresh && meta_path.exists() && head_path.exists() {
        let m = crate::model::checkpoint::load(&meta_path)?;
        let h = crate::model::checkpoint::load(&head_path)?;
        return Ok((m, lora_free_train(ctx, variant, &h)?));
    }
    let graph_key = format!("{variant}/step_qa_full");
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let mut train0 = ctx.init_train(&graph_key)?;
    for t in train0.tensors.iter_mut() {
        if let Some(bare) = t.name.strip_prefix("meta.") {
            t.data = meta.get(bare)?.data.clone();
        } else if let Ok(h) = head.get(&t.name) {
            t.data = h.data.clone();
        }
    }
    let task = SquadTask::new(v.vocab, v.seq);
    let mut trainer = Trainer::new(&ctx.engine, &graph_key, ParamStore::default(), train0, cfg.clone())?;
    trainer.run(common::qa_batch_fn(task, v.train_batch))?;
    let (new_meta, new_head) = split_full_tree(&trainer.train);
    crate::model::checkpoint::save(&meta_path, &new_meta)?;
    crate::model::checkpoint::save(&head_path, &new_head)?;
    Ok((new_meta, lora_free_train(ctx, variant, &new_head)?))
}

/// Wrap a bare head as the fwd graph's trainable tree with ZERO LoRA
/// (B = 0 ⇒ exactly the base model) so AHWA-trained models evaluate
/// through the same forward artifact.
fn lora_free_train(ctx: &Ctx, variant: &str, head: &ParamStore) -> Result<ParamStore> {
    let mut train = ctx.init_train(&format!("{variant}/step_qa_lora"))?;
    for t in train.tensors.iter_mut() {
        if t.name.starts_with("head.") {
            if let Ok(h) = head.get(&t.name) {
                t.data = h.data.clone();
            }
        } else if t.name.ends_with("_b") {
            t.data.iter_mut().for_each(|x| *x = 0.0);
        }
    }
    Ok(train)
}

/// End-to-end driver (EXPERIMENTS.md §E2E): digital pretrain → AHWA-LoRA
/// adapt (logging the loss curve) → PCM drift eval → summary.
pub fn e2e(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let pre_steps = args.usize("pretrain-steps", 400);
    let steps = args.usize("steps", 300);
    let ecfg = EvalConfig::from_args(args);
    let hw = infer_hw(8, 8, 3.0, 0.04);

    eprintln!("[e2e] stage 1: digital pretraining ({pre_steps} steps)");
    let (meta, head) = pretrained_encoder(&ctx, &variant, pre_steps)?;

    eprintln!("[e2e] stage 2: AHWA-LoRA adaptation ({steps} steps, noise 6.7%)");
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let cfg = TrainConfig {
        steps,
        log_every: 25,
        ..TrainConfig::from_args(args)
    };
    let graph_key = format!("{variant}/step_qa_lora");
    let train0 = graft_head(&ctx.init_train(&graph_key)?, &head);
    let task = SquadTask::new(v.vocab, v.seq);
    let mut trainer = Trainer::new(&ctx.engine, &graph_key, meta.clone(), train0, cfg)?;
    let losses = trainer.run(common::qa_batch_fn(task, v.train_batch))?;

    eprintln!("[e2e] stage 3: PCM deployment + drift evaluation");
    let grid = qa_drift_grid(&ctx, &format!("{variant}/fwd_qa"), meta, &trainer.train, &ecfg, hw)?;

    let mut t = Table::new("E2E — loss curve (sampled) and drift grid", &["quantity", "value"]);
    for i in (0..losses.len()).step_by((losses.len() / 10).max(1)) {
        t.row(vec![format!("loss@step{}", i + 1), f(losses[i] as f64, 4)]);
    }
    t.row(vec!["loss@final".into(), f(*losses.last().unwrap() as f64, 4)]);
    for (label, f1, em) in &grid {
        t.row(vec![format!("F1/EM@{label}"), format!("{} / {}", f(*f1, 2), f(*em, 2))]);
    }
    t.print();
    let first5: f32 = losses[..5.min(losses.len())].iter().sum::<f32>() / 5.0_f32.min(losses.len() as f32);
    anyhow::ensure!(
        trainer.tail_loss(10) < first5,
        "e2e loss did not decrease"
    );
    ctx.save_result("e2e", &t.render())
}
