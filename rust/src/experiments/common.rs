//! Shared experiment machinery: pretraining, adaptation, cached
//! checkpoints, drift-grid evaluation.
//!
//! Every experiment follows the paper's three-step pipeline
//! (Methods — AHWA-LoRA Training):
//!
//! 1. **meta-weight deployment** — a pretrained base model. The image has
//!    no HF checkpoints, so the base is *digitally pretrained here* on
//!    the task family (cached under `artifacts/runs/`), standing in for
//!    "pre-trained MobileBERT/BERT/LLaMA" (DESIGN.md §Substitutions).
//! 2. **AHWA-LoRA training** — hardware constraints in the forward pass,
//!    gradients into LoRA (+ digital head) only.
//! 3. **deployment + drift evaluation** — program onto simulated PCM,
//!    evaluate over 0 s … 10 y with global drift compensation.

use std::path::PathBuf;

use anyhow::Result;

use crate::config::run::{EvalConfig, TrainConfig};
use crate::data::squad::SquadTask;
use crate::eval::drift_eval::{pcm_eval_hw, AnalogDeployment, QaEvalSet};
use crate::model::checkpoint;
use crate::model::params::{ParamStore, Tensor};
use crate::pcm::drift::DRIFT_TIMES;
use crate::pcm::PcmModel;
use crate::runtime::Engine;
use crate::train::{OwnedArg, OwnedBatch, Trainer};
use crate::util::rng::Pcg64;

pub struct Ctx {
    pub engine: Engine,
    pub runs_dir: PathBuf,
    pub results_dir: PathBuf,
    /// When true, ignore cached checkpoints and retrain.
    pub fresh: bool,
}

impl Ctx {
    pub fn new() -> Result<Ctx> {
        let engine = Engine::from_artifacts()?;
        let runs_dir = engine.manifest.root.join("runs");
        let results_dir = PathBuf::from("results");
        std::fs::create_dir_all(&runs_dir)?;
        std::fs::create_dir_all(&results_dir)?;
        Ok(Ctx {
            engine,
            runs_dir,
            results_dir,
            fresh: false,
        })
    }

    pub fn init_meta(&self, variant: &str) -> Result<ParamStore> {
        checkpoint::load(self.engine.manifest.init_path(&format!("{variant}.meta")))
    }

    pub fn init_train(&self, graph_key: &str) -> Result<ParamStore> {
        let tag = graph_key.replace('/', ".");
        checkpoint::load(self.engine.manifest.init_path(&format!("{tag}.train")))
    }

    pub fn save_result(&self, name: &str, markdown: &str) -> Result<()> {
        let path = self.results_dir.join(format!("{name}.md"));
        std::fs::write(&path, markdown)?;
        eprintln!("[exp] wrote {}", path.display());
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Batch providers
// ---------------------------------------------------------------------------

pub fn qa_batch_fn(task: SquadTask, b: usize) -> impl FnMut(usize, &mut Pcg64) -> OwnedBatch {
    move |_, rng| {
        let batch = task.batch(b, rng);
        OwnedBatch(vec![
            OwnedArg::I32(batch.tokens),
            OwnedArg::I32(batch.starts),
            OwnedArg::I32(batch.ends),
        ])
    }
}

// ---------------------------------------------------------------------------
// Base-model pretraining (step 1 of the pipeline)
// ---------------------------------------------------------------------------

/// Extract the `meta.*` tensors of a full-regime trainable tree as a
/// bare-named meta store; `head.*` tensors become the head store.
pub fn split_full_tree(train: &ParamStore) -> (ParamStore, ParamStore) {
    let mut meta = Vec::new();
    let mut head = Vec::new();
    for t in &train.tensors {
        if let Some(bare) = t.name.strip_prefix("meta.") {
            meta.push(Tensor {
                name: bare.to_string(),
                shape: t.shape.clone(),
                data: t.data.clone(),
            });
        } else {
            head.push(t.clone());
        }
    }
    (ParamStore::from_tensors(meta), ParamStore::from_tensors(head))
}

/// Graft a head store into a lora-regime trainable tree (keeps LoRA
/// init, replaces `head.*` values) — used to warm-start adaptation from
/// the pretrained head.
pub fn graft_head(train_init: &ParamStore, head: &ParamStore) -> ParamStore {
    let mut tensors = Vec::new();
    for t in &train_init.tensors {
        if t.name.starts_with("head.") {
            if let Ok(h) = head.get(&t.name) {
                tensors.push(h.clone());
                continue;
            }
        }
        tensors.push(t.clone());
    }
    ParamStore::from_tensors(tensors)
}

/// Digitally pretrain the encoder base on the QA task (cached). Returns
/// (meta, qa_head).
pub fn pretrained_encoder(ctx: &Ctx, variant: &str, steps: usize) -> Result<(ParamStore, ParamStore)> {
    let meta_path = ctx.runs_dir.join(format!("{variant}.pretrained.meta.bin"));
    let head_path = ctx.runs_dir.join(format!("{variant}.pretrained.head.bin"));
    if !ctx.fresh && meta_path.exists() && head_path.exists() {
        return Ok((checkpoint::load(&meta_path)?, checkpoint::load(&head_path)?));
    }
    eprintln!("[exp] pretraining base '{variant}' ({steps} digital steps)…");
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let graph_key = format!("{variant}/step_qa_full");
    // full graphs take no meta inputs (meta lives in the trainable tree)
    let empty_meta = ParamStore::default();
    let mut train0 = ctx.init_train(&graph_key)?;
    // seed the trainable meta from the exported init
    let init_meta = ctx.init_meta(variant)?;
    for t in train0.tensors.iter_mut() {
        if let Some(bare) = t.name.strip_prefix("meta.") {
            t.data = init_meta.get(bare)?.data.clone();
        }
    }
    let cfg = TrainConfig {
        steps,
        lr: 1e-3,
        log_every: 100,
        ..TrainConfig::digital()
    };
    let task = SquadTask::new(v.vocab, v.seq);
    let mut trainer = Trainer::new(&ctx.engine, &graph_key, empty_meta, train0, cfg)?;
    trainer.run(qa_batch_fn(task, v.train_batch))?;
    let (meta, head) = split_full_tree(&trainer.train);
    checkpoint::save(&meta_path, &meta)?;
    checkpoint::save(&head_path, &head)?;
    Ok((meta, head))
}

/// Digitally pretrain a decoder base on mixed LM data (cached).
pub fn pretrained_decoder(ctx: &Ctx, variant: &str, steps: usize) -> Result<ParamStore> {
    let meta_path = ctx.runs_dir.join(format!("{variant}.pretrained.meta.bin"));
    if !ctx.fresh && meta_path.exists() {
        return Ok(checkpoint::load(&meta_path)?);
    }
    eprintln!("[exp] pretraining decoder base '{variant}' ({steps} digital steps)…");
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let graph_key = format!("{variant}/step_lm_full");
    let mut train0 = ctx.init_train(&graph_key)?;
    let init_meta = ctx.init_meta(variant)?;
    for t in train0.tensors.iter_mut() {
        if let Some(bare) = t.name.strip_prefix("meta.") {
            t.data = init_meta.get(bare)?.data.clone();
        }
    }
    let cfg = TrainConfig {
        steps,
        lr: 1e-3,
        log_every: 100,
        ..TrainConfig::digital()
    };
    let instruct = crate::data::instruct::InstructTask::new(v.vocab, v.seq);
    let gsm = crate::data::gsm::GsmTask::new(v.seq);
    let b = v.train_batch;
    let mut trainer = Trainer::new(&ctx.engine, &graph_key, ParamStore::default(), train0, cfg)?;
    trainer.run(move |step, rng| {
        // alternate corpora so the base has both formats
        let (tokens, mask) = if step % 2 == 0 {
            instruct.batch(b, rng)
        } else {
            gsm.sft_batch(b, rng)
        };
        OwnedBatch(vec![OwnedArg::I32(tokens), OwnedArg::F32(mask)])
    })?;
    let (meta, _) = split_full_tree(&trainer.train);
    checkpoint::save(&meta_path, &meta)?;
    Ok(meta)
}

// ---------------------------------------------------------------------------
// Adaptation (step 2) + drift evaluation (step 3)
// ---------------------------------------------------------------------------

/// AHWA-LoRA adaptation on the QA task; cached under `cache_tag`.
pub fn adapt_lora_qa(
    ctx: &Ctx,
    graph_key: &str,
    meta: &ParamStore,
    head: &ParamStore,
    cfg: &TrainConfig,
    cache_tag: &str,
) -> Result<ParamStore> {
    let path = ctx.runs_dir.join(format!("{cache_tag}.train.bin"));
    if !ctx.fresh && path.exists() {
        return Ok(checkpoint::load(&path)?);
    }
    let variant = graph_key.split('/').next().unwrap();
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let train0 = graft_head(&ctx.init_train(graph_key)?, head);
    let task = SquadTask::new(v.vocab, v.seq);
    let mut trainer = Trainer::new(&ctx.engine, graph_key, meta.clone(), train0, cfg.clone())?;
    trainer.run(qa_batch_fn(task, v.train_batch))?;
    if trainer.collapsed() {
        anyhow::bail!("training collapsed");
    }
    checkpoint::save(&path, &trainer.train)?;
    Ok(trainer.train.clone())
}

/// Drift-grid QA evaluation of a (meta, adapter) pair.
pub fn qa_drift_grid(
    ctx: &Ctx,
    fwd_key: &str,
    meta: ParamStore,
    train: &ParamStore,
    ecfg: &EvalConfig,
    hw: [f32; 5],
) -> Result<Vec<(String, f64, f64)>> {
    let fwd = ctx.engine.load(fwd_key)?;
    let variant = fwd_key.split('/').next().unwrap();
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let task = SquadTask::new(v.vocab, v.seq);
    let eval_set = QaEvalSet::generate(&task, ecfg.examples, ecfg.seed);

    let mut prog_rng = Pcg64::with_stream(ecfg.seed, 0x9209);
    let dep = AnalogDeployment::program(meta, PcmModel::default(), hw[1].max(0.0), &mut prog_rng);

    let mut out = Vec::new();
    for (label, secs) in DRIFT_TIMES {
        let (mut f1s, mut ems) = (0.0, 0.0);
        for trial in 0..ecfg.trials {
            let mut rng = Pcg64::with_stream(ecfg.seed, 0xd217 ^ ((trial as u64) << 9));
            let meta_t = dep.meta_at(secs, ecfg.compensate, &mut rng);
            let eval_hw = pcm_eval_hw(hw[2], hw[3], hw[4]);
            let (f1, em) = eval_set.score(&fwd, &meta_t, train, eval_hw, ecfg.seed ^ trial as u64)?;
            f1s += f1;
            ems += em;
        }
        out.push((
            label.to_string(),
            f1s / ecfg.trials as f64,
            ems / ecfg.trials as f64,
        ));
    }
    Ok(out)
}

/// Digital (no hardware) QA score.
pub fn qa_digital(
    ctx: &Ctx,
    fwd_key: &str,
    meta: &ParamStore,
    train: &ParamStore,
    ecfg: &EvalConfig,
) -> Result<(f64, f64)> {
    let fwd = ctx.engine.load(fwd_key)?;
    let variant = fwd_key.split('/').next().unwrap();
    let v = ctx.engine.manifest.variant(variant)?.clone();
    let task = SquadTask::new(v.vocab, v.seq);
    let eval_set = QaEvalSet::generate(&task, ecfg.examples, ecfg.seed);
    eval_set.score(&fwd, meta, train, [0.0; 5], ecfg.seed)
}

/// Default hw vector for PCM-backed inference at given bit widths.
pub fn infer_hw(dac_bits: u32, adc_bits: u32, clip_sigma: f32, adc_noise: f32) -> [f32; 5] {
    let lv = |b: u32| if b == 0 { 0.0 } else { ((1u32 << (b - 1)) - 1) as f32 };
    [0.0, clip_sigma, lv(dac_bits), lv(adc_bits), adc_noise]
}
