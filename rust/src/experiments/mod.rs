//! One driver per paper table/figure (DESIGN.md §Experiment index).
//!
//! Run via the CLI: `ahwa-lora exp <id>` where `<id>` ∈
//! {table1, table2, table3, table4, table5, table6, table7, table8,
//!  table9, table10, fig2a, fig2b, fig3a, fig3b, fig4a, fig4b, fig4c,
//!  e2e, all}. Results print as markdown and are written to
//! `results/<id>.md`; EXPERIMENTS.md records paper-vs-measured.

pub mod ablations;
pub mod common;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod llm;
pub mod table1;
pub mod table2;
pub mod table3;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub const ALL_IDS: [&str; 18] = [
    "table1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table10", "fig2a", "fig2b", "fig3a", "fig3b", "fig4a", "fig4b", "fig4c", "e2e",
];

pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "table1" => table1::run(args),
        "table2" => table2::run(args),
        "table3" => table3::run(args),
        "table4" => llm::table4(args),
        "table5" => llm::table5(args),
        "table6" => ablations::learning_rate(args),
        "table7" => ablations::weight_noise(args),
        "table8" => ablations::clipping(args),
        "table9" => llm::table9(args),
        "table10" => llm::table10(args),
        "fig2a" => fig2::rank_pareto(args),
        "fig2b" => fig2::placement(args),
        "fig3a" => fig3::dynamic_adaptation(args),
        "fig3b" => fig3::scalability(args),
        "fig4a" => fig4::latency_balance(args),
        "fig4b" => fig4::tcdm(args),
        "fig4c" => fig4::total_latency(args),
        "e2e" => table1::e2e(args),
        "all" => {
            let mut failures = Vec::new();
            for id in ALL_IDS {
                eprintln!("\n=== {id} ===");
                let t0 = std::time::Instant::now();
                if let Err(e) = run(id, args) {
                    eprintln!("[exp] {id} FAILED: {e:#}");
                    failures.push(id);
                }
                eprintln!("[exp] {id} took {:.1} s", t0.elapsed().as_secs_f64());
            }
            if failures.is_empty() {
                Ok(())
            } else {
                bail!("experiments failed: {failures:?}")
            }
        }
        _ => bail!("unknown experiment '{id}'; known: {ALL_IDS:?} or 'all'"),
    }
}
