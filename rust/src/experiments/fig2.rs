//! Figure 2 — LoRA resource-allocation studies on the MobileBERT proxy.
//!
//! * `rank_pareto` (Fig. 2a): F1 vs adapter memory for r ∈ {1,2,4,8,16}
//!   across drift times — diminishing returns with a knee at r = 8.
//! * `placement` (Fig. 2b): adapters on {all, FFN-only, QKV-only}
//!   linears — "all" wins at every drift time.

use anyhow::Result;

use crate::config::manifest::Role;
use crate::config::run::{EvalConfig, TrainConfig};
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::{adapt_lora_qa, infer_hw, pretrained_encoder, qa_drift_grid, Ctx};

fn study(
    args: &Args,
    title: &str,
    result_name: &str,
    configs: &[(&str, String)], // (label, graph suffix e.g. "@r4" / "")
) -> Result<()> {
    let ctx = Ctx::new()?;
    let variant = args.str("variant", "mobilebert_proxy");
    let steps = args.usize("steps", 200);
    let ecfg = EvalConfig::from_args(args);
    let hw = infer_hw(8, 8, 3.0, 0.04);
    let (meta, head) = pretrained_encoder(&ctx, &variant, args.usize("pretrain-steps", 400))?;

    let mut t = Table::new(
        title,
        &["config", "LoRA params (K)", "0s", "1h", "1d", "1w", "1m", "1y", "10y"],
    );
    for (label, suffix) in configs {
        let step_key = format!("{variant}/step_qa_lora{suffix}");
        let fwd_key = format!("{variant}/fwd_qa{suffix}");
        let cfg = TrainConfig {
            steps,
            ..TrainConfig::from_args(args)
        };
        let train = adapt_lora_qa(
            &ctx,
            &step_key,
            &meta,
            &head,
            &cfg,
            &format!("{variant}.{result_name}.{}", label.replace(['=', ' '], "_")),
        )?;
        // adapter budget: lora tensors only (heads are task-owned)
        let spec = ctx.engine.manifest.graph(&step_key)?;
        let lora_params: usize = spec
            .inputs_with_role(Role::Train)
            .filter(|io| io.name.starts_with("lora."))
            .map(|io| io.numel())
            .sum();
        let grid = qa_drift_grid(&ctx, &fwd_key, meta.clone(), &train, &ecfg, hw)?;
        let mut row = vec![label.to_string(), f(lora_params as f64 / 1e3, 1)];
        row.extend(grid.iter().map(|(_, f1, _)| f(*f1, 2)));
        t.row(row);
    }
    t.print();
    Ctx::new()?.save_result(result_name, &t.render())
}

pub fn rank_pareto(args: &Args) -> Result<()> {
    study(
        args,
        "Fig. 2a — F1 vs LoRA rank over drift (Pareto study)",
        "fig2a",
        &[
            ("r=1", "@r1".into()),
            ("r=2", "@r2".into()),
            ("r=4", "@r4".into()),
            ("r=8", "".into()),
            ("r=16", "@r16".into()),
        ],
    )
}

pub fn placement(args: &Args) -> Result<()> {
    study(
        args,
        "Fig. 2b — LoRA placement over drift",
        "fig2b",
        &[
            ("all", "".into()),
            ("ffn", "@ffn".into()),
            ("qkv", "@qkv".into()),
        ],
    )
}
