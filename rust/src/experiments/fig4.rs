//! Figure 4 — AIMC ⇄ PMCA latency analysis (pure simulator study; this
//! is the paper's hardware-codesign evaluation and runs entirely on the
//! pmca/pipeline substrates).

use anyhow::Result;

use crate::pipeline::balance::best_point;
use crate::pipeline::schedule::{INTEGRATION_TIMES_NS, TOKEN_PARALLELISM};
use crate::pmca::cluster::SnitchCluster;
use crate::pmca::kernels::LoraWorkload;
use crate::pmca::redmule::RedMulE;
use crate::pmca::tcdm;
use crate::util::cli::Args;
use crate::util::table::{f, Table};

use super::common::Ctx;

/// The two MobileBERT layer slices the paper studies.
pub const LAYERS: [(&str, usize, usize); 2] = [("128x128", 128, 128), ("512x128", 512, 128)];
const SEQ: usize = 320; // paper SL

pub fn latency_balance(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let rank = args.usize("rank", 8);
    let (c, e) = (SnitchCluster::default(), RedMulE::default());
    let mut t = Table::new(
        "Fig. 4a — AIMC vs PMCA latency per token batch",
        &["layer", "T_int (ns)", "t", "AIMC (µs)", "PMCA (µs)", "PMCA/AIMC"],
    );
    for (name, m, n) in LAYERS {
        for t_int in INTEGRATION_TIMES_NS {
            for &tok in &TOKEN_PARALLELISM {
                let w = LoraWorkload { m, n, r: rank, t: tok };
                let p = crate::pipeline::schedule::pipeline_latency(&w, t_int, SEQ, &c, &e);
                t.row(vec![
                    name.to_string(),
                    f(t_int, 0),
                    tok.to_string(),
                    f(p.aimc_ns / 1e3, 2),
                    f(p.pmca_ns / 1e3, 2),
                    f(p.ratio(), 2),
                ]);
            }
        }
    }
    t.print();
    ctx.save_result("fig4a", &t.render())
}

pub fn tcdm(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let rank = args.usize("rank", 8);
    let c = SnitchCluster::default();
    let mut t = Table::new(
        "Fig. 4b — PMCA TCDM requirement vs parallel tokens",
        &["layer", "t", "TCDM (KiB)", "fits 128 KiB?"],
    );
    for (name, m, n) in LAYERS {
        for &tok in &TOKEN_PARALLELISM {
            let w = LoraWorkload { m, n, r: rank, t: tok };
            let fp = tcdm::footprint(&w);
            t.row(vec![
                name.to_string(),
                tok.to_string(),
                f(fp.kib(), 1),
                if tcdm::fits(&w, &c) { "yes".into() } else { "NO (spill)".into() },
            ]);
        }
    }
    t.print();
    ctx.save_result("fig4b", &t.render())
}

pub fn total_latency(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let rank = args.usize("rank", 8);
    let (c, e) = (SnitchCluster::default(), RedMulE::default());
    let mut t = Table::new(
        "Fig. 4c — total latency for SL=320 (balanced pipeline) vs AIMC-only",
        &["layer", "T_int (ns)", "best t", "AIMC-only (µs)", "AHWA-LoRA (µs)", "overhead %"],
    );
    for (name, m, n) in LAYERS {
        for t_int in INTEGRATION_TIMES_NS {
            // the same sweep+best the serving scheduler commits to at
            // build time (pinned against it in tests/pipeline_golden.rs)
            let b = best_point(m, n, rank, t_int, SEQ, &c, &e);
            t.row(vec![
                name.to_string(),
                f(t_int, 0),
                b.t.to_string(),
                f(b.latency.baseline_ns / 1e3, 2),
                f(b.latency.steady_ns / 1e3, 2),
                f(100.0 * b.overhead(), 2),
            ]);
        }
    }
    t.print();
    ctx.save_result("fig4c", &t.render())
}
