//! Decoder-LLM experiments (LLaMA-3.1-8B proxy): instruction tuning
//! (Table IV), GRPO reinforcement learning (Table V), and the
//! noise-robustness sweeps (Supp. Tables IX/X).
//!
//! Following the paper's LLaMA protocol: all linear layers noisy, NO
//! weight clipping, NO explicit DAC/ADC modeling; training noise 6.7 %
//! (SFT) / 3.0 % (RL); evaluation applies fixed Gaussian weight noise
//! per trial, or the full PCM model at 0 s drift.

use anyhow::Result;

use crate::aimc::tile::is_mappable;
use crate::config::run::TrainConfig;
use crate::data::instruct::{Instruction, InstructTask, ALL_INSTRUCTIONS};
use crate::data::tokenizer::{EOS, ESOL, SEP};
use crate::eval::drift_eval::{fwd_batch_shape, lm_logits, AnalogDeployment};
use crate::model::params::ParamStore;
use crate::pcm::PcmModel;
use crate::rl::grpo::GrpoTrainer;
use crate::rl::reward::score;
use crate::runtime::LoadedGraph;
use crate::train::{OwnedArg, OwnedBatch, Trainer};
use crate::util::cli::Args;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};

use super::common::{pretrained_decoder, Ctx};

const VARIANT: &str = "llama_proxy";

/// Fixed Gaussian weight perturbation on the mappable (analog) tensors:
/// the paper's LLM evaluation protocol (noise relative to max|w|).
pub fn gaussian_meta(meta: &ParamStore, level: f64, rng: &mut Pcg64) -> ParamStore {
    let mut out = meta.clone();
    if level <= 0.0 {
        return out;
    }
    for t in out.tensors.iter_mut() {
        if is_mappable(&t.name) && t.shape.len() == 2 {
            let amp = level as f32 * t.data.iter().fold(0f32, |m, x| m.max(x.abs()));
            for v in t.data.iter_mut() {
                *v += amp * rng.normal_f32();
            }
        }
    }
    out
}

/// Zero-LoRA trainable tree for the fwd graph (B=0 ⇒ exactly the base).
fn zero_lora(ctx: &Ctx, variant: &str) -> Result<ParamStore> {
    let mut train = ctx.init_train(&format!("{variant}/step_lm_lora"))?;
    for t in train.tensors.iter_mut() {
        if t.name.ends_with("_b") {
            t.data.iter_mut().for_each(|x| *x = 0.0);
        }
    }
    Ok(train)
}

// ---------------------------------------------------------------------------
// Batched greedy decoding (evaluation path)
// ---------------------------------------------------------------------------

/// Greedy-decode many prompts at once through the fixed-batch fwd graph.
///
/// Thin wrapper over [`crate::serve::decode::greedy_chunks`]: offline
/// eval and live serving share ONE step engine (same PAD layout, same
/// argmax tie-break, same stop rules), so the conformance suite's
/// bit-identity pin covers the numbers behind Tables IV/V/IX/X too.
pub fn batched_greedy(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    prompts: &[Vec<i32>],
    max_new: usize,
    seed: u64,
) -> Result<Vec<Vec<i32>>> {
    let (b, s) = fwd_batch_shape(graph);
    let vocab = graph.spec.outputs[0].shape[2];
    crate::serve::decode::greedy_chunks(b, s, vocab, prompts, max_new, &[ESOL, EOS], |buf| {
        lm_logits(graph, meta, train, buf, [0.0; 5], seed)
    })
}

/// Zero-shot suite accuracy: greedy exact-match of the expected
/// transform response (response compared up to EOS).
fn suite_accuracy(
    ctx: &Ctx,
    meta: &ParamStore,
    train: &ParamStore,
    kind: Instruction,
    n: usize,
    seed: u64,
) -> Result<f64> {
    let fwd = ctx.engine.load(&format!("{VARIANT}/fwd_lm"))?;
    let v = ctx.engine.manifest.variant(VARIANT)?.clone();
    let task = InstructTask::new(v.vocab, v.seq);
    let mut rng = Pcg64::with_stream(seed, kind.type_token() as u64);
    let mut prompts = Vec::with_capacity(n);
    let mut expected = Vec::with_capacity(n);
    for _ in 0..n {
        let ex = task.example(kind, &mut rng);
        // prompt = everything through [SEP]
        let sep = ex.tokens.iter().position(|&t| t == SEP).unwrap();
        prompts.push(ex.tokens[..=sep].to_vec());
        expected.push(ex.response);
    }
    let decoded = batched_greedy(&fwd, meta, train, &prompts, task.src_len + 2, seed)?;
    let mut ok = 0;
    for (d, e) in decoded.iter().zip(&expected) {
        let d_trim: Vec<i32> = d.iter().copied().take_while(|&t| t != EOS).collect();
        if d_trim == *e {
            ok += 1;
        }
    }
    Ok(100.0 * ok as f64 / n as f64)
}

/// GSM accuracy via batched greedy decoding.
pub fn gsm_accuracy(
    ctx: &Ctx,
    meta: &ParamStore,
    train: &ParamStore,
    n: usize,
    seed: u64,
) -> Result<f64> {
    let fwd = ctx.engine.load(&format!("{VARIANT}/fwd_lm"))?;
    let v = ctx.engine.manifest.variant(VARIANT)?.clone();
    let task = crate::data::gsm::GsmTask::new(v.seq);
    let mut rng = Pcg64::new(seed);
    let problems: Vec<_> = (0..n).map(|_| task.problem(&mut rng)).collect();
    let prompts: Vec<Vec<i32>> = problems.iter().map(|p| p.prompt.clone()).collect();
    let decoded = batched_greedy(&fwd, meta, train, &prompts, 14, seed)?;
    let correct = decoded
        .iter()
        .zip(&problems)
        .filter(|(d, p)| score(d, p.answer()).answer_exact > 0.0)
        .count();
    Ok(100.0 * correct as f64 / n as f64)
}

// ---------------------------------------------------------------------------
// Adaptation runs (cached)
// ---------------------------------------------------------------------------

/// AHWA-LoRA instruction tuning (SFT) at the given training noise.
fn sft_lora(ctx: &Ctx, meta: &ParamStore, noise: f64, steps: usize, tag: &str) -> Result<ParamStore> {
    let cache = ctx.runs_dir.join(format!("{VARIANT}.{tag}.train.bin"));
    if !ctx.fresh && cache.exists() {
        return Ok(crate::model::checkpoint::load(&cache)?);
    }
    eprintln!("[llm] SFT '{tag}' ({steps} steps, noise {noise})…");
    let v = ctx.engine.manifest.variant(VARIANT)?.clone();
    let cfg = TrainConfig {
        steps,
        lr: 2e-4,
        weight_decay: 0.01,
        warmup: 5,
        weight_noise: noise,
        adc_noise: 0.0,
        clip_sigma: 0.0,
        dac_bits: 0,
        adc_bits: 0,
        log_every: 50,
        ..Default::default()
    };
    let task = InstructTask::new(v.vocab, v.seq);
    let b = v.train_batch;
    let train0 = ctx.init_train(&format!("{VARIANT}/step_lm_lora"))?;
    let mut trainer = Trainer::new(&ctx.engine, &format!("{VARIANT}/step_lm_lora"), meta.clone(), train0, cfg)?;
    trainer.run(move |_, rng| {
        let (tokens, mask) = task.batch(b, rng);
        OwnedBatch(vec![OwnedArg::I32(tokens), OwnedArg::F32(mask)])
    })?;
    crate::model::checkpoint::save(&cache, &trainer.train)?;
    Ok(trainer.train.clone())
}

/// GRPO run at the given training noise (cached).
fn grpo_lora(ctx: &Ctx, meta: &ParamStore, noise: f64, steps: usize, tag: &str) -> Result<ParamStore> {
    let cache = ctx.runs_dir.join(format!("{VARIANT}.{tag}.train.bin"));
    if !ctx.fresh && cache.exists() {
        return Ok(crate::model::checkpoint::load(&cache)?);
    }
    eprintln!("[llm] GRPO '{tag}' ({steps} steps, noise {noise})…");
    let cfg = TrainConfig {
        steps,
        lr: 5e-4, // proxy-scale counterpart of the paper's 5e-6
        weight_decay: 0.1,
        warmup: steps / 10,
        weight_noise: noise,
        adc_noise: 0.0,
        clip_sigma: 0.0,
        dac_bits: 0,
        adc_bits: 0,
        log_every: 10,
        ..Default::default()
    };
    let train0 = ctx.init_train(&format!("{VARIANT}/step_grpo_lora"))?;
    let mut trainer = GrpoTrainer::new(&ctx.engine, VARIANT, meta.clone(), train0, cfg)?;
    trainer.run()?;
    crate::model::checkpoint::save(&cache, &trainer.train)?;
    Ok(trainer.train.clone())
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table IV — zero-shot suites: digital vs analog-pre vs analog-post.
pub fn table4(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let steps = args.usize("steps", 150);
    let n = args.usize("examples", 48);
    let trials = args.usize("trials", 2);
    let noise = args.f64("noise", 0.067);
    let meta = pretrained_decoder(&ctx, VARIANT, args.usize("pretrain-steps", 500))?;
    let base_train = zero_lora(&ctx, VARIANT)?;
    let sft = sft_lora(&ctx, &meta, noise, steps, "table4.sft")?;

    let mut t = Table::new(
        "Table IV — zero-shot suite accuracy (%): digital / analog-pre / analog-post",
        &["Model Variant", "copy-suite", "reverse-suite", "map-suite"],
    );
    let eval_row = |label: &str,
                    m: &dyn Fn(&mut Pcg64) -> ParamStore,
                    train: &ParamStore,
                    avg_trials: usize|
     -> Result<Vec<String>> {
        let mut row = vec![label.to_string()];
        for kind in ALL_INSTRUCTIONS {
            let mut acc = 0.0;
            for trial in 0..avg_trials {
                let mut rng = Pcg64::with_stream(404, trial as u64);
                let meta_t = m(&mut rng);
                acc += suite_accuracy(&ctx, &meta_t, train, kind, n, 404 + trial as u64)?;
            }
            row.push(f(acc / avg_trials as f64, 1));
        }
        Ok(row)
    };
    t.row(eval_row("Digital (baseline)", &|_| meta.clone(), &base_train, 1)?);
    t.row(eval_row(
        "Analog (pre-AHWA-LoRA)",
        &|rng| gaussian_meta(&meta, noise, rng),
        &base_train,
        trials,
    )?);
    t.row(eval_row(
        "Analog (post-AHWA-LoRA)",
        &|rng| gaussian_meta(&meta, noise, rng),
        &sft,
        trials,
    )?);
    t.print();
    ctx.save_result("table4", &t.render())
}

/// Table V — GSM accuracy: digital pre/post-LoRA vs analog pre/post.
pub fn table5(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let steps = args.usize("rl-steps", 40);
    let n = args.usize("examples", 64);
    let trials = args.usize("trials", 2);
    let noise = args.f64("rl-noise", 0.03);
    let meta = pretrained_decoder(&ctx, VARIANT, args.usize("pretrain-steps", 500))?;
    let base_train = zero_lora(&ctx, VARIANT)?;

    let digital_post = grpo_lora(&ctx, &meta, 0.0, steps, "table5.grpo.digital")?;
    let analog_post = grpo_lora(&ctx, &meta, noise, steps, "table5.grpo.analog")?;

    let digital_pre = gsm_accuracy(&ctx, &meta, &base_train, n, 505)?;
    let digital_post_acc = gsm_accuracy(&ctx, &meta, &digital_post, n, 505)?;
    let noisy_eval = |train: &ParamStore| -> Result<f64> {
        let mut acc = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(515, trial as u64);
            let meta_t = gaussian_meta(&meta, noise, &mut rng);
            acc += gsm_accuracy(&ctx, &meta_t, train, n, 505 + trial as u64)?;
        }
        Ok(acc / trials as f64)
    };
    let analog_pre = noisy_eval(&base_train)?;
    let analog_post_acc = noisy_eval(&analog_post)?;

    let mut t = Table::new(
        "Table V — GSM accuracy (%) with CoT format",
        &["Benchmark", "Dig. Pre-LoRA", "Dig. Post-LoRA", "Analog Pre", "Analog Post"],
    );
    t.row(vec![
        "GSM-synthetic".into(),
        f(digital_pre, 2),
        f(digital_post_acc, 2),
        f(analog_pre, 2),
        f(analog_post_acc, 2),
    ]);
    t.print();
    ctx.save_result("table5", &t.render())
}

/// Supp. Table IX — suite accuracy vs inference noise level (+ PCM 0s).
pub fn table9(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let n = args.usize("examples", 48);
    let trials = args.usize("trials", 2);
    let meta = pretrained_decoder(&ctx, VARIANT, args.usize("pretrain-steps", 500))?;
    let sft = sft_lora(&ctx, &meta, 0.067, args.usize("steps", 150), "table4.sft")?;

    let levels = [0.0, 0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.067];
    let mut hdr: Vec<String> = vec!["suite".into()];
    hdr.extend(levels.iter().map(|l| format!("{:.1}%", l * 100.0)));
    hdr.push("PCM(0s)".into());
    let mut t = Table::new(
        "Supp. Table IX — accuracy vs inference noise (trained at 6.7%)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let kind = Instruction::Copy; // the paper's HellaSwag analogue
    let mut row = vec![kind.name().to_string()];
    for level in levels {
        let mut acc = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(909, trial as u64);
            let meta_t = gaussian_meta(&meta, level, &mut rng);
            acc += suite_accuracy(&ctx, &meta_t, &sft, kind, n, 909 + trial as u64)?;
        }
        row.push(f(acc / trials as f64, 1));
    }
    // full PCM statistical model at zero drift (no clipping: paper LLM protocol)
    let mut acc = 0.0;
    for trial in 0..trials {
        let mut rng = Pcg64::with_stream(919, trial as u64);
        let dep = AnalogDeployment::program(meta.clone(), PcmModel::default(), 0.0, &mut rng);
        let meta_t = dep.meta_at(0.0, true, &mut rng);
        acc += suite_accuracy(&ctx, &meta_t, &sft, kind, n, 919 + trial as u64)?;
    }
    row.push(f(acc / trials as f64, 1));
    t.row(row);
    t.print();
    ctx.save_result("table9", &t.render())
}

/// Supp. Table X — GSM accuracy vs inference noise (+ PCM 0s).
pub fn table10(args: &Args) -> Result<()> {
    let ctx = Ctx::new()?;
    let n = args.usize("examples", 64);
    let trials = args.usize("trials", 2);
    let meta = pretrained_decoder(&ctx, VARIANT, args.usize("pretrain-steps", 500))?;
    let analog_post = grpo_lora(&ctx, &meta, 0.03, args.usize("rl-steps", 40), "table5.grpo.analog")?;

    let levels = [0.0, 0.01, 0.02, 0.03];
    let mut hdr: Vec<String> = vec!["benchmark".into()];
    hdr.extend(levels.iter().map(|l| format!("{:.1}%", l * 100.0)));
    hdr.push("PCM(0s)".into());
    let mut t = Table::new(
        "Supp. Table X — GSM accuracy vs inference noise (trained at 3.0%)",
        &hdr.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut row = vec!["GSM-synthetic".to_string()];
    for level in levels {
        let mut acc = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(1010, trial as u64);
            let meta_t = gaussian_meta(&meta, level, &mut rng);
            acc += gsm_accuracy(&ctx, &meta_t, &analog_post, n, 1010 + trial as u64)?;
        }
        row.push(f(acc / trials as f64, 2));
    }
    let mut acc = 0.0;
    for trial in 0..trials {
        let mut rng = Pcg64::with_stream(1020, trial as u64);
        let dep = AnalogDeployment::program(meta.clone(), PcmModel::default(), 0.0, &mut rng);
        let meta_t = dep.meta_at(0.0, true, &mut rng);
        acc += gsm_accuracy(&ctx, &meta_t, &analog_post, n, 1020 + trial as u64)?;
    }
    row.push(f(acc / trials as f64, 2));
    t.row(row);
    t.print();
    ctx.save_result("table10", &t.render())
}
