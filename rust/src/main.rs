//! AHWA-LoRA coordinator CLI.
//!
//! ```text
//! ahwa-lora exp <id> [--steps N] [--trials N] [--variant V] [--fresh]
//! ahwa-lora train [--variant V] [--steps N] [--noise X] …
//! ahwa-lora latency [--rank R]          # Fig. 4 pipeline study
//! ahwa-lora serve-demo [--requests N] [--workers W] [--queue-depth D]
//!                      [--t-int NS] [--no-sched]
//! ahwa-lora list                        # artifacts + variants
//! ```

use anyhow::{bail, Result};

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest};
use ahwa_lora::experiments;
use ahwa_lora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "exp" => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            experiments::run(id, &args)
        }
        "train" => {
            // direct access to the AHWA-LoRA trainer for ad-hoc runs
            let mut forwarded = args.clone();
            forwarded.positional = vec!["e2e".into()];
            experiments::run("e2e", &forwarded)
        }
        "latency" => {
            experiments::run("fig4a", &args)?;
            experiments::run("fig4b", &args)?;
            experiments::run("fig4c", &args)
        }
        "serve-demo" => serve_demo(&args),
        "list" => list(),
        "" | "help" | "--help" => {
            println!(
                "usage: ahwa-lora <exp|train|latency|serve-demo|list> [flags]\n\
                 experiments: {:?} or 'all'",
                experiments::ALL_IDS
            );
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn list() -> Result<()> {
    let m = Manifest::load(default_artifacts_dir())?;
    println!("variants:");
    for (name, v) in &m.variants {
        println!(
            "  {name:<18} {} d={} L={} V={} S={} rank={}",
            v.kind, v.d_model, v.n_layers, v.vocab, v.seq, v.rank
        );
    }
    println!("graphs ({}):", m.graphs.len());
    for key in m.graphs.keys() {
        println!("  {key}");
    }
    Ok(())
}

/// Live multi-task serving demonstration (Table III's deployment):
/// deploy GLUE adapters, fire a mixed request wave through the sharded
/// engine pool, report per-worker routing / batching / hot-swap metrics.
fn serve_demo(args: &Args) -> Result<()> {
    use ahwa_lora::data::glue::{GlueGen, GlueTask};
    use ahwa_lora::serve::registry::SharedRegistry;
    use ahwa_lora::serve::{submit_wave, SchedConfig, Server};
    use ahwa_lora::util::rng::Pcg64;

    let n_requests = args.usize("requests", 64);
    let workers = args.usize("workers", 2);
    let queue_depth = args.usize("queue-depth", 128);
    let t_int = args.usize("t-int", 256) as f64;
    let no_sched = args.bool("no-sched");
    let variant = args.str("variant", "mobilebert_proxy");

    let ctx = ahwa_lora::experiments::common::Ctx::new()?;
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _) = ahwa_lora::experiments::common::pretrained_encoder(
        &ctx,
        &variant,
        args.usize("pretrain-steps", 400),
    )?;

    // adapters: use cached GLUE adapters if present, else fresh inits
    let registry = SharedRegistry::new();
    let tasks = [GlueTask::Sst2, GlueTask::Qnli, GlueTask::Cola];
    for t in tasks {
        let cache = ctx
            .runs_dir
            .join(format!("{variant}.glue.{}.train.bin", t.adapter_key()));
        let params = if cache.exists() {
            ahwa_lora::model::checkpoint::load(&cache)?
        } else {
            ctx.init_train(&format!("{variant}/step_cls_lora"))?
        };
        registry.deploy(t.adapter_key(), params);
    }
    println!(
        "deployed {} adapters ({:.2}M params total on DPUs)",
        registry.tasks().len(),
        registry.total_params() as f64 / 1e6
    );

    let mut builder = Server::builder(&variant)
        .manifest(ctx.engine.manifest.clone())
        .workers(workers)
        .queue_depth(queue_depth);
    if no_sched {
        println!("pipeline-aware scheduling: OFF (fixed size/deadline batching)");
    } else {
        // batch fills come from the Fig. 4 AIMC/PMCA balancing model of
        // the variant's own projection layer
        let sched = SchedConfig::for_layer(v.d_model, v.d_model, v.rank).t_int(t_int);
        println!(
            "pipeline-aware scheduling: {}x{} rank {} @ t_int={t_int:.0}ns (--no-sched to disable)",
            v.d_model, v.d_model, v.rank
        );
        builder = builder.scheduler(sched);
    }
    let server = builder.build(meta, registry)?;
    let client = server.client();
    let mut rng = Pcg64::new(42);
    let mut jobs = Vec::new();
    for i in 0..n_requests {
        let task = tasks[i % tasks.len()];
        let gen = GlueGen::new(task, v.vocab, v.seq);
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }
    let t0 = std::time::Instant::now();
    let responses = submit_wave(&client, &jobs)?;
    let wall = t0.elapsed();
    println!(
        "served {} requests in {:.1} ms ({:.0} req/s) across {} workers",
        responses.len(),
        wall.as_secs_f64() * 1e3,
        responses.len() as f64 / wall.as_secs_f64(),
        server.workers(),
    );
    println!("{}", server.metrics_report());
    server.shutdown()?;
    Ok(())
}
