//! AHWA-LoRA coordinator CLI.
//!
//! ```text
//! ahwa-lora exp <id> [--steps N] [--trials N] [--variant V] [--fresh]
//! ahwa-lora train [--variant V] [--steps N] [--noise X] …
//! ahwa-lora latency [--rank R]          # Fig. 4 pipeline study
//! ahwa-lora serve-demo [--requests N] [--workers W] [--queue-depth D]
//!                      [--t-int NS] [--no-sched] [--no-coord]
//!                      [--refresh-scale S] [--refresh-tol T] [--refresh-steps K]
//! ahwa-lora list                        # artifacts + variants
//! ```

use anyhow::{bail, Result};

use ahwa_lora::config::manifest::{default_artifacts_dir, Manifest};
use ahwa_lora::experiments;
use ahwa_lora::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    match args.subcommand.as_str() {
        "exp" => {
            let id = args
                .positional
                .first()
                .map(|s| s.as_str())
                .unwrap_or("all");
            experiments::run(id, &args)
        }
        "train" => {
            // direct access to the AHWA-LoRA trainer for ad-hoc runs
            let mut forwarded = args.clone();
            forwarded.positional = vec!["e2e".into()];
            experiments::run("e2e", &forwarded)
        }
        "latency" => {
            experiments::run("fig4a", &args)?;
            experiments::run("fig4b", &args)?;
            experiments::run("fig4c", &args)
        }
        "serve-demo" => serve_demo(&args),
        "list" => list(),
        "" | "help" | "--help" => {
            println!(
                "usage: ahwa-lora <exp|train|latency|serve-demo|list> [flags]\n\
                 experiments: {:?} or 'all'",
                experiments::ALL_IDS
            );
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn list() -> Result<()> {
    let m = Manifest::load(default_artifacts_dir())?;
    println!("variants:");
    for (name, v) in &m.variants {
        println!(
            "  {name:<18} {} d={} L={} V={} S={} rank={}",
            v.kind, v.d_model, v.n_layers, v.vocab, v.seq, v.rank
        );
    }
    println!("graphs ({}):", m.graphs.len());
    for key in m.graphs.keys() {
        println!("  {key}");
    }
    Ok(())
}

/// Live multi-task serving demonstration (Table III's deployment):
/// deploy GLUE adapters, fire a mixed request wave through the sharded
/// engine pool, report per-worker routing / batching / hot-swap metrics.
/// With `--refresh-scale S` (drift seconds per wall second, e.g. 5e4)
/// the drift-aware refresh worker re-fits and hot-swaps adapters live
/// while the wave is served.
fn serve_demo(args: &Args) -> Result<()> {
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Duration;

    use ahwa_lora::config::run::TrainConfig;
    use ahwa_lora::data::glue::{GlueGen, GlueTask};
    use ahwa_lora::pcm::PcmModel;
    use ahwa_lora::serve::registry::SharedRegistry;
    use ahwa_lora::serve::{
        submit_wave, DecayModel, RefreshConfig, RefreshCoupling, SchedConfig, Server,
        TrainerRefitter,
    };
    use ahwa_lora::train::{OwnedArg, OwnedBatch};
    use ahwa_lora::util::rng::Pcg64;

    let n_requests = args.usize("requests", 64);
    let workers = args.usize("workers", 2);
    let queue_depth = args.usize("queue-depth", 128);
    let t_int = args.usize("t-int", 256) as f64;
    let no_sched = args.bool("no-sched");
    let no_coord = args.bool("no-coord");
    let refresh_scale = args.f64("refresh-scale", 0.0);
    let refresh_tol = args.f64("refresh-tol", 0.05);
    let variant = args.str("variant", "mobilebert_proxy");

    let ctx = ahwa_lora::experiments::common::Ctx::new()?;
    let v = ctx.engine.manifest.variant(&variant)?.clone();
    let (meta, _) = ahwa_lora::experiments::common::pretrained_encoder(
        &ctx,
        &variant,
        args.usize("pretrain-steps", 400),
    )?;

    // adapters: use cached GLUE adapters if present, else fresh inits
    let registry = SharedRegistry::new();
    let tasks = [GlueTask::Sst2, GlueTask::Qnli, GlueTask::Cola];
    for t in tasks {
        let cache = ctx
            .runs_dir
            .join(format!("{variant}.glue.{}.train.bin", t.adapter_key()));
        let params = if cache.exists() {
            ahwa_lora::model::checkpoint::load(&cache)?
        } else {
            ctx.init_train(&format!("{variant}/step_cls_lora"))?
        };
        registry.deploy(t.adapter_key(), params);
    }
    println!(
        "deployed {} adapters ({:.2}M params total on DPUs)",
        registry.tasks().len(),
        registry.total_params() as f64 / 1e6
    );

    let mut builder = Server::builder(&variant)
        .manifest(ctx.engine.manifest.clone())
        .workers(workers)
        .queue_depth(queue_depth);
    if no_sched {
        println!("pipeline-aware scheduling: OFF (fixed size/deadline batching)");
    } else {
        // batch fills come from the Fig. 4 AIMC/PMCA balancing model of
        // the variant's own projection layer
        let mut sched = SchedConfig::for_layer(v.d_model, v.d_model, v.rank).t_int(t_int);
        println!(
            "pipeline-aware scheduling: {}x{} rank {} @ t_int={t_int:.0}ns (--no-sched to disable)",
            v.d_model, v.d_model, v.rank
        );
        if refresh_scale > 0.0 {
            // refresh-aware: shrink fills / tighten deadlines ahead of a
            // modeled drift trigger so hot-swaps land between batches
            sched = sched.coupling(RefreshCoupling::default());
            println!("refresh coupling: ON (swaps land between batches; watch stale_reqs/swap_gap)");
        }
        builder = builder.scheduler(sched);
    }
    if no_coord {
        // uncoordinated: every worker couples to the refresh runner
        // independently (tasks sharing a tolerance stall all shards at
        // once — watch holds_peak)
        builder = builder.no_coordination();
        println!("pool refresh coordination: OFF (--no-coord)");
    } else if refresh_scale > 0.0 && !no_sched {
        println!(
            "pool refresh coordination: ON (staggered triggers + adaptive window/hold; \
             watch holds_peak/stagger_shift)"
        );
    }
    if refresh_scale > 0.0 {
        // drift-aware refresh: re-fit each task's LoRA against the
        // drifted meta-weights with a bounded Trainer budget and
        // hot-swap it, live under traffic
        let mut gens = BTreeMap::new();
        for t in tasks {
            gens.insert(t.adapter_key().to_string(), GlueGen::new(t, v.vocab, v.seq));
        }
        let train_batch = v.train_batch;
        let batches = Arc::new(move |task: &str, _step: usize, rng: &mut Pcg64| {
            let gen = gens.get(task).expect("refresh batch for undeployed task");
            let b = gen.batch(train_batch, rng);
            OwnedBatch(vec![OwnedArg::I32(b.tokens), OwnedArg::I32(b.labels)])
        });
        let refitter = TrainerRefitter::new(
            ctx.engine.manifest.clone(),
            &format!("{variant}/step_cls_lora"),
            TrainConfig::default(),
            batches,
        );
        let cfg = RefreshConfig::new(DecayModel::analytic(PcmModel::default()), Arc::new(refitter))
            .tolerance(refresh_tol)
            .time_scale(refresh_scale)
            .step_budget(args.usize("refresh-steps", 8))
            .check_every(Duration::from_millis(25));
        println!(
            "drift-aware refresh: ON (drift x{refresh_scale:.0}, tolerance {refresh_tol:.3})"
        );
        builder = builder.refresh(cfg);
    }
    let server = builder.build(meta, registry)?;
    let client = server.client();
    let mut rng = Pcg64::new(42);
    let mut jobs = Vec::new();
    for i in 0..n_requests {
        let task = tasks[i % tasks.len()];
        let gen = GlueGen::new(task, v.vocab, v.seq);
        let (tokens, _, _) = gen.example(&mut rng);
        jobs.push((task.adapter_key().to_string(), tokens));
    }
    let t0 = std::time::Instant::now();
    let responses = submit_wave(&client, &jobs)?;
    let wall = t0.elapsed();
    println!(
        "served {} requests in {:.1} ms ({:.0} req/s) across {} workers",
        responses.len(),
        wall.as_secs_f64() * 1e3,
        responses.len() as f64 / wall.as_secs_f64(),
        server.workers(),
    );
    // one final policy evaluation so short runs still show the cycle
    server.refresh_tick_now();
    let events = server.refresh_events();
    if !events.is_empty() {
        println!("refresh events:");
        for e in &events {
            println!(
                "  {} @ drift age {:.0}s: decay {:.4} -> {:.4} ({} steps, swapped to v{})",
                e.task, e.drift_age_secs, e.pre_decay, e.post_decay, e.steps, e.version
            );
        }
        let agg = server.metrics();
        println!(
            "refresh-aware scheduling: {} stale request(s), worst swap->serve gap {:.1} µs",
            agg.stale_batch_requests,
            agg.swap_gap_ns as f64 / 1e3
        );
    }
    println!("{}", server.metrics_report());
    server.shutdown()?;
    Ok(())
}
