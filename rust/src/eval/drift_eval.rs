//! Drift evaluation harness.
//!
//! Implements the paper's inference protocol (Methods — Training and
//! Inference Details): program the trained model's mappable weights
//! onto simulated PCM arrays once, then for each drift time t ∈
//! {0 s … 10 y} and Monte-Carlo trial, read the arrays through the full
//! device model (programming noise → drift(t) → read noise → global
//! drift compensation), run the AOT-compiled forward graph with the
//! perturbed weights, and score the task metric. Results are averaged
//! over trials (paper: 10).
//!
//! The alternative `gaussian` mode reproduces the Table IX/X protocol:
//! additive Gaussian weight noise at a chosen relative amplitude,
//! using the graph's own in-graph noise path (fresh key per trial).

use anyhow::Result;

use crate::aimc::mapping::program_tensor;
use crate::aimc::tile::is_mappable;
use crate::config::manifest::Role;
use crate::model::params::ParamStore;
use crate::pcm::{read_tensor, PcmModel, ProgrammedTensor};
use crate::runtime::pack::{assemble_inputs, literal_to_f32, DataArg, PaddedChunks};
use crate::runtime::{Engine, LoadedGraph};
use crate::util::rng::Pcg64;

/// A trained model programmed onto the simulated analog substrate.
pub struct AnalogDeployment {
    /// (tensor name, programmed devices) for every mappable meta tensor.
    pub programmed: Vec<(String, ProgrammedTensor)>,
    /// Clean meta store (unmappable tensors are used as-is).
    pub meta: ParamStore,
    pub model: PcmModel,
}

impl AnalogDeployment {
    /// Program every mappable tensor (paper: all linear layers; ~81 % of
    /// MobileBERT parameters) with `clip_sigma` channel clipping.
    pub fn program(meta: ParamStore, model: PcmModel, clip_sigma: f32, rng: &mut Pcg64) -> Self {
        let mut programmed = Vec::new();
        for t in &meta.tensors {
            if is_mappable(&t.name) && t.shape.len() == 2 {
                let pt = program_tensor(&model, &t.data, t.shape[0], t.shape[1], clip_sigma, rng);
                programmed.push((t.name.clone(), pt));
            }
        }
        AnalogDeployment {
            programmed,
            meta,
            model,
        }
    }

    /// Devices on the analog substrate (2 per weight, differential).
    pub fn n_devices(&self) -> usize {
        self.programmed.iter().map(|(_, p)| p.n_devices()).sum()
    }

    /// Effective meta weights at drift time `t_seconds` for one trial.
    pub fn meta_at(&self, t_seconds: f64, compensate: bool, rng: &mut Pcg64) -> ParamStore {
        let mut out = self.meta.clone();
        for (name, pt) in &self.programmed {
            let w = read_tensor(&self.model, pt, t_seconds, compensate, rng);
            out.get_mut(name).expect("programmed tensor in meta").data = w;
        }
        out
    }

    /// Relative L2 deviation of the substrate-read weights from the
    /// clean meta targets at drift age `t_seconds`, averaged over
    /// Monte-Carlo `trials`:
    /// `√(Σ‖w(t) − w₀‖² / Σ‖w₀‖²)` over every programmed tensor.
    ///
    /// With `compensate` this is the *post-GDC* deviation — the quantity
    /// the serving refresh policy (`serve::refresh::DecayModel::Sampled`)
    /// tracks against a per-task tolerance. Note the t = 0 value is the
    /// programming-noise floor, not zero; tolerances for sampled decay
    /// must sit above it.
    pub fn relative_deviation(
        &self,
        t_seconds: f64,
        trials: usize,
        compensate: bool,
        seed: u64,
    ) -> f64 {
        let trials = trials.max(1);
        let mut acc = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(seed, 0x5eed ^ ((trial as u64) << 8));
            let mut num = 0.0f64;
            let mut den = 0.0f64;
            for (name, pt) in &self.programmed {
                let w = read_tensor(&self.model, pt, t_seconds, compensate, &mut rng);
                let w0 = &self.meta.get(name).expect("programmed tensor in meta").data;
                for (a, b) in w.iter().zip(w0.iter()) {
                    let d = (a - b) as f64;
                    num += d * d;
                    den += (*b as f64) * (*b as f64);
                }
            }
            acc += (num / den.max(f64::EPSILON)).sqrt();
        }
        acc / trials as f64
    }
}

/// Inference-time hardware vector: PCM perturbations come from the rust
/// device model, so the in-graph noise path is disabled and clipping is
/// already burned into the programmed conductances.
pub fn pcm_eval_hw(dac_levels: f32, adc_levels: f32, adc_noise: f32) -> [f32; 5] {
    [0.0, 0.0, dac_levels, adc_levels, adc_noise]
}

// ---------------------------------------------------------------------------
// Forward-pass evaluation wrappers
// ---------------------------------------------------------------------------

/// Run a QA forward graph over an eval set; returns predicted spans.
/// The search window excludes the question region (SQuAD decode rule
/// adapted to the synthetic layout).
pub fn qa_predict(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    tokens: &[i32],
    hw: [f32; 5],
    seed: u64,
) -> Result<Vec<(usize, usize)>> {
    let (b, s) = fwd_batch_shape(graph);
    let mut preds = Vec::with_capacity(tokens.len() / s);
    let mut chunks = PaddedChunks::new(tokens, b, s);
    while let Some((chunk, take, offset)) = chunks.next_chunk() {
        let inputs = assemble_inputs(
            &graph.spec,
            meta,
            train,
            None,
            &[DataArg::I32(chunk)],
            seed ^ (offset as u64).wrapping_mul(0x9e37),
            hw,
            None,
        )?;
        let outs = graph.run(&inputs)?;
        let sl = literal_to_f32(&outs[0])?;
        let el = literal_to_f32(&outs[1])?;
        for i in 0..take {
            let srow = &sl[i * s..(i + 1) * s];
            let erow = &el[i * s..(i + 1) * s];
            // passage starts after [CLS] Q marker [SEP]; window must
            // admit the longest legal span (marker + 3 tokens + delim)
            let (ps, pe) = super::metrics::best_span(&srow[4..], &erow[4..], 6);
            preds.push((ps + 4, pe + 4));
        }
    }
    Ok(preds)
}

/// Run a classification forward graph; returns raw logit rows.
pub fn cls_logits(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    tokens: &[i32],
    hw: [f32; 5],
    seed: u64,
) -> Result<Vec<Vec<f32>>> {
    let (b, s) = fwd_batch_shape(graph);
    let n_cls = graph.spec.outputs[0].shape[1];
    let mut rows = Vec::with_capacity(tokens.len() / s);
    let mut chunks = PaddedChunks::new(tokens, b, s);
    while let Some((chunk, take, offset)) = chunks.next_chunk() {
        let inputs = assemble_inputs(
            &graph.spec,
            meta,
            train,
            None,
            &[DataArg::I32(chunk)],
            seed ^ (offset as u64).wrapping_mul(0x517c),
            hw,
            None,
        )?;
        let outs = graph.run(&inputs)?;
        let logits = literal_to_f32(&outs[0])?;
        for i in 0..take {
            rows.push(logits[i * n_cls..(i + 1) * n_cls].to_vec());
        }
    }
    Ok(rows)
}

/// Full-sequence LM logits for a batch of token rows (decoder eval /
/// sampling). `tokens` must be exactly [b, s] for the graph.
pub fn lm_logits(
    graph: &LoadedGraph,
    meta: &ParamStore,
    train: &ParamStore,
    tokens: &[i32],
    hw: [f32; 5],
    seed: u64,
) -> Result<Vec<f32>> {
    let inputs = assemble_inputs(
        &graph.spec,
        meta,
        train,
        None,
        &[DataArg::I32(tokens)],
        seed,
        hw,
        None,
    )?;
    let outs = graph.run(&inputs)?;
    literal_to_f32(&outs[0])
}

pub fn fwd_batch_shape(graph: &LoadedGraph) -> (usize, usize) {
    let io = graph
        .spec
        .inputs_with_role(Role::Data)
        .next()
        .expect("fwd graph has a tokens input");
    (io.shape[0], io.shape[1])
}

// ---------------------------------------------------------------------------
// Drift-grid driver
// ---------------------------------------------------------------------------

/// Score one (metric_fn) over the drift grid. `metric_fn` receives the
/// perturbed meta store and a trial seed and returns a scalar metric.
pub fn drift_grid<F>(
    deployment: &AnalogDeployment,
    times: &[(&str, f64)],
    trials: usize,
    compensate: bool,
    seed: u64,
    mut metric_fn: F,
) -> Result<Vec<(String, f64)>>
where
    F: FnMut(&ParamStore, u64) -> Result<f64>,
{
    let mut out = Vec::with_capacity(times.len());
    for (label, secs) in times {
        let mut acc = 0.0;
        for trial in 0..trials {
            let mut rng = Pcg64::with_stream(seed, 0xd41f7 ^ (trial as u64) << 8);
            let meta_t = deployment.meta_at(*secs, compensate, &mut rng);
            acc += metric_fn(&meta_t, seed ^ (trial as u64).wrapping_mul(0xabcd_1234))?;
        }
        out.push((label.to_string(), acc / trials as f64));
    }
    Ok(out)
}

/// Convenience: QA F1/EM on a fixed eval set at one weight instance.
pub struct QaEvalSet {
    pub tokens: Vec<i32>,
    pub golds: Vec<(usize, usize)>,
}

impl QaEvalSet {
    pub fn generate(task: &crate::data::squad::SquadTask, n: usize, seed: u64) -> QaEvalSet {
        let mut rng = Pcg64::new(seed);
        let b = task.batch(n, &mut rng);
        let golds = b
            .starts
            .iter()
            .zip(&b.ends)
            .map(|(&s, &e)| (s as usize, e as usize))
            .collect();
        QaEvalSet {
            tokens: b.tokens,
            golds,
        }
    }

    pub fn score(
        &self,
        graph: &LoadedGraph,
        meta: &ParamStore,
        train: &ParamStore,
        hw: [f32; 5],
        seed: u64,
    ) -> Result<(f64, f64)> {
        let preds = qa_predict(graph, meta, train, &self.tokens, hw, seed)?;
        Ok(super::metrics::span_f1_em(&preds, &self.golds))
    }
}

/// Shared helper: load a fwd graph and the engine in one call.
pub fn load_fwd<'e>(engine: &'e Engine, key: &str) -> Result<std::rc::Rc<LoadedGraph>> {
    engine.load(key)
}

#[cfg(test)]
mod deviation_tests {
    use super::*;
    use crate::model::params::Tensor;
    use crate::pcm::PcmModel;

    fn toy_deployment() -> AnalogDeployment {
        let mut rng = Pcg64::new(21);
        let mut data = vec![0f32; 32 * 16];
        rng.fill_normal(&mut data, 0.0, 0.05);
        // `wq` is a mappable leaf name, so it lands on the substrate
        let meta = ParamStore::from_tensors(vec![Tensor {
            name: "layers.0.wq".to_string(),
            shape: vec![32, 16],
            data,
        }]);
        AnalogDeployment::program(meta, PcmModel::default(), 3.0, &mut Pcg64::new(22))
    }

    #[test]
    fn relative_deviation_grows_with_drift_age() {
        let dep = toy_deployment();
        assert_eq!(dep.programmed.len(), 1, "wq must be programmed");
        let floor = dep.relative_deviation(0.0, 3, true, 5);
        assert!(floor > 0.0, "programming noise gives a nonzero floor");
        let year = dep.relative_deviation(31_536_000.0, 3, true, 5);
        assert!(
            year > floor,
            "post-GDC deviation must grow with drift: {year} vs floor {floor}"
        );
    }
}

#[cfg(test)]
mod probe_tests {
    use super::*;
    use crate::data::squad::SquadTask;

    /// Diagnostic: noise sensitivity of the trained table1 checkpoint.
    #[test]
    #[ignore]
    fn probe_noise_sensitivity() {
        let engine = Engine::from_artifacts().unwrap();
        let runs = engine.manifest.root.join("runs");
        let meta = crate::model::checkpoint::load(runs.join("mobilebert_proxy.pretrained.meta.bin")).unwrap();
        let train = crate::model::checkpoint::load(runs.join("mobilebert_proxy.table1.lora.train.bin")).unwrap();
        let fwd = engine.load("mobilebert_proxy/fwd_qa").unwrap();
        let v = engine.manifest.variant("mobilebert_proxy").unwrap().clone();
        let task = SquadTask::new(v.vocab, v.seq);
        let eval = QaEvalSet::generate(&task, 128, 3);
        for noise in [0.0f32, 0.067, 0.15, 0.25, 0.4, 0.6] {
            // use the graph's own noise path with varying key
            let hw = [noise, 3.0, 127.0, 127.0, 0.04];
            let (f1, em) = eval.score(&fwd, &meta, &train, hw, 42).unwrap();
            eprintln!("noise={noise}: F1 {f1:.2} EM {em:.2}");
        }
    }
}
