//! Task metrics: SQuAD span F1/EM, accuracy, binary F1, Matthews
//! correlation, Pearson/Spearman — the exact set the paper reports.

use crate::util::stats;

/// SQuAD-style span scoring: predictions and golds are inclusive token
/// index ranges. F1 = token-overlap F1, EM = exact span match, both in
/// percent, averaged over examples.
pub fn span_f1_em(pred: &[(usize, usize)], gold: &[(usize, usize)]) -> (f64, f64) {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return (0.0, 0.0);
    }
    let mut f1_sum = 0.0;
    let mut em_sum = 0.0;
    for (&(ps, pe), &(gs, ge)) in pred.iter().zip(gold) {
        if (ps, pe) == (gs, ge) {
            em_sum += 1.0;
        }
        let (ps, pe) = (ps.min(pe), ps.max(pe));
        let inter = overlap(ps, pe, gs, ge);
        if inter > 0 {
            let p_len = pe - ps + 1;
            let g_len = ge - gs + 1;
            let prec = inter as f64 / p_len as f64;
            let rec = inter as f64 / g_len as f64;
            f1_sum += 2.0 * prec * rec / (prec + rec);
        }
    }
    let n = pred.len() as f64;
    (100.0 * f1_sum / n, 100.0 * em_sum / n)
}

fn overlap(a0: usize, a1: usize, b0: usize, b1: usize) -> usize {
    let lo = a0.max(b0);
    let hi = a1.min(b1);
    hi.saturating_sub(lo) + usize::from(hi >= lo)
}

/// Classification accuracy in percent.
pub fn accuracy(pred: &[i32], gold: &[i32]) -> f64 {
    assert_eq!(pred.len(), gold.len());
    if pred.is_empty() {
        return 0.0;
    }
    100.0 * pred.iter().zip(gold).filter(|(p, g)| p == g).count() as f64 / pred.len() as f64
}

/// Binary F1 (positive class = 1) in percent (MRPC/QQP).
pub fn binary_f1(pred: &[i32], gold: &[i32]) -> f64 {
    let tp = count(pred, gold, 1, 1);
    let fp = count(pred, gold, 1, 0);
    let fn_ = count(pred, gold, 0, 1);
    if tp == 0 {
        return 0.0;
    }
    let prec = tp as f64 / (tp + fp) as f64;
    let rec = tp as f64 / (tp + fn_) as f64;
    100.0 * 2.0 * prec * rec / (prec + rec)
}

/// Matthews correlation coefficient ×100 (CoLA).
pub fn matthews(pred: &[i32], gold: &[i32]) -> f64 {
    let tp = count(pred, gold, 1, 1) as f64;
    let tn = count(pred, gold, 0, 0) as f64;
    let fp = count(pred, gold, 1, 0) as f64;
    let fn_ = count(pred, gold, 0, 1) as f64;
    let denom = ((tp + fp) * (tp + fn_) * (tn + fp) * (tn + fn_)).sqrt();
    if denom == 0.0 {
        return 0.0;
    }
    100.0 * (tp * tn - fp * fn_) / denom
}

fn count(pred: &[i32], gold: &[i32], p: i32, g: i32) -> usize {
    pred.iter().zip(gold).filter(|(&a, &b)| a == p && b == g).count()
}

/// STS-B score: mean of Pearson and Spearman ×100 (GLUE convention).
pub fn pearson_spearman(pred: &[f64], gold: &[f64]) -> f64 {
    100.0 * 0.5 * (stats::pearson(pred, gold) + stats::spearman(pred, gold))
}

/// Argmax over logits row; ties break to the FIRST maximum (keeps
/// decodes deterministic across refactors).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in row.iter().enumerate().skip(1) {
        if *v > row[best] {
            best = i;
        }
    }
    best
}

/// Best legal span (start <= end, bounded window) from start/end logits
/// — the standard SQuAD decoding rule.
pub fn best_span(start_logits: &[f32], end_logits: &[f32], max_len: usize) -> (usize, usize) {
    let s_len = start_logits.len();
    let mut best = (0usize, 0usize);
    let mut best_score = f32::NEG_INFINITY;
    for s in 0..s_len {
        for e in s..(s + max_len).min(s_len) {
            let score = start_logits[s] + end_logits[e];
            if score > best_score {
                best_score = score;
                best = (s, e);
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_exact_match() {
        let (f1, em) = span_f1_em(&[(3, 5)], &[(3, 5)]);
        assert_eq!((f1, em), (100.0, 100.0));
    }

    #[test]
    fn span_partial_overlap() {
        // pred [3,4], gold [4,5]: inter 1, p_len 2, g_len 2 -> F1 0.5
        let (f1, em) = span_f1_em(&[(3, 4)], &[(4, 5)]);
        assert_eq!(em, 0.0);
        assert!((f1 - 50.0).abs() < 1e-9);
    }

    #[test]
    fn span_disjoint() {
        let (f1, em) = span_f1_em(&[(0, 1)], &[(5, 6)]);
        assert_eq!((f1, em), (0.0, 0.0));
    }

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1, 1], &[1, 0, 0, 1]), 75.0);
    }

    #[test]
    fn f1_ignores_true_negatives() {
        // all-negative predictions on all-negative golds: F1 = 0 by
        // convention (no positives)
        assert_eq!(binary_f1(&[0, 0], &[0, 0]), 0.0);
        assert_eq!(binary_f1(&[1, 1, 0], &[1, 1, 0]), 100.0);
    }

    #[test]
    fn matthews_perfect_and_inverse() {
        assert!((matthews(&[1, 1, 0, 0], &[1, 1, 0, 0]) - 100.0).abs() < 1e-9);
        assert!((matthews(&[0, 0, 1, 1], &[1, 1, 0, 0]) + 100.0).abs() < 1e-9);
        assert_eq!(matthews(&[1, 1, 1, 1], &[1, 1, 0, 0]), 0.0);
    }

    #[test]
    fn pearson_spearman_perfect() {
        let x = [1.0, 2.0, 3.0, 4.0];
        assert!((pearson_spearman(&x, &x) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn best_span_respects_order_and_window() {
        let s = vec![0.0, 5.0, 0.0, 4.0];
        let e = vec![8.0, 0.0, 4.5, 0.0];
        // e=0 has a high end logit but (0,0) scores 8 < (1,2)'s 9.5;
        // ends before the start are never considered.
        let (bs, be) = best_span(&s, &e, 3);
        assert!(bs <= be);
        assert_eq!((bs, be), (1, 2));
    }

    #[test]
    fn argmax_ties_first() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
