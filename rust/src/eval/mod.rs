//! Evaluation: the metric zoo and the drift-evaluation harness.
//!
//! [`metrics`] implements SQuAD F1/EM and the GLUE metric set;
//! [`drift_eval`] programs a trained model onto the simulated PCM
//! arrays and measures task metrics across the paper's 0 s – 10 y drift
//! grid (with global drift compensation), or under plain Gaussian
//! weight noise for the Table IX/X sweeps.

pub mod drift_eval;
pub mod metrics;
