//! Tiny argv parser (the image has no clap).
//!
//! Grammar: `prog <subcommand> [positional...] [--flag[=| ]value] [--switch]`.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_empty() {
                out.subcommand = a;
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse("train qa extra");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.positional, vec!["qa", "extra"]);
    }

    #[test]
    fn flags_space_and_equals() {
        let a = parse("exp table1 --steps 300 --variant=tiny --verbose");
        assert_eq!(a.usize("steps", 0), 300);
        assert_eq!(a.str("variant", ""), "tiny");
        assert!(a.bool("verbose"));
        assert!(!a.bool("quiet"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.f64("lr", 2e-4), 2e-4);
        assert_eq!(a.u64("seed", 7), 7);
    }
}
