//! Micro-benchmark harness (the image has no criterion).
//!
//! `cargo bench` targets use `harness = false` and drive this directly.
//! Methodology follows criterion's core loop: warmup, then timed batches
//! until a wall-clock budget is hit; reports mean / p50 / p95 / p99 over
//! batch means plus throughput if an item count is supplied. Results can
//! be persisted machine-readably with [`Bencher::write_json`]
//! (`BENCH_<name>.json`), so CI can diff serving-bench regressions
//! without scraping stdout.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use super::json::Value;
use super::stats;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub throughput: Option<f64>, // items / second
    /// Cost-model prediction for one iteration (ns), when the scenario
    /// has one (e.g. the serving scheduler's modeled batch latency);
    /// reported next to the measurement with the model/measured ratio.
    pub modeled_ns: Option<f64>,
}

impl BenchResult {
    /// One result as a JSON object (`scenario`, the latency percentiles,
    /// and — when present — `throughput` / `modeled_ns`).
    pub fn to_json(&self) -> Value {
        let mut pairs = vec![
            ("scenario", Value::str(self.name.clone())),
            ("iters", Value::num(self.iters as f64)),
            ("mean_ns", Value::num(self.mean_ns)),
            ("p50_ns", Value::num(self.p50_ns)),
            ("p95_ns", Value::num(self.p95_ns)),
            ("p99_ns", Value::num(self.p99_ns)),
        ];
        if let Some(t) = self.throughput {
            pairs.push(("throughput", Value::num(t)));
        }
        if let Some(m) = self.modeled_ns {
            pairs.push(("modeled_ns", Value::num(m)));
        }
        Value::obj(pairs)
    }
    pub fn report(&self) -> String {
        let t = match self.throughput {
            Some(t) if t >= 1e9 => format!("  {:8.2} Gitem/s", t / 1e9),
            Some(t) if t >= 1e6 => format!("  {:8.2} Mitem/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:8.2} Kitem/s", t / 1e3),
            Some(t) => format!("  {t:8.2} item/s"),
            None => String::new(),
        };
        let m = match self.modeled_ns {
            Some(m) if self.mean_ns > 0.0 => {
                format!("  model {:>12} ({:.2}x measured)", fmt_ns(m), m / self.mean_ns)
            }
            Some(m) => format!("  model {:>12}", fmt_ns(m)),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} iters  mean {:>12}  p50 {:>12}  p95 {:>12}{}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            t,
            m
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

pub struct Bencher {
    pub warmup: Duration,
    pub budget: Duration,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            budget: Duration::from_secs(2),
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn with_budget(secs: f64) -> Bencher {
        Bencher {
            budget: Duration::from_secs_f64(secs),
            ..Default::default()
        }
    }

    /// Benchmark `f`, which performs ONE logical iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_items(name, None, f)
    }

    /// Benchmark with a per-iteration item count for throughput reporting.
    pub fn bench_items<F: FnMut()>(&mut self, name: &str, items: Option<u64>, mut f: F) -> &BenchResult {
        // Warmup + calibrate batch size so one batch is ~1-10 ms.
        let wstart = Instant::now();
        let mut calib_iters: u64 = 0;
        while wstart.elapsed() < self.warmup {
            f();
            calib_iters += 1;
        }
        let per_iter = self.warmup.as_nanos() as f64 / calib_iters.max(1) as f64;
        let batch = ((5e6 / per_iter).ceil() as u64).clamp(1, 1 << 20);

        let mut batch_means: Vec<f64> = Vec::new();
        let mut total_iters = 0u64;
        let start = Instant::now();
        while start.elapsed() < self.budget {
            let t0 = Instant::now();
            for _ in 0..batch {
                f();
            }
            let dt = t0.elapsed().as_nanos() as f64;
            batch_means.push(dt / batch as f64);
            total_iters += batch;
        }
        let mean_ns = stats::mean(&batch_means);
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns,
            p50_ns: stats::percentile(&batch_means, 50.0),
            p95_ns: stats::percentile(&batch_means, 95.0),
            p99_ns: stats::percentile(&batch_means, 99.0),
            throughput: items.map(|n| n as f64 * 1e9 / mean_ns),
            modeled_ns: None,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Time a single execution of a long-running section (for end-to-end
    /// drivers where repeated runs are too expensive).
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        self.once_vs_model(name, None, f)
    }

    /// [`Self::once`], annotated with a cost-model prediction so the
    /// report shows modeled vs measured (serving scheduler scenarios).
    pub fn once_modeled<T, F: FnOnce() -> T>(&mut self, name: &str, modeled_ns: f64, f: F) -> T {
        self.once_vs_model(name, Some(modeled_ns), f)
    }

    fn once_vs_model<T, F: FnOnce() -> T>(
        &mut self,
        name: &str,
        modeled_ns: Option<f64>,
        f: F,
    ) -> T {
        let t0 = Instant::now();
        let out = f();
        let ns = t0.elapsed().as_nanos() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            p50_ns: ns,
            p95_ns: ns,
            p99_ns: ns,
            throughput: None,
            modeled_ns,
        };
        println!("{}", result.report());
        self.results.push(result);
        out
    }

    /// Persist every recorded result to `BENCH_<name>.json` in the
    /// current directory (the serving benches call this so CI and
    /// scripts can diff runs without scraping stdout). Returns the
    /// path written.
    pub fn write_json(&self, name: &str) -> std::io::Result<PathBuf> {
        self.write_json_to(Path::new("."), name)
    }

    /// [`Self::write_json`] into an explicit directory.
    pub fn write_json_to(&self, dir: &Path, name: &str) -> std::io::Result<PathBuf> {
        let results: Vec<Value> = self.results.iter().map(BenchResult::to_json).collect();
        let doc = Value::obj(vec![
            ("bench", Value::str(name)),
            ("results", Value::Arr(results)),
        ]);
        let path = dir.join(format!("BENCH_{name}.json"));
        std::fs::write(&path, doc.to_string())?;
        Ok(path)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bencher {
            warmup: Duration::from_millis(10),
            budget: Duration::from_millis(50),
            results: vec![],
        };
        let mut acc = 0u64;
        let r = b.bench_items("spin", Some(10), || {
            for i in 0..10u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.throughput.unwrap() > 0.0);
    }

    #[test]
    fn once_modeled_reports_model_column() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(1),
            results: vec![],
        };
        b.once_modeled("modeled", 1234.0, || black_box(1 + 1));
        let r = b.results.last().unwrap();
        assert_eq!(r.modeled_ns, Some(1234.0));
        assert!(r.report().contains("model"));
        b.once("plain", || black_box(0));
        assert!(!b.results.last().unwrap().report().contains("model"));
    }

    #[test]
    fn write_json_round_trips_scenarios() {
        let mut b = Bencher {
            warmup: Duration::from_millis(1),
            budget: Duration::from_millis(1),
            results: vec![],
        };
        b.once_modeled("wave", 1234.0, || black_box(0));
        b.once("plain", || black_box(0));
        let dir = std::env::temp_dir();
        let name = format!("bench_selftest_{}", std::process::id());
        let path = b.write_json_to(&dir, &name).unwrap();
        let doc = Value::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("bench").unwrap().as_str().unwrap(), name);
        let results = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].get("scenario").unwrap().as_str().unwrap(), "wave");
        assert_eq!(results[0].get("modeled_ns").unwrap().as_f64().unwrap(), 1234.0);
        assert!(results[0].get("p99_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert!(results[1].opt("modeled_ns").is_none());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn fmt_ns_scales() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1.5e3).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.0e9).contains(" s"));
    }
}
