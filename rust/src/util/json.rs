//! Minimal JSON parser + writer (the image has no serde).
//!
//! Supports the full JSON grammar; used for `artifacts/manifest.json`,
//! experiment result files, and run configs. Numbers are stored as f64
//! (the manifest only carries shapes/scalars, well inside f64 range).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key '{key}'")),
            _ => bail!("not an object (looking up '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_arr(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers --------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    pub fn nums(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|x| Value::Num(*x)).collect())
    }

    // ---- writer ------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at offset {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number '{s}': {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at offset {}", self.i),
                    }
                }
                c => {
                    // re-assemble multi-byte UTF-8 sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xf0 {
                            4
                        } else if c >= 0xe0 {
                            3
                        } else {
                            2
                        };
                        let start = self.i - 1;
                        self.i = start + len;
                        out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => bail!("expected ',' or ']' got '{}' at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => bail!("expected ',' or '}}' got '{}' at {}", c as char, self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Num(-1500.0));
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"shape":[8,48],"dtype":"int32","nested":{"x":1.5},"s":"a\"b\\c\nd"}"#;
        let v = Value::parse(src).unwrap();
        let re = Value::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        let re2 = Value::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re2);
    }

    #[test]
    fn unicode_escapes_and_utf8() {
        let v = Value::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("12 34").is_err());
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"graphs":{"tiny/fwd_qa":{"inputs":[{"name":"meta.tok_emb","role":"meta","shape":[64,16],"dtype":"float32"}]}}}"#;
        let v = Value::parse(src).unwrap();
        let inp = &v.get("graphs").unwrap().get("tiny/fwd_qa").unwrap().get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().usize_arr().unwrap(), vec![64, 16]);
    }
}
