//! PCG64-based pseudo-random number generation.
//!
//! The PCM device model draws hundreds of millions of Gaussians per
//! drift-evaluation trial (one per device, per non-ideality), so this is
//! a genuinely hot path (see EXPERIMENTS.md §Perf). We use the PCG-XSL-RR
//! 128/64 generator (O'Neill 2014) for the uniform stream and a cached
//! Box–Muller transform for normals.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift+rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Box–Muller produces pairs; cache the second draw.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Independent streams for the same seed (used to give every tile /
    /// trial / worker its own generator without correlation).
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare_normal: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Deterministic child generator — the rust analogue of
    /// `jax.random.fold_in`.
    pub fn fold_in(&self, data: u64) -> Pcg64 {
        let mut h = self.state as u64 ^ 0x9e37_79b9_7f4a_7c15;
        h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9) ^ data;
        h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        Pcg64::with_stream(h ^ (h >> 31), (self.inc >> 1) as u64 ^ data)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // multiply-shift; bias is negligible for n << 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via the Marsaglia polar method (pair-cached).
    /// ~3× faster than sin/cos Box–Muller on this target — the device
    /// model draws two normals per weight, so this is THE hot path
    /// (EXPERIMENTS.md §Perf, iteration 1).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let x = 2.0 * self.uniform() - 1.0;
            let y = 2.0 * self.uniform() - 1.0;
            let s = x * x + y * y;
            if s < 1.0 && s > 0.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(y * f);
                return x * f;
            }
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with i.i.d. N(mu, sigma) — the vectorised hot path.
    pub fn fill_normal(&mut self, out: &mut [f32], mu: f32, sigma: f32) {
        // polar method writing accepted pairs directly (no Option churn)
        let mut i = 0;
        let n = out.len();
        while i + 1 < n {
            let x = 2.0 * self.uniform() - 1.0;
            let y = 2.0 * self.uniform() - 1.0;
            let s = x * x + y * y;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let f = (-2.0 * s.ln() / s).sqrt();
            out[i] = mu + sigma * (x * f) as f32;
            out[i + 1] = mu + sigma * (y * f) as f32;
            i += 2;
        }
        if i < n {
            out[i] = mu + sigma * self.normal_f32();
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Shuffle a slice in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Categorical draw from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f32]) -> usize {
        let total: f64 = weights.iter().map(|w| *w as f64).sum();
        if total <= 0.0 {
            return self.below(weights.len().max(1));
        }
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= *w as f64;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::with_stream(42, 1);
        let mut b = Pcg64::with_stream(42, 2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fold_in_children_differ() {
        let root = Pcg64::new(7);
        let mut c1 = root.fold_in(1);
        let mut c2 = root.fold_in(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::new(1);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 200_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn fill_normal_matches_moments() {
        let mut r = Pcg64::new(4);
        let mut buf = vec![0f32; 100_001]; // odd length exercises the tail
        r.fill_normal(&mut buf, 2.0, 0.5);
        let mean = buf.iter().map(|x| *x as f64).sum::<f64>() / buf.len() as f64;
        let var = buf.iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / buf.len() as f64;
        assert!((mean - 2.0).abs() < 0.02);
        assert!((var - 0.25).abs() < 0.02);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[r.below(10)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn choose_distinct() {
        let mut r = Pcg64::new(6);
        let picked = r.choose(100, 20);
        let mut sorted = picked.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 20);
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::new(7);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 2);
    }
}
