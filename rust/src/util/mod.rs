//! Infrastructure substrates.
//!
//! The build image is fully offline and the vendored crate set contains
//! only the `xla` crate's dependency closure — no serde, clap, rand,
//! criterion or tokio. Everything a production coordinator needs from
//! those crates is implemented here, scoped to what this system uses.

pub mod bench;
pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
