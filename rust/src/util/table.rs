//! Markdown table rendering for experiment drivers (paper tables/figures
//! are reproduced as aligned markdown so EXPERIMENTS.md can embed them).

#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let line = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                s.push(' ');
                s.push_str(c);
                for _ in c.chars().count()..width[i] {
                    s.push(' ');
                }
                s.push_str(" |");
            }
            s.push('\n');
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        out.push_str(&line(&self.header));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&"-".repeat(w + 2));
            sep.push('|');
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&line(r));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Format helper: fixed-width float with `d` decimals.
pub fn f(v: f64, d: usize) -> String {
    format!("{v:.d$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_markdown() {
        let mut t = Table::new("T", &["method", "f1"]);
        t.row(vec!["AHWA".into(), f(90.01, 2)]);
        t.row(vec!["AHWA-LoRA".into(), f(89.17, 2)]);
        let s = t.render();
        assert!(s.contains("### T"));
        assert!(s.contains("| AHWA      | 90.01 |"));
        assert!(s.lines().filter(|l| l.starts_with('|')).count() == 4);
    }

    #[test]
    #[should_panic]
    fn rejects_wrong_width() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x".into()]);
    }
}
