//! Descriptive statistics used across the evaluation harness and benches.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Smoothing factor shared by the serving-side online estimators
/// (arrival rate in `serve::sched`, swap-gap / refit-budget series in
/// `serve::refresh`) — one constant, so the coupling's claim that the
/// estimators smooth identically cannot silently drift.
pub const EWMA_ALPHA: f64 = 0.25;

/// One [`EWMA_ALPHA`] step over an optional running value (the first
/// observation seeds the series).
pub fn ewma(prev: Option<f64>, x: f64) -> f64 {
    match prev {
        Some(e) => (1.0 - EWMA_ALPHA) * e + EWMA_ALPHA * x,
        None => x,
    }
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if n < 2.0 {
        return 0.0;
    }
    let (mx, my) = (mean(x), mean(y));
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (a, b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx).powi(2);
        syy += (b - my).powi(2);
    }
    if sxx == 0.0 || syy == 0.0 {
        0.0
    } else {
        sxy / (sxx * syy).sqrt()
    }
}

/// Spearman rank correlation (average ranks for ties).
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    pearson(&ranks(x), &ranks(y))
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < idx.len() {
        let mut j = i;
        while j + 1 < idx.len() && xs[idx[j + 1]] == xs[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            r[idx[k]] = avg;
        }
        i = j + 1;
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn pearson_perfect() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yn = [6.0, 4.0, 2.0];
        assert!((pearson(&x, &yn) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.0, 8.0, 27.0, 64.0];
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_ties_average() {
        let r = ranks(&[1.0, 2.0, 2.0, 3.0]);
        assert_eq!(r, vec![1.0, 2.5, 2.5, 4.0]);
    }
}
