//! Property-based testing mini-framework (the image has no proptest).
//!
//! `check(name, cases, |g| ...)` runs a property over `cases` randomized
//! inputs drawn through the [`Gen`] handle; on failure it reports the
//! case seed so the exact input is reproducible with `replay`.

use std::time::Duration;

use super::rng::Pcg64;

pub struct Gen {
    pub rng: Pcg64,
    pub seed: u64,
}

impl Gen {
    /// Uniform integer in `[lo, hi]` (inclusive). Well-defined over the
    /// whole domain: `hi - lo + 1` is never materialised, so ranges
    /// reaching `usize::MAX` do not overflow.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi, "usize_in: empty range {lo}..={hi}");
        let span = hi - lo;
        if span == usize::MAX {
            // full range: the +1 span would wrap to 0; draw raw bits
            return self.rng.next_u64() as usize;
        }
        lo + self.rng.below(span + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform_in(lo, hi)
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.uniform_in(lo as f64, hi as f64) as f32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Uniform [`Duration`] in `[lo, hi]` at nanosecond granularity
    /// (for scheduler/refresh timing properties).
    pub fn duration_in(&mut self, lo: Duration, hi: Duration) -> Duration {
        debug_assert!(lo <= hi, "duration_in: empty range {lo:?}..={hi:?}");
        Duration::from_nanos(self.usize_in(lo.as_nanos() as usize, hi.as_nanos() as usize) as u64)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn vec_normal(&mut self, len: usize, mu: f32, sigma: f32) -> Vec<f32> {
        let mut v = vec![0f32; len];
        self.rng.fill_normal(&mut v, mu, sigma);
        v
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `prop` over `cases` random inputs. Panics (with the failing seed)
/// on the first property violation.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: u64, mut prop: F) {
    for case in 0..cases {
        let seed = 0x5eed_0000 + case;
        run_case(name, seed, &mut prop);
    }
}

/// Re-run a single failing case by seed.
pub fn replay<F: FnMut(&mut Gen)>(name: &str, seed: u64, mut prop: F) {
    run_case(name, seed, &mut prop);
}

fn run_case<F: FnMut(&mut Gen)>(name: &str, seed: u64, prop: &mut F) {
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut g = Gen {
            rng: Pcg64::new(seed),
            seed,
        };
        prop(&mut g);
    }));
    if let Err(e) = result {
        let msg = e
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".into());
        panic!("property '{name}' failed at seed {seed:#x}: {msg}\nreplay with util::proptest::replay(\"{name}\", {seed:#x}, ...)");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("add-commutes", 32, |g| {
            let (a, b) = (g.f64_in(-1e3, 1e3), g.f64_in(-1e3, 1e3));
            assert_eq!(a + b, b + a);
            n += 1;
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property 'sometimes-fails'")]
    fn failing_property_reports_seed() {
        check("sometimes-fails", 64, |g| {
            assert!(g.usize_in(0, 9) < 9, "drew the bad value");
        });
    }

    #[test]
    fn usize_in_survives_extreme_ranges() {
        check("usize-in-extremes", 64, |g| {
            // full domain: `hi - lo + 1` used to overflow and panic
            let _ = g.usize_in(0, usize::MAX);
            let v = g.usize_in(usize::MAX - 1, usize::MAX);
            assert!(v >= usize::MAX - 1);
            assert_eq!(g.usize_in(7, 7), 7, "degenerate range is exact");
            let w = g.usize_in(usize::MAX, usize::MAX);
            assert_eq!(w, usize::MAX);
        });
    }

    #[test]
    fn duration_in_stays_in_range() {
        check("duration-in-range", 16, |g| {
            let d = g.duration_in(Duration::from_nanos(5), Duration::from_millis(2));
            assert!(d >= Duration::from_nanos(5) && d <= Duration::from_millis(2));
            assert_eq!(
                g.duration_in(Duration::from_micros(7), Duration::from_micros(7)),
                Duration::from_micros(7),
                "degenerate range is exact"
            );
        });
    }

    #[test]
    fn gen_ranges() {
        check("gen-ranges", 16, |g| {
            let u = g.usize_in(3, 7);
            assert!((3..=7).contains(&u));
            let f = g.f32_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let v = g.vec_f32(10, 0.0, 1.0);
            assert_eq!(v.len(), 10);
        });
    }
}
