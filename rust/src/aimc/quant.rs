//! DAC/ADC quantizer models — rust mirror of the L1 kernel semantics
//! (`python/compile/kernels/aimc_linear.py::_quant_sym`), used for
//! analysis, the Fig. 3a precision study, and cross-layer consistency
//! tests (the python and rust implementations must agree bit-for-bit in
//! f32 on shared inputs).

/// Symmetric mid-tread quantizer; `levels = 2^(bits-1) - 1`, `levels<=0`
/// bypasses.
#[inline]
pub fn quant_sym(v: f32, scale: f32, levels: f32) -> f32 {
    if levels <= 0.0 {
        return v;
    }
    let s = scale.max(1e-9);
    (v / s * levels).round().clamp(-levels, levels) / levels.max(1.0) * s
}

pub fn levels_for_bits(bits: u32) -> f32 {
    ((1u32 << (bits - 1)) - 1) as f32
}

/// Quantize a buffer against its abs-max (per-tile DAC ranging).
pub fn quant_block(v: &mut [f32], levels: f32) {
    if levels <= 0.0 {
        return;
    }
    let scale = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    for x in v.iter_mut() {
        *x = quant_sym(*x, scale, levels);
    }
}

/// RMS quantization error of a signal at a given bit width (analysis
/// helper for the ADC-precision study).
pub fn rms_quant_error(v: &[f32], bits: u32) -> f64 {
    let levels = levels_for_bits(bits);
    let scale = v.iter().fold(0f32, |m, x| m.max(x.abs()));
    let mut e = 0f64;
    for &x in v {
        let q = quant_sym(x, scale, levels);
        e += ((q - x) as f64).powi(2);
    }
    (e / v.len().max(1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn bits_to_levels() {
        assert_eq!(levels_for_bits(8), 127.0);
        assert_eq!(levels_for_bits(6), 31.0);
        assert_eq!(levels_for_bits(4), 7.0);
    }

    #[test]
    fn error_bounded_by_half_step() {
        proptest::check("quant-halfstep", 50, |g| {
            let v = g.f32_in(-2.0, 2.0);
            let scale = 2.0;
            let levels = levels_for_bits(*g.pick(&[4, 6, 8]));
            let q = quant_sym(v, scale, levels);
            assert!((q - v).abs() <= scale / levels / 2.0 + 1e-6);
        });
    }

    #[test]
    fn idempotent() {
        proptest::check("quant-idempotent", 50, |g| {
            let v = g.f32_in(-1.0, 1.0);
            let q1 = quant_sym(v, 1.0, 127.0);
            let q2 = quant_sym(q1, 1.0, 127.0);
            assert!((q1 - q2).abs() < 1e-7);
        });
    }

    #[test]
    fn more_bits_less_error() {
        let mut rng = crate::util::rng::Pcg64::new(1);
        let mut v = vec![0f32; 4096];
        rng.fill_normal(&mut v, 0.0, 1.0);
        let e4 = rms_quant_error(&v, 4);
        let e6 = rms_quant_error(&v, 6);
        let e8 = rms_quant_error(&v, 8);
        assert!(e4 > e6 && e6 > e8);
        // roughly 2 bits = 4x error ratio
        assert!((e4 / e6 - 4.0).abs() < 1.0, "{}", e4 / e6);
    }

    #[test]
    fn bypass() {
        assert_eq!(quant_sym(0.1234, 1.0, 0.0), 0.1234);
    }
}
