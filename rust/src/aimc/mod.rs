//! AIMC crossbar-tile model.
//!
//! * [`mapping`] — differential channel-wise weight→conductance mapping
//!   with adaptive c·σ clipping (Methods — Model Mapping).
//! * [`tile`] — 512×512 tile allocator: how a layer's weight matrix is
//!   partitioned across physical tiles (drives Fig. 4's layer geometry
//!   and Table III's "mappable parameters" accounting).
//! * [`quant`] — DAC/ADC quantizer models (rust mirror of the L1 kernel
//!   semantics, used for analysis and cross-layer consistency tests).

pub mod mapping;
pub mod quant;
pub mod tile;
