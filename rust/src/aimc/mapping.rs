//! Differential channel-wise weight→conductance mapping.
//!
//! Each weight is stored on a device *pair*: `w ∝ g⁺ − g⁻` with only one
//! of the pair non-zero (sign split). Per output channel (column), the
//! mapping scale is chosen so the clipping threshold — c·σ of the fitted
//! channel weight distribution (paper: 3σ; Supplementary Table VIII
//! ablates 2σ/2.5σ/3σ/fixed) — lands on G_max.

use crate::pcm::{drift, programming, PcmModel, ProgrammedTensor};
use crate::util::rng::Pcg64;

/// Per-channel clip threshold: `clip_sigma`·σ(channel), or the channel
/// abs-max when `clip_sigma <= 0` (no clipping; LLaMA experiments).
pub fn channel_clip(w: &[f32], rows: usize, cols: usize, clip_sigma: f32) -> Vec<f32> {
    let mut out = vec![0f32; cols];
    for c in 0..cols {
        let mut sum = 0f64;
        let mut sum2 = 0f64;
        let mut amax = 0f32;
        for r in 0..rows {
            let v = w[r * cols + c];
            sum += v as f64;
            sum2 += (v * v) as f64;
            amax = amax.max(v.abs());
        }
        let n = rows as f64;
        let var = (sum2 / n - (sum / n).powi(2)).max(0.0);
        out[c] = if clip_sigma > 0.0 {
            (clip_sigma * var.sqrt() as f32).max(1e-9)
        } else {
            amax.max(1e-9)
        };
    }
    out
}

/// Program a weight matrix (row-major `rows`×`cols`) onto PCM device
/// pairs: clip → scale per channel → sign-split → programming noise →
/// sample per-device drift exponents → record the GDC reference read.
pub fn program_tensor(
    model: &PcmModel,
    w: &[f32],
    rows: usize,
    cols: usize,
    clip_sigma: f32,
    rng: &mut Pcg64,
) -> ProgrammedTensor {
    assert_eq!(w.len(), rows * cols);
    let clip = channel_clip(w, rows, cols, clip_sigma);
    let col_scale: Vec<f32> = clip.iter().map(|&c| model.g_max / c).collect();

    let n = rows * cols;
    let mut g_plus = vec![0f32; n];
    let mut g_minus = vec![0f32; n];
    for r in 0..rows {
        for c in 0..cols {
            let i = r * cols + c;
            let clipped = w[i].clamp(-clip[c], clip[c]);
            let g = clipped * col_scale[c];
            if g >= 0.0 {
                g_plus[i] = g;
            } else {
                g_minus[i] = -g;
            }
        }
    }
    programming::apply_programming_noise(model, &mut g_plus, rng);
    programming::apply_programming_noise(model, &mut g_minus, rng);
    let nu_plus = drift::sample_nu(model, &g_plus, rng);
    let nu_minus = drift::sample_nu(model, &g_minus, rng);
    let gdc_reference = crate::pcm::compensation::gdc_reference(&g_plus, &g_minus);

    ProgrammedTensor {
        rows,
        cols,
        g_plus,
        g_minus,
        nu_plus,
        nu_minus,
        col_scale,
        gdc_reference,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;

    #[test]
    fn sign_split_is_exclusive() {
        let model = PcmModel::ideal();
        let mut rng = Pcg64::new(1);
        let mut w = vec![0f32; 64 * 16];
        rng.fill_normal(&mut w, 0.0, 0.1);
        let t = program_tensor(&model, &w, 64, 16, 3.0, &mut rng);
        for i in 0..w.len() {
            assert!(t.g_plus[i] == 0.0 || t.g_minus[i] == 0.0);
            assert!(t.g_plus[i] >= 0.0 && t.g_minus[i] >= 0.0);
        }
    }

    #[test]
    fn clip_threshold_scales_with_sigma() {
        let mut rng = Pcg64::new(2);
        let mut w = vec![0f32; 512 * 4];
        rng.fill_normal(&mut w, 0.0, 0.2);
        let c2 = channel_clip(&w, 512, 4, 2.0);
        let c3 = channel_clip(&w, 512, 4, 3.0);
        for (a, b) in c2.iter().zip(&c3) {
            assert!((b / a - 1.5).abs() < 1e-4);
        }
    }

    #[test]
    fn no_clip_uses_absmax() {
        let w = vec![0.1f32, -0.5, 0.2, 0.05, 1.5, -0.3]; // 3x2
        let c = channel_clip(&w, 3, 2, 0.0);
        assert!((c[0] - 1.5).abs() < 1e-6);
        assert!((c[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn ideal_roundtrip_within_clip() {
        // Inside the clip range, mapping→read must reconstruct exactly
        // under the ideal model.
        proptest::check("mapping-roundtrip", 20, |g| {
            let rows = g.usize_in(2, 40);
            let cols = g.usize_in(1, 12);
            let w = g.vec_normal(rows * cols, 0.0, 0.05);
            let model = PcmModel::ideal();
            let mut rng = Pcg64::new(g.seed);
            let t = program_tensor(&model, &w, rows, cols, 0.0, &mut rng); // absmax clip: lossless
            let got = crate::pcm::read_tensor(&model, &t, 0.0, false, &mut rng);
            for (a, b) in got.iter().zip(&w) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        });
    }

    #[test]
    fn clipping_saturates_outliers() {
        let model = PcmModel::ideal();
        let mut rng = Pcg64::new(3);
        let mut w = vec![0f32; 256];
        rng.fill_normal(&mut w, 0.0, 0.05);
        w[0] = 10.0; // enormous outlier
        let t = program_tensor(&model, &w, 256, 1, 3.0, &mut rng);
        let got = crate::pcm::read_tensor(&model, &t, 0.0, false, &mut rng);
        // the outlier saturates at 3sigma of the channel distribution
        // (which it inflates itself: sigma ~ sqrt(100/256) ~ 0.63)
        assert!(got[0] < 2.0, "outlier should clip, got {}", got[0]);
        assert!(got[0] > 1.0, "clip should keep the 3-sigma mass, got {}", got[0]);
    }

    #[test]
    fn gdc_reference_recorded() {
        let model = PcmModel::default();
        let mut rng = Pcg64::new(4);
        let mut w = vec![0f32; 128];
        rng.fill_normal(&mut w, 0.0, 0.1);
        let t = program_tensor(&model, &w, 32, 4, 3.0, &mut rng);
        assert!(t.gdc_reference > 0.0);
    }
}
