//! 512×512 crossbar tile allocation.
//!
//! A dense layer `rows×cols` occupies `⌈rows/512⌉ × ⌈cols/512⌉` physical
//! tiles; the k-dimension partials are summed by the digital periphery
//! (mirroring the L1 kernel's grid). This accounting drives the Fig. 4
//! layer geometry, the multi-chip comparison in Table III, and the
//! "mappable vs unmappable parameter" split.

/// Physical tile geometry (unit cells).
pub const TILE_ROWS: usize = 512;
pub const TILE_COLS: usize = 512;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    pub layer_rows: usize,
    pub layer_cols: usize,
    pub tiles_r: usize,
    pub tiles_c: usize,
}

impl TileGrid {
    pub fn for_layer(rows: usize, cols: usize) -> TileGrid {
        TileGrid {
            layer_rows: rows,
            layer_cols: cols,
            tiles_r: rows.div_ceil(TILE_ROWS),
            tiles_c: cols.div_ceil(TILE_COLS),
        }
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles_r * self.tiles_c
    }

    /// Unit cells consumed (each holds one differential pair).
    pub fn cells_used(&self) -> usize {
        self.layer_rows * self.layer_cols
    }

    /// Fraction of allocated tile area actually holding weights.
    pub fn utilization(&self) -> f64 {
        self.cells_used() as f64 / (self.n_tiles() * TILE_ROWS * TILE_COLS) as f64
    }
}

/// Mappability rule from the paper: linear (dense) layer weights go to
/// tiles; LayerNorm/bias/embedding-lookup and task heads stay digital.
pub fn is_mappable(name: &str) -> bool {
    let leaf = name.rsplit('.').next().unwrap_or(name);
    matches!(leaf, "wq" | "wk" | "wv" | "wo" | "w1" | "w2" | "emb_proj" | "w_lm")
}

/// Split a named parameter inventory into (mappable, unmappable) counts.
pub fn mappability_split(params: &[(String, Vec<usize>)]) -> (usize, usize) {
    let mut mappable = 0;
    let mut unmappable = 0;
    for (name, shape) in params {
        let n: usize = shape.iter().product();
        if is_mappable(name) {
            mappable += n;
        } else {
            unmappable += n;
        }
    }
    (mappable, unmappable)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_fit() {
        let g = TileGrid::for_layer(512, 512);
        assert_eq!(g.n_tiles(), 1);
        assert_eq!(g.utilization(), 1.0);
    }

    #[test]
    fn paper_fig4_layers() {
        // Fig. 4 studies 128x128 and 512x128 MobileBERT layer slices:
        // both fit a single tile.
        assert_eq!(TileGrid::for_layer(128, 128).n_tiles(), 1);
        assert_eq!(TileGrid::for_layer(512, 128).n_tiles(), 1);
        // A BERT-Large FFN (1024x4096) needs 2x8 tiles.
        let g = TileGrid::for_layer(1024, 4096);
        assert_eq!((g.tiles_r, g.tiles_c), (2, 8));
    }

    #[test]
    fn partial_tiles_lower_utilization() {
        let g = TileGrid::for_layer(600, 100);
        assert_eq!(g.n_tiles(), 2);
        assert!(g.utilization() < 0.5);
    }

    #[test]
    fn mappability_matches_paper_inventory() {
        assert!(is_mappable("layers.3.wq"));
        assert!(is_mappable("emb_proj"));
        assert!(is_mappable("w_lm"));
        assert!(!is_mappable("layers.0.ln1_g"));
        assert!(!is_mappable("layers.2.bq"));
        assert!(!is_mappable("tok_emb")); // lookup table stays digital
        assert!(!is_mappable("head.w_cls"));
    }

    #[test]
    fn split_counts() {
        let params = vec![
            ("layers.0.wq".to_string(), vec![128, 128]),
            ("layers.0.bq".to_string(), vec![128]),
        ];
        let (m, u) = mappability_split(&params);
        assert_eq!(m, 128 * 128);
        assert_eq!(u, 128);
    }
}
